//! Property tests for the scheduler (in-tree prop harness, DESIGN.md §0):
//! the invariants Algorithm 1 must uphold on *any* choice matrix and
//! grouping, not just the paper's workloads.

use moepim::config::SchedulePolicy;
use moepim::grouping::Grouping;
use moepim::moe::{ChoiceMatrix, TraceGenerator};
use moepim::sched::{self, compact};
use moepim::util::prop::{self, Gen};

/// Random (choices, grouping) instance.
fn instance(g: &mut Gen) -> (ChoiceMatrix, Grouping) {
    let e = *[4usize, 8, 16].get(g.usize(3)).unwrap();
    let tokens = g.size(1, 64);
    let mode = g.usize(3);
    let mut tg = TraceGenerator::new(e, g.case_seed ^ 0xABCD);
    let choices = match mode {
        0 => tg.expert_choice(tokens, (tokens / 2).max(1), 1.0),
        1 => tg.token_choice_zipf(tokens, (e / 4).max(1), 1.2),
        _ => {
            // fully random sparse matrix, including empty rows
            let mut m = ChoiceMatrix::new(tokens, e);
            for t in 0..tokens {
                for x in 0..e {
                    if g.bool(0.2) {
                        m.set(t, x, true);
                    }
                }
            }
            m
        }
    };
    let group_size = *[1usize, 2, 4].get(g.usize(3)).unwrap();
    let group_size = if e % group_size == 0 { group_size } else { 1 };
    let grouping = Grouping::uniform(e, group_size, g.case_seed);
    (choices, grouping)
}

#[test]
fn work_is_conserved_by_all_policies() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            assert_eq!(s.total_work(), m.total_work(), "{p:?}");
        }
    });
}

#[test]
fn per_group_order_is_preserved() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let queues = compact::group_queues(&m, &gr);
        for p in [SchedulePolicy::Compact, SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            for (i, q) in queues.iter().enumerate() {
                assert_eq!(&s.lane_work(i), q, "{p:?} lane {i}");
            }
        }
    });
}

#[test]
fn reschedule_keeps_compact_makespan() {
    prop::check(200, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let o = sched::build(&m, &gr, SchedulePolicy::Reschedule);
        assert_eq!(o.makespan_slots(), c.makespan_slots());
    });
}

#[test]
fn reschedule_never_increases_transfers() {
    prop::check(200, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let o = sched::build(&m, &gr, SchedulePolicy::Reschedule);
        assert!(o.transfers() <= c.transfers(),
                "O {} > C {}", o.transfers(), c.transfers());
    });
}

#[test]
fn compact_makespan_is_bottleneck_group() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let bottleneck = compact::group_queues(&m, &gr)
            .iter()
            .map(Vec::len)
            .max()
            .unwrap_or(0);
        assert_eq!(c.makespan_slots(), bottleneck);
    });
}

#[test]
fn tokenwise_never_faster_than_compact() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let t = sched::build(&m, &gr, SchedulePolicy::TokenWise);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        assert!(t.makespan_slots() >= c.makespan_slots());
    });
}

#[test]
fn tokenwise_transfers_equal_active_tokens() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let t = sched::build(&m, &gr, SchedulePolicy::TokenWise);
        let active =
            (0..m.tokens()).filter(|&tk| m.token_fanout(tk) > 0).count();
        assert_eq!(t.transfers(), active);
    });
}

#[test]
fn transfers_lower_bound_is_distinct_tokens() {
    // no schedule can transfer fewer times than the number of distinct
    // tokens with work (each must reach the chip at least once)
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let active =
            (0..m.tokens()).filter(|&tk| m.token_fanout(tk) > 0).count();
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            assert!(s.transfers() >= active, "{p:?}");
        }
    });
}

#[test]
fn utilization_bounded() {
    prop::check(100, |g| {
        let (m, gr) = instance(g);
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let u = sched::build(&m, &gr, p).utilization();
            assert!((0.0..=1.0).contains(&u), "{p:?}: {u}");
        }
    });
}
