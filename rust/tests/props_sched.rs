//! Property tests for the scheduler (in-tree prop harness, DESIGN.md §0):
//! the invariants Algorithm 1 must uphold on *any* choice matrix and
//! grouping, not just the paper's workloads — plus the online
//! [`BatchPlanner`]'s contention accounting and its depth-L step pricing
//! (one planned layer-step per functional layer).

use moepim::config::SchedulePolicy;
use moepim::grouping::Grouping;
use moepim::moe::{ChoiceMatrix, TraceGenerator};
use moepim::sched::{self, compact, BatchPlanner};
use moepim::util::prop::{self, Gen};

/// Random (choices, grouping) instance.
fn instance(g: &mut Gen) -> (ChoiceMatrix, Grouping) {
    let e = *[4usize, 8, 16].get(g.usize(3)).unwrap();
    let tokens = g.size(1, 64);
    let mode = g.usize(3);
    let mut tg = TraceGenerator::new(e, g.case_seed ^ 0xABCD);
    let choices = match mode {
        0 => tg.expert_choice(tokens, (tokens / 2).max(1), 1.0),
        1 => tg.token_choice_zipf(tokens, (e / 4).max(1), 1.2),
        _ => {
            // fully random sparse matrix, including empty rows
            let mut m = ChoiceMatrix::new(tokens, e);
            for t in 0..tokens {
                for x in 0..e {
                    if g.bool(0.2) {
                        m.set(t, x, true);
                    }
                }
            }
            m
        }
    };
    let group_size = *[1usize, 2, 4].get(g.usize(3)).unwrap();
    let group_size = if e % group_size == 0 { group_size } else { 1 };
    let grouping = Grouping::uniform(e, group_size, g.case_seed);
    (choices, grouping)
}

#[test]
fn work_is_conserved_by_all_policies() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            assert_eq!(s.total_work(), m.total_work(), "{p:?}");
        }
    });
}

#[test]
fn per_group_order_is_preserved() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let queues = compact::group_queues(&m, &gr);
        for p in [SchedulePolicy::Compact, SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            for (i, q) in queues.iter().enumerate() {
                assert_eq!(&s.lane_work(i), q, "{p:?} lane {i}");
            }
        }
    });
}

#[test]
fn reschedule_keeps_compact_makespan() {
    prop::check(200, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let o = sched::build(&m, &gr, SchedulePolicy::Reschedule);
        assert_eq!(o.makespan_slots(), c.makespan_slots());
    });
}

#[test]
fn reschedule_never_increases_transfers() {
    prop::check(200, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let o = sched::build(&m, &gr, SchedulePolicy::Reschedule);
        assert!(o.transfers() <= c.transfers(),
                "O {} > C {}", o.transfers(), c.transfers());
    });
}

#[test]
fn compact_makespan_is_bottleneck_group() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        let bottleneck = compact::group_queues(&m, &gr)
            .iter()
            .map(Vec::len)
            .max()
            .unwrap_or(0);
        assert_eq!(c.makespan_slots(), bottleneck);
    });
}

#[test]
fn tokenwise_never_faster_than_compact() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let t = sched::build(&m, &gr, SchedulePolicy::TokenWise);
        let c = sched::build(&m, &gr, SchedulePolicy::Compact);
        assert!(t.makespan_slots() >= c.makespan_slots());
    });
}

#[test]
fn tokenwise_transfers_equal_active_tokens() {
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let t = sched::build(&m, &gr, SchedulePolicy::TokenWise);
        let active =
            (0..m.tokens()).filter(|&tk| m.token_fanout(tk) > 0).count();
        assert_eq!(t.transfers(), active);
    });
}

#[test]
fn transfers_lower_bound_is_distinct_tokens() {
    // no schedule can transfer fewer times than the number of distinct
    // tokens with work (each must reach the chip at least once)
    prop::check(150, |g| {
        let (m, gr) = instance(g);
        let active =
            (0..m.tokens()).filter(|&tk| m.token_fanout(tk) > 0).count();
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let s = sched::build(&m, &gr, p);
            assert!(s.transfers() >= active, "{p:?}");
        }
    });
}

#[test]
fn utilization_bounded() {
    prop::check(100, |g| {
        let (m, gr) = instance(g);
        for p in [SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                  SchedulePolicy::Reschedule] {
            let u = sched::build(&m, &gr, p).utilization();
            assert!((0.0..=1.0).contains(&u), "{p:?}: {u}");
        }
    });
}

// ---------------------------------------------------------------------------
// Online BatchPlanner invariants (the serving engine's per-step pricing)
// ---------------------------------------------------------------------------

/// Random per-slot expert sets for one batch step.
fn expert_sets(g: &mut Gen, e: usize) -> Vec<Vec<usize>> {
    let b = g.size(1, 6).max(1);
    (0..b)
        .map(|_| (0..e).filter(|_| g.bool(0.3)).collect())
        .collect()
}

#[test]
fn planner_contention_zero_under_singleton_grouping() {
    // with exclusive peripherals there is nothing to contend for: every
    // step's sharing-attributable cycles must be exactly zero
    prop::check(150, |g| {
        let e = *[4usize, 8, 16].get(g.usize(3)).unwrap();
        let policy = *[SchedulePolicy::TokenWise, SchedulePolicy::Compact,
                       SchedulePolicy::Reschedule]
            .get(g.usize(3))
            .unwrap();
        let mut p =
            BatchPlanner::with_grouping(Grouping::singleton(e), policy);
        for _ in 0..g.size(1, 8).max(1) {
            let sets = expert_sets(g, e);
            let plan = p.plan(&sets);
            assert_eq!(plan.contention_cycles, 0, "{policy:?}");
        }
        assert_eq!(p.stats().contention_cycles, 0);
    });
}

#[test]
fn planner_grouped_makespan_never_beats_exclusive() {
    // peripheral sharing can only serialise work: the grouped makespan is
    // >= the exclusive-peripherals makespan on the same step, and the
    // plan's contention_cycles is exactly the difference
    prop::check(150, |g| {
        let e = *[4usize, 8, 16].get(g.usize(3)).unwrap();
        let group_size = *[2usize, 4].get(g.usize(2)).unwrap();
        let group_size = if e % group_size == 0 { group_size } else { 2 };
        let policy = *[SchedulePolicy::Compact, SchedulePolicy::Reschedule]
            .get(g.usize(2))
            .unwrap();
        let mut grouped = BatchPlanner::with_grouping(
            Grouping::uniform(e, group_size, g.case_seed),
            policy,
        );
        let mut exclusive =
            BatchPlanner::with_grouping(Grouping::singleton(e), policy);
        let sets = expert_sets(g, e);
        let gp = grouped.plan(&sets);
        let xp = exclusive.plan(&sets);
        assert!(
            gp.cycles >= xp.cycles,
            "grouped {} < exclusive {}", gp.cycles, xp.cycles
        );
        assert_eq!(gp.contention_cycles, gp.cycles - xp.cycles);
        assert_eq!(gp.work, xp.work, "work must be grouping-invariant");
    });
}

#[test]
fn planner_steps_scale_linearly_in_depth() {
    // a depth-L decode step is priced as L planned layer-steps: for a
    // fixed batch, stats().steps after n cycles is exactly n * L
    prop::check(100, |g| {
        let e = *[4usize, 8, 16].get(g.usize(3)).unwrap();
        let layers = g.size(1, 5).max(1);
        let cycles = g.size(1, 6).max(1);
        let sets = expert_sets(g, e);
        let mut p = BatchPlanner::new(e, 2, SchedulePolicy::Reschedule);
        let mut work_one_cycle = None;
        for cycle in 0..cycles {
            let layer_sets: Vec<Vec<Vec<usize>>> =
                (0..layers).map(|_| sets.clone()).collect();
            let plans = p.plan_layers(&layer_sets);
            assert_eq!(plans.len(), layers);
            let cycle_work: usize = plans.iter().map(|pl| pl.work).sum();
            // identical per-layer sets => identical per-cycle work
            match work_one_cycle {
                None => work_one_cycle = Some(cycle_work),
                Some(w) => assert_eq!(cycle_work, w, "cycle {cycle}"),
            }
        }
        let s = p.stats();
        assert_eq!(s.steps, (cycles * layers) as u64,
                   "steps must equal cycles x layers");
        assert_eq!(s.work, (cycles * layers) as u64
                   * sets.iter().map(Vec::len).sum::<usize>() as u64);
    });
}
