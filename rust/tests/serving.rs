//! Coordinator/server integration: slot-batched serving over the real
//! model (requires `make artifacts`), including failure injection for bad
//! requests and artifact-directory errors.
//!
//! All server scenarios share one #[test]: the PJRT client is single-owner
//! and each `Server::spawn` compiles every artifact, so one router thread
//! serves every scenario below.

use std::path::PathBuf;

use moepim::coordinator::{Request, Server};
use moepim::util::rng::Pcg32;

fn artifacts_dir() -> PathBuf {
    std::env::var("MOEPIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn prompt(len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(512) as i32).collect()
}

#[test]
fn server_lifecycle_and_batching() {
    let server = Server::spawn(artifacts_dir()).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );

    // concurrent requests of different lengths interleave and all finish
    let rxs: Vec<_> = (0..4u64)
        .map(|i| {
            server.submit(Request {
                id: i,
                prompt: prompt(8 + 4 * i as usize, i),
                gen_len: 3 + i as usize,
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("terminal response");
        assert_eq!(resp.id, i as u64);
        let tokens = resp.result.as_ref().expect("generation succeeds");
        assert_eq!(tokens.len(), 3 + i);
        assert!(resp.latency_us >= resp.ttft_us);
        assert!(resp.ttft_us >= resp.queue_us);
    }

    // identical prompts give identical streams (deterministic serving),
    // whether they ride a batch together or decode alone
    let a = server.generate(100, prompt(16, 77), 5).unwrap();
    let b = server.generate(101, prompt(16, 77), 5).unwrap();
    assert_eq!(a.result, b.result);
    assert!(a.is_ok());

    // concurrent submits produce the same streams as isolated requests:
    // the batched path must be token-equivalent to the single-token tail
    let isolated: Vec<_> = (0..3u64)
        .map(|i| {
            server
                .generate(200 + i, prompt(10 + i as usize, 50 + i), 6)
                .unwrap()
                .result
                .expect("isolated generation")
        })
        .collect();
    let rxs: Vec<_> = (0..3u64)
        .map(|i| {
            server.submit(Request {
                id: 300 + i,
                prompt: prompt(10 + i as usize, 50 + i),
                gen_len: 6,
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(
            resp.result.as_ref().expect("batched generation"),
            &isolated[i],
            "batched stream {i} diverged from isolated serving"
        );
        assert!(resp.batched_steps > 0, "request {i} never rode a batch");
    }

    // slot admission is FIFO: a burst larger than the slot pool is admitted
    // strictly in submit order
    let burst = 9u64;
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            server.submit(Request {
                id: 400 + i,
                prompt: prompt(8, 1000 + i),
                gen_len: 4,
            })
        })
        .collect();
    let mut seqs = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.is_ok());
        seqs.push(resp.admit_seq);
    }
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "admission order broke FIFO: {seqs:?}");

    // generation clamps at max_seq rather than wedging the router
    let resp = server.generate(102, prompt(16, 5), 10_000).unwrap();
    let tokens = resp.result.expect("clamped generation succeeds");
    assert!(!tokens.is_empty());
    assert!(tokens.len() <= 96);

    // an oversized prompt gets a *terminal error reply* (not a dropped
    // channel); the server survives and keeps serving
    let rx = server.submit(Request {
        id: 103,
        prompt: prompt(500, 9),
        gen_len: 4,
    });
    let resp = rx.recv().expect("oversized prompt still gets a reply");
    let err = resp.result.expect_err("oversized prompt must error");
    assert!(err.contains("max_seq"), "unexpected error: {err}");
    let after = server.generate(104, prompt(8, 11), 2).unwrap();
    assert_eq!(after.result.expect("server still serves").len(), 2);

    // an empty prompt errors terminally too
    let resp = server.generate(105, Vec::new(), 2).unwrap();
    assert!(resp.result.is_err(), "empty prompt must error");

    // serving telemetry is live and consistent
    let stats = server.stats().unwrap();
    assert!(stats.slots >= 1);
    assert!(stats.completed >= 18, "stats: {stats:?}");
    assert_eq!(stats.errored, 2);
    assert!(stats.batch_dispatches > 0, "no batched dispatch happened");
    assert!(stats.mean_batch_occupancy() > 1.0);
    assert!(stats.planner.steps > 0, "planner never ran");
    assert!(stats.planner.work > 0);
    assert!(stats.tokens_generated > 0);
}

#[test]
fn spawn_fails_cleanly_on_bad_dir() {
    let err = Server::spawn(PathBuf::from("/nonexistent/artifacts"));
    assert!(err.is_err());
}
