//! Coordinator/server integration: slot-batched serving over the real
//! depth-L model (requires `make artifacts`), including failure injection
//! for bad requests and artifact-directory errors, plus a randomized
//! churn scenario pinned against per-session engine references.
//!
//! All server scenarios share one #[test] because each `Server::spawn`
//! compiles every artifact — one router thread serves every scenario
//! below to keep the suite fast (concurrent multi-server serving is
//! exercised by `tests/cluster_concurrent.rs`).  The churn references
//! are computed from a private `ModelEngine` that is dropped before the
//! server spawns, purely so the reference buffers are gone before the
//! serving run starts.

use std::path::PathBuf;

use moepim::coordinator::{
    DecodeMode, ModelEngine, Request, Server, ServerOptions,
};
use moepim::runtime::Runtime;
use moepim::util::rng::Pcg32;
use moepim::workload::{
    run_against_server, AdmissionPolicy, ArrivalProcess, SizeModel,
    WorkloadSpec,
};

fn artifacts_dir() -> PathBuf {
    std::env::var("MOEPIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn prompt(len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(512) as i32).collect()
}

/// One churn request: prompt, requested generation length, and the
/// per-session reference stream it must reproduce.
struct ChurnCase {
    prompt: Vec<i32>,
    gen_len: usize,
    want: Vec<i32>,
}

/// Randomized mixed-length churn cases with their reference streams,
/// computed on the per-session cached path (sparse MoE — the mode the
/// serving engine always uses).  At artifact depth L >= 2 this pins the
/// whole serving stack against per-session references at real depth; the
/// CI matrix provides the L=3 set.
fn churn_cases(engine: &ModelEngine, n: usize) -> Vec<ChurnCase> {
    let m = engine.model.clone();
    let mut rng = Pcg32::new(0xC4C4);
    (0..n)
        .map(|i| {
            let plen =
                4 + rng.gen_range(m.prompt_len.saturating_sub(4).max(1));
            let gen_len = 1 + rng.gen_range(11);
            let p = prompt(plen, 3000 + i as u64);
            let want = engine
                .generate(&p, gen_len, DecodeMode::Cached)
                .expect("reference generation")
                .tokens;
            ChurnCase { prompt: p, gen_len, want }
        })
        .collect()
}

#[test]
fn server_lifecycle_batching_and_churn() {
    // ---- per-session references first (own PJRT client, dropped before
    //      the server thread constructs its own) -------------------------
    let (cases, n_layers) = {
        let rt = Runtime::load(&artifacts_dir()).expect(
            "artifacts missing — run `make artifacts` before `cargo test`",
        );
        let n_layers = rt.manifest.model.n_layers;
        let engine = ModelEngine::new(rt).with_sparse_moe(true);
        (churn_cases(&engine, 10), n_layers)
    };

    let server = Server::spawn(artifacts_dir()).expect("server spawns");

    // concurrent requests of different lengths interleave and all finish
    let rxs: Vec<_> = (0..4u64)
        .map(|i| {
            server.submit(Request::new(
                i,
                prompt(8 + 4 * i as usize, i),
                3 + i as usize,
            ))
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("terminal response");
        assert_eq!(resp.id, i as u64);
        let tokens = resp.result.as_ref().expect("generation succeeds");
        assert_eq!(tokens.len(), 3 + i);
        // a served request has real admission/first-token events
        let ttft = resp.ttft_us.expect("served request has a TTFT");
        let queued = resp.queue_us.expect("served request was admitted");
        assert!(resp.latency_us >= ttft);
        assert!(ttft >= queued);
        assert!(resp.admit_seq.is_some());
    }

    // identical prompts give identical streams (deterministic serving),
    // whether they ride a batch together or decode alone
    let a = server.generate(100, prompt(16, 77), 5).unwrap();
    let b = server.generate(101, prompt(16, 77), 5).unwrap();
    assert_eq!(a.result, b.result);
    assert!(a.is_ok());

    // concurrent submits produce the same streams as isolated requests:
    // the batched path must be token-equivalent to the single-token tail
    let isolated: Vec<_> = (0..3u64)
        .map(|i| {
            server
                .generate(200 + i, prompt(10 + i as usize, 50 + i), 6)
                .unwrap()
                .result
                .expect("isolated generation")
        })
        .collect();
    let rxs: Vec<_> = (0..3u64)
        .map(|i| {
            server.submit(Request::new(
                300 + i,
                prompt(10 + i as usize, 50 + i),
                6,
            ))
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(
            resp.result.as_ref().expect("batched generation"),
            &isolated[i],
            "batched stream {i} diverged from isolated serving"
        );
        assert!(resp.batched_steps > 0, "request {i} never rode a batch");
    }

    // slot admission is FIFO: a burst larger than the slot pool is admitted
    // strictly in submit order
    let burst = 9u64;
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            server.submit(Request::new(400 + i, prompt(8, 1000 + i), 4))
        })
        .collect();
    let mut seqs = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.is_ok());
        seqs.push(resp.admit_seq.expect("served burst request admitted"));
    }
    let mut sorted = seqs.clone();
    sorted.sort_unstable();
    assert_eq!(seqs, sorted, "admission order broke FIFO: {seqs:?}");

    // generation clamps at max_seq rather than wedging the router
    let resp = server.generate(102, prompt(16, 5), 10_000).unwrap();
    let tokens = resp.result.expect("clamped generation succeeds");
    assert!(!tokens.is_empty());
    assert!(tokens.len() <= 96);

    // an oversized prompt gets a *terminal error reply* (not a dropped
    // channel) with `None` in every never-happened field; the server
    // survives and keeps serving
    let rx = server.submit(Request::new(103, prompt(500, 9), 4));
    let resp = rx.recv().expect("oversized prompt still gets a reply");
    let err = resp.result.expect_err("oversized prompt must error");
    assert!(err.contains("max_seq"), "unexpected error: {err}");
    assert_eq!(resp.admit_seq, None, "rejected request was never admitted");
    assert_eq!(resp.queue_us, None);
    assert_eq!(resp.ttft_us, None);
    let after = server.generate(104, prompt(8, 11), 2).unwrap();
    assert_eq!(after.result.expect("server still serves").len(), 2);

    // an empty prompt errors terminally too
    let resp = server.generate(105, Vec::new(), 2).unwrap();
    assert!(resp.result.is_err(), "empty prompt must error");
    assert!(resp.ttft_us.is_none());

    // ---- randomized churn: staggered submissions, mixed prompt/gen
    //      lengths, slot recycling; every stream pinned against its
    //      per-session reference -----------------------------------------
    let mut rng = Pcg32::new(0x57A6);
    let mut pending: Vec<(usize, std::sync::mpsc::Receiver<_>)> = Vec::new();
    let mut submitted = 0usize;
    let mut checked = 0usize;
    while checked < cases.len() {
        // submit a random-sized wave (requests arrive while earlier ones
        // are mid-generation or already retiring — admission interleaves
        // with decode cycles and recycled slots)
        let wave = 1 + rng.gen_range(3);
        for _ in 0..wave {
            if submitted >= cases.len() {
                break;
            }
            let c = &cases[submitted];
            let rx = server.submit(Request::new(
                500 + submitted as u64,
                c.prompt.clone(),
                c.gen_len,
            ));
            pending.push((submitted, rx));
            submitted += 1;
        }
        // drain a random number of outstanding responses (all of them
        // once everything is submitted)
        let drain = if submitted >= cases.len() {
            pending.len()
        } else {
            rng.gen_range(pending.len() + 1)
        };
        for (idx, rx) in pending.drain(..drain) {
            let resp = rx.recv().expect("terminal churn response");
            let tokens =
                resp.result.as_ref().expect("churn generation succeeds");
            assert_eq!(
                tokens, &cases[idx].want,
                "churn request {idx} diverged from its per-session \
                 reference"
            );
            assert_eq!(tokens.len(), cases[idx].gen_len);
            assert!(resp.admit_seq.is_some());
            let ttft = resp.ttft_us.unwrap();
            assert!(resp.queue_us.unwrap() <= ttft);
            assert!(ttft <= resp.latency_us);
            checked += 1;
        }
    }
    assert_eq!(checked, cases.len());

    // serving telemetry is live and consistent; the planner prices every
    // decode cycle as L layer-steps
    let stats = server.stats().unwrap();
    assert!(stats.slots >= 1);
    assert!(stats.completed >= 28, "stats: {stats:?}");
    assert_eq!(stats.errored, 2);
    assert!(stats.batch_dispatches > 0, "no batched dispatch happened");
    assert!(stats.mean_batch_occupancy() > 1.0);
    assert!(stats.planner.steps > 0, "planner never ran");
    assert_eq!(
        stats.planner.steps % n_layers as u64,
        0,
        "planner steps must be a whole number of depth-{n_layers} cycles"
    );
    assert!(stats.planner.work > 0);
    assert!(stats.tokens_generated > 0);

    // ---- gen_len == 0 regression: an immediate terminal success that
    //      never queues, never occupies a slot, and never ran prefill ----
    let rx = server.submit(Request::new(600, prompt(8, 21), 0));
    let resp = rx.recv().expect("zero-length request gets a reply");
    let toks = resp.result.expect("zero-length request succeeds");
    assert!(toks.is_empty());
    assert_eq!(resp.admit_seq, None, "zero-length must not take a slot");
    assert_eq!(resp.queue_us, None);
    assert_eq!(resp.ttft_us, None);
    assert_eq!(resp.batched_steps + resp.single_steps, 0);
    let after = server.generate(601, prompt(8, 22), 2).unwrap();
    assert_eq!(after.result.expect("server still serves").len(), 2);
    let s2 = server.stats().unwrap();
    assert_eq!(s2.errored, 2, "zero-length request must not count errored");
    assert_eq!(s2.completed, stats.completed + 2);

    // ---- seeded loadtest driver smoke over the live FIFO server: an
    //      open-loop burst (near-simultaneous arrivals) must preserve
    //      admit_seq monotonicity in submit order ------------------------
    let spec = WorkloadSpec {
        seed: 0xF1F0,
        requests: 8,
        arrival: ArrivalProcess::Poisson { rate_rps: 1e5 },
        sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 4 },
        slo_e2e_ms: 60_000.0,
        deadline_slack_us_per_token: 0,
        interactive_mix: 1.0,
    };
    let out = run_against_server(&server, &spec).expect("loadtest driver");
    assert_eq!(out.samples.len(), 8);
    assert!(out.samples.iter().all(|s| s.ok), "{:?}", out.samples);
    assert_eq!(out.tokens_generated(), 8 * 4);
    let mut by_submit = out.samples.clone();
    by_submit.sort_by_key(|s| s.submit_seq);
    let seqs: Vec<u64> = by_submit
        .iter()
        .map(|s| s.admit_seq.expect("burst request admitted"))
        .collect();
    assert!(
        seqs.windows(2).all(|w| w[0] < w[1]),
        "FIFO admission order broke submit order: {seqs:?}"
    );

    drop(server);

    // ---- SJF admission under the closed-loop driver: mixed job sizes
    //      keep the queue non-empty, and the starvation guard must get
    //      every long job through — all requests end terminally Ok ------
    let sjf_server = Server::spawn_with(artifacts_dir(),
                                        AdmissionPolicy::sjf())
        .expect("sjf server spawns");
    let spec = WorkloadSpec {
        seed: 0x57F5,
        requests: 10,
        arrival: ArrivalProcess::Closed { users: 3, think_ms: 0.0 },
        sizes: SizeModel::Uniform { prompt: (6, 12), gen: (1, 10) },
        slo_e2e_ms: 60_000.0,
        deadline_slack_us_per_token: 0,
        interactive_mix: 1.0,
    };
    let out = run_against_server(&sjf_server, &spec)
        .expect("closed-loop loadtest");
    assert_eq!(out.samples.len(), 10, "a request starved or vanished");
    assert!(out.samples.iter().all(|s| s.ok), "{:?}", out.samples);
    assert!(out.samples.iter().all(|s| s.admit_seq.is_some()));
    assert!(out.tokens_generated() > 0);
    drop(sjf_server);

    // ---- chunked prefill end-to-end: a server admitting prompts in
    //      3-token chunks must reproduce every per-session reference
    //      stream bit-for-bit while admissions interleave with decode ----
    let chunked = Server::spawn_opts(artifacts_dir(), ServerOptions {
        prefill_chunk: 3,
        ..ServerOptions::default()
    })
    .expect("chunked server spawns");
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, c)| {
            (
                i,
                chunked.submit(Request::new(
                    700 + i as u64,
                    c.prompt.clone(),
                    c.gen_len,
                )),
            )
        })
        .collect();
    for (i, rx) in rxs {
        let resp = rx.recv().expect("terminal chunked response");
        let tokens =
            resp.result.as_ref().expect("chunked generation succeeds");
        assert_eq!(
            tokens, &cases[i].want,
            "chunked request {i} diverged from its per-session reference"
        );
        assert!(resp.admit_seq.is_some());
        let ttft = resp.ttft_us.expect("served request has a TTFT");
        assert!(resp.queue_us.expect("admitted") <= ttft);
        assert!(ttft <= resp.latency_us);
    }
    let chunk_stats = chunked.stats().unwrap();
    assert!(
        chunk_stats.prefill_chunks > 0,
        "chunked server never advanced a prefill chunk"
    );
    // prompts are 4..=prompt_len tokens in 3-token chunks: at least as
    // many advances as requests, and more than one for any prompt > 3
    assert!(chunk_stats.prefill_chunks >= cases.len() as u64);
    assert_eq!(chunk_stats.errored, 0);

    // gen_len == 0 on the chunked path: still an immediate terminal
    // success that never takes a slot and never consumes chunk budget
    let rx = chunked.submit(Request::new(800, prompt(8, 23), 0));
    let resp = rx.recv().expect("zero-length request gets a reply");
    assert!(resp.result.expect("zero-length succeeds").is_empty());
    assert_eq!(resp.admit_seq, None, "zero-length must not take a slot");
    assert_eq!(resp.queue_us, None);
    assert_eq!(resp.ttft_us, None);
    let s3 = chunked.stats().unwrap();
    assert_eq!(
        s3.prefill_chunks, chunk_stats.prefill_chunks,
        "zero-length request consumed prefill chunk budget"
    );
    assert_eq!(s3.completed, chunk_stats.completed + 1);
    // an oversized prompt still errors terminally on the chunked path
    let resp = chunked.generate(801, prompt(500, 9), 4).unwrap();
    let err = resp.result.expect_err("oversized prompt must error");
    assert!(err.contains("max_seq"), "unexpected error: {err}");
    assert!(resp.ttft_us.is_none());
}

#[test]
fn driver_outcomes_are_per_run_deltas_on_a_reused_server() {
    // regression: a second loadtest run against the same server must
    // report only its own work, not the server's lifetime counters.
    // Exact form: the server was fresh before run 1, so the lifetime
    // stats must equal the sum of the two per-run deltas — if a run
    // reported lifetime views instead, the sum would overshoot.
    let server = Server::spawn(artifacts_dir()).expect("server spawns");
    let spec = WorkloadSpec {
        seed: 0xDE17A,
        requests: 6,
        arrival: ArrivalProcess::Closed { users: 2, think_ms: 0.0 },
        sizes: SizeModel::Uniform { prompt: (6, 12), gen: (1, 6) },
        slo_e2e_ms: 60_000.0,
        deadline_slack_us_per_token: 0,
        interactive_mix: 1.0,
    };
    let first = run_against_server(&server, &spec).expect("first run");
    let second = run_against_server(&server, &spec).expect("second run");
    for out in [&first, &second] {
        assert_eq!(out.samples.len(), spec.requests);
        assert!(out.samples.iter().all(|s| s.ok), "{:?}", out.samples);
        assert!(out.planner.steps > 0, "a run reported no planner work");
    }
    let stats = server.stats().expect("stats");
    assert_eq!(stats.planner.steps,
               first.planner.steps + second.planner.steps);
    assert_eq!(stats.planner.work,
               first.planner.work + second.planner.work);
    assert_eq!(stats.planner.cycles,
               first.planner.cycles + second.planner.cycles);
    assert_eq!(stats.planner.contention_cycles,
               first.planner.contention_cycles
                   + second.planner.contention_cycles);
    assert_eq!(stats.planner.transfers,
               first.planner.transfers + second.planner.transfers);
    assert_eq!(stats.batch_dispatches,
               first.batch_dispatches + second.batch_dispatches);
    assert_eq!(stats.batched_tokens,
               first.batched_tokens + second.batched_tokens);
    assert_eq!(stats.single_dispatches,
               first.single_dispatches + second.single_dispatches);
    assert_eq!(stats.prefill_chunks,
               first.prefill_chunks + second.prefill_chunks);
    assert_eq!(stats.shed_requests,
               first.shed_requests + second.shed_requests);
}

#[test]
fn spawn_fails_cleanly_on_bad_dir() {
    let err = Server::spawn(PathBuf::from("/nonexistent/artifacts"));
    assert!(err.is_err());
}
