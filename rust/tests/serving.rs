//! Coordinator/server integration: batched serving over the real model
//! (requires `make artifacts`), including failure injection for bad
//! requests and artifact-directory errors.

use std::path::PathBuf;

use moepim::coordinator::{Request, Server};
use moepim::util::rng::Pcg32;

fn artifacts_dir() -> PathBuf {
    std::env::var("MOEPIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn prompt(len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(512) as i32).collect()
}

#[test]
fn server_lifecycle_and_batching() {
    let server = Server::spawn(artifacts_dir()).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );

    // concurrent requests of different lengths interleave and all finish
    let rxs: Vec<_> = (0..4u64)
        .map(|i| {
            server.submit(Request {
                id: i,
                prompt: prompt(8 + 4 * i as usize, i),
                gen_len: 3 + i as usize,
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.tokens.len(), 3 + i);
        assert!(resp.latency_us >= resp.ttft_us);
    }

    // identical prompts give identical streams (deterministic serving)
    let a = server.generate(100, prompt(16, 77), 5).unwrap();
    let b = server.generate(101, prompt(16, 77), 5).unwrap();
    assert_eq!(a.tokens, b.tokens);

    // generation clamps at max_seq rather than wedging the router
    let resp = server.generate(102, prompt(16, 5), 10_000).unwrap();
    assert!(!resp.tokens.is_empty());
    assert!(resp.tokens.len() <= 96);

    // an oversized prompt is rejected per-request; the server survives and
    // keeps serving
    let rx = server.submit(Request {
        id: 103,
        prompt: prompt(500, 9),
        gen_len: 4,
    });
    assert!(
        rx.recv().is_err(),
        "oversized prompt must fail its own channel only"
    );
    let after = server.generate(104, prompt(8, 11), 2).unwrap();
    assert_eq!(after.tokens.len(), 2);
}

#[test]
fn spawn_fails_cleanly_on_bad_dir() {
    let err = Server::spawn(PathBuf::from("/nonexistent/artifacts"));
    assert!(err.is_err());
}
