//! End-to-end functional tests over the real AOT artifacts (requires
//! `make artifacts`; the Makefile's `test` target guarantees it).
//!
//! The PJRT client is single-owner, and HLO compilation of the 40 MB
//! constant-laden modules is the expensive part, so everything shares one
//! `Runtime` inside a single #[test].

use moepim::coordinator::{DecodeMode, ModelEngine};
use moepim::moe::gate::expert_choice_route;
use moepim::runtime::{Runtime, TensorIn};
use moepim::util::rng::Pcg32;

fn prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

#[test]
fn functional_pipeline_end_to_end() {
    let rt = Runtime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    assert_eq!(rt.platform(), "cpu");
    assert_eq!(rt.n_executables(), 14);

    check_shapes(&rt);
    check_gate_row_locality(&rt);
    check_input_validation(&rt);

    let engine = ModelEngine::new(rt);
    check_cached_equals_recompute(&engine);
    check_prefill_determinism(&engine);
    check_go_cache_state_evolves(&engine);
    check_sparse_matches_dense(engine);
}

/// §Perf L2-1: the sparse-gather MoE decode must track the dense-masked
/// path.  The two are different HLO modules, so a 1-ulp dequant-scale
/// difference can flip a quantisation round (one ADC LSB); we therefore
/// compare *token streams* (robust through the sampling margin) over
/// several prompts rather than bit-exact hiddens.
fn check_sparse_matches_dense(engine: ModelEngine) {
    let m = engine.model.clone();
    let dense = &engine;
    let mut dense_streams = Vec::new();
    for seed in [11u64, 31] {
        let p = prompt(m.prompt_len, seed, m.vocab);
        dense_streams
            .push(dense.generate(&p, 8, DecodeMode::Cached).unwrap().tokens);
    }
    let sparse = engine.with_sparse_moe(true);
    for (i, seed) in [11u64, 31].into_iter().enumerate() {
        let p = prompt(m.prompt_len, seed, m.vocab);
        let got = sparse.generate(&p, 8, DecodeMode::Cached).unwrap().tokens;
        assert_eq!(got, dense_streams[i], "seed {seed}");
    }
}

/// Every executable produces outputs of the manifest-implied shapes.
fn check_shapes(rt: &Runtime) {
    let m = &rt.manifest.model;
    let (s, d, e, v) = (m.max_seq, m.d_model, m.n_experts, m.vocab);
    let (h, dh) = (m.n_heads, m.d_head);

    let ids: Vec<i32> = (0..s as i32).map(|i| i % m.vocab as i32).collect();
    let x = rt
        .get("embed_prefill")
        .unwrap()
        .run(&[TensorIn::I32(&ids)])
        .unwrap();
    assert_eq!(x.len(), 1);
    assert_eq!(x[0].len(), s * d);

    let attn = rt
        .get("attn_prefill")
        .unwrap()
        .run(&[
            TensorIn::F32(x[0].as_f32().unwrap()),
            TensorIn::I32(&[m.prompt_len as i32]),
        ])
        .unwrap();
    assert_eq!(attn.len(), 3);
    assert_eq!(attn[0].len(), s * d);
    assert_eq!(attn[1].len(), s * h * dh);
    assert_eq!(attn[2].len(), s * h * dh);

    let scores = rt
        .get("gate_full")
        .unwrap()
        .run(&[TensorIn::F32(attn[0].as_f32().unwrap())])
        .unwrap();
    assert_eq!(scores[0].len(), s * e);

    let logits = rt
        .get("logits_one")
        .unwrap()
        .run(&[TensorIn::F32(&vec![0.1; d])])
        .unwrap();
    assert_eq!(logits[0].len(), v);

    // batched decode artifacts take the pooled shapes
    let b = m.batch_slots;
    assert!(b >= 1);
    let hb = vec![0.05f32; b * d];
    let sb = rt
        .get("gate_batch")
        .unwrap()
        .run(&[TensorIn::F32(&hb)])
        .unwrap();
    assert_eq!(sb[0].len(), b * e);
    let attn_b = rt
        .get("attn_decode_batch")
        .unwrap()
        .run(&[
            TensorIn::F32(&hb),
            TensorIn::F32(&vec![0.0f32; b * s * h * dh]),
            TensorIn::F32(&vec![0.0f32; b * s * h * dh]),
            TensorIn::I32(&vec![0i32; b]),
        ])
        .unwrap();
    assert_eq!(attn_b[0].len(), b * d);
    assert_eq!(attn_b[1].len(), b * h * dh);
    assert_eq!(attn_b[2].len(), b * h * dh);
}

/// gate_one on row i equals gate_full's row i (row-locality — the identity
/// that makes the GO cache sound at the HLO level).
fn check_gate_row_locality(rt: &Runtime) {
    let m = &rt.manifest.model;
    let (s, d, e) = (m.max_seq, m.d_model, m.n_experts);
    let mut rng = Pcg32::new(99);
    let h: Vec<f32> = (0..s * d).map(|_| rng.gen_normal() as f32).collect();
    let full = rt
        .get("gate_full")
        .unwrap()
        .run(&[TensorIn::F32(&h)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    for row in [0usize, 7, s - 1] {
        let one = rt
            .get("gate_one")
            .unwrap()
            .run(&[TensorIn::F32(&h[row * d..(row + 1) * d])])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        for j in 0..e {
            let a = full[row * e + j];
            let b = one[j];
            assert!(
                (a - b).abs() < 1e-4 + 1e-4 * a.abs().max(b.abs()),
                "row {row} expert {j}: {a} vs {b}"
            );
        }
    }
}

/// Shape/dtype validation fails loudly instead of corrupting memory.
fn check_input_validation(rt: &Runtime) {
    let exe = rt.get("gate_one").unwrap();
    assert!(exe.run(&[]).is_err(), "arity check");
    assert!(
        exe.run(&[TensorIn::F32(&[0.0; 3])]).is_err(),
        "element-count check"
    );
    assert!(
        exe.run(&[TensorIn::I32(&vec![0; rt.manifest.model.d_model])])
            .is_err(),
        "dtype check"
    );
}

/// The paper's core functional claim: GO-cached streaming decode produces
/// exactly the token stream of the retained-everything recompute.
fn check_cached_equals_recompute(engine: &ModelEngine) {
    let m = &engine.model;
    for seed in [7u64, 21, 1234] {
        let p = prompt(m.prompt_len, seed, m.vocab);
        let gen_len = 10;
        let cached = engine
            .generate(&p, gen_len, DecodeMode::Cached)
            .expect("cached generation");
        let reference = engine
            .generate(&p, gen_len, DecodeMode::Recompute)
            .expect("recompute generation");
        assert_eq!(
            cached.tokens, reference.tokens,
            "seed {seed}: GO-cached stream diverged from recompute"
        );
        assert_eq!(cached.tokens.len(), gen_len);
    }
}

fn check_prefill_determinism(engine: &ModelEngine) {
    let p = prompt(engine.model.prompt_len, 5, engine.model.vocab);
    let (_, a) = engine.prefill(&p).unwrap();
    let (_, b) = engine.prefill(&p).unwrap();
    assert_eq!(a, b);
}

/// Across a generation the GO cache must actually change state (tokens
/// displace prompt entries) — guards against a trivially-passing
/// equivalence where no update ever fires.
fn check_go_cache_state_evolves(engine: &ModelEngine) {
    let m = &engine.model;
    let p = prompt(m.prompt_len, 3, m.vocab);
    let (mut session, mut next) = engine.prefill(&p).unwrap();
    let mut any_new_token_selected = false;
    for _ in 0..12 {
        let before = session.pos;
        next = engine.decode_cached(&mut session, next).unwrap();
        // the session advanced
        assert_eq!(session.pos, before + 1);
        if session.pos > m.prompt_len + 2 {
            any_new_token_selected = true;
        }
    }
    assert!(any_new_token_selected);

    // and the batch router over real scores still matches what the cache
    // produced during the walk (spot-check expert 0 membership makes sense)
    let scores = vec![0.0f32; m.max_seq * m.n_experts];
    let r = expert_choice_route(&scores, m.max_seq, m.n_experts,
                                m.expert_capacity, Some(m.prompt_len));
    assert_eq!(r.choices.tokens_of(0).len(), m.expert_capacity);
}
