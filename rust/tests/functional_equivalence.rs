//! End-to-end functional tests over the real AOT artifacts (requires
//! `make artifacts`; the Makefile's `test` target guarantees it).  The
//! suite is depth-agnostic: it reads `n_layers_functional` from the
//! manifest and pins the whole stack, so the CI matrix runs it against
//! both an L=1 and an L=3 artifact set.
//!
//! HLO compilation of the multi-MB constant-laden modules is the
//! expensive part of constructing a `Runtime` (each owns its own PJRT
//! client), so everything shares one `Runtime` inside a single #[test].

use moepim::cache::GoCache;
use moepim::config::manifest::layer_artifact;
use moepim::coordinator::{DecodeMode, ModelEngine};
use moepim::moe::gate::{expert_choice_route, softmax_rows};
use moepim::runtime::{Runtime, TensorIn};
use moepim::util::rng::Pcg32;

fn prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

#[test]
fn functional_pipeline_end_to_end() {
    let rt = Runtime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    assert_eq!(rt.platform(), "cpu");
    // 4 shared executables + 10 per-block families per layer
    let n_layers = rt.manifest.model.n_layers;
    assert!(n_layers >= 1);
    assert_eq!(rt.n_executables(), 4 + 10 * n_layers);

    check_shapes(&rt);
    check_gate_row_locality(&rt);
    check_input_validation(&rt);

    let engine = ModelEngine::new(rt);
    check_cached_equals_recompute(&engine);
    check_layered_decode_matches_manual(&engine);
    check_prefill_determinism(&engine);
    check_go_cache_state_evolves(&engine);
    check_sparse_matches_dense(engine);
}

/// §Perf L2-1: the sparse-gather MoE decode must track the dense-masked
/// path at every layer.  The two are different HLO modules, so a 1-ulp
/// dequant-scale difference can flip a quantisation round (one ADC LSB);
/// we therefore compare *token streams* (robust through the sampling
/// margin) over several prompts rather than bit-exact hiddens.
fn check_sparse_matches_dense(engine: ModelEngine) {
    let m = engine.model.clone();
    let dense = &engine;
    let mut dense_streams = Vec::new();
    for seed in [11u64, 31] {
        let p = prompt(m.prompt_len, seed, m.vocab);
        dense_streams
            .push(dense.generate(&p, 8, DecodeMode::Cached).unwrap().tokens);
    }
    let sparse = engine.with_sparse_moe(true);
    for (i, seed) in [11u64, 31].into_iter().enumerate() {
        let p = prompt(m.prompt_len, seed, m.vocab);
        let got = sparse.generate(&p, 8, DecodeMode::Cached).unwrap().tokens;
        assert_eq!(got, dense_streams[i], "seed {seed}");
    }
}

/// Every executable produces outputs of the manifest-implied shapes, at
/// every layer.
fn check_shapes(rt: &Runtime) {
    let m = &rt.manifest.model;
    let (s, d, e, v) = (m.max_seq, m.d_model, m.n_experts, m.vocab);
    let (h, dh) = (m.n_heads, m.d_head);

    let ids: Vec<i32> = (0..s as i32).map(|i| i % m.vocab as i32).collect();
    let x = rt
        .get("embed_prefill")
        .unwrap()
        .run(&[TensorIn::I32(&ids)])
        .unwrap();
    assert_eq!(x.len(), 1);
    assert_eq!(x[0].len(), s * d);

    for layer in 0..m.n_layers {
        let attn = rt
            .get(&layer_artifact("attn_prefill", layer))
            .unwrap()
            .run(&[
                TensorIn::F32(x[0].as_f32().unwrap()),
                TensorIn::I32(&[m.prompt_len as i32]),
            ])
            .unwrap();
        assert_eq!(attn.len(), 3, "layer {layer}");
        assert_eq!(attn[0].len(), s * d);
        assert_eq!(attn[1].len(), s * h * dh);
        assert_eq!(attn[2].len(), s * h * dh);

        let scores = rt
            .get(&layer_artifact("gate_full", layer))
            .unwrap()
            .run(&[TensorIn::F32(attn[0].as_f32().unwrap())])
            .unwrap();
        assert_eq!(scores[0].len(), s * e, "layer {layer}");
    }

    let logits = rt
        .get("logits_one")
        .unwrap()
        .run(&[TensorIn::F32(&vec![0.1; d])])
        .unwrap();
    assert_eq!(logits[0].len(), v);

    // batched decode artifacts take the pooled per-layer shapes
    let b = m.batch_slots;
    assert!(b >= 1);
    let hb = vec![0.05f32; b * d];
    for layer in 0..m.n_layers {
        let sb = rt
            .get(&layer_artifact("gate_batch", layer))
            .unwrap()
            .run(&[TensorIn::F32(&hb)])
            .unwrap();
        assert_eq!(sb[0].len(), b * e, "layer {layer}");
        let attn_b = rt
            .get(&layer_artifact("attn_decode_batch", layer))
            .unwrap()
            .run(&[
                TensorIn::F32(&hb),
                TensorIn::F32(&vec![0.0f32; b * s * h * dh]),
                TensorIn::F32(&vec![0.0f32; b * s * h * dh]),
                TensorIn::I32(&vec![0i32; b]),
            ])
            .unwrap();
        assert_eq!(attn_b[0].len(), b * d, "layer {layer}");
        assert_eq!(attn_b[1].len(), b * h * dh);
        assert_eq!(attn_b[2].len(), b * h * dh);
    }
}

/// gate_one on row i equals gate_full's row i at every layer
/// (row-locality — the identity that makes the GO cache sound at the HLO
/// level).
fn check_gate_row_locality(rt: &Runtime) {
    let m = &rt.manifest.model;
    let (s, d, e) = (m.max_seq, m.d_model, m.n_experts);
    let mut rng = Pcg32::new(99);
    let h: Vec<f32> = (0..s * d).map(|_| rng.gen_normal() as f32).collect();
    for layer in 0..m.n_layers {
        let full = rt
            .get(&layer_artifact("gate_full", layer))
            .unwrap()
            .run(&[TensorIn::F32(&h)])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        for row in [0usize, 7, s - 1] {
            let one = rt
                .get(&layer_artifact("gate_one", layer))
                .unwrap()
                .run(&[TensorIn::F32(&h[row * d..(row + 1) * d])])
                .unwrap()
                .remove(0)
                .into_f32()
                .unwrap();
            for j in 0..e {
                let a = full[row * e + j];
                let b = one[j];
                assert!(
                    (a - b).abs() < 1e-4 + 1e-4 * a.abs().max(b.abs()),
                    "layer {layer} row {row} expert {j}: {a} vs {b}"
                );
            }
        }
    }
}

/// Shape/dtype validation fails loudly instead of corrupting memory.
fn check_input_validation(rt: &Runtime) {
    let exe = rt.get("gate_one").unwrap();
    assert!(exe.run(&[]).is_err(), "arity check");
    assert!(
        exe.run(&[TensorIn::F32(&[0.0; 3])]).is_err(),
        "element-count check"
    );
    assert!(
        exe.run(&[TensorIn::I32(&vec![0; rt.manifest.model.d_model])])
            .is_err(),
        "dtype check"
    );
}

/// The paper's core functional claim at its own setting (one simulated
/// layer, §IV-A): GO-cached streaming decode produces exactly the token
/// stream of the retained-everything recompute.
///
/// At L >= 2 the two modes are *not* stream-equivalent by construction —
/// a batch re-route can displace an earlier token from a mid-stack
/// expert, rewriting that token's layer-l output and hence its
/// layer-(l+1) K/V contribution, state the cached path deliberately froze
/// (see coordinator::engine docs).  Deep stacks are pinned
/// streaming-vs-streaming instead: `check_layered_decode_matches_manual`
/// below, `batch_equivalence.rs`, and the serving churn test.
fn check_cached_equals_recompute(engine: &ModelEngine) {
    let m = &engine.model;
    if m.n_layers != 1 {
        // still exercise the recompute path at depth: it must run and be
        // deterministic even though its stream may diverge from cached
        let p = prompt(m.prompt_len, 7, m.vocab);
        let a = engine.generate(&p, 4, DecodeMode::Recompute).unwrap();
        let b = engine.generate(&p, 4, DecodeMode::Recompute).unwrap();
        assert_eq!(a.tokens, b.tokens, "recompute must stay deterministic");
        return;
    }
    for seed in [7u64, 21, 1234] {
        let p = prompt(m.prompt_len, seed, m.vocab);
        let gen_len = 10;
        let cached = engine
            .generate(&p, gen_len, DecodeMode::Cached)
            .expect("cached generation");
        let reference = engine
            .generate(&p, gen_len, DecodeMode::Recompute)
            .expect("recompute generation");
        assert_eq!(
            cached.tokens, reference.tokens,
            "seed {seed}: GO-cached stream diverged from recompute"
        );
        assert_eq!(cached.tokens.len(), gen_len);
    }
}

/// Deterministic Gumbel-max sampling, reimplemented against the raw
/// `logits_one` artifact (independent of `ModelEngine::sample`).
fn sample_ref(rt: &Runtime, h_row: &[f32], pos: usize) -> i32 {
    let logits = rt
        .get("logits_one")
        .unwrap()
        .run(&[TensorIn::F32(h_row)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let mut rng = Pcg32::new(0x6_0D1_CE ^ (pos as u64) << 8);
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        let u = rng.gen_f64().max(1e-12);
        let gumbel = -(-u.ln()).ln();
        let score = v as f64 + gumbel;
        if score > best_v {
            best_v = score;
            best = i;
        }
    }
    best as i32
}

/// Depth-L pin that holds at *any* L: the engine's layer plumbing
/// (per-layer KV banks, per-layer GO banks, layer-ordered stack
/// execution) must reproduce a manual reference that drives the raw
/// per-token artifacts with its own independent storage layout.
fn check_layered_decode_matches_manual(engine: &ModelEngine) {
    let rt = engine.runtime();
    let m = engine.model.clone();
    let p = prompt(m.prompt_len, 71, m.vocab);
    let gen_len = 6;

    // engine stream (dense decode MoE — `engine` has sparse_moe off)
    let (mut session, mut next) = engine.prefill(&p).unwrap();
    let mut want = vec![next];
    while want.len() < gen_len {
        next = engine.decode_cached(&mut session, next).unwrap();
        want.push(next);
    }

    // manual reference: plain per-layer Vec buffers, rows written in place
    let t = p.len();
    let (s, d, e) = (m.max_seq, m.d_model, m.n_experts);
    let r = m.n_heads * m.d_head;
    let mut padded = p.clone();
    padded.resize(s, 0);
    let mut x = rt
        .get("embed_prefill")
        .unwrap()
        .run(&[TensorIn::I32(&padded)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let mut kbufs: Vec<Vec<f32>> = Vec::new();
    let mut vbufs: Vec<Vec<f32>> = Vec::new();
    let mut banks: Vec<GoCache> = Vec::new();
    for layer in 0..m.n_layers {
        let mut attn = rt
            .get(&layer_artifact("attn_prefill", layer))
            .unwrap()
            .run(&[TensorIn::F32(&x), TensorIn::I32(&[t as i32])])
            .unwrap();
        let h = attn.remove(0).into_f32().unwrap();
        let k = attn.remove(0).into_f32().unwrap();
        let v = attn.remove(0).into_f32().unwrap();
        let scores = rt
            .get(&layer_artifact("gate_full", layer))
            .unwrap()
            .run(&[TensorIn::F32(&h)])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        let routing = expert_choice_route(
            &scores, s, e, m.capacity(layer), Some(t));
        x = rt
            .get(&layer_artifact("moe_full", layer))
            .unwrap()
            .run(&[TensorIn::F32(&h), TensorIn::F32(&routing.gates)])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        let mut bank = GoCache::new(e, m.capacity(layer), 0);
        bank.seed_from_routing(&routing);
        banks.push(bank);
        kbufs.push(k);
        vbufs.push(v);
    }
    let mut got = vec![sample_ref(rt, &x[(t - 1) * d..t * d], t)];
    let mut pos = t;
    while got.len() < gen_len {
        let token = *got.last().unwrap();
        let mut x1 = rt
            .get("embed_one")
            .unwrap()
            .run(&[TensorIn::I32(&[token])])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        for layer in 0..m.n_layers {
            let mut attn = rt
                .get(&layer_artifact("attn_decode", layer))
                .unwrap()
                .run(&[
                    TensorIn::F32(&x1),
                    TensorIn::F32(&kbufs[layer]),
                    TensorIn::F32(&vbufs[layer]),
                    TensorIn::I32(&[pos as i32]),
                ])
                .unwrap();
            let h1 = attn.remove(0).into_f32().unwrap();
            let k_row = attn.remove(0).into_f32().unwrap();
            let v_row = attn.remove(0).into_f32().unwrap();
            kbufs[layer][pos * r..(pos + 1) * r].copy_from_slice(&k_row);
            vbufs[layer][pos * r..(pos + 1) * r].copy_from_slice(&v_row);
            let scores1 = rt
                .get(&layer_artifact("gate_one", layer))
                .unwrap()
                .run(&[TensorIn::F32(&h1)])
                .unwrap()
                .remove(0)
                .into_f32()
                .unwrap();
            let upd = banks[layer].update_scores(pos, &scores1);
            let probs = softmax_rows(&scores1, 1, e);
            let mut gates = vec![0f32; e];
            for &ex in &upd.selected {
                gates[ex] = probs[ex];
            }
            x1 = rt
                .get(&layer_artifact("moe_one", layer))
                .unwrap()
                .run(&[TensorIn::F32(&h1), TensorIn::F32(&gates)])
                .unwrap()
                .remove(0)
                .into_f32()
                .unwrap();
        }
        pos += 1;
        got.push(sample_ref(rt, &x1, pos));
    }
    assert_eq!(got, want, "manual artifact-driven stream diverged");
}

fn check_prefill_determinism(engine: &ModelEngine) {
    let p = prompt(engine.model.prompt_len, 5, engine.model.vocab);
    let (_, a) = engine.prefill(&p).unwrap();
    let (_, b) = engine.prefill(&p).unwrap();
    assert_eq!(a, b);
}

/// Across a generation the GO banks must actually change state (tokens
/// displace prompt entries) — guards against a trivially-passing
/// equivalence where no update ever fires.
fn check_go_cache_state_evolves(engine: &ModelEngine) {
    let m = &engine.model;
    let p = prompt(m.prompt_len, 3, m.vocab);
    let (mut session, mut next) = engine.prefill(&p).unwrap();
    let mut any_new_token_selected = false;
    for _ in 0..12 {
        let before = session.pos;
        next = engine.decode_cached(&mut session, next).unwrap();
        // the session advanced
        assert_eq!(session.pos, before + 1);
        if session.pos > m.prompt_len + 2 {
            any_new_token_selected = true;
        }
    }
    assert!(any_new_token_selected);

    // and the batch router over real scores still matches what the cache
    // produced during the walk (spot-check expert 0 membership makes sense)
    let scores = vec![0.0f32; m.max_seq * m.n_experts];
    let r = expert_choice_route(&scores, m.max_seq, m.n_experts,
                                m.expert_capacity, Some(m.prompt_len));
    assert_eq!(r.choices.tokens_of(0).len(), m.expert_capacity);
}
