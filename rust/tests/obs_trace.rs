//! Observability integration: span-trace determinism on the virtual
//! clock, disabled-sink exactness, ring-overflow accounting, and the
//! Chrome/Perfetto export round trip (DESIGN.md §Observability).
//!
//! Everything here runs on the virtual backend, so the suite needs no
//! artifact set and every assertion is byte-exact per seed.

use moepim::obs::{
    check_conservation, chrome_trace, EventKind, SpanOutcome, TraceSink,
    SPANS_SCHEMA,
};
use moepim::util::json::{self, Json};
use moepim::workload::{
    report, run_virtual, run_virtual_traced, AdmissionPolicy,
    ArrivalProcess, PlacementPolicy, ShardedDriver, SizeModel,
    VirtualConfig, WorkloadSpec,
};

fn spec(seed: u64) -> WorkloadSpec {
    WorkloadSpec {
        seed,
        requests: 48,
        arrival: ArrivalProcess::Bursty {
            rate_rps: 900.0,
            mean_on_ms: 10.0,
            mean_off_ms: 20.0,
        },
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

/// Run a traced virtual experiment and return the rendered span document.
fn traced_dump(cfg: &VirtualConfig, spec: &WorkloadSpec,
               policy: AdmissionPolicy) -> String {
    let mut sink = TraceSink::on(true);
    run_virtual_traced(cfg, spec, policy, &mut sink);
    chrome_trace(&[sink.drain(Some(0), "vsim")], "virtual")
        .to_string_pretty()
}

#[test]
fn virtual_trace_is_byte_identical_per_seed() {
    let cfg = VirtualConfig::default();
    let s = spec(0x0B5);
    let policy = AdmissionPolicy::fifo();
    let a = traced_dump(&cfg, &s, policy);
    let b = traced_dump(&cfg, &s, policy);
    assert_eq!(a, b, "same seed must dump the same bytes");
    // a different seed shifts arrivals and sizes, so the trace must move
    let c = traced_dump(&cfg, &spec(0x0B6), policy);
    assert_ne!(a, c, "trace ignored the workload seed");
}

#[test]
fn tracing_never_perturbs_the_outcome() {
    let cfg = VirtualConfig::default();
    let spec = spec(0x7E57);
    let policy = AdmissionPolicy::sjf();
    let plain = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
        .to_string_pretty();
    // enabled sink: the outcome (and thus the report) must not move
    let mut sink = TraceSink::on(true);
    let out = run_virtual_traced(&cfg, &spec, policy, &mut sink);
    assert!(!sink.drain(Some(0), "vsim").events.is_empty());
    assert_eq!(report::build(&spec, policy, &out).to_string_pretty(),
               plain, "an enabled sink perturbed the virtual outcome");
    // disabled sink through the same traced entry point: still exact,
    // and nothing is recorded
    let mut off = TraceSink::off();
    let out = run_virtual_traced(&cfg, &spec, policy, &mut off);
    let shard = off.drain(Some(0), "vsim");
    assert!(shard.events.is_empty());
    assert_eq!(shard.dropped_events, 0);
    assert_eq!(report::build(&spec, policy, &out).to_string_pretty(),
               plain, "a disabled sink perturbed the virtual outcome");
}

#[test]
fn ring_overflow_keeps_newest_and_surfaces_drop_count() {
    let cfg = VirtualConfig::default();
    let spec = spec(0x4176);
    let policy = AdmissionPolicy::fifo();
    // a deliberately tiny ring: the run records far more events than fit
    let mut sink = TraceSink::ring(32);
    run_virtual_traced(&cfg, &spec, policy, &mut sink);
    let shard = sink.drain(Some(0), "vsim");
    assert_eq!(shard.events.len(), 32);
    assert!(shard.dropped_events > 0, "tiny ring never overflowed");
    // drop-oldest: surviving events are the newest, still time-ordered
    assert!(shard
        .events
        .windows(2)
        .all(|w| w[0].t_ns <= w[1].t_ns));
    // the drop count rides into the export header
    let doc = chrome_trace(&[shard], "virtual");
    let dropped = doc
        .path(&["otherData", "dropped_events"])
        .and_then(Json::as_f64)
        .expect("dropped_events in otherData");
    assert!(dropped > 0.0);
}

#[test]
fn export_round_trips_and_conserves_terminals() {
    let cfg = VirtualConfig::default();
    let spec = spec(0xC0DE);
    let policy = AdmissionPolicy::fifo();
    let mut sink = TraceSink::on(true);
    run_virtual_traced(&cfg, &spec, policy, &mut sink);
    let text = chrome_trace(&[sink.drain(Some(0), "vsim")], "virtual")
        .to_string_pretty();
    let doc = json::parse(&text).expect("span dump parses back");
    assert_eq!(doc.path(&["otherData", "schema"]).and_then(Json::as_str),
               Some(SPANS_SCHEMA));
    assert_eq!(doc.path(&["otherData", "clock"]).and_then(Json::as_str),
               Some("virtual"));
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    // every event row is a well-formed Chrome trace event (metadata
    // rows carry no timestamp; everything else must)
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).expect("no ph");
        assert!(e.get("pid").and_then(Json::as_f64).is_some(), "no pid");
        if ph != "M" {
            assert!(e.get("ts").and_then(Json::as_f64).is_some(), "no ts");
        }
    }
    // exactly one terminal per submitted request, checked through the
    // JSON round trip (what CI validates on the dumped artifact)
    let n = check_conservation(&doc).expect("conservation holds");
    assert_eq!(n, spec.requests, "every request must terminate once");
}

#[test]
fn conservation_check_rejects_a_lost_request() {
    // a request that queues but never terminates must fail the check
    let mut sink = TraceSink::ring(16);
    sink.record(10, EventKind::Queued { id: 1 });
    sink.record(20, EventKind::Queued { id: 2 });
    sink.record(
        30,
        EventKind::Terminal { id: 2, outcome: SpanOutcome::Ok },
    );
    let doc = chrome_trace(&[sink.drain(Some(0), "test")], "virtual");
    let err = check_conservation(&doc)
        .expect_err("a terminal-less request must be caught");
    assert!(err.contains('1'), "error should name the lost id: {err}");
}

#[test]
fn sharded_virtual_trace_is_deterministic_and_lane_tagged() {
    let spec = spec(0x5AAD);
    let cfg = VirtualConfig::default();
    let policy = AdmissionPolicy::fifo();
    let run_once = || {
        let driver = ShardedDriver::new(3, PlacementPolicy::RoundRobin);
        let (run, traces) = driver.run_virtual_traced(&cfg, &spec, policy);
        assert_eq!(traces.len(), 3, "one span shard per backend");
        (
            report::build_sharded(&spec, policy, &driver, &run)
                .to_string_pretty(),
            chrome_trace(&traces, "virtual").to_string_pretty(),
        )
    };
    let (report_a, trace_a) = run_once();
    let (report_b, trace_b) = run_once();
    assert_eq!(report_a, report_b);
    assert_eq!(trace_a, trace_b, "sharded trace must be byte-repeatable");
    // each backend renders as its own pid lane
    let doc = json::parse(&trace_a).expect("sharded dump parses");
    let mut pids: Vec<i64> = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents")
        .iter()
        .filter_map(|e| e.get("pid").and_then(Json::as_f64))
        .map(|p| p as i64)
        .collect();
    pids.sort_unstable();
    pids.dedup();
    assert_eq!(pids, vec![0, 1, 2], "pid lanes must map shard indices");
    let n = check_conservation(&doc).expect("sharded conservation holds");
    assert_eq!(n, spec.requests);
}
