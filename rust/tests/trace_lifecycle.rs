//! End-to-end pins on the trace lifecycle (record → replay → calibrate →
//! scenarios), all on the virtual clock so no artifact set is needed:
//! a recorded run must replay byte-identically through its JSON round
//! trip, a canonical-replay scenario must re-materialize exactly from its
//! own trace, every scenario preset must agree between the batch and
//! live virtual backends, sharded recordings must carry shard tags, and
//! self-calibration must land inside the 15% acceptance gate.

use moepim::util::json;
use moepim::workload::record::{RecordedTrace, TraceBackend, TraceRecorder};
use moepim::workload::{
    calibrate, report, run_virtual, run_virtual_live, run_virtual_requests,
    scenario_names, scenario_spec, AdmissionPolicy, ArrivalProcess,
    PlacementPolicy, ShardedDriver, SizeModel, VirtualConfig, WorkloadSpec,
};

fn open_spec() -> WorkloadSpec {
    WorkloadSpec {
        seed: 0x7ACE,
        requests: 48,
        arrival: ArrivalProcess::Poisson { rate_rps: 600.0 },
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

/// Record a virtual run and push the trace through its JSON text form,
/// exactly like `--record FILE` followed by `--replay FILE`.
fn record_through_json(
    cfg: &VirtualConfig,
    spec: &WorkloadSpec,
    policy: AdmissionPolicy,
) -> (String, RecordedTrace) {
    let out = run_virtual(cfg, spec, policy);
    let recorded = report::build(spec, policy, &out).to_string_pretty();
    let trace = TraceRecorder::new(spec, policy)
        .finish(&out, TraceBackend::from_virtual(cfg));
    let text = trace.to_json().to_string_pretty();
    let doc = json::parse(&text).expect("trace text parses");
    (recorded, RecordedTrace::from_json(&doc).expect("trace loads"))
}

#[test]
fn recorded_virtual_runs_replay_byte_identically() {
    // the tentpole round trip: record -> serialize -> load -> replay the
    // exact request stream -> the replay's report is the recorded one,
    // byte for byte, under both admission policies
    let cfg = VirtualConfig::default();
    let spec = open_spec();
    for policy in [AdmissionPolicy::fifo(), AdmissionPolicy::sjf()] {
        let (recorded, trace) = record_through_json(&cfg, &spec, policy);
        let replay = run_virtual_requests(
            &cfg,
            trace.original_spec(),
            &trace.replay_requests(),
            policy,
        );
        let replayed = report::build(trace.original_spec(), policy, &replay)
            .to_string_pretty();
        assert_eq!(
            replayed,
            recorded,
            "replay diverged under {}",
            policy.label()
        );
    }
}

#[test]
fn canonical_replay_scenarios_rematerialize_from_their_own_trace() {
    // diurnal and mixed-tenants arrive on Replay timelines, which
    // materialize should canonicalize (sorted, zero-start) — so the
    // recorded arrival stream folded back into a replay_spec() must
    // regenerate the recorded workload exactly, sizes and deadlines
    // included (size draws are salted independently of arrivals)
    let cfg = VirtualConfig::default();
    for name in ["diurnal", "mixed-tenants"] {
        let spec = scenario_spec(name, 2026).expect(name);
        let (_, trace) =
            record_through_json(&cfg, &spec, AdmissionPolicy::fifo());
        assert_eq!(
            trace.replay_spec().materialize(),
            spec.materialize(),
            "{name}: replay_spec did not round-trip the workload"
        );
    }
}

#[test]
fn every_scenario_preset_matches_between_batch_and_live_virtual_backends() {
    // the "both backends" half of the scenario acceptance: the batch
    // virtual cluster and the incrementally-pumped live backend must
    // agree sample for sample on every preset (all presets are
    // open-loop, so both paths are defined)
    let cfg = VirtualConfig::default();
    let policy = AdmissionPolicy::fifo();
    for name in scenario_names() {
        let spec = scenario_spec(name, 2026).expect(name);
        let batch = run_virtual(&cfg, &spec, policy);
        let live = run_virtual_live(&cfg, &spec, policy, 1);
        assert_eq!(live.shards.len(), 1, "{name}");
        assert_eq!(
            batch.samples, live.shards[0].outcome.samples,
            "{name}: batch and live virtual backends diverged"
        );
        // and the preset is report-deterministic end to end
        let a = report::build(&spec, policy, &batch).to_string_pretty();
        let b =
            report::build(&spec, policy, &run_virtual(&cfg, &spec, policy))
                .to_string_pretty();
        assert_eq!(a, b, "{name}: report not byte-identical");
    }
}

#[test]
fn sharded_recordings_tag_every_request_and_round_trip() {
    let cfg = VirtualConfig::default();
    let spec = open_spec();
    let policy = AdmissionPolicy::fifo();
    let driver = ShardedDriver::new(2, PlacementPolicy::RoundRobin);
    let run = driver.run_virtual(&cfg, &spec, policy);
    let backend = TraceBackend {
        shards: 2,
        placement: Some("round-robin".to_string()),
        ..TraceBackend::from_virtual(&cfg)
    };
    let trace =
        TraceRecorder::new(&spec, policy).finish_sharded(&run, backend);
    assert_eq!(trace.requests.len(), spec.requests);
    assert!(
        trace.requests.iter().all(|r| r.shard.is_some()),
        "sharded trace left requests untagged"
    );
    assert!(
        trace.requests.iter().any(|r| r.shard == Some(1)),
        "round-robin over 2 shards never used shard 1"
    );
    let doc = json::parse(&trace.to_json().to_string_pretty()).unwrap();
    assert_eq!(RecordedTrace::from_json(&doc).unwrap(), trace);
}

#[test]
fn calibration_against_a_recorded_scenario_lands_inside_the_gate() {
    // the acceptance gate: fit the virtual cost constants against a
    // recorded run and re-predict it to within 15% at p50 and p99
    let cfg = VirtualConfig::default();
    let spec = scenario_spec("mixed-tenants", 2026).unwrap();
    let (_, trace) =
        record_through_json(&cfg, &spec, AdmissionPolicy::fifo());
    let cal = calibrate(&trace, &cfg).expect("fit");
    assert!(cal.n_samples > 16, "only {} usable samples", cal.n_samples);
    assert!(
        cal.p50_err_pct <= 15.0 && cal.p99_err_pct <= 15.0,
        "re-prediction error p50 {:.2}% p99 {:.2}% exceeds the 15% gate",
        cal.p50_err_pct,
        cal.p99_err_pct
    );
    // the fitted document carries the constants a study would reuse
    let doc = cal.to_json();
    for path in [
        ["fitted", "cycle_ns"],
        ["fitted", "dispatch_overhead_ns"],
        ["fitted", "prefill_ns_per_token"],
    ] {
        assert!(
            doc.path(&path).and_then(|v| v.as_f64()).unwrap_or(0.0) >= 1.0,
            "missing or degenerate {path:?}"
        );
    }
}
