//! E6 (DESIGN.md §5): the paper's headline claims, pinned with tolerance
//! bands against the calibrated simulator.  These are the "does the
//! reproduction actually reproduce" tests — qualitative orderings are
//! asserted strictly, quantitative targets within the band a closed-source
//! simulator substitution warrants (±35%; most land within ±15%, see
//! `moepim eval calibration`).

use moepim::eval::{calibration, fig4, fig5, sweep, table1};

const BAND: f64 = 0.35;

#[test]
fn all_calibration_targets_within_band() {
    let mut failures = Vec::new();
    for t in calibration::targets() {
        if !t.within(BAND) {
            failures.push(format!(
                "{}: paper {} vs measured {:.2} ({:.2}x)",
                t.name, t.paper, t.measured, t.ratio()
            ));
        }
    }
    assert!(failures.is_empty(), "targets out of band:\n{}",
            failures.join("\n"));
}

#[test]
fn headline_cache_improvements() {
    // "The latency and energy generating 8 tokens improve by 4.2x and
    //  10.1x" — and grow with length ("6.7x ... 14.1x" at 64)
    let i8 = fig4::improvement(8);
    let i64 = fig4::improvement(64);
    assert!(i8.latency_x > 3.0 && i8.latency_x < 5.5, "{}", i8.latency_x);
    assert!(i8.energy_x > 7.0 && i8.energy_x < 13.0, "{}", i8.energy_x);
    assert!(i64.latency_x > i8.latency_x);
    assert!(i64.energy_x > i8.energy_x);
}

#[test]
fn kv_cache_alone_saves_latency_not_energy() {
    // §IV-B: "the KV cache reduces attention latency but does not benefit
    // from energy because DRAM costs extra energy"
    let rows = fig4::fig4a(8);
    let by = |l: &str| rows.iter().find(|r| r.cache == l).unwrap();
    let none = by("no cache");
    let kv = by("KV cache");
    assert!(kv.latency_ns < none.latency_ns * 0.8, "latency improves");
    assert!(kv.energy_nj > none.energy_nj * 0.55,
            "energy stays near baseline: {} vs {}", kv.energy_nj,
            none.energy_nj);
}

#[test]
fn amdahl_needs_both_caches() {
    // "The maximized benefits come from the combination" — each cache
    // alone leaves the other bottleneck standing
    let rows = fig4::fig4a(8);
    let by = |l: &str| rows.iter().find(|r| r.cache == l).unwrap();
    let kvgo = by("KVGO cache").latency_ns;
    assert!(by("KV cache").latency_ns > 1.5 * kvgo);
    assert!(by("GO cache").latency_ns > 1.5 * kvgo);
}

#[test]
fn table1_orderings() {
    let rows = table1::table1();
    // S2O best latency & energy, S4O best density (Table I)
    assert!(rows[1].latency_ns < rows[0].latency_ns);
    assert!(rows[1].latency_ns <= rows[2].latency_ns);
    assert!(rows[1].energy_nj < rows[0].energy_nj);
    assert!(rows[2].density >= rows[1].density);
    assert!(rows[2].density >= rows[0].density * 0.95);
}

#[test]
fn fig5_orderings() {
    let rows = fig5::fig5();
    let by = |l: &str| rows.iter().find(|r| r.label == l).unwrap();
    // sorted beats uniform on latency (balanced bottleneck group)
    assert!(by("S2O").latency_ns <= by("U2O").latency_ns * 1.001);
    assert!(by("S4O").latency_ns <= by("U4O").latency_ns * 1.001);
    // group of 2 beats group of 4 on area efficiency at the 40% ratio
    assert!(by("S2O").gops_per_mm2 > by("S4O").gops_per_mm2);
    // reschedule reclaims compact's transfer overhead at equal latency
    for (c, o) in [("U2C", "U2O"), ("S2C", "S2O"), ("U4C", "U4O"),
                   ("S4C", "S4O")] {
        assert!(by(o).transfers <= by(c).transfers);
        assert!((by(o).latency_ns - by(c).latency_ns).abs() < 1e-9);
    }
}

#[test]
fn area_efficiency_improvement_near_2_2x() {
    let rows = fig5::fig5();
    let (label, x) = fig5::best_improvement(&rows);
    assert!(x > 1.7 && x < 2.6, "best {label} at {x:.2}x (paper: up to 2.2x)");
}

#[test]
fn isaac_ratio_flips_optimal_group_size() {
    // §IV-B: at a 5% crossbar-area ratio, larger groups win — the paper's
    // generalisation quoting 82.7 GOPS/mm² at g=4
    let rows = sweep::sweep(&[0.05], &[1, 2, 4]);
    let eff = |g: usize| {
        rows.iter().find(|r| r.group_size == g).unwrap().gops_per_mm2
    };
    assert!(eff(4) > eff(2) && eff(2) > eff(1));
    let p = sweep::isaac_point().gops_per_mm2;
    assert!(p > 82.7 * (1.0 - BAND) && p < 82.7 * (1.0 + BAND),
            "ISAAC point {p:.1} vs paper 82.7");
}

#[test]
fn crossbar_count_matches_paper() {
    // §IV-A: "Our model requires 1536 crossbars for 16 experts"
    use moepim::config::{HardwareConfig, MoeModelConfig};
    use moepim::moe::LayerLayout;
    let layout = LayerLayout::new(&MoeModelConfig::llama_moe_4_16(),
                                  &HardwareConfig::paper());
    assert_eq!(layout.total_xbars(), 1536);
}

#[test]
fn go_cache_traffic_matches_paper() {
    // §IV-A: "Each newly generated token only adds 32B of score data, and
    // the output cache size is fixed at 512 KB"
    use moepim::cache::GoCache;
    assert_eq!(GoCache::score_bytes_per_token(16), 32);
    assert_eq!(GoCache::output_cache_bytes(8, 16, 4096), 512 * 1024);
}
