//! Property suite for decode-side preemption and QoS tiers
//! (DESIGN.md §Preemption & QoS).
//!
//! Three laws, checked over randomized shapes and traffic:
//!
//! 1. **Checkpoint round-trip**: capturing a slot's KV/GO bank state plus
//!    session cursor and restoring it — into the same slot, another slot,
//!    or a freshly-built pool — leaves every bank byte-identical
//!    (bit-level f32 comparison, padding included), for arbitrary layer
//!    counts, slot counts, and fill depths.
//! 2. **Slot conservation**: under QoS preemption every submitted request
//!    still gets exactly one terminal reply, and every preemption of a
//!    live decode session is matched by exactly one restore.
//! 3. **No priority inversion**: scanning the span-event stream in
//!    processing order, a batch-tier request is never granted (or
//!    restored into) a slot while an interactive request is waiting.

use moepim::cache::{GoCache, KvPool};
use moepim::coordinator::{SlotCheckpoint, SlotSession};
use moepim::obs::{EventKind, TraceSink};
use moepim::util::prop::{self, Gen};
use moepim::workload::{
    run_virtual_traced, AdmissionPolicy, ArrivalProcess, Priority,
    SizeModel, VirtualConfig, WorkloadSpec,
};

const MAX_SEQ: usize = 16;
const N_HEADS: usize = 2;
const D_HEAD: usize = 3;
const N_EXPERTS: usize = 4;
const GO_CAP: usize = 3;
const OUT_DIM: usize = 5;

/// One slot's worth of random per-layer padded K/V banks.
fn random_banks(g: &mut Gen, layers: usize) -> Vec<Vec<f32>> {
    (0..layers)
        .map(|_| {
            g.vec_f64(MAX_SEQ * N_HEADS * D_HEAD)
                .into_iter()
                .map(|x| x as f32)
                .collect()
        })
        .collect()
}

/// Random per-layer GO banks with populated score entries and outputs.
fn random_go(g: &mut Gen, layers: usize) -> Vec<GoCache> {
    (0..layers)
        .map(|_| {
            let mut bank = GoCache::new(N_EXPERTS, GO_CAP, OUT_DIM);
            for token in 0..g.size(1, 6) {
                let scores: Vec<f32> = g
                    .vec_f64(N_EXPERTS)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                bank.update_scores(token, &scores);
            }
            for _ in 0..g.size(0, 4) {
                let out: Vec<f32> = g
                    .vec_f64(OUT_DIM)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                bank.store_output(g.usize(N_EXPERTS), g.usize(GO_CAP),
                                  &out);
            }
            bank
        })
        .collect()
}

/// Bit-level slice equality: NaNs and signed zeros must survive the round
/// trip too, so `==` on f32 is not strong enough in principle.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn checkpoint_restore_round_trips_banks_byte_identically() {
    prop::check(64, |g| {
        let layers = g.size(1, 4);
        let slots = g.size(1, 4);
        let slot = g.usize(slots);
        let valid = g.size(1, MAX_SEQ);

        let mut kv = KvPool::new(layers, slots, MAX_SEQ, N_HEADS, D_HEAD);
        let ks = random_banks(g, layers);
        let vs = random_banks(g, layers);
        kv.seed_slot(slot, &ks, &vs, valid);
        let mut go = random_go(g, layers);
        let go_before = go.clone();
        let session = SlotSession {
            ids: (0..valid as i32).collect(),
            pos: valid,
        };

        let ckpt = SlotCheckpoint::capture(&kv, &go, &session, slot);
        assert_eq!(ckpt.n_layers(), layers);
        assert_eq!(ckpt.kv_len(), valid);

        // dirty the pool and the banks the way a preempting request would
        kv.reset_slot(slot);
        let other_valid = g.size(1, MAX_SEQ);
        kv.seed_slot(
            slot,
            &random_banks(g, layers),
            &random_banks(g, layers),
            other_valid,
        );
        for bank in go.iter_mut() {
            bank.reset();
        }

        // restore into the original slot: byte-identical banks
        ckpt.restore_into(&mut kv, &mut go, slot);
        assert_eq!(kv.len(slot), valid, "valid row count lost");
        for l in 0..layers {
            assert!(bits_eq(kv.slot_k(l, slot), &ks[l]),
                    "K bank layer {l} diverged");
            assert!(bits_eq(kv.slot_v(l, slot), &vs[l]),
                    "V bank layer {l} diverged");
        }
        assert_eq!(go, go_before, "GO banks diverged");
        assert_eq!(ckpt.session, session, "session cursor diverged");

        // restore may target a *different* slot of a fresh pool (the
        // engine resumes into whatever slot is free)
        let slot2 = g.usize(slots);
        let mut kv2 = KvPool::new(layers, slots, MAX_SEQ, N_HEADS, D_HEAD);
        let mut go2: Vec<GoCache> = (0..layers)
            .map(|_| GoCache::new(N_EXPERTS, GO_CAP, OUT_DIM))
            .collect();
        ckpt.restore_into(&mut kv2, &mut go2, slot2);
        assert_eq!(kv2.len(slot2), valid);
        for l in 0..layers {
            assert!(bits_eq(kv2.slot_k(l, slot2), &ks[l]),
                    "cross-slot K bank layer {l} diverged");
            assert!(bits_eq(kv2.slot_v(l, slot2), &vs[l]),
                    "cross-slot V bank layer {l} diverged");
        }
        assert_eq!(go2, go_before, "cross-slot GO banks diverged");
    });
}

/// A randomized two-tier flood on the virtual clock.  The first `slots`
/// arrivals land at t=0 (filling every slot); the rest arrive on a random
/// ascending timeline, so interactive stragglers must preempt.
fn random_two_tier_spec(g: &mut Gen, slots: usize) -> WorkloadSpec {
    let requests = slots + g.size(2, 8);
    let mut t = 0u64;
    let mut times = vec![0u64; slots];
    for _ in slots..requests {
        t += g.size(50, 400) as u64;
        times.push(t);
    }
    WorkloadSpec {
        seed: 0x9005 ^ g.case_seed,
        requests,
        arrival: ArrivalProcess::Replay { times_us: times },
        sizes: SizeModel::Fixed {
            prompt_len: 4 + g.usize(8),
            gen_len: 8 + g.usize(28),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 0.1 + g.f64() * 0.4,
    }
}

#[test]
fn every_preempted_request_is_restored_or_replied_exactly_once() {
    prop::check(32, |g| {
        let cfg = VirtualConfig { qos: true, ..VirtualConfig::default() };
        let spec = random_two_tier_spec(g, cfg.slots);
        let mut sink = TraceSink::on(true);
        let out = run_virtual_traced(
            &cfg, &spec, AdmissionPolicy::deadline(), &mut sink);
        let shard = sink.drain(Some(0), "vsim");

        assert_eq!(out.samples.len(), spec.requests,
                   "a request never reached a terminal reply");
        assert!(out.samples.iter().all(|s| s.ok));

        let mut terminals = vec![0u64; spec.requests];
        let mut preempts = vec![0u64; spec.requests];
        let mut restores = vec![0u64; spec.requests];
        for ev in &shard.events {
            match ev.kind {
                EventKind::Terminal { id, .. } => {
                    terminals[id as usize] += 1;
                }
                EventKind::Preempt { id, .. } => {
                    preempts[id as usize] += 1;
                }
                EventKind::Restore { id, .. } => {
                    restores[id as usize] += 1;
                }
                _ => {}
            }
        }
        for id in 0..spec.requests {
            assert_eq!(terminals[id], 1,
                       "request {id}: {} terminal replies", terminals[id]);
            // monolithic prefill (chunk 0) means every preemption evicts
            // a live decode session, and every eviction is later resumed
            assert_eq!(preempts[id], restores[id],
                       "request {id}: {} preempts vs {} restores",
                       preempts[id], restores[id]);
        }
        assert_eq!(preempts.iter().sum::<u64>(), out.preemptions);
        assert_eq!(restores.iter().sum::<u64>(), out.restores);
    });
}

#[test]
fn no_batch_grant_while_an_interactive_request_waits() {
    prop::check(32, |g| {
        let cfg = VirtualConfig { qos: true, ..VirtualConfig::default() };
        let spec = random_two_tier_spec(g, cfg.slots);
        let mix = spec.interactive_mix;
        let mut sink = TraceSink::on(true);
        run_virtual_traced(
            &cfg, &spec, AdmissionPolicy::deadline(), &mut sink);
        let shard = sink.drain(Some(0), "vsim");

        // replay the event stream in processing order, tracking which
        // requests are waiting in the admission queue per tier
        let mut waiting_interactive = 0usize;
        let tier = |id: u64| Priority::assign(id, mix);
        let mut is_waiting = vec![false; spec.requests];
        let mut track = |id: u64, now_waiting: bool,
                         waiting_interactive: &mut usize| {
            let was = is_waiting[id as usize];
            if was != now_waiting && tier(id) == Priority::Interactive {
                if now_waiting {
                    *waiting_interactive += 1;
                } else {
                    *waiting_interactive -= 1;
                }
            }
            is_waiting[id as usize] = now_waiting;
        };
        for ev in &shard.events {
            match ev.kind {
                EventKind::Queued { id }
                | EventKind::Preempt { id, .. } => {
                    track(id, true, &mut waiting_interactive);
                }
                EventKind::SlotGrant { id, .. }
                | EventKind::Restore { id, .. } => {
                    assert!(
                        tier(id) == Priority::Interactive
                            || waiting_interactive == 0,
                        "batch request {id} granted a slot while {} \
                         interactive request(s) waited",
                        waiting_interactive
                    );
                    track(id, false, &mut waiting_interactive);
                }
                _ => {}
            }
        }
    });
}
