//! Property tests for the GO cache: the streaming `TopKUpdate` (Eq. 4-5)
//! must select exactly what a batch expert-choice router over the full
//! token set would select, under any score stream, capacity and prefix —
//! the paper's correctness claim for the cache, mirrored by python's
//! tests/test_routing.py.

use moepim::cache::{GoCache, KvCache};
use moepim::moe::gate::expert_choice_route;
use moepim::util::prop::{self, Gen};
use moepim::util::rng::Pcg32;

fn scores(g: &mut Gen, t: usize, e: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(g.case_seed ^ 0x5C0E);
    (0..t * e).map(|_| rng.gen_normal() as f32).collect()
}

#[test]
fn streaming_topk_equals_batch_topk() {
    prop::check(250, |g| {
        let e = *[2usize, 4, 8, 16].get(g.usize(4)).unwrap();
        let total = g.size(2, 48).max(2);
        let prefix = g.size(1, total - 1).max(1);
        let cap = g.size(1, prefix).max(1);
        let s = scores(g, total, e);

        let pre = expert_choice_route(&s[..prefix * e], prefix, e, cap, None);
        let mut cache = GoCache::new(e, cap, 0);
        cache.seed_from_routing(&pre);
        for t in prefix..total {
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
        }

        let full = expert_choice_route(&s, total, e, cap, None);
        for x in 0..e {
            assert_eq!(
                cache.selected_tokens(x),
                full.choices.tokens_of(x),
                "expert {x}, prefix {prefix}, cap {cap}, total {total}"
            );
        }
    });
}

#[test]
fn ties_resolve_to_earlier_token_both_ways() {
    prop::check(100, |g| {
        // quantised scores force ties
        let e = 4;
        let total = g.size(4, 24).max(4);
        let cap = g.size(1, 3).max(1);
        let mut rng = Pcg32::new(g.case_seed);
        let s: Vec<f32> = (0..total * e)
            .map(|_| (rng.gen_range(3) as f32) * 0.5)
            .collect();
        let pre = expert_choice_route(&s[..cap * e], cap, e, cap, None);
        let mut cache = GoCache::new(e, cap, 0);
        cache.seed_from_routing(&pre);
        for t in cap..total {
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
        }
        let full = expert_choice_route(&s, total, e, cap, None);
        for x in 0..e {
            assert_eq!(cache.selected_tokens(x), full.choices.tokens_of(x));
        }
    });
}

#[test]
fn at_most_one_eviction_per_expert_per_step() {
    prop::check(150, |g| {
        let e = 8;
        let cap = g.size(1, 6).max(1);
        let steps = g.size(cap, 40).max(cap);
        let mut cache = GoCache::new(e, cap, 0);
        for t in 0..steps {
            let row: Vec<f32> =
                (0..e).map(|_| g.normal() as f32).collect();
            let before: Vec<Vec<usize>> =
                (0..e).map(|x| cache.selected_tokens(x)).collect();
            let upd = cache.update_probs(t, &row);
            assert_eq!(upd.selected.len(), upd.evicted.len());
            for x in 0..e {
                let after = cache.selected_tokens(x);
                assert!(after.len() <= cap);
                let removed = before[x]
                    .iter()
                    .filter(|tk| !after.contains(tk))
                    .count();
                assert!(removed <= 1);
            }
        }
    });
}

#[test]
fn selection_threshold_never_decreases() {
    // each expert's cached minimum is monotone non-decreasing over the
    // stream — the property that lets the chip keep one comparator per
    // expert instead of re-sorting
    prop::check(150, |g| {
        let e = 4;
        let cap = g.size(1, 4).max(1);
        let steps = g.size(cap + 1, 32).max(cap + 1);
        let mut cache = GoCache::new(e, cap, 0);
        let mut last_min = vec![f32::NEG_INFINITY; e];
        for t in 0..steps {
            let row: Vec<f32> =
                (0..e).map(|_| g.normal() as f32).collect();
            cache.update_probs(t, &row);
            for x in 0..e {
                if let Some(th) = cache.threshold(x) {
                    assert!(
                        th.prob >= last_min[x],
                        "expert {x} threshold decreased"
                    );
                    last_min[x] = th.prob;
                }
            }
        }
    });
}

#[test]
fn kv_cache_roundtrips_rows() {
    prop::check(100, |g| {
        let h = g.size(1, 4).max(1);
        let dh = g.size(1, 16).max(1);
        let max = g.size(2, 24).max(2);
        let mut kv = KvCache::new(max, h, dh);
        let r = h * dh;
        let mut rows: Vec<Vec<f32>> = Vec::new();
        let n = g.size(1, max).max(1);
        for i in 0..n {
            let row: Vec<f32> =
                (0..r).map(|j| (i * r + j) as f32).collect();
            kv.append(&row, &row);
            rows.push(row);
        }
        assert_eq!(kv.len(), n);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(kv.row_k(i), row.as_slice());
        }
    });
}
