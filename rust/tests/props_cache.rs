//! Property tests for the caches.
//!
//! GO cache: the streaming `TopKUpdate` (Eq. 4-5) must select exactly
//! what a batch expert-choice router over the full token set would
//! select, under any score stream, capacity and prefix — the paper's
//! correctness claim for the cache, mirrored by python's
//! tests/test_routing.py.  The batched engine's two-phase step adds a
//! second contract: `peek_probs` + `apply_update` must equal the direct
//! `update_probs`, and a peek alone must leave the cache untouched.
//!
//! KV pool: slot *and layer* isolation — any interleaving of
//! `seed_slot` / `append_slot` / `reset_slot` calls must never perturb
//! any other slot's or layer's bytes (checked against a byte-exact
//! reference model after every operation).

use moepim::cache::{GoCache, KvCache, KvPool};
use moepim::moe::gate::{expert_choice_route, softmax_rows};
use moepim::util::prop::{self, Gen};
use moepim::util::rng::Pcg32;

fn scores(g: &mut Gen, t: usize, e: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(g.case_seed ^ 0x5C0E);
    (0..t * e).map(|_| rng.gen_normal() as f32).collect()
}

#[test]
fn streaming_topk_equals_batch_topk() {
    prop::check(250, |g| {
        let e = *[2usize, 4, 8, 16].get(g.usize(4)).unwrap();
        let total = g.size(2, 48).max(2);
        let prefix = g.size(1, total - 1).max(1);
        let cap = g.size(1, prefix).max(1);
        let s = scores(g, total, e);

        let pre = expert_choice_route(&s[..prefix * e], prefix, e, cap, None);
        let mut cache = GoCache::new(e, cap, 0);
        cache.seed_from_routing(&pre);
        for t in prefix..total {
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
        }

        let full = expert_choice_route(&s, total, e, cap, None);
        for x in 0..e {
            assert_eq!(
                cache.selected_tokens(x),
                full.choices.tokens_of(x),
                "expert {x}, prefix {prefix}, cap {cap}, total {total}"
            );
        }
    });
}

#[test]
fn ties_resolve_to_earlier_token_both_ways() {
    prop::check(100, |g| {
        // quantised scores force ties
        let e = 4;
        let total = g.size(4, 24).max(4);
        let cap = g.size(1, 3).max(1);
        let mut rng = Pcg32::new(g.case_seed);
        let s: Vec<f32> = (0..total * e)
            .map(|_| (rng.gen_range(3) as f32) * 0.5)
            .collect();
        let pre = expert_choice_route(&s[..cap * e], cap, e, cap, None);
        let mut cache = GoCache::new(e, cap, 0);
        cache.seed_from_routing(&pre);
        for t in cap..total {
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
        }
        let full = expert_choice_route(&s, total, e, cap, None);
        for x in 0..e {
            assert_eq!(cache.selected_tokens(x), full.choices.tokens_of(x));
        }
    });
}

#[test]
fn at_most_one_eviction_per_expert_per_step() {
    prop::check(150, |g| {
        let e = 8;
        let cap = g.size(1, 6).max(1);
        let steps = g.size(cap, 40).max(cap);
        let mut cache = GoCache::new(e, cap, 0);
        for t in 0..steps {
            let row: Vec<f32> =
                (0..e).map(|_| g.normal() as f32).collect();
            let before: Vec<Vec<usize>> =
                (0..e).map(|x| cache.selected_tokens(x)).collect();
            let upd = cache.update_probs(t, &row);
            assert_eq!(upd.selected.len(), upd.evicted.len());
            for x in 0..e {
                let after = cache.selected_tokens(x);
                assert!(after.len() <= cap);
                let removed = before[x]
                    .iter()
                    .filter(|tk| !after.contains(tk))
                    .count();
                assert!(removed <= 1);
            }
        }
    });
}

#[test]
fn selection_threshold_never_decreases() {
    // each expert's cached minimum is monotone non-decreasing over the
    // stream — the property that lets the chip keep one comparator per
    // expert instead of re-sorting
    prop::check(150, |g| {
        let e = 4;
        let cap = g.size(1, 4).max(1);
        let steps = g.size(cap + 1, 32).max(cap + 1);
        let mut cache = GoCache::new(e, cap, 0);
        let mut last_min = vec![f32::NEG_INFINITY; e];
        for t in 0..steps {
            let row: Vec<f32> =
                (0..e).map(|_| g.normal() as f32).collect();
            cache.update_probs(t, &row);
            for x in 0..e {
                if let Some(th) = cache.threshold(x) {
                    assert!(
                        th.prob >= last_min[x],
                        "expert {x} threshold decreased"
                    );
                    last_min[x] = th.prob;
                }
            }
        }
    });
}

#[test]
fn kv_cache_roundtrips_rows_per_layer() {
    prop::check(100, |g| {
        let layers = g.size(1, 3).max(1);
        let h = g.size(1, 4).max(1);
        let dh = g.size(1, 16).max(1);
        let max = g.size(2, 24).max(2);
        let mut kv = KvCache::new(layers, max, h, dh);
        let r = h * dh;
        let mut rows: Vec<Vec<Vec<f32>>> = Vec::new();
        let n = g.size(1, max).max(1);
        for i in 0..n {
            let layer_rows: Vec<Vec<f32>> = (0..layers)
                .map(|l| {
                    (0..r)
                        .map(|j| ((l * max + i) * r + j) as f32)
                        .collect()
                })
                .collect();
            kv.append(&layer_rows, &layer_rows);
            rows.push(layer_rows);
        }
        assert_eq!(kv.len(), n);
        for (i, layer_rows) in rows.iter().enumerate() {
            for (l, row) in layer_rows.iter().enumerate() {
                assert_eq!(kv.row_k(l, i), row.as_slice());
            }
        }
    });
}

/// Byte-exact reference model of a [`KvPool`]: per slot, per layer, the
/// expected full padded K/V buffers plus the shared length.
struct PoolModel {
    layers: usize,
    slots: usize,
    slot_elems: usize,
    len: Vec<usize>,
    /// k[slot][layer] / v[slot][layer]: full padded [S * H * Dh] buffers
    k: Vec<Vec<Vec<f32>>>,
    v: Vec<Vec<Vec<f32>>>,
}

impl PoolModel {
    fn new(layers: usize, slots: usize, max_seq: usize, row: usize) -> Self {
        let slot_elems = max_seq * row;
        PoolModel {
            layers,
            slots,
            slot_elems,
            len: vec![0; slots],
            k: vec![vec![vec![0.0; slot_elems]; layers]; slots],
            v: vec![vec![vec![0.0; slot_elems]; layers]; slots],
        }
    }

    fn assert_matches(&self, pool: &KvPool) {
        for slot in 0..self.slots {
            assert_eq!(pool.len(slot), self.len[slot], "slot {slot} len");
            for layer in 0..self.layers {
                assert_eq!(
                    pool.slot_k(layer, slot),
                    self.k[slot][layer].as_slice(),
                    "slot {slot} layer {layer} K bytes perturbed"
                );
                assert_eq!(
                    pool.slot_v(layer, slot),
                    self.v[slot][layer].as_slice(),
                    "slot {slot} layer {layer} V bytes perturbed"
                );
            }
        }
    }
}

/// Random interleavings of `seed_slot` / `append_slot` / `reset_slot`
/// must never perturb any other slot or layer (byte-exact, checked after
/// every single operation).
#[test]
fn kv_pool_slot_and_layer_isolation() {
    prop::check(120, |g| {
        let layers = g.size(1, 3).max(1);
        let slots = g.size(1, 4).max(1);
        let max_seq = g.size(2, 6).max(2);
        let h = g.size(1, 2).max(1);
        let dh = g.size(1, 3).max(1);
        let r = h * dh;
        let mut pool = KvPool::new(layers, slots, max_seq, h, dh);
        let mut model = PoolModel::new(layers, slots, max_seq, r);
        let ops = g.size(4, 40).max(4);
        let mut stamp = 1.0f32;
        for _ in 0..ops {
            let slot = g.usize(slots);
            match g.usize(3) {
                // seed: overwrite the slot's whole padded region
                0 => {
                    let valid = g.usize(max_seq + 1);
                    let ks: Vec<Vec<f32>> = (0..layers)
                        .map(|l| {
                            vec![stamp + l as f32; max_seq * r]
                        })
                        .collect();
                    let vs: Vec<Vec<f32>> = (0..layers)
                        .map(|l| {
                            vec![-(stamp + l as f32); max_seq * r]
                        })
                        .collect();
                    pool.seed_slot(slot, &ks, &vs, valid);
                    for l in 0..layers {
                        model.k[slot][l].copy_from_slice(&ks[l]);
                        model.v[slot][l].copy_from_slice(&vs[l]);
                    }
                    model.len[slot] = valid;
                    stamp += layers as f32;
                }
                // append: one row per layer at the current length
                1 if model.len[slot] < max_seq => {
                    let k_rows: Vec<Vec<f32>> = (0..layers)
                        .map(|l| vec![stamp + l as f32; r])
                        .collect();
                    let v_rows: Vec<Vec<f32>> = (0..layers)
                        .map(|l| vec![-(stamp + l as f32); r])
                        .collect();
                    pool.append_slot(slot, &k_rows, &v_rows);
                    let off = model.len[slot] * r;
                    for l in 0..layers {
                        model.k[slot][l][off..off + r]
                            .copy_from_slice(&k_rows[l]);
                        model.v[slot][l][off..off + r]
                            .copy_from_slice(&v_rows[l]);
                    }
                    model.len[slot] += 1;
                    stamp += layers as f32;
                }
                1 => {} // slot full: appending would panic by contract
                // reset: zero the slot everywhere
                _ => {
                    pool.reset_slot(slot);
                    for l in 0..layers {
                        model.k[slot][l].fill(0.0);
                        model.v[slot][l].fill(0.0);
                    }
                    model.len[slot] = 0;
                }
            }
            model.assert_matches(&pool);
            // the contiguous layer banks stay consistent with the
            // per-slot views (the zero-copy borrow the engine hands to
            // the batched attention artifact)
            for l in 0..layers {
                let bank = pool.layer_k(l);
                for slot in 0..slots {
                    assert_eq!(
                        &bank[slot * model.slot_elems
                            ..(slot + 1) * model.slot_elems],
                        pool.slot_k(l, slot),
                        "layer {l} bank vs slot {slot} view"
                    );
                }
            }
        }
    });
}

/// The batched engine's two-phase step: peeking an update and applying it
/// later must equal the direct update, and a peek alone must leave every
/// expert's state untouched.
#[test]
fn go_peek_then_apply_equals_direct_update() {
    prop::check(150, |g| {
        let e = *[2usize, 4, 8, 16].get(g.usize(4)).unwrap();
        let cap = g.size(1, 6).max(1);
        let steps = g.size(2, 40).max(2);
        let mut direct = GoCache::new(e, cap, 0);
        let mut staged = GoCache::new(e, cap, 0);
        for t in 0..steps {
            let row: Vec<f32> = (0..e).map(|_| g.normal() as f32).collect();
            let probs = softmax_rows(&row, 1, e);

            let before: Vec<Vec<usize>> =
                (0..e).map(|x| staged.selected_tokens(x)).collect();
            let peeked = staged.peek_probs(t, &probs);
            // peek must not mutate: state identical, and re-peeking gives
            // the same answer
            for x in 0..e {
                assert_eq!(staged.selected_tokens(x), before[x],
                           "peek mutated expert {x}");
            }
            assert_eq!(staged.peek_probs(t, &probs), peeked);

            let applied = direct.update_probs(t, &probs);
            assert_eq!(peeked, applied, "peek disagrees with direct update");

            staged.apply_update(t, &peeked);
            for x in 0..e {
                assert_eq!(
                    staged.selected_tokens(x),
                    direct.selected_tokens(x),
                    "expert {x} diverged after apply"
                );
                assert_eq!(staged.threshold(x), direct.threshold(x));
            }
        }
    });
}
