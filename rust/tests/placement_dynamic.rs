//! Integration pins for the placement control loop (DESIGN.md
//! §Placement): on a seeded skewed bursty trace the dynamic loop must
//! demonstrably beat the static route-aware split it generalizes —
//! lower merged p99 TTFT and a flatter per-shard load split — and
//! replication within a fixed area budget must improve on
//! migration-only while `area_mm2_delta` stays within the budget by
//! construction.  Everything here is virtual-clock: every run (and its
//! v2 report, placement block included) is byte-identical per seed.

use moepim::placement::{
    checkpoint_spill_mm2, DynamicConfig, PlacementReport,
};
use moepim::workload::{
    report, run_virtual, run_virtual_dynamic, AdmissionPolicy,
    ArrivalProcess, PlacementPolicy, ShardedDriver, ShardedRun,
    SizeModel, VirtualConfig, WorkloadSpec,
};

/// The contested workload: tight bursts with a Zipf-skewed routing
/// stream, so one expert group's home shard becomes a hot spot the
/// static split can do nothing about.
fn skewed_spec(seed: u64) -> WorkloadSpec {
    WorkloadSpec {
        seed,
        requests: 48,
        arrival: ArrivalProcess::Bursty {
            rate_rps: 4_000.0,
            mean_on_ms: 5.0,
            mean_off_ms: 20.0,
        },
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 2.0,
            prompt: (4, 48),
            gen: (1, 24),
        },
        slo_e2e_ms: 150.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

fn skewed_cfg() -> VirtualConfig {
    VirtualConfig { route_skew: 2.0, ..VirtualConfig::default() }
}

/// Seeds the comparative pins scan: per-seed structural invariants must
/// hold on every one, and the strict performance wins must show up on
/// at least one (the loop is a statistical optimization, not a per-seed
/// guarantee).
const SEEDS: [u64; 5] = [7, 11, 13, 29, 2026];

fn p99(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(f64::total_cmp);
    if xs.is_empty() {
        0.0
    } else {
        xs[((xs.len() - 1) as f64 * 0.99).round() as usize]
    }
}

fn merged_ttft_p99(run: &ShardedRun) -> f64 {
    p99(run
        .shards
        .iter()
        .flat_map(|s| s.outcome.samples.iter())
        .filter_map(|s| s.ttft_us)
        .collect())
}

fn merged_e2e_p99(run: &ShardedRun) -> f64 {
    p99(run
        .shards
        .iter()
        .flat_map(|s| s.outcome.samples.iter())
        .map(|s| s.e2e_us)
        .collect())
}

/// Per-shard served-request spread: max minus min — the flatness of the
/// split in request counts.
fn request_spread(run: &ShardedRun) -> usize {
    let counts: Vec<usize> =
        run.shards.iter().map(|s| s.requests).collect();
    counts.iter().max().unwrap() - counts.iter().min().unwrap()
}

fn static_route_aware(seed: u64) -> ShardedRun {
    let cfg = skewed_cfg();
    let driver =
        ShardedDriver::new(3, PlacementPolicy::route_aware(&cfg));
    driver.run_virtual(&cfg, &skewed_spec(seed), AdmissionPolicy::fifo())
}

fn dynamic(seed: u64, budget_mm2: f64) -> (ShardedRun, PlacementReport) {
    let cfg = skewed_cfg();
    let cfgs = vec![cfg.clone(); 3];
    let dcfg = DynamicConfig::from_virtual(&cfg, 4, budget_mm2);
    run_virtual_dynamic(&cfgs, &skewed_spec(seed),
                        AdmissionPolicy::fifo(), &dcfg)
}

/// The headline acceptance pin: against the same seeds, the dynamic
/// loop (migration only, no replication budget) must never worsen the
/// imbalance it measured, and on at least one seed it must strictly
/// beat static route-aware on merged p99 TTFT *and* split flatness.
#[test]
fn dynamic_beats_static_route_aware_on_a_skewed_burst() {
    let mut strict_win = false;
    for seed in SEEDS {
        let stat = static_route_aware(seed);
        let (dynr, pr) = dynamic(seed, 0.0);
        // structural, every seed: a rebalance pass never increases the
        // spread it measured, and all 48 requests still terminate
        assert!(
            pr.imbalance_after <= pr.imbalance_before + 1e-9,
            "seed {seed}: rebalance worsened imbalance \
             ({} -> {})",
            pr.imbalance_before, pr.imbalance_after
        );
        let served: usize =
            dynr.shards.iter().map(|s| s.outcome.samples.len()).sum();
        assert_eq!(served, 48, "seed {seed}: lost requests");
        assert_eq!(pr.replicas, 0, "seed {seed}: zero budget replicated");
        strict_win |= merged_ttft_p99(&dynr) < merged_ttft_p99(&stat)
            && request_spread(&dynr) < request_spread(&stat);
    }
    assert!(
        strict_win,
        "dynamic placement never strictly beat static route-aware \
         (p99 TTFT and request spread) on any scanned seed"
    );
}

/// Replication on top of migration: the budget buys hot-group replicas
/// (priced on the paper chip), `area_mm2_delta` stays within budget on
/// every seed, and on at least one seed the replicated run strictly
/// improves a merged tail latency over migration-only.
#[test]
fn replication_improves_on_migration_within_budget() {
    // ~85.3 mm² per group replica at g=2 on the paper chip: 100 mm²
    // buys exactly one
    const BUDGET: f64 = 100.0;
    let mut replicated_somewhere = false;
    let mut strict_win = false;
    for seed in SEEDS {
        let (base, _) = dynamic(seed, 0.0);
        let (repl, pr) = dynamic(seed, BUDGET);
        assert!(
            pr.area_mm2_delta <= BUDGET + 1e-9,
            "seed {seed}: ledger overspent ({} mm2)", pr.area_mm2_delta
        );
        assert!(pr.replicas <= 1, "seed {seed}: budget buys one replica");
        let served: usize =
            repl.shards.iter().map(|s| s.outcome.samples.len()).sum();
        assert_eq!(served, 48, "seed {seed}: lost requests");
        replicated_somewhere |= pr.replicas > 0;
        strict_win |= pr.replicas > 0
            && (merged_ttft_p99(&repl) < merged_ttft_p99(&base)
                || merged_e2e_p99(&repl) < merged_e2e_p99(&base));
    }
    assert!(replicated_somewhere, "the budget never bought a replica");
    assert!(
        strict_win,
        "replication never strictly improved a merged tail latency \
         over migration-only on any scanned seed"
    );
}

/// Replica routing is part of the deterministic state: same seed, same
/// budget → the same replicas, the same migrations, the same samples.
#[test]
fn replication_is_deterministic_per_seed() {
    for seed in SEEDS {
        let (run_a, pr_a) = dynamic(seed, 100.0);
        let (run_b, pr_b) = dynamic(seed, 100.0);
        assert_eq!(pr_a, pr_b, "seed {seed}: placement report diverged");
        assert_eq!(run_a, run_b, "seed {seed}: run diverged");
    }
}

/// The v2 report — placement block included — is byte-identical across
/// reruns per seed, and always carries the control loop's counters.
#[test]
fn dynamic_v2_report_is_byte_identical_per_seed() {
    let policy = AdmissionPolicy::fifo();
    for seed in [11, 2026] {
        let spec = skewed_spec(seed);
        let (run_a, pr_a) = dynamic(seed, 100.0);
        let (run_b, pr_b) = dynamic(seed, 100.0);
        let a = report::build_sharded_placed(&spec, policy, 3, "dynamic",
                                             &run_a, &pr_a)
            .to_string_pretty();
        let b = report::build_sharded_placed(&spec, policy, 3, "dynamic",
                                             &run_b, &pr_b)
            .to_string_pretty();
        assert_eq!(a, b, "seed {seed}: report not byte-identical");
        for key in ["\"placement\"", "\"migrations\"", "\"replicas\"",
                    "\"area_mm2_delta\"", "\"imbalance_before\"",
                    "\"imbalance_after\"", "\"checkpoint_spill_mm2\""] {
            assert!(a.contains(key), "report misses {key}");
        }
    }
}

/// The checkpoint store's area side-channel: a QoS run that provably
/// preempts must surface a non-zero checkpoint high-water mark, the
/// control run without QoS must not, and the report prices the
/// beyond-one-slot excess linearly on the paper chip.
#[test]
fn checkpoint_spill_prices_the_preemption_store() {
    // the batch-saturation shape from the QoS pin suite: 4 batch
    // requests fill every slot at t=0, interactive arrivals then force
    // preemptions under the deadline policy
    let spec = WorkloadSpec {
        seed: 0x9105,
        requests: 20,
        arrival: ArrivalProcess::Replay {
            times_us: (0..20u64)
                .map(|i| if i < 4 { 0 } else { (i - 3) * 400 })
                .collect(),
        },
        sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 64 },
        slo_e2e_ms: 250.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 0.2,
    };
    let policy = AdmissionPolicy::deadline();
    let qos = run_virtual(
        &VirtualConfig { qos: true, ..VirtualConfig::default() },
        &spec, policy,
    );
    let control = run_virtual(&VirtualConfig::default(), &spec, policy);
    assert!(qos.preemptions >= 1, "saturated slots never preempted");
    assert!(
        qos.peak_checkpoints >= 1,
        "preemptions fired but no checkpoint was ever held"
    );
    assert_eq!(control.peak_checkpoints, 0, "no-QoS run held checkpoints");
    // linear paper-chip pricing, first snapshot free
    assert_eq!(checkpoint_spill_mm2(0), 0.0);
    assert_eq!(checkpoint_spill_mm2(1), 0.0);
    let per = checkpoint_spill_mm2(2);
    assert!(per > 0.0);
    let spill = checkpoint_spill_mm2(qos.peak_checkpoints);
    assert!(
        (spill
            - qos.peak_checkpoints.saturating_sub(1) as f64 * per)
            .abs()
            < 1e-9
    );
    // and the v1 report carries both the counter and its pricing
    let doc = report::build(&spec, policy, &qos).to_string_pretty();
    assert!(doc.contains("\"peak_checkpoints\""));
    assert!(doc.contains("\"checkpoint_spill_mm2\""));
}

/// Heterogeneous fleets: with capacity-weighted comparison the big
/// backend must absorb the largest share of a skewed burst — summed
/// over the seed scan so one unlucky burst shape can't flip the pin.
#[test]
fn capacity_weighting_loads_the_big_shard_most() {
    let mut served = [0usize; 3];
    for seed in SEEDS {
        let base = skewed_cfg();
        let cfgs = vec![
            VirtualConfig { slots: 2, ..base.clone() },
            VirtualConfig { slots: 6, ..base.clone() },
            VirtualConfig { slots: 2, ..base.clone() },
        ];
        let dcfg = DynamicConfig::from_virtual(&base, 4, 0.0);
        let (run, _) = run_virtual_dynamic(
            &cfgs, &skewed_spec(seed), AdmissionPolicy::fifo(), &dcfg);
        for (i, s) in run.shards.iter().enumerate() {
            served[i] += s.outcome.samples.len();
        }
    }
    assert_eq!(served.iter().sum::<usize>(), 48 * SEEDS.len());
    assert!(
        served[1] > served[0] && served[1] > served[2],
        "the 6-slot shard did not absorb the largest share: {served:?}"
    );
}
