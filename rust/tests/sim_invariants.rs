//! Simulator-level invariants across random configurations: cache-regime
//! orderings, monotonicity, determinism, and breakdown consistency.

use moepim::config::{
    CachePolicy, GroupingPolicy, RoutingMode, SchedulePolicy, SimConfig,
};
use moepim::sim::Simulator;
use moepim::util::prop::{self, Gen};

fn random_cfg(g: &mut Gen) -> SimConfig {
    let mut cfg = SimConfig::baseline();
    cfg.group_size = *[1usize, 2, 4].get(g.usize(3)).unwrap();
    cfg.grouping = match g.usize(2) {
        0 => GroupingPolicy::Uniform,
        _ => GroupingPolicy::Sorted,
    };
    cfg.schedule = match g.usize(3) {
        0 => SchedulePolicy::TokenWise,
        1 => SchedulePolicy::Compact,
        _ => SchedulePolicy::Reschedule,
    };
    cfg.prompt_len = g.size(8, 48).max(8);
    cfg.gen_len = g.size(1, 16).max(1);
    cfg.skew = g.f64() * 1.5;
    cfg.seed = g.case_seed;
    cfg
}

#[test]
fn cache_regime_latency_ordering() {
    prop::check(40, |g| {
        let base = random_cfg(g);
        let run = |cache: CachePolicy| {
            let mut c = base.clone();
            c.cache = cache;
            Simulator::paper(c).run().decode_total()
        };
        let none = run(CachePolicy::NONE);
        let kv = run(CachePolicy::KV);
        let go = run(CachePolicy::GO);
        let kvgo = run(CachePolicy::KVGO);
        assert!(kvgo.latency_ns <= kv.latency_ns * 1.0001);
        assert!(kvgo.latency_ns <= go.latency_ns * 1.0001);
        assert!(kv.latency_ns <= none.latency_ns * 1.0001);
        assert!(go.latency_ns <= none.latency_ns * 1.0001);
        assert!(kvgo.energy_nj <= none.energy_nj * 1.0001);
    });
}

#[test]
fn totals_equal_breakdown_sums() {
    prop::check(40, |g| {
        let mut cfg = random_cfg(g);
        cfg.cache = CachePolicy::KVGO;
        let r = Simulator::paper(cfg).run();
        for (i, s) in
            std::iter::once(&r.prefill).chain(&r.decode_steps).enumerate()
        {
            let b = &s.breakdown;
            let lat = b.attn_ns + b.gate_ns + b.moe_ns + b.dram_ns;
            let nrg = b.attn_nj + b.gate_nj + b.moe_nj + b.dram_nj;
            assert!((lat - s.latency_ns).abs() < 1e-6, "stage {i} latency");
            assert!((nrg - s.energy_nj).abs() < 1e-6, "stage {i} energy");
        }
    });
}

#[test]
fn decode_cost_monotone_in_gen_len() {
    prop::check(30, |g| {
        let mut a = random_cfg(g);
        a.gen_len = g.size(1, 8).max(1);
        let mut b = a.clone();
        b.gen_len = a.gen_len + g.size(1, 16).max(1);
        let ra = Simulator::paper(a).run();
        let rb = Simulator::paper(b).run();
        assert!(rb.decode_total().latency_ns > ra.decode_total().latency_ns);
        assert!(rb.decode_total().energy_nj > ra.decode_total().energy_nj);
    });
}

#[test]
fn runs_are_deterministic() {
    prop::check(20, |g| {
        let cfg = random_cfg(g);
        let a = Simulator::paper(cfg.clone()).run();
        let b = Simulator::paper(cfg).run();
        assert_eq!(a.total(), b.total());
        assert_eq!(a.moe_area_mm2, b.moe_area_mm2);
    });
}

#[test]
fn area_independent_of_schedule_and_cache() {
    prop::check(30, |g| {
        let a = random_cfg(g);
        let mut b = a.clone();
        b.schedule = SchedulePolicy::Compact;
        b.cache = CachePolicy::KVGO;
        let ra = Simulator::paper(a).run();
        let rb = Simulator::paper(b).run();
        assert_eq!(ra.moe_area_mm2, rb.moe_area_mm2);
    });
}

#[test]
fn all_metrics_finite_and_positive() {
    prop::check(60, |g| {
        let mut cfg = random_cfg(g);
        cfg.routing = if g.bool(0.5) {
            RoutingMode::ExpertChoice
        } else {
            RoutingMode::TokenChoice
        };
        cfg.cache = *[CachePolicy::NONE, CachePolicy::KV, CachePolicy::GO,
                      CachePolicy::KVGO]
            .get(g.usize(4))
            .unwrap();
        let r = Simulator::paper(cfg).run();
        let t = r.total();
        assert!(t.latency_ns.is_finite() && t.latency_ns > 0.0);
        assert!(t.energy_nj.is_finite() && t.energy_nj > 0.0);
        assert!(t.macs > 0);
        assert!(r.density().is_finite() && r.density() > 0.0);
        assert!(r.gops_per_mm2().is_finite());
    });
}

#[test]
fn sharing_never_increases_prefill_energy_much() {
    // sharing changes transfers, not activations: MoE prefill energy moves
    // only by the broadcast term
    prop::check(30, |g| {
        let mut a = random_cfg(g);
        a.group_size = 1;
        a.grouping = GroupingPolicy::None;
        a.schedule = SchedulePolicy::TokenWise;
        let mut b = a.clone();
        b.group_size = 4;
        b.grouping = GroupingPolicy::Sorted;
        b.schedule = SchedulePolicy::Compact;
        let ra = Simulator::paper(a).run();
        let rb = Simulator::paper(b).run();
        assert_eq!(ra.prefill.activations, rb.prefill.activations);
        let moe_a = ra.prefill.breakdown.moe_nj;
        let moe_b = rb.prefill.breakdown.moe_nj;
        assert!(moe_b < moe_a * 1.15,
                "broadcast overhead bounded: {moe_a} -> {moe_b}");
    });
}
