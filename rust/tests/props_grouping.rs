//! Property tests for expert grouping: partition validity for every policy
//! and the statistical guarantee of §III-B — workload-sorted grouping
//! balances group loads at least as well as random assignment.

use moepim::grouping::{stats, Grouping};
use moepim::moe::TraceGenerator;
use moepim::util::prop::{self, Gen};
use moepim::util::rng::Pcg32;

fn loads(g: &mut Gen, e: usize) -> Vec<f64> {
    let mut rng = Pcg32::new(g.case_seed ^ 0x10AD);
    (0..e).map(|_| rng.gen_f64() * 100.0).collect()
}

#[test]
fn every_policy_partitions_experts() {
    prop::check(150, |g| {
        let e = *[4usize, 8, 16, 32].get(g.usize(4)).unwrap();
        let gs = *[1usize, 2, 4].get(g.usize(3)).unwrap();
        let gs = if e % gs == 0 { gs } else { 1 };
        let l = loads(g, e);
        for grouping in [
            Grouping::singleton(e),
            Grouping::uniform(e, gs, g.case_seed),
            Grouping::sorted(&l, gs),
        ] {
            let mut all: Vec<usize> = grouping.groups.concat();
            all.sort_unstable();
            assert_eq!(all, (0..e).collect::<Vec<_>>());
            for (x, &gi) in grouping.group_of.iter().enumerate() {
                assert!(grouping.groups[gi].contains(&x));
            }
        }
    });
}

#[test]
fn sorted_groups_have_equal_size() {
    prop::check(100, |g| {
        let e = 16;
        let gs = *[2usize, 4, 8].get(g.usize(3)).unwrap();
        let grouping = Grouping::sorted(&loads(g, e), gs);
        assert_eq!(grouping.n_groups(), e / gs);
        for grp in &grouping.groups {
            assert_eq!(grp.len(), gs);
        }
    });
}

#[test]
fn sorted_imbalance_not_worse_than_uniform_mean() {
    // averaged over several uniform seeds, sorted grouping's max/mean group
    // load must be at least as good — the §III-B claim
    prop::check(60, |g| {
        let e = 16;
        let gs = *[2usize, 4].get(g.usize(2)).unwrap();
        let l = loads(g, e);
        let sorted = Grouping::sorted(&l, gs).imbalance(&l);
        let mut uni_sum = 0.0;
        let trials = 16;
        for s in 0..trials {
            uni_sum +=
                Grouping::uniform(e, gs, g.case_seed ^ s).imbalance(&l);
        }
        let uni_mean = uni_sum / trials as f64;
        assert!(
            sorted <= uni_mean + 1e-9,
            "sorted {sorted:.4} vs uniform mean {uni_mean:.4} (g={gs})"
        );
    });
}

#[test]
fn sorted_pairing_is_optimal_for_two() {
    // for g=2 the lowest-with-highest pairing minimises the max pair sum
    // (classic two-partition result); verify against brute force on small E
    prop::check(40, |g| {
        let e = 6;
        let l = loads(g, e);
        let sorted = Grouping::sorted(&l, 2);
        let best = brute_force_best_pairing(&l);
        let got = sorted
            .group_loads(&l)
            .into_iter()
            .fold(f64::MIN, f64::max);
        assert!(
            got <= best + 1e-9,
            "sorted pairing max {got:.4} vs optimal {best:.4}"
        );
    });
}

fn brute_force_best_pairing(loads: &[f64]) -> f64 {
    // minimal possible max-pair-sum over all perfect matchings of 6 items
    let idx: Vec<usize> = (0..loads.len()).collect();
    let mut best = f64::MAX;
    fn rec(rem: Vec<usize>, cur_max: f64, loads: &[f64], best: &mut f64) {
        if rem.is_empty() {
            *best = best.min(cur_max);
            return;
        }
        let a = rem[0];
        for i in 1..rem.len() {
            let b = rem[i];
            let pair = loads[a] + loads[b];
            let mut next = rem.clone();
            next.remove(i);
            next.remove(0);
            rec(next, cur_max.max(pair), loads, best);
        }
    }
    rec(idx, f64::MIN, loads, &mut best);
    best
}

#[test]
fn trace_based_calibration_feeds_sorted_grouping() {
    prop::check(30, |g| {
        let e = 16;
        let mut tg = TraceGenerator::new(e, g.case_seed);
        let traces: Vec<_> =
            (0..4).map(|_| tg.token_choice_zipf(64, 4, 1.0)).collect();
        let l = stats::mean_loads(&traces);
        assert_eq!(l.len(), e);
        let total: f64 = l.iter().sum();
        assert!((total - 256.0).abs() < 1e-6); // 64 tokens * k=4
        // grouping on these loads is a valid partition
        let grouping = Grouping::sorted(&l, 2);
        assert_eq!(grouping.n_groups(), 8);
    });
}

#[test]
fn load_cv_detects_imbalance() {
    prop::check(60, |g| {
        let e = 8;
        let mut tg = TraceGenerator::new(e, g.case_seed);
        let balanced = tg.expert_choice(32, 8, 1.0);
        let skewed = tg.token_choice_zipf(256, 4, 1.5);
        let cv_b = stats::load_cv(&stats::loads_of(&balanced));
        let cv_s = stats::load_cv(&stats::loads_of(&skewed));
        assert!(cv_b < 1e-9, "expert-choice is exactly balanced");
        assert!(cv_s > 0.1, "zipf token-choice must be imbalanced");
    });
}
