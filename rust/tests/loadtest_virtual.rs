//! End-to-end loadtest pins on the virtual-time cluster (no artifacts
//! needed): byte-identical report determinism across the acceptance
//! matrix (two arrival processes × two admission policies), FIFO
//! admit-order preservation, SJF's reorder-but-don't-starve contract
//! under the closed-loop driver, and policy-independent traffic
//! materialization.

use moepim::workload::report;
use moepim::workload::{
    run_virtual, AdmissionPolicy, ArrivalProcess, Sample, SizeModel,
    VirtualConfig, WorkloadSpec,
};

fn open_spec(arrival: ArrivalProcess) -> WorkloadSpec {
    WorkloadSpec {
        seed: 0xBEEF,
        requests: 48,
        arrival,
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

fn render(spec: &WorkloadSpec, policy: AdmissionPolicy) -> String {
    let out = run_virtual(&VirtualConfig::default(), spec, policy);
    report::build(spec, policy, &out).to_string_pretty()
}

#[test]
fn reports_are_byte_identical_across_reruns() {
    // the acceptance matrix: 2 arrival processes x 2 admission policies
    let processes = [
        ArrivalProcess::Poisson { rate_rps: 400.0 },
        ArrivalProcess::Bursty {
            rate_rps: 1200.0,
            mean_on_ms: 10.0,
            mean_off_ms: 30.0,
        },
    ];
    for arrival in processes {
        for policy in [AdmissionPolicy::fifo(), AdmissionPolicy::sjf()] {
            let spec = open_spec(arrival.clone());
            let a = render(&spec, policy);
            let b = render(&spec, policy);
            assert_eq!(
                a,
                b,
                "report not byte-identical: {} x {}",
                arrival.label(),
                policy.label()
            );
            // and it is real JSON with the headline metrics
            let parsed = moepim::util::json::parse(&a).expect("valid JSON");
            assert_eq!(
                parsed.path(&["workload", "policy"]).unwrap().as_str(),
                Some(policy.label())
            );
            assert!(parsed.path(&["latency_us", "e2e", "p99"]).is_some());
            assert!(parsed.path(&["slo", "attainment"]).is_some());
            assert!(parsed
                .path(&["throughput", "tokens_per_s"])
                .is_some());
            assert!(parsed
                .path(&["planner", "contention_ratio"])
                .is_some());
        }
    }
}

#[test]
fn different_seeds_give_different_reports() {
    let a = open_spec(ArrivalProcess::Poisson { rate_rps: 400.0 });
    let b = WorkloadSpec { seed: 0xD00D, ..a.clone() };
    assert_ne!(
        render(&a, AdmissionPolicy::fifo()),
        render(&b, AdmissionPolicy::fifo())
    );
}

/// submit-order vs admit-order inversions: pairs where a later-submitted
/// request was admitted earlier.
fn inversions(samples: &[Sample]) -> usize {
    let mut admitted: Vec<(u64, u64)> = samples
        .iter()
        .filter_map(|s| s.admit_seq.map(|a| (s.submit_seq, a)))
        .collect();
    admitted.sort_unstable();
    let mut count = 0;
    for (i, a) in admitted.iter().enumerate() {
        for b in admitted.iter().skip(i + 1) {
            if a.1 > b.1 {
                count += 1;
            }
        }
    }
    count
}

#[test]
fn sjf_reorders_but_never_starves_fifo_never_reorders() {
    // closed loop with more users than slots keeps a queue standing, so
    // the policies actually get choices to make
    let cfg = VirtualConfig { slots: 2, ..VirtualConfig::default() };
    let spec = WorkloadSpec {
        seed: 0xC10C,
        requests: 30,
        arrival: ArrivalProcess::Closed { users: 6, think_ms: 0.0 },
        sizes: SizeModel::Uniform { prompt: (4, 8), gen: (1, 16) },
        slo_e2e_ms: 100.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    };

    let fifo = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
    assert_eq!(fifo.samples.len(), 30);
    assert!(fifo.samples.iter().all(|s| s.ok));
    assert_eq!(
        inversions(&fifo.samples),
        0,
        "FIFO must preserve submit order"
    );

    let sjf = run_virtual(&cfg, &spec, AdmissionPolicy::sjf());
    // no starvation: every request (long ones included) reaches a
    // terminal Ok — the starvation guard bounds how often a job can be
    // passed over
    assert_eq!(sjf.samples.len(), 30, "a request starved");
    assert!(sjf.samples.iter().all(|s| s.ok));
    assert!(sjf.samples.iter().all(|s| s.admit_seq.is_some()));
    // but SJF genuinely reorders: some shorter job overtook an earlier
    // longer one
    assert!(
        inversions(&sjf.samples) > 0,
        "SJF never exercised its ordering under a standing queue"
    );

    // identical traffic reached both policies: same ids, same sizes
    let mut fifo_ids: Vec<u64> = fifo.samples.iter().map(|s| s.id).collect();
    let mut sjf_ids: Vec<u64> = sjf.samples.iter().map(|s| s.id).collect();
    fifo_ids.sort_unstable();
    sjf_ids.sort_unstable();
    assert_eq!(fifo_ids, sjf_ids);
}

#[test]
fn edf_completes_everything_and_reports_under_pressure() {
    // sanity rather than a strong claim: EDF runs, completes everything,
    // and produces a valid report under the same standing-queue pressure
    let cfg = VirtualConfig { slots: 2, ..VirtualConfig::default() };
    let spec = WorkloadSpec {
        seed: 0xEDF0,
        requests: 30,
        arrival: ArrivalProcess::Closed { users: 6, think_ms: 0.0 },
        sizes: SizeModel::Uniform { prompt: (4, 8), gen: (1, 16) },
        slo_e2e_ms: 100.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    };
    let edf = run_virtual(&cfg, &spec, AdmissionPolicy::deadline());
    assert_eq!(edf.samples.len(), 30);
    assert!(edf.samples.iter().all(|s| s.ok));
    let doc = report::build(&spec, AdmissionPolicy::deadline(), &edf);
    let s = doc.to_string_pretty();
    assert!(moepim::util::json::parse(&s).is_ok());
}

/// The tentpole's virtual pin: a bursty arrival of long prompts — the
/// ROADMAP head-of-line regime — must show strictly lower queue-p99 with
/// chunking on vs off at the same seed.
///
/// Mechanism: monolithic admission charges every granted request's whole
/// prefill to the engine clock *inside the admission pass*, so when a
/// burst refills several slots at once, the 2nd..Nth grants (and every
/// later completion) wait out the earlier lumps; chunked admission grants
/// all free slots at the same instant and spreads the identical linear
/// prefill cost across subsequent cycles, interleaved with decode.
///
/// The burst rides a `Replay` timeline (12 long prompts, 50 µs apart,
/// hitting an idle cluster) rather than the exponential on/off process:
/// the pin needs the last burst request to be a multi-grant "lump
/// victim" *structurally*, and exponential window draws make the burst
/// shape a seed lottery.  The p99 margin here is ~35% (≈4 histogram
/// buckets), far above the log-bucket width, and was cross-checked
/// against a python mirror of this event loop under both optimistic and
/// fully-serial planner cost models.
#[test]
fn chunked_prefill_improves_bursty_queue_p99() {
    let spec = WorkloadSpec {
        seed: 25,
        requests: 12,
        arrival: ArrivalProcess::Replay {
            times_us: (0..12u64).map(|k| k * 50).collect(),
        },
        // long prompts, short generations: prefill dominates slot
        // residency, which is exactly where head-of-line blocking bites
        sizes: SizeModel::Uniform { prompt: (48, 80), gen: (1, 2) },
        slo_e2e_ms: 250.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    };
    // a prefill-heavy chip (30 µs/token) in both runs — the comparison
    // turns exactly one knob, the chunk budget
    let mono_cfg = VirtualConfig {
        prefill_ns_per_token: 30_000,
        ..VirtualConfig::default()
    };
    let chunk_cfg = VirtualConfig {
        prefill_chunk: 16,
        ..mono_cfg.clone()
    };
    let mono = run_virtual(&mono_cfg, &spec, AdmissionPolicy::fifo());
    let chunked = run_virtual(&chunk_cfg, &spec, AdmissionPolicy::fifo());
    assert_eq!(mono.samples.len(), 12);
    assert_eq!(chunked.samples.len(), 12);
    assert!(mono.samples.iter().all(|s| s.ok));
    assert!(chunked.samples.iter().all(|s| s.ok));
    assert_eq!(mono.prefill_chunks, 0);
    assert!(chunked.prefill_chunks > 0);

    let mono_q = report::summarize(&spec, &mono).queue;
    let chunk_q = report::summarize(&spec, &chunked).queue;
    assert!(
        chunk_q.quantile(0.99) < mono_q.quantile(0.99),
        "queue p99 must strictly improve with chunking: chunked {} >= \
         monolithic {}",
        chunk_q.quantile(0.99),
        mono_q.quantile(0.99)
    );
    assert!(
        chunk_q.mean_us() < mono_q.mean_us(),
        "mean queue must improve with chunking: chunked {} >= \
         monolithic {}",
        chunk_q.mean_us(),
        mono_q.mean_us()
    );
    // the win reshapes waiting, it doesn't shrink the work: the chunked
    // makespan stays in the same ballpark (bounded per-cycle overhead)
    assert!(chunked.duration_s <= mono.duration_s * 1.5);

    // and on the exponential-window bursty process itself (the shape the
    // ROADMAP item named): at this seed the burst structure makes the
    // tail request a lump victim with a ~4-bucket p99 margin under both
    // bracketing planner cost models of the mirror study, and the mean
    // improves too (many grants in every refill pass are lump victims)
    let bursty = WorkloadSpec {
        seed: 351,
        requests: 48,
        arrival: ArrivalProcess::Bursty {
            rate_rps: 3_000.0,
            mean_on_ms: 4.0,
            mean_off_ms: 20.0,
        },
        ..spec.clone()
    };
    let b_mono_cfg = VirtualConfig {
        prefill_ns_per_token: 20_000,
        ..VirtualConfig::default()
    };
    let b_chunk_cfg = VirtualConfig {
        prefill_chunk: 16,
        ..b_mono_cfg.clone()
    };
    let b_mono = run_virtual(&b_mono_cfg, &bursty, AdmissionPolicy::fifo());
    let b_chunked =
        run_virtual(&b_chunk_cfg, &bursty, AdmissionPolicy::fifo());
    assert!(b_mono.samples.iter().all(|s| s.ok));
    assert!(b_chunked.samples.iter().all(|s| s.ok));
    let bm = report::summarize(&bursty, &b_mono).queue;
    let bc = report::summarize(&bursty, &b_chunked).queue;
    assert!(
        bc.quantile(0.99) < bm.quantile(0.99),
        "bursty queue p99 must strictly improve with chunking: chunked \
         {} >= monolithic {}",
        bc.quantile(0.99),
        bm.quantile(0.99)
    );
    assert!(
        bc.mean_us() < bm.mean_us() * 0.97,
        "bursty mean queue must improve with chunking: chunked {} vs \
         monolithic {}",
        bc.mean_us(),
        bm.mean_us()
    );
    assert!(b_chunked.duration_s <= b_mono.duration_s * 1.5);
}

/// The QoS tentpole's virtual pin (DESIGN.md §Preemption & QoS): on a
/// seeded two-tier workload where batch-tier requests saturate every
/// slot, interactive p99 TTFT must meet a tight SLO *with* preemption
/// and demonstrably violate it *without* — same spec, same seed, the
/// only knob turned is `qos`.
///
/// Shape: 4 batch requests land at t=0 and fill all 4 default slots
/// with 64-token generations (each slot busy for tens of decode cycles,
/// ≥ 25 µs dispatch overhead per cycle ⇒ well over 1.5 ms of residency);
/// 16 more requests arrive every 400 µs, of which ids 4/9/14/19 are
/// interactive (mix 0.2).  Without preemption an interactive arrival
/// waits out a whole batch generation before its first token; with it,
/// the wait is one checkpoint (100 µs modeled) plus its own prefill.
/// 800 µs sits between those regimes with a comfortable margin on both
/// sides.
#[test]
fn qos_preemption_pins_interactive_ttft_under_batch_saturation() {
    const TTFT_SLO_US: f64 = 800.0;
    let spec = WorkloadSpec {
        seed: 0x9105,
        requests: 20,
        arrival: ArrivalProcess::Replay {
            times_us: (0..20u64)
                .map(|i| if i < 4 { 0 } else { (i - 3) * 400 })
                .collect(),
        },
        sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 64 },
        slo_e2e_ms: 250.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 0.2,
    };
    let policy = AdmissionPolicy::deadline();
    let qos_cfg = VirtualConfig { qos: true, ..VirtualConfig::default() };
    let out = run_virtual(&qos_cfg, &spec, policy);
    let control =
        run_virtual(&VirtualConfig::default(), &spec, policy);

    // both tiers fully served either way: QoS reshapes waiting, it never
    // drops work
    for (label, o) in [("qos", &out), ("control", &control)] {
        assert_eq!(o.samples.len(), 20, "{label}: lost replies");
        assert!(o.samples.iter().all(|s| s.ok), "{label}: a request failed");
    }
    assert!(out.preemptions >= 1, "saturated slots never preempted");
    assert_eq!(out.restores, out.preemptions);
    assert_eq!(control.preemptions, 0);

    let interactive_ttft = |o: &moepim::workload::LoadOutcome| -> Vec<f64> {
        let mut ts: Vec<f64> = o
            .samples
            .iter()
            .filter(|s| {
                moepim::workload::Priority::assign(s.id, 0.2)
                    == moepim::workload::Priority::Interactive
            })
            .map(|s| s.ttft_us.expect("interactive request decoded"))
            .collect();
        ts.sort_by(f64::total_cmp);
        ts
    };
    let qos_ttft = interactive_ttft(&out);
    let control_ttft = interactive_ttft(&control);
    assert_eq!(qos_ttft.len(), 4, "mix 0.2 over 20 ids → 4 interactive");
    // p99 over 4 samples is the max — use it directly
    let qos_p99 = *qos_ttft.last().unwrap();
    let control_p99 = *control_ttft.last().unwrap();
    assert!(
        qos_p99 <= TTFT_SLO_US,
        "interactive p99 TTFT misses SLO with preemption on: \
         {qos_p99:.0} µs > {TTFT_SLO_US} µs"
    );
    assert!(
        control_p99 > TTFT_SLO_US,
        "control must violate the SLO or the pin proves nothing: \
         {control_p99:.0} µs <= {TTFT_SLO_US} µs"
    );
    assert!(qos_p99 < control_p99);

    // checkpoint/restore is modeled work on the virtual clock: the
    // preempted run can never finish *earlier* than the untouched one
    assert!(
        out.duration_s >= control.duration_s,
        "preemption charged no cycles: {} < {}",
        out.duration_s,
        control.duration_s
    );

    // the v1 report over the two-tier run is byte-identical per seed
    let a = report::build(&spec, policy, &out).to_string_pretty();
    let b = report::build(&spec, policy, &run_virtual(&qos_cfg, &spec, policy))
        .to_string_pretty();
    assert_eq!(a, b, "two-tier report not byte-identical");
    let parsed = moepim::util::json::parse(&a).expect("valid JSON");
    assert_eq!(
        parsed.path(&["server", "preemptions"]).unwrap().as_f64(),
        Some(out.preemptions as f64)
    );
    assert_eq!(
        parsed.path(&["workload", "interactive_mix"]).unwrap().as_f64(),
        Some(0.2)
    );
}

#[test]
fn loadtest_counts_planner_layer_steps_per_decode_cycle() {
    // a depth-L virtual cluster prices every decode cycle as L planned
    // layer-steps, mirroring the real server's telemetry contract
    let spec = open_spec(ArrivalProcess::Poisson { rate_rps: 400.0 });
    for layers in [1usize, 3] {
        let cfg = VirtualConfig {
            n_layers: layers,
            ..VirtualConfig::default()
        };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        assert!(out.planner.steps > 0);
        assert_eq!(
            out.planner.steps % layers as u64,
            0,
            "steps must be a whole number of depth-{layers} cycles"
        );
    }
}
