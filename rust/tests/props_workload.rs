//! Property tests for the workload subsystem: the latency histogram's
//! quantile contract (monotonicity, merge == concat-then-build, bounded
//! relative bucket error), the sharded fan-out's merge contract (for any
//! seed / shard count / placement, merged quantiles track the
//! concatenated samples within the documented bucket error),
//! arrival-generator determinism and mean-rate convergence, and
//! admission-policy selection invariants.
//!
//! No artifacts needed — everything here is host-side math.

use moepim::util::prop;
use moepim::util::rng::Pcg32;
use moepim::workload::{
    report, shard, AdmissionPolicy, ArrivalProcess, LatencyHistogram,
    PlacementPolicy, QueuedMeta, ShardedDriver, SizeModel, VirtualConfig,
    WorkloadSpec,
};

// ---------------------------------------------------------------------------
// LatencyHistogram
// ---------------------------------------------------------------------------

#[test]
fn quantiles_are_monotone_in_q() {
    prop::check(200, |g| {
        let n = g.size(1, 400);
        let mut h = LatencyHistogram::new();
        for _ in 0..n {
            // heavy-tailed positive values spanning several octaves
            h.record(g.normal().abs() * 1e4 + g.f64());
        }
        let mut prev = 0.0f64;
        for k in 1..=50 {
            let q = k as f64 / 50.0;
            let v = h.quantile(q);
            assert!(
                v >= prev,
                "quantile not monotone: q={q} gave {v} after {prev}"
            );
            prev = v;
        }
        assert!(h.quantile(1.0) <= h.max_us() * 1.05 + 1e-9);
    });
}

#[test]
fn merge_equals_concat_then_build() {
    prop::check(150, |g| {
        let n1 = g.size(0, 200);
        let n2 = g.size(0, 200);
        let xs: Vec<f64> =
            (0..n1).map(|_| g.normal().abs() * 5e3).collect();
        let ys: Vec<f64> =
            (0..n2).map(|_| g.normal().abs() * 50.0).collect();
        let mut h1 = LatencyHistogram::new();
        let mut h2 = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for &v in &xs {
            h1.record(v);
            all.record(v);
        }
        for &v in &ys {
            h2.record(v);
            all.record(v);
        }
        h1.merge(&h2);
        assert_eq!(h1.count(), all.count());
        assert_eq!(h1.min_us(), all.min_us());
        assert_eq!(h1.max_us(), all.max_us());
        for k in 1..=25 {
            let q = k as f64 / 25.0;
            assert_eq!(h1.quantile(q), all.quantile(q), "q={q}");
        }
        let (m1, m2) = (h1.mean_us(), all.mean_us());
        assert!((m1 - m2).abs() <= m2.abs() * 1e-9 + 1e-9);
    });
}

#[test]
fn quantile_error_is_bounded_relative_to_exact() {
    let bound = LatencyHistogram::rel_error_bound() + 1e-9;
    prop::check(200, |g| {
        let n = g.size(1, 300);
        let mut vals: Vec<f64> = (0..n)
            .map(|_| g.normal().abs() * 2e4 + 1e-3)
            .collect();
        let mut h = LatencyHistogram::new();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = (g.usize(100) as f64 + 1.0) / 100.0;
        // identical rank rule on both sides: order statistic ceil(q·n)
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        let exact = vals[rank - 1];
        let approx = h.quantile(q);
        let err = (approx - exact).abs() / exact;
        assert!(
            err <= bound,
            "q={q} exact={exact} approx={approx} err={err} > {bound}"
        );
    });
}

/// For any seed and shard count, the shard-merged e2e histogram's
/// quantiles equal the exact order statistics of the concatenated
/// per-shard samples within the documented `2^(1/16) - 1` bucket error —
/// i.e. splitting an experiment across shards and merging loses nothing
/// beyond the histogram's own (bounded) bucketing.
#[test]
fn shard_merged_quantiles_match_concat_within_bucket_error() {
    let bound = LatencyHistogram::rel_error_bound() + 1e-9;
    let placements = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::least_outstanding(&VirtualConfig::default()),
        PlacementPolicy::SizeHash,
        PlacementPolicy::route_aware(&VirtualConfig::default()),
    ];
    prop::check(25, |g| {
        let seed = g.rng.next_u64();
        let shards = 1 + g.usize(8);
        let placement = placements[g.usize(placements.len())];
        let spec = WorkloadSpec {
            seed,
            requests: g.size(4, 64),
            arrival: ArrivalProcess::Poisson {
                rate_rps: 200.0 + g.f64() * 3000.0,
            },
            sizes: SizeModel::Uniform { prompt: (4, 16), gen: (1, 10) },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        };
        let run = ShardedDriver::new(shards, placement).run_virtual(
            &VirtualConfig::default(),
            &spec,
            AdmissionPolicy::fifo(),
        );
        let merged = shard::merge(&spec, &run.shards);

        // exact reference: every successful sample across all shards
        let mut all: Vec<f64> = run
            .shards
            .iter()
            .flat_map(|s| s.outcome.samples.iter())
            .filter(|x| x.ok)
            .map(|x| x.e2e_us)
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = all.len();
        assert_eq!(merged.summary.e2e.count(), n as u64);
        if n == 0 {
            return;
        }
        for k in 1..=20 {
            let q = k as f64 / 20.0;
            // identical rank rule on both sides: order statistic ceil(q·n)
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = all[rank - 1];
            let approx = merged.summary.e2e.quantile(q);
            if exact == 0.0 {
                assert_eq!(approx, 0.0, "q={q}");
            } else {
                let err = (approx - exact).abs() / exact;
                assert!(
                    err <= bound,
                    "{} x {shards} shards q={q}: exact={exact} \
                     approx={approx} err={err} > {bound}",
                    placement.label()
                );
            }
        }
        // and the merged histogram is exactly the concat-then-build one
        let mut concat = LatencyHistogram::new();
        for &v in &all {
            concat.record(v);
        }
        for k in 1..=20 {
            let q = k as f64 / 20.0;
            assert_eq!(
                merged.summary.e2e.quantile(q),
                concat.quantile(q),
                "merge != concat at q={q}"
            );
        }
        // sanity: per-shard summaries partition the merged counts
        let total: u64 = run
            .shards
            .iter()
            .map(|s| report::summarize(&spec, &s.outcome).completed)
            .sum();
        assert_eq!(merged.summary.completed, total);
    });
}

// ---------------------------------------------------------------------------
// Arrival generators
// ---------------------------------------------------------------------------

#[test]
fn arrival_timelines_are_seed_deterministic_and_monotone() {
    prop::check(60, |g| {
        let seed = g.rng.next_u64();
        let n = g.size(1, 300);
        for p in [
            ArrivalProcess::Poisson { rate_rps: 200.0 },
            ArrivalProcess::Bursty {
                rate_rps: 800.0,
                mean_on_ms: 10.0,
                mean_off_ms: 30.0,
            },
            ArrivalProcess::Replay { times_us: vec![0, 5, 11, 40] },
        ] {
            let a = p.times_ns(n, &mut Pcg32::new(seed));
            let b = p.times_ns(n, &mut Pcg32::new(seed));
            assert_eq!(a, b, "{} not deterministic", p.label());
            assert!(
                a.windows(2).all(|w| w[0] <= w[1]),
                "{} times not monotone",
                p.label()
            );
        }
    });
}

#[test]
fn poisson_mean_rate_converges() {
    prop::check(25, |g| {
        let rate = 50.0 + g.f64() * 1950.0;
        let n = g.size(200, 4000).max(1);
        let t = ArrivalProcess::Poisson { rate_rps: rate }
            .times_ns(n, &mut Pcg32::new(g.rng.next_u64()));
        let span_s = *t.last().unwrap() as f64 / 1e9;
        if span_s <= 0.0 {
            return; // degenerate shrunk case
        }
        let empirical = n as f64 / span_s;
        // mean of n exponentials: sigma ~ rate/sqrt(n); 5-sigma + slack
        let tol = 5.0 / (n as f64).sqrt() + 0.02;
        let rel = (empirical - rate).abs() / rate;
        assert!(
            rel <= tol,
            "rate {rate}: empirical {empirical} off by {rel} (> {tol}, n={n})"
        );
    });
}

#[test]
fn bursty_long_run_rate_is_duty_cycle_limited() {
    let p = ArrivalProcess::Bursty {
        rate_rps: 2000.0,
        mean_on_ms: 10.0,
        mean_off_ms: 30.0,
    };
    let n = 3000;
    let t = p.times_ns(n, &mut Pcg32::new(0xB0B));
    let span_s = *t.last().unwrap() as f64 / 1e9;
    let empirical = n as f64 / span_s;
    // duty cycle 10/(10+30) = 0.25 -> ~500 rps long-run; allow wide slack
    // but pin it well below the in-burst rate and above zero
    assert!(empirical < 1200.0, "empirical {empirical}");
    assert!(empirical > 100.0, "empirical {empirical}");
}

// ---------------------------------------------------------------------------
// Admission policies
// ---------------------------------------------------------------------------

fn random_queue(g: &mut prop::Gen, n: usize) -> Vec<QueuedMeta> {
    (0..n)
        .map(|_| QueuedMeta {
            gen_len: g.usize(64),
            deadline_us: if g.bool(0.7) {
                Some(g.usize(1_000_000) as u64)
            } else {
                None
            },
            waited_us: g.usize(1_000_000) as u64,
            // up to 12 so the starvation guard (limit 8) genuinely fires
            passed_over: g.usize(12) as u32,
        })
        .collect()
}

#[test]
fn policies_select_in_range_and_fifo_is_head() {
    prop::check(300, |g| {
        let n = g.size(1, 40);
        let q = random_queue(g, n);
        for policy in [
            AdmissionPolicy::fifo(),
            AdmissionPolicy::sjf(),
            AdmissionPolicy::deadline(),
        ] {
            let pick = policy.select(&q);
            assert!(pick < q.len(), "{} out of range", policy.label());
        }
        assert_eq!(AdmissionPolicy::fifo().select(&q), 0);
    });
}

#[test]
fn sjf_picks_a_minimal_job_unless_guard_fires() {
    prop::check(300, |g| {
        let n = g.size(1, 40);
        let q = random_queue(g, n);
        let limit = AdmissionPolicy::DEFAULT_STARVATION_LIMIT;
        let pick = AdmissionPolicy::sjf().select(&q);
        if q[0].passed_over >= limit {
            assert_eq!(pick, 0, "starvation guard must boost the head");
        } else {
            let min_gen = q.iter().map(|m| m.gen_len).min().unwrap();
            assert_eq!(q[pick].gen_len, min_gen);
            // stable: no earlier index has the same gen_len
            assert!(q[..pick].iter().all(|m| m.gen_len > min_gen));
        }
    });
}

#[test]
fn edf_picks_minimal_slack_unless_guard_fires() {
    prop::check(300, |g| {
        let n = g.size(1, 40);
        let q = random_queue(g, n);
        let limit = AdmissionPolicy::DEFAULT_STARVATION_LIMIT;
        let pick = AdmissionPolicy::deadline().select(&q);
        let slack = |m: &QueuedMeta| -> i64 {
            match m.deadline_us {
                Some(d) => d as i64 - m.waited_us as i64,
                None => i64::MAX,
            }
        };
        if q[0].passed_over >= limit {
            assert_eq!(pick, 0);
        } else {
            let min_slack = q.iter().map(slack).min().unwrap();
            assert_eq!(slack(&q[pick]), min_slack);
            assert!(q[..pick].iter().all(|m| slack(m) > min_slack));
        }
    });
}
