//! End-to-end pins for the sharded fan-out (no artifacts needed): merged
//! `moepim.slo_report.v2` documents are byte-identical per seed across
//! reruns for every shard count × placement policy, a 1-shard run
//! degenerates to the unsharded `loadtest` output (same samples, same
//! latency quantiles in the report), every request is served by exactly
//! one shard, and the per-shard/imbalance sections are self-consistent.

use moepim::util::json;
use moepim::workload::{
    report, run_virtual, run_virtual_live, shard, AdmissionPolicy,
    ArrivalProcess, PlacementPolicy, ShardedDriver, SizeModel,
    VirtualConfig, WorkloadSpec,
};

fn spec() -> WorkloadSpec {
    WorkloadSpec {
        seed: 0x5AAD,
        requests: 64,
        arrival: ArrivalProcess::Poisson { rate_rps: 2_000.0 },
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

fn placements() -> Vec<PlacementPolicy> {
    vec![
        PlacementPolicy::RoundRobin,
        PlacementPolicy::least_outstanding(&VirtualConfig::default()),
        PlacementPolicy::SizeHash,
        PlacementPolicy::route_aware(&VirtualConfig::default()),
    ]
}

fn render_sharded(spec: &WorkloadSpec, shards: usize,
                  placement: PlacementPolicy, policy: AdmissionPolicy)
    -> String {
    let cfg = VirtualConfig::default();
    let driver = ShardedDriver::new(shards, placement);
    let run = driver.run_virtual(&cfg, spec, policy);
    report::build_sharded(spec, policy, &driver, &run).to_string_pretty()
}

#[test]
fn merged_reports_are_byte_identical_across_reruns() {
    let spec = spec();
    for placement in placements() {
        for shards in [1usize, 2, 4, 8] {
            let a = render_sharded(&spec, shards, placement,
                                   AdmissionPolicy::sjf());
            let b = render_sharded(&spec, shards, placement,
                                   AdmissionPolicy::sjf());
            assert_eq!(
                a,
                b,
                "v2 report not byte-identical: {} x {} shards",
                placement.label(),
                shards
            );
            let parsed = json::parse(&a).expect("valid JSON");
            assert_eq!(
                parsed.path(&["schema"]).unwrap().as_str(),
                Some("moepim.slo_report.v2")
            );
            assert_eq!(
                parsed.path(&["workload", "shards"]).unwrap().as_usize(),
                Some(shards)
            );
            assert_eq!(
                parsed.path(&["workload", "placement"]).unwrap().as_str(),
                Some(placement.label())
            );
            assert_eq!(
                parsed.path(&["shards"]).unwrap().as_arr().unwrap().len(),
                shards
            );
            assert!(parsed.path(&["imbalance", "load_ratio"]).is_some());
            assert!(parsed
                .path(&["imbalance", "merged_p99_e2e_us"])
                .is_some());
        }
    }
}

#[test]
fn different_seeds_give_different_merged_reports() {
    let a = spec();
    let b = WorkloadSpec { seed: 0xD1FF, ..a.clone() };
    assert_ne!(
        render_sharded(&a, 4, PlacementPolicy::RoundRobin,
                       AdmissionPolicy::fifo()),
        render_sharded(&b, 4, PlacementPolicy::RoundRobin,
                       AdmissionPolicy::fifo()),
    );
}

/// The headline acceptance pin: a 1-shard fan-out is the unsharded
/// loadtest.  Sample-level equality first (the strongest statement), then
/// the report level: every latency quantile in the v2 document equals the
/// v1 document's value byte-for-byte.
#[test]
fn one_shard_reproduces_unsharded_loadtest_exactly() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    for policy in [
        AdmissionPolicy::fifo(),
        AdmissionPolicy::sjf(),
        AdmissionPolicy::deadline(),
    ] {
        let direct = run_virtual(&cfg, &spec, policy);
        let driver = ShardedDriver::new(1, PlacementPolicy::RoundRobin);
        let run = driver.run_virtual(&cfg, &spec, policy);
        assert_eq!(run.shards.len(), 1);
        assert_eq!(
            run.shards[0].outcome.samples, direct.samples,
            "1-shard sample stream diverged under {}",
            policy.label()
        );

        let v1 = report::build(&spec, policy, &direct).to_string_pretty();
        let v2 = report::build_sharded(&spec, policy, &driver, &run)
            .to_string_pretty();
        let v1 = json::parse(&v1).expect("v1 parses");
        let v2 = json::parse(&v2).expect("v2 parses");
        for hist in ["queue", "ttft", "e2e"] {
            for field in ["count", "mean", "min", "max", "p50", "p95", "p99"]
            {
                let path = ["latency_us", hist, field];
                assert_eq!(
                    v1.path(&path).unwrap().as_f64(),
                    v2.path(&path).unwrap().as_f64(),
                    "{policy:?}: latency_us.{hist}.{field} diverged"
                );
            }
        }
        for path in [
            ["slo", "attainment"],
            ["throughput", "duration_s"],
            ["throughput", "tokens_per_s"],
            ["counts", "completed"],
            ["counts", "tokens"],
            ["planner", "cycles"],
        ] {
            assert_eq!(
                v1.path(&path).unwrap().as_f64(),
                v2.path(&path).unwrap().as_f64(),
                "{policy:?}: {path:?} diverged"
            );
        }
    }
}

#[test]
fn every_request_is_served_by_exactly_one_shard() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    for placement in placements() {
        for shards in [2usize, 4, 8] {
            let run = ShardedDriver::new(shards, placement)
                .run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
            let mut ids: Vec<u64> = run
                .shards
                .iter()
                .flat_map(|s| s.outcome.samples.iter().map(|x| x.id))
                .collect();
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..spec.requests as u64).collect::<Vec<u64>>(),
                "{} x {} shards lost or duplicated a request",
                placement.label(),
                shards
            );
            for s in &run.shards {
                assert_eq!(s.outcome.samples.len(), s.requests);
                assert_eq!(s.outcome.shard, Some(s.shard));
            }
        }
    }
}

#[test]
fn sharded_closed_loop_completes_with_split_user_population() {
    let cfg = VirtualConfig { slots: 2, ..VirtualConfig::default() };
    let spec = WorkloadSpec {
        arrival: ArrivalProcess::Closed { users: 6, think_ms: 0.0 },
        requests: 48,
        ..spec()
    };
    for shards in [2usize, 4] {
        let driver =
            ShardedDriver::new(shards,
                               PlacementPolicy::least_outstanding(&cfg));
        let run = driver.run_virtual(&cfg, &spec, AdmissionPolicy::sjf());
        let total: usize =
            run.shards.iter().map(|s| s.outcome.samples.len()).sum();
        assert_eq!(total, spec.requests);
        assert!(run
            .shards
            .iter()
            .all(|s| s.outcome.samples.iter().all(|x| x.ok)));
    }
}

#[test]
fn merged_counts_and_imbalance_are_consistent() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    let run = ShardedDriver::new(4, PlacementPolicy::SizeHash)
        .run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
    let (merged, imb) = shard::analyze(&spec, &run.shards);
    // the convenience entry points must agree with the one-pass fold
    assert_eq!(merged.summary.e2e.count(),
               shard::merge(&spec, &run.shards).summary.e2e.count());
    assert_eq!(imb, shard::imbalance(&spec, &run.shards));

    let completed: u64 = run
        .shards
        .iter()
        .map(|s| s.outcome.samples.iter().filter(|x| x.ok).count() as u64)
        .sum();
    assert_eq!(merged.summary.completed, completed);
    assert_eq!(
        merged.summary.e2e.count(),
        completed,
        "merged e2e histogram must hold every successful sample"
    );
    let steps: u64 = run.shards.iter().map(|s| s.outcome.planner.steps).sum();
    assert_eq!(merged.planner.steps, steps);
    let max_dur = run
        .shards
        .iter()
        .map(|s| s.outcome.duration_s)
        .fold(0.0f64, f64::max);
    assert_eq!(merged.duration_s, max_dur);

    assert!(imb.requests_max >= imb.requests_min);
    assert!(imb.load_ratio >= 1.0);
    assert!(imb.p99_gap_us >= 0.0);
    // each shard's p99 bounds the extremes the imbalance section reports
    for s in &run.shards {
        let p99 =
            report::summarize(&spec, &s.outcome).e2e.quantile(0.99);
        assert!(p99 <= imb.p99_e2e_max_us + 1e-9);
        assert!(p99 >= imb.p99_e2e_min_us - 1e-9);
    }
}

/// Routing-aware placement is a function of the request's seeded routing
/// stream alone, mapping each request's dominant expert *group* `g` to
/// shard `g % N`.  Pinned behaviourally: with 16 experts in groups of 2
/// there are exactly 8 groups, so (a) at N=8 the assignment *is* the
/// group id, and an N=4 assignment must be its residue (`a4 == a8 % 4` —
/// true only if both derive from one per-request group), and (b) at
/// N=16 shards 8..16 can never receive a request.
#[test]
fn route_aware_assignment_is_stable_and_grouped() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    let placement = PlacementPolicy::route_aware(&cfg);
    let reqs = spec.materialize();
    let a4 = placement.assign(&spec, &reqs, 4);
    assert_eq!(a4, placement.assign(&spec, &reqs, 4), "not deterministic");

    // (a) residue consistency: shard_4(r) == group(r) % 4 == shard_8(r) % 4
    let a8 = placement.assign(&spec, &reqs, 8);
    let residues: Vec<usize> = a8.iter().map(|&g| g % 4).collect();
    assert_eq!(a4, residues, "group-to-shard mapping is not `group % N`");

    // (b) only 8 groups exist, so shards >= 8 stay empty at N=16
    let a16 = placement.assign(&spec, &reqs, 16);
    assert!(
        a16.iter().all(|&s| s < 8),
        "a request landed on a shard beyond the 8 expert groups: {a16:?}"
    );
    // and the N=8 assignment already was the group id
    assert_eq!(a8, a16);

    // colocation: requests sharing a group never split across shards
    let run = ShardedDriver::new(4, placement)
        .run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
    let total: usize = run.shards.iter().map(|s| s.requests).sum();
    assert_eq!(total, spec.requests);
    for (id, (&s4, &g)) in a4.iter().zip(&a8).enumerate() {
        assert_eq!(s4, g % 4, "request {id} split from its group");
    }
}

// ---------------------------------------------------------------------------
// live-signal placement (run_virtual_live)
// ---------------------------------------------------------------------------

/// The live-placement pump is the router loop: with one backend there is
/// nothing to place, so `run_virtual_live` at N=1 must reproduce the
/// classic single-cluster run sample-for-sample — the guard that the
/// incremental advance/drain pump is an exact reformulation and not a
/// near-miss reimplementation.
#[test]
fn one_shard_live_placement_reproduces_unsharded_loadtest() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    for policy in [
        AdmissionPolicy::fifo(),
        AdmissionPolicy::sjf(),
        AdmissionPolicy::deadline(),
    ] {
        let direct = run_virtual(&cfg, &spec, policy);
        let live = run_virtual_live(&cfg, &spec, policy, 1);
        assert_eq!(live.shards.len(), 1);
        assert_eq!(
            live.shards[0].outcome.samples, direct.samples,
            "1-shard live run diverged under {}",
            policy.label()
        );
        assert_eq!(live.shards[0].outcome.planner.cycles,
                   direct.planner.cycles);
        assert_eq!(live.shards[0].outcome.batch_dispatches,
                   direct.batch_dispatches);
    }
}

/// A skewed burst: the estimate-based `LeastOutstanding` split and the
/// live-signal placement see different worlds.  The estimator charges
/// each shard its *predicted* service time at split time; the live
/// pump reads each backend's *actual* queue at each arrival.  Under
/// bursty skewed traffic those disagree — the regression pin that the
/// live path really replaced the estimates (if it secretly re-derived
/// the same split, every seed would match).  Both must still conserve
/// every request exactly once.
#[test]
fn live_placement_diverges_from_estimate_split_under_skewed_burst() {
    let cfg = VirtualConfig { slots: 2, ..VirtualConfig::default() };
    let shards = 2usize;
    let mut any_diverged = false;
    for seed in [7u64, 8, 9] {
        let spec = WorkloadSpec {
            seed,
            requests: 48,
            arrival: ArrivalProcess::Bursty {
                rate_rps: 4_000.0,
                mean_on_ms: 5.0,
                mean_off_ms: 20.0,
            },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 2.0,
                prompt: (4, 48),
                gen: (1, 24),
            },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        };
        let reqs = spec.materialize();
        let estimate = PlacementPolicy::least_outstanding(&cfg)
            .assign(&spec, &reqs, shards);
        let mut est_ids: Vec<Vec<u64>> = vec![Vec::new(); shards];
        for (r, &s) in reqs.iter().zip(&estimate) {
            est_ids[s.min(shards - 1)].push(r.id);
        }
        let live =
            run_virtual_live(&cfg, &spec, AdmissionPolicy::fifo(), shards);
        let mut live_ids: Vec<Vec<u64>> = live
            .shards
            .iter()
            .map(|s| s.outcome.samples.iter().map(|x| x.id).collect())
            .collect();
        // conservation on both sides: every request on exactly one shard
        for ids in live_ids.iter_mut().chain(est_ids.iter_mut()) {
            ids.sort_unstable();
        }
        let mut all_live: Vec<u64> =
            live_ids.iter().flatten().copied().collect();
        all_live.sort_unstable();
        assert_eq!(all_live, (0..spec.requests as u64).collect::<Vec<_>>());
        if live_ids != est_ids {
            any_diverged = true;
        }
    }
    assert!(
        any_diverged,
        "live placement reproduced the estimate-based split on every \
         seed — the live signals are not actually being read"
    );
}

/// Live placement is still a deterministic virtual-clock path: same
/// inputs, byte-identical merged report.
#[test]
fn live_placement_reports_are_byte_identical_across_reruns() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    let policy = AdmissionPolicy::sjf();
    let a = run_virtual_live(&cfg, &spec, policy, 4);
    let b = run_virtual_live(&cfg, &spec, policy, 4);
    assert_eq!(a.shards, b.shards, "live runs diverged across reruns");
    let ra = report::build_sharded_labeled(
        &spec, policy, 4, "live-least-outstanding", &a)
        .to_string_pretty();
    let rb = report::build_sharded_labeled(
        &spec, policy, 4, "live-least-outstanding", &b)
        .to_string_pretty();
    assert_eq!(ra, rb);
    let parsed = json::parse(&ra).expect("valid JSON");
    assert_eq!(
        parsed.path(&["workload", "placement"]).unwrap().as_str(),
        Some("live-least-outstanding")
    );
    assert_eq!(
        parsed.path(&["shards"]).unwrap().as_arr().unwrap().len(),
        4
    );
}
