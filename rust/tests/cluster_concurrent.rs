//! Cluster front-door integration over the real depth-L model (requires
//! `make artifacts`): concurrent N-shard serving must reproduce the
//! single-backend token streams byte-identically, stream tokens in
//! order, genuinely overlap its backends, survive shutdown under load
//! with exactly one terminal reply per request, and shed — terminally,
//! immediately, and accountably — when every backend is saturated.
//!
//! All scenarios share one #[test]: every `Server::spawn` compiles the
//! whole artifact set, so the legs reuse as few spawns as possible and
//! run back to back.

use std::collections::BTreeMap;
use std::path::PathBuf;

use moepim::coordinator::{
    Cluster, ClusterOptions, ClusterPlacement, Reply, Server,
};
use moepim::workload::{
    request_for, ArrivalProcess, RequestSpec, SizeModel, WorkloadSpec,
};

fn artifacts_dir() -> PathBuf {
    std::env::var("MOEPIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// The shared workload: seeded sizes, open-loop arrivals (unused — the
/// legs submit as a burst; token streams do not depend on timing).
fn spec(requests: usize) -> WorkloadSpec {
    WorkloadSpec {
        seed: 2026,
        requests,
        arrival: ArrivalProcess::Poisson { rate_rps: 50_000.0 },
        sizes: SizeModel::Uniform { prompt: (6, 12), gen: (1, 6) },
        slo_e2e_ms: 60_000.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

#[test]
fn cluster_matches_serial_streams_and_survives_load() {
    let dir = artifacts_dir();
    let spec24 = spec(24);
    let reqs: Vec<RequestSpec> = spec24.materialize();

    // ---- leg A: single-backend reference streams ----------------------
    // One standalone server serves every request; its per-request token
    // streams are the byte-level reference for the concurrent cluster
    // (the engine is deterministic in (prompt, gen_len), so placement
    // and batching composition must not change a single token).
    let mut reference: BTreeMap<u64, Vec<i32>> = BTreeMap::new();
    {
        let server = Server::spawn(dir.clone()).expect(
            "artifacts missing — run `make artifacts` before `cargo test`",
        );
        let rxs: Vec<_> = reqs
            .iter()
            .map(|r| server.submit(request_for(&spec24, r)))
            .collect();
        for rx in rxs {
            let resp = rx.recv().expect("terminal reference reply");
            let tokens =
                resp.result.as_ref().expect("reference succeeds").clone();
            reference.insert(resp.id, tokens);
        }
    }
    assert_eq!(reference.len(), reqs.len());
    let reference_total: usize = reference.values().map(Vec::len).sum();

    // ---- leg B: concurrent round-robin cluster ------------------------
    // Round-robin with shedding off assigns submit order mod N — the
    // same split a static round-robin fan-out produces — so every
    // response is checkable against both its reference stream and its
    // expected shard.  The first few requests ride the streaming path.
    let cluster = Cluster::spawn(&dir, ClusterOptions {
        shards: 2,
        placement: ClusterPlacement::RoundRobin,
        ..ClusterOptions::default()
    })
    .expect("cluster spawns");
    const STREAMED: usize = 4;
    let stream_rxs: Vec<_> = reqs[..STREAMED]
        .iter()
        .map(|r| cluster.submit_streaming(request_for(&spec24, r)))
        .collect();
    let term_rxs: Vec<_> = reqs[STREAMED..]
        .iter()
        .map(|r| cluster.submit(request_for(&spec24, r)))
        .collect();
    let mut cluster_total = 0usize;
    for (i, rx) in stream_rxs.into_iter().enumerate() {
        // streaming lifecycle: tokens in index order, then exactly one
        // terminal whose token vector equals the streamed concatenation
        let mut streamed: Vec<i32> = Vec::new();
        let mut terminal = None;
        for event in rx.iter() {
            match event {
                Reply::Token { id, index, token } => {
                    assert!(
                        terminal.is_none(),
                        "token after terminal on request {id}"
                    );
                    assert_eq!(index as usize, streamed.len(),
                               "stream index out of order");
                    streamed.push(token);
                }
                Reply::Terminal(resp) => {
                    assert!(terminal.is_none(), "double terminal");
                    terminal = Some(resp);
                }
            }
        }
        // rx.iter() ended: the replier hung up after the terminal
        let resp = terminal.expect("streaming request got a terminal");
        let want = &reference[&resp.id];
        let got = resp.result.as_ref().expect("streamed request succeeds");
        assert_eq!(got, want, "cluster stream diverged from reference");
        assert_eq!(&streamed, want,
                   "streamed tokens != terminal tokens");
        assert_eq!(resp.shard, Some(i % 2), "round-robin shard tag");
        cluster_total += got.len();
    }
    for (i, rx) in term_rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("terminal cluster reply");
        let want = &reference[&resp.id];
        let got = resp.result.as_ref().expect("cluster request succeeds");
        assert_eq!(got, want, "cluster stream diverged from reference");
        assert_eq!(resp.shard, Some((STREAMED + i) % 2),
                   "round-robin shard tag");
        cluster_total += got.len();
    }
    // merged counters, modulo timing: same requests, same total tokens
    assert_eq!(cluster_total, reference_total);
    let stats = cluster.stats().expect("cluster stats");
    assert_eq!(stats.placed, vec![12, 12]);
    assert_eq!(stats.shed, vec![0, 0]);
    assert_eq!(stats.shed_requests(), 0);
    let completed: u64 = stats.shards.iter().map(|s| s.completed).sum();
    assert_eq!(completed, reqs.len() as u64);
    // genuine concurrency: the two router threads' dispatch windows
    // [first, last] overlap on the shared wall clock
    let windows: Vec<(u64, u64)> = stats
        .shards
        .iter()
        .map(|s| {
            (
                s.first_dispatch_unix_us.expect("shard 0/1 dispatched"),
                s.last_dispatch_unix_us.expect("shard 0/1 dispatched"),
            )
        })
        .collect();
    assert!(
        windows[0].0 <= windows[1].1 && windows[1].0 <= windows[0].1,
        "shard dispatch windows never overlapped: {windows:?} — \
         backends ran serially"
    );
    drop(cluster);

    // ---- leg C: shutdown under load -----------------------------------
    // Drop the cluster while requests are still in flight: every
    // submitted request must still get exactly one terminal reply (a
    // success or a "server shut down" error), never a silent hangup.
    let cluster = Cluster::spawn(&dir, ClusterOptions {
        shards: 2,
        placement: ClusterPlacement::RoundRobin,
        ..ClusterOptions::default()
    })
    .expect("cluster spawns");
    let spec12 = spec(12);
    let rxs: Vec<_> = spec12
        .materialize()
        .iter()
        .map(|r| cluster.submit(request_for(&spec12, r)))
        .collect();
    drop(cluster);
    for rx in rxs {
        let resp = rx.recv().expect(
            "request in flight at shutdown still gets a terminal reply",
        );
        if let Err(e) = &resp.result {
            assert!(e.contains("shut down"), "unexpected error: {e}");
        }
        assert!(
            rx.recv().is_err(),
            "more than one terminal reply for request {}", resp.id
        );
    }

    // ---- leg D: forced shedding ---------------------------------------
    // Live placement with shed_depth 1: a 40-request burst saturates
    // both backends (each fills slots+1 in-flight long before decode
    // finishes), so the front door must shed — terminally, immediately,
    // and with counters that reconcile exactly.
    let cluster = Cluster::spawn(&dir, ClusterOptions {
        shards: 2,
        placement: ClusterPlacement::LiveLeastOutstanding,
        shed_depth: 1,
        ..ClusterOptions::default()
    })
    .expect("cluster spawns");
    let spec40 = spec(40);
    let rxs: Vec<_> = spec40
        .materialize()
        .iter()
        .map(|r| cluster.submit(request_for(&spec40, r)))
        .collect();
    let mut served = 0u64;
    let mut shed = 0u64;
    for rx in rxs {
        let resp = rx.recv().expect("every request gets a terminal reply");
        match &resp.result {
            Ok(_) => served += 1,
            Err(e) => {
                assert!(e.contains("overloaded"),
                        "unexpected error: {e}");
                // a shed is decided at the front door, before serving:
                // it must come back with no admission or token events
                assert!(resp.ttft_us.is_none());
                assert!(resp.admit_seq.is_none());
                assert!(resp.shard.is_some(),
                        "shed reply carries its candidate shard");
                shed += 1;
            }
        }
        assert!(rx.recv().is_err(), "duplicate terminal reply");
    }
    assert_eq!(served + shed, 40);
    assert!(shed > 0, "a 40-request burst against 2 backends at shed \
                       depth 1 must shed");
    assert!(served > 0, "shedding must not starve the cluster entirely");
    let stats = cluster.stats().expect("cluster stats");
    assert_eq!(stats.shed_requests(), shed, "shed telemetry reconciles");
    let errored: u64 = stats.shards.iter().map(|s| s.errored).sum();
    // front-door sheds never reach a backend, so backend error counts
    // stay clean (only queue_cap sheds would land there, and it is off)
    assert_eq!(errored, 0);
}
