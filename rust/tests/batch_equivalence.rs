//! Tentpole equivalence pin: the slot-batched decode path must produce
//! exactly the token streams of the per-session cached path (requires
//! `make artifacts`), at whatever depth L the artifact set was lowered
//! with — the CI matrix runs this against both an L=1 and an L=3 set.
//!
//! The batched artifacts unroll B copies of the single-token subgraph
//! (python/compile/model.py) at every layer, so each row is
//! bit-compatible with the `*_one[_l{n}]` executables on that slot alone;
//! this test closes the loop over real HLO numerics end-to-end, including
//! partially-filled batches (padding rows), slot recycling, the
//! single-token fallback, and the per-layer planner telemetry.

use moepim::coordinator::{BatchEngine, DecodeMode, ModelEngine};
use moepim::runtime::Runtime;
use moepim::util::rng::Pcg32;

fn prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

#[test]
fn batched_decode_matches_per_session_cached() {
    let rt = Runtime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    // the serving engine always decodes sparse (§Perf L2-1); the reference
    // streams use the same mode so the comparison isolates *batching*
    let engine = ModelEngine::new(rt).with_sparse_moe(true);
    let m = engine.model.clone();
    assert!(m.batch_slots >= 2, "need a real batch width for this test");

    // uneven gen lengths: the batch drains to a tail on purpose
    let prompts: Vec<Vec<i32>> = (0..m.batch_slots)
        .map(|i| prompt(8 + 3 * i, 900 + i as u64, m.vocab))
        .collect();
    let gen_lens: Vec<usize> =
        (0..m.batch_slots).map(|i| 5 + 2 * i).collect();

    let reference: Vec<Vec<i32>> = prompts
        .iter()
        .zip(&gen_lens)
        .map(|(p, &g)| {
            engine.generate(p, g, DecodeMode::Cached).unwrap().tokens
        })
        .collect();

    let mut batch = BatchEngine::new(engine);

    // admit every prompt; streams start with the prefill-sampled token
    let mut streams: Vec<Vec<i32>> = Vec::new();
    let mut slot_of: Vec<usize> = Vec::new();
    for p in &prompts {
        let (slot, first) = batch.admit(p).unwrap();
        slot_of.push(slot);
        streams.push(vec![first]);
    }

    // drain: every cycle advances all unfinished sessions in one batched
    // step (the final cycles exercise padding rows as sessions finish)
    loop {
        let steps: Vec<(usize, i32)> = (0..prompts.len())
            .filter(|&i| streams[i].len() < gen_lens[i])
            .map(|i| (slot_of[i], *streams[i].last().unwrap()))
            .collect();
        if steps.is_empty() {
            break;
        }
        let out = batch.decode_batch(&steps).unwrap();
        assert_eq!(out.next.len(), steps.len());
        // one plan per functional layer, each internally consistent
        assert_eq!(out.plans.len(), m.n_layers);
        for plan in &out.plans {
            assert_eq!(plan.work, plan.schedule.total_work());
        }
        for (slot, next) in out.next {
            let i = slot_of.iter().position(|&s| s == slot).unwrap();
            streams[i].push(next);
        }
    }

    for (i, (got, want)) in streams.iter().zip(&reference).enumerate() {
        assert_eq!(got, want, "slot {i}: batched stream diverged");
        assert_eq!(got.len(), gen_lens[i]);
    }

    // ---- slot recycling + single-token fallback over pooled storage ----
    for &slot in &slot_of {
        batch.release(slot);
    }
    let (slot, first) = batch.admit(&prompts[0]).unwrap();
    let mut tail = vec![first];
    while tail.len() < gen_lens[0] {
        let (next, plans) =
            batch.decode_single(slot, *tail.last().unwrap()).unwrap();
        assert_eq!(plans.len(), m.n_layers);
        tail.push(next);
    }
    assert_eq!(
        &tail, &reference[0],
        "single-token fallback on a recycled slot diverged"
    );

    // planner telemetry accumulated across both paths; every decode step
    // is priced as L planned layer-steps
    let stats = batch.planner_stats();
    assert!(stats.steps > 0);
    assert_eq!(stats.steps % m.n_layers as u64, 0,
               "steps must be a whole number of depth-L decode cycles");
    assert!(stats.work > 0);
    assert!(stats.cycles >= stats.contention_cycles);

    // a full pool refuses further admissions without corrupting state
    let mut admitted = vec![slot];
    while let Ok((s, _)) = batch.admit(&prompts[0]) {
        admitted.push(s);
    }
    assert_eq!(admitted.len(), batch.slots());
    assert!(batch.free_slot().is_none());
    // …and a full pool refuses chunked claims too
    assert!(batch.begin_prefill(&prompts[0]).is_err());

    // ---- chunked prefill equivalence matrix ----------------------------
    // chunk sizes {1, 7, P, >P} must reproduce the monolithic streams
    // bit-for-bit: every advance replays the padded layer stack at the
    // grown prefix length, so the final chunk's dispatches (and the banks
    // + first token they produce) are exactly the monolithic ones.  The
    // CI artifact matrix runs this at L=1 and L=3.
    for slot in 0..batch.slots() {
        batch.release(slot);
    }
    let case = 1usize; // mixed-length case with a real multi-chunk prompt
    let p = prompts[case].clone();
    let plen = p.len();
    for chunk in [1usize, 7, plen, plen + 5] {
        let slot = batch.begin_prefill(&p).unwrap();
        // mid-prefill the slot is claimed but not yet decodable
        assert!(batch.session(slot).is_none());
        assert_eq!(batch.prefilling(), vec![slot], "chunk {chunk}");
        assert_ne!(batch.free_slot(), Some(slot), "claimed slot stayed free");
        let mut first = None;
        let mut advances = 0usize;
        while first.is_none() {
            first = batch.advance_prefill(slot, chunk).unwrap();
            advances += 1;
            assert!(advances <= plen, "chunk {chunk}: prefill never ended");
        }
        assert_eq!(
            advances,
            plen.div_ceil(chunk),
            "chunk {chunk}: wrong number of chunk advances for a \
             {plen}-token prompt"
        );
        let cursor_done = batch.session(slot).expect("prefill completed");
        assert_eq!(cursor_done.pos, plen);
        let mut stream = vec![first.unwrap()];
        while stream.len() < gen_lens[case] {
            let (next, _plans) =
                batch.decode_single(slot, *stream.last().unwrap()).unwrap();
            stream.push(next);
        }
        assert_eq!(
            &stream, &reference[case],
            "chunk {chunk}: chunked prefill diverged from the monolithic \
             stream"
        );
        batch.release(slot);
    }

    // aborting a partial prefill releases a clean slot: a fresh monolithic
    // admission on the same pool state reproduces the reference stream
    let slot = batch.begin_prefill(&p).unwrap();
    let mid = batch.advance_prefill(slot, 3).unwrap();
    assert!(mid.is_none(), "a 3-token chunk must not finish this prompt");
    batch.release(slot);
    assert!(batch.session(slot).is_none());
    assert!(batch.prefill_state(slot).is_none());
    let (slot2, first) = batch.admit(&p).unwrap();
    let mut stream = vec![first];
    while stream.len() < gen_lens[case] {
        let (next, _plans) =
            batch.decode_single(slot2, *stream.last().unwrap()).unwrap();
        stream.push(next);
    }
    assert_eq!(
        &stream, &reference[case],
        "monolithic admission after an aborted chunked prefill diverged"
    );
}

/// Preemption-equivalence legs (DESIGN.md §Preemption & QoS): a session
/// checkpointed at decode step k and restored later — into a *different*
/// slot, over a pool an interloping request has dirtied in between — must
/// produce a token stream bit-identical to the never-preempted run, for
/// k ∈ {0, 1, mid, last}.  The CI artifact matrix runs this at L=1 and
/// L=3, pinning the cross-layer bank snapshot at both depths.
#[test]
fn preemption_checkpoint_restore_is_stream_invariant() {
    let rt = Runtime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    let engine = ModelEngine::new(rt).with_sparse_moe(true);
    let m = engine.model.clone();
    assert!(m.batch_slots >= 2, "restore-into-another-slot needs >= 2");

    let gen = 9usize;
    let p = prompt(10, 4242, m.vocab);
    let interloper = prompt(6, 777, m.vocab);
    // never-preempted reference: the per-session cached stream the
    // batched paths are already pinned to above
    let reference =
        engine.generate(&p, gen, DecodeMode::Cached).unwrap().tokens;

    let mut batch = BatchEngine::new(engine);
    // k = gen-2 is the last checkpointable step that still leaves a
    // decode to run after the restore (k = gen-1 would make the tail
    // comparison vacuous)
    for k in [0usize, 1, gen / 2, gen - 2] {
        let (slot, first) = batch.admit(&p).unwrap();
        let mut stream = vec![first];
        for _ in 0..k {
            let (next, _plans) =
                batch.decode_single(slot, *stream.last().unwrap()).unwrap();
            stream.push(next);
        }
        let ckpt = batch.checkpoint_slot(slot).unwrap();
        assert_eq!(ckpt.n_layers(), m.n_layers, "k={k}");
        batch.release(slot);

        // an interloper claims the freed slot and dirties the pooled
        // KV/GO state the checkpoint must be independent of
        let (islot, ifirst) = batch.admit(&interloper).unwrap();
        let mut itail = ifirst;
        for _ in 0..2 {
            let (next, _plans) =
                batch.decode_single(islot, itail).unwrap();
            itail = next;
        }

        let rslot = batch.restore_slot(&ckpt).unwrap();
        assert_ne!(rslot, islot, "k={k}: restore landed on a live slot");
        while stream.len() < gen {
            let (next, _plans) =
                batch.decode_single(rslot, *stream.last().unwrap())
                    .unwrap();
            stream.push(next);
        }
        assert_eq!(
            &stream, &reference,
            "k={k}: preempted/restored stream diverged from the \
             never-preempted run"
        );
        batch.release(rslot);
        batch.release(islot);
    }

    // transactional discipline: a restore that finds no free slot fails
    // without touching any live session, and succeeds once one frees up
    let (slot_a, _first) = batch.admit(&p).unwrap();
    let ckpt = batch.checkpoint_slot(slot_a).unwrap();
    let mut filled = Vec::new();
    while let Ok((s, _)) = batch.admit(&interloper) {
        filled.push(s);
    }
    assert!(batch.free_slot().is_none());
    assert!(batch.restore_slot(&ckpt).is_err(),
            "restore into a full pool must fail");
    let before = batch.session(slot_a).cloned();
    assert!(before.is_some(), "failed restore disturbed a live session");
    batch.release(filled[0]);
    let rs = batch.restore_slot(&ckpt).unwrap();
    assert_eq!(batch.session(rs), Some(&ckpt.session),
               "restored session cursor mismatch");

    // checkpointing an empty or mid-prefill slot is an error, not a wedge
    batch.release(rs);
    assert!(batch.checkpoint_slot(rs).is_err(),
            "checkpoint of an empty slot must fail");
}
