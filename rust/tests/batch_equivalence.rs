//! Tentpole equivalence pin: the slot-batched decode path must produce
//! exactly the token streams of the per-session cached path (requires
//! `make artifacts`), at whatever depth L the artifact set was lowered
//! with — the CI matrix runs this against both an L=1 and an L=3 set.
//!
//! The batched artifacts unroll B copies of the single-token subgraph
//! (python/compile/model.py) at every layer, so each row is
//! bit-compatible with the `*_one[_l{n}]` executables on that slot alone;
//! this test closes the loop over real HLO numerics end-to-end, including
//! partially-filled batches (padding rows), slot recycling, the
//! single-token fallback, and the per-layer planner telemetry.

use moepim::coordinator::{BatchEngine, DecodeMode, ModelEngine};
use moepim::runtime::Runtime;
use moepim::util::rng::Pcg32;

fn prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

#[test]
fn batched_decode_matches_per_session_cached() {
    let rt = Runtime::load_default().expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    );
    // the serving engine always decodes sparse (§Perf L2-1); the reference
    // streams use the same mode so the comparison isolates *batching*
    let engine = ModelEngine::new(rt).with_sparse_moe(true);
    let m = engine.model.clone();
    assert!(m.batch_slots >= 2, "need a real batch width for this test");

    // uneven gen lengths: the batch drains to a tail on purpose
    let prompts: Vec<Vec<i32>> = (0..m.batch_slots)
        .map(|i| prompt(8 + 3 * i, 900 + i as u64, m.vocab))
        .collect();
    let gen_lens: Vec<usize> =
        (0..m.batch_slots).map(|i| 5 + 2 * i).collect();

    let reference: Vec<Vec<i32>> = prompts
        .iter()
        .zip(&gen_lens)
        .map(|(p, &g)| {
            engine.generate(p, g, DecodeMode::Cached).unwrap().tokens
        })
        .collect();

    let mut batch = BatchEngine::new(engine);

    // admit every prompt; streams start with the prefill-sampled token
    let mut streams: Vec<Vec<i32>> = Vec::new();
    let mut slot_of: Vec<usize> = Vec::new();
    for p in &prompts {
        let (slot, first) = batch.admit(p).unwrap();
        slot_of.push(slot);
        streams.push(vec![first]);
    }

    // drain: every cycle advances all unfinished sessions in one batched
    // step (the final cycles exercise padding rows as sessions finish)
    loop {
        let steps: Vec<(usize, i32)> = (0..prompts.len())
            .filter(|&i| streams[i].len() < gen_lens[i])
            .map(|i| (slot_of[i], *streams[i].last().unwrap()))
            .collect();
        if steps.is_empty() {
            break;
        }
        let out = batch.decode_batch(&steps).unwrap();
        assert_eq!(out.next.len(), steps.len());
        // one plan per functional layer, each internally consistent
        assert_eq!(out.plans.len(), m.n_layers);
        for plan in &out.plans {
            assert_eq!(plan.work, plan.schedule.total_work());
        }
        for (slot, next) in out.next {
            let i = slot_of.iter().position(|&s| s == slot).unwrap();
            streams[i].push(next);
        }
    }

    for (i, (got, want)) in streams.iter().zip(&reference).enumerate() {
        assert_eq!(got, want, "slot {i}: batched stream diverged");
        assert_eq!(got.len(), gen_lens[i]);
    }

    // ---- slot recycling + single-token fallback over pooled storage ----
    for &slot in &slot_of {
        batch.release(slot);
    }
    let (slot, first) = batch.admit(&prompts[0]).unwrap();
    let mut tail = vec![first];
    while tail.len() < gen_lens[0] {
        let (next, plans) =
            batch.decode_single(slot, *tail.last().unwrap()).unwrap();
        assert_eq!(plans.len(), m.n_layers);
        tail.push(next);
    }
    assert_eq!(
        &tail, &reference[0],
        "single-token fallback on a recycled slot diverged"
    );

    // planner telemetry accumulated across both paths; every decode step
    // is priced as L planned layer-steps
    let stats = batch.planner_stats();
    assert!(stats.steps > 0);
    assert_eq!(stats.steps % m.n_layers as u64, 0,
               "steps must be a whole number of depth-L decode cycles");
    assert!(stats.work > 0);
    assert!(stats.cycles >= stats.contention_cycles);

    // a full pool refuses further admissions without corrupting state
    let mut admitted = vec![slot];
    while let Ok((s, _)) = batch.admit(&prompts[0]) {
        admitted.push(s);
    }
    assert_eq!(admitted.len(), batch.slots());
    assert!(batch.free_slot().is_none());
}
