//! Throughput-under-load benchmarks over the virtual-time cluster: how
//! fast the workload subsystem itself runs (driver + planner-priced
//! decode cycles — this is host-side code on the serving hot path), plus
//! derived metrics comparing admission policies under identical seeded
//! traffic (simulated tokens/sec, p99 e2e, SLO attainment).
//!
//! `cargo bench --bench loadgen` — no artifacts needed.

use moepim::util::bench::Bench;
use moepim::workload::report;
use moepim::workload::{
    run_virtual, AdmissionPolicy, ArrivalProcess, SizeModel, VirtualConfig,
    WorkloadSpec,
};

fn spec(arrival: ArrivalProcess, requests: usize) -> WorkloadSpec {
    WorkloadSpec {
        seed: 0xBE0C,
        requests,
        arrival,
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 50.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    }
}

fn main() {
    let b = Bench::new("loadgen");
    let cfg = VirtualConfig::default();

    // ---- simulation throughput: wall time per experiment ----------------
    let poisson = spec(ArrivalProcess::Poisson { rate_rps: 400.0 }, 64);
    b.run("virtual/poisson_fifo/64req", || {
        run_virtual(&cfg, &poisson, AdmissionPolicy::fifo()).samples.len()
    });
    let closed = spec(
        ArrivalProcess::Closed { users: 8, think_ms: 0.0 },
        64,
    );
    b.run("virtual/closed_sjf/64req", || {
        run_virtual(&cfg, &closed, AdmissionPolicy::sjf()).samples.len()
    });

    // ---- policy comparison under identical seeded traffic ---------------
    let pressure = spec(ArrivalProcess::Poisson { rate_rps: 2000.0 }, 128);
    for policy in [
        AdmissionPolicy::fifo(),
        AdmissionPolicy::sjf(),
        AdmissionPolicy::deadline(),
    ] {
        let out = run_virtual(&cfg, &pressure, policy);
        let s = report::summarize(&pressure, &out);
        b.metric(
            &format!("policy/{}/tokens_per_s", policy.label()),
            s.tokens_per_s,
            "tok/s (virtual)",
        );
        b.metric(
            &format!("policy/{}/p99_e2e", policy.label()),
            s.e2e.quantile(0.99) / 1e3,
            "ms (virtual)",
        );
        b.metric(
            &format!("policy/{}/slo_attainment", policy.label()),
            s.attainment * 100.0,
            "%",
        );
        b.metric(
            &format!("policy/{}/contention", policy.label()),
            out.planner.contention_ratio() * 100.0,
            "% of cycles",
        );
    }
}
