//! L3 hot-path micro-benchmarks: the pieces that run per request / per
//! token in the coordinator and simulator — scheduler builders, GO-cache
//! TopKUpdate, routing, trace generation, and (when artifacts exist) the
//! PJRT decode step itself.
//!
//! `cargo bench --bench hotpath`

use moepim::cache::GoCache;
use moepim::config::SchedulePolicy;
use moepim::grouping::Grouping;
use moepim::moe::gate::{expert_choice_route, softmax_rows};
use moepim::moe::TraceGenerator;
use moepim::sched;
use moepim::util::bench::Bench;
use moepim::util::rng::Pcg32;

fn scores(t: usize, e: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..t * e).map(|_| rng.gen_normal() as f32).collect()
}

fn main() {
    let b = Bench::new("hotpath");

    // ---- routing ---------------------------------------------------------
    let s32 = scores(32, 16, 1);
    b.run("route/expert_choice/32x16", || {
        expert_choice_route(&s32, 32, 16, 8, None).choices.total_work()
    });
    let s1k = scores(1024, 64, 2);
    b.run("route/expert_choice/1024x64", || {
        expert_choice_route(&s1k, 1024, 64, 64, None)
            .choices
            .total_work()
    });
    b.run("route/softmax/1024x64", || {
        softmax_rows(&s1k, 1024, 64).len()
    });

    // ---- GO cache --------------------------------------------------------
    let row: Vec<f32> = scores(1, 16, 3);
    b.run("go_cache/topk_update/16exp", || {
        let mut cache = GoCache::new(16, 8, 0);
        for t in 0..64 {
            cache.update_scores(t, &row);
        }
        cache.selected_tokens(0).len()
    });

    // ---- scheduler (the per-prefill path) ----------------------------------
    let mut gen = TraceGenerator::new(16, 5);
    let choices = gen.token_choice_zipf(32, 4, 0.35);
    let grouping = Grouping::uniform(16, 2, 5);
    b.run("sched/reschedule/32tok", || {
        sched::build(&choices, &grouping, SchedulePolicy::Reschedule)
            .transfers()
    });

    // ---- trace generation --------------------------------------------------
    b.run("trace/expert_choice/32tok", || {
        TraceGenerator::new(16, 11).expert_choice(32, 8, 1.0).total_work()
    });

    // ---- PJRT decode step (needs `make artifacts`) -------------------------
    let dir = std::env::var("MOEPIM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    match moepim::runtime::Runtime::load(&dir) {
        Ok(rt) => {
            let engine = moepim::coordinator::ModelEngine::new(rt);
            let prompt: Vec<i32> = (0..32).collect();
            let (mut session, mut next) = engine.prefill(&prompt).unwrap();
            b.run("pjrt/decode_cached_step/dense", || {
                if session.pos + 1 >= engine.model.max_seq {
                    let (s2, n2) = engine.prefill(&prompt).unwrap();
                    session = s2;
                    next = n2;
                }
                next = engine.decode_cached(&mut session, next).unwrap();
                next
            });
            // §Perf L2-1: sparse-gather MoE on the decode path
            let engine = engine.with_sparse_moe(true);
            let (mut session, mut next) = engine.prefill(&prompt).unwrap();
            b.run("pjrt/decode_cached_step/sparse", || {
                if session.pos + 1 >= engine.model.max_seq {
                    let (s2, n2) = engine.prefill(&prompt).unwrap();
                    session = s2;
                    next = n2;
                }
                next = engine.decode_cached(&mut session, next).unwrap();
                next
            });
            b.run("pjrt/prefill_32tok", || {
                engine.prefill(&prompt).unwrap().1
            });

            // ---- batched vs round-robin serving decode ------------------
            // Both closures advance B sessions by one token per iteration,
            // so the time ratio is exactly the tokens/sec ratio.
            let bslots = engine.model.batch_slots.max(1);
            let max_seq = engine.model.max_seq;
            let mut rr: Vec<(moepim::coordinator::Session, i32)> = (0..bslots)
                .map(|_| engine.prefill(&prompt).unwrap())
                .collect();
            let rr_stats =
                b.run(&format!("pjrt/decode_roundrobin/{bslots}x1"), || {
                    for (s, next) in rr.iter_mut() {
                        if s.pos + 1 >= max_seq {
                            let (s2, n2) = engine.prefill(&prompt).unwrap();
                            *s = s2;
                            *next = n2;
                        }
                        *next = engine.decode_cached(s, *next).unwrap();
                    }
                    rr.len()
                });
            drop(rr);

            let mut batch = moepim::coordinator::BatchEngine::new(engine);
            let mut steps: Vec<(usize, i32)> = (0..bslots)
                .map(|_| batch.admit(&prompt).unwrap())
                .collect();
            let bt_stats =
                b.run(&format!("pjrt/decode_batched/{bslots}slots"), || {
                    let full = steps.iter().any(|&(slot, _)| {
                        batch.session(slot).unwrap().pos + 1 >= max_seq
                    });
                    if full {
                        for &(slot, _) in &steps {
                            batch.release(slot);
                        }
                        steps = (0..bslots)
                            .map(|_| batch.admit(&prompt).unwrap())
                            .collect();
                    }
                    let out = batch.decode_batch(&steps).unwrap();
                    steps = out.next;
                    steps.len()
                });
            println!(
                "batched decode at B={bslots}: {:.2}x tokens/sec vs \
                 round-robin",
                rr_stats.mid_ns / bt_stats.mid_ns
            );
        }
        Err(e) => {
            println!("(skipping PJRT benches: {e})");
        }
    }
}
