//! Bench for Fig. 4 (generation-stage cache study): regenerates every bar
//! and series of the figure, printing both the *simulated* chip numbers
//! (the paper's data) and the host cost of producing them.
//!
//! `cargo bench --bench fig4_cache`

use moepim::config::SimConfig;
use moepim::eval::fig4;
use moepim::sim::Simulator;
use moepim::util::bench::Bench;

fn main() {
    let b = Bench::new("fig4");

    // ---- the figure itself (simulated metrics) -------------------------
    println!("\n{}", fig4::render_fig4a(8));
    println!("{}", fig4::render_fig4b());

    let imp8 = fig4::improvement(8);
    let imp64 = fig4::improvement(64);
    b.metric("kvgo_latency_x_8tok", imp8.latency_x, "x (paper 4.2)");
    b.metric("kvgo_energy_x_8tok", imp8.energy_x, "x (paper 10.1)");
    b.metric("kvgo_latency_x_64tok", imp64.latency_x, "x (paper 6.7)");
    b.metric("kvgo_energy_x_64tok", imp64.energy_x, "x (paper 14.1)");

    // ---- host cost of the simulator on each cache regime ---------------
    for cache in fig4::CACHE_VARIANTS {
        let mut cfg = SimConfig::baseline();
        cfg.cache = cache;
        let label = cache.label().replace(' ', "_");
        b.run(&format!("simulate_8tok/{label}"), || {
            Simulator::paper(cfg.clone()).run().total().latency_ns
        });
    }

    // full-figure regeneration cost (what `moepim eval fig4a` pays)
    b.run("fig4a_rows", || fig4::fig4a(8).len());
}
