//! Bench for Fig. 5 (grouping × scheduling study): regenerates all nine
//! bars (simulated MoE-part latency/energy/area-efficiency) and measures
//! the host cost of the three schedule builders — the L3 hot path.
//!
//! `cargo bench --bench fig5_sched`

use moepim::config::SchedulePolicy;
use moepim::eval::fig5;
use moepim::grouping::Grouping;
use moepim::moe::TraceGenerator;
use moepim::sched;
use moepim::util::bench::Bench;

fn main() {
    let b = Bench::new("fig5");

    // ---- the figure itself ----------------------------------------------
    println!("\n{}", fig5::render());
    let rows = fig5::fig5();
    let (best_label, best_x) = fig5::best_improvement(&rows);
    b.metric(&format!("best_area_eff_{best_label}"), best_x,
             "x vs base (paper 2.2)");

    // ---- schedule-builder host cost (prefill-scale and larger) -----------
    for tokens in [32usize, 256, 1024] {
        let mut gen = TraceGenerator::new(16, 7);
        let choices = gen.token_choice_zipf(tokens, 4, 0.35);
        let grouping = Grouping::uniform(16, 2, 7);
        for (name, policy) in [
            ("tokenwise", SchedulePolicy::TokenWise),
            ("compact", SchedulePolicy::Compact),
            ("reschedule", SchedulePolicy::Reschedule),
        ] {
            b.run(&format!("build/{name}/{tokens}tok"), || {
                sched::build(&choices, &grouping, policy).makespan_slots()
            });
        }
    }

    // transfer counting on a large schedule
    let mut gen = TraceGenerator::new(16, 9);
    let choices = gen.token_choice_zipf(1024, 4, 0.35);
    let grouping = Grouping::uniform(16, 2, 9);
    let schedule = sched::build(&choices, &grouping,
                                SchedulePolicy::Reschedule);
    b.run("transfers/1024tok", || schedule.transfers());
}
