//! Bench for Table I (total latency/energy/density): regenerates the three
//! columns end-to-end and measures the whole-inference simulation cost per
//! configuration, plus the area-ratio sweep of §IV-B.
//!
//! `cargo bench --bench table1_total`

use moepim::eval::{calibration, sweep, table1};
use moepim::sim::Simulator;
use moepim::util::bench::Bench;

fn main() {
    let b = Bench::new("table1");

    // ---- the table itself -------------------------------------------------
    println!("\n{}", table1::render());
    let rows = table1::table1();
    b.metric("baseline_latency_ns", rows[0].latency_ns,
             "ns (paper 2,297,724)");
    b.metric("baseline_energy_nj", rows[0].energy_nj,
             "nJ (paper 5,393,776)");
    b.metric("s4o_density", rows[2].density, "GOPS/W/mm2 (paper 15.6)");

    // ---- §IV-B ratio sweep -------------------------------------------------
    println!("{}", sweep::render());
    b.metric("isaac_point", sweep::isaac_point().gops_per_mm2,
             "GOPS/mm2 (paper 82.7)");

    // ---- calibration table (paper vs measured, all targets) ---------------
    println!("{}", calibration::render());
    let worst = calibration::targets()
        .into_iter()
        .map(|t| (t.ratio() - 1.0).abs())
        .fold(0.0f64, f64::max);
    b.metric("worst_target_deviation", worst, "rel (lower is better)");

    // ---- host cost per Table-I column --------------------------------------
    for (label, cfg) in table1::configs() {
        let tag = label.replace([' ', ','], "_");
        b.run(&format!("simulate/{tag}"), || {
            Simulator::paper(cfg.clone()).run().total().latency_ns
        });
    }
}
