//! Whole-model extension: the paper simulates a single transformer block
//! ("all blocks have the same size", §IV-A); this module lifts one-layer
//! reports to the 32-block Llama-MoE-4/16 model under two deployment
//! styles:
//!
//! * **sequential** — one chip holds one layer's MoE; blocks execute
//!   back-to-back (latency and energy scale by `n_layers`, area stays one
//!   layer's);
//! * **spatial pipeline** — every block has its own crossbar complement
//!   (area scales by `n_layers`) and consecutive *requests* stream through
//!   the layer pipeline, so steady-state throughput is bounded by the
//!   slowest stage while a single request's latency still sums all stages.

use crate::sim::metrics::InferenceReport;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Deployment {
    Sequential,
    SpatialPipeline,
}

/// Whole-model estimates derived from a single-layer report.
#[derive(Debug, Clone)]
pub struct ModelEstimate {
    pub deployment: Deployment,
    pub n_layers: usize,
    /// one full inference (prefill + decode), ns
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub area_mm2: f64,
    /// steady-state request throughput, requests/s
    pub throughput_rps: f64,
}

pub fn scale_to_model(report: &InferenceReport, n_layers: usize,
                      deployment: Deployment) -> ModelEstimate {
    let t = report.total();
    let layers = n_layers as f64;
    match deployment {
        Deployment::Sequential => ModelEstimate {
            deployment,
            n_layers,
            latency_ns: t.latency_ns * layers,
            energy_nj: t.energy_nj * layers,
            area_mm2: report.moe_area_mm2,
            // chip is busy for the whole request
            throughput_rps: 1e9 / (t.latency_ns * layers),
        },
        Deployment::SpatialPipeline => ModelEstimate {
            deployment,
            n_layers,
            latency_ns: t.latency_ns * layers,
            energy_nj: t.energy_nj * layers,
            area_mm2: report.moe_area_mm2 * layers,
            // a new request can enter every stage-time
            throughput_rps: 1e9 / t.latency_ns,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::Simulator;

    fn one_layer() -> InferenceReport {
        Simulator::paper(SimConfig::s2o_kvgo()).run()
    }

    #[test]
    fn sequential_scales_time_not_area() {
        let r = one_layer();
        let m = scale_to_model(&r, 32, Deployment::Sequential);
        assert!((m.latency_ns - r.total().latency_ns * 32.0).abs() < 1e-3);
        assert_eq!(m.area_mm2, r.moe_area_mm2);
    }

    #[test]
    fn pipeline_scales_area_not_throughput_cost() {
        let r = one_layer();
        let seq = scale_to_model(&r, 32, Deployment::Sequential);
        let pipe = scale_to_model(&r, 32, Deployment::SpatialPipeline);
        assert!((pipe.area_mm2 - r.moe_area_mm2 * 32.0).abs() < 1e-6);
        assert!((pipe.throughput_rps / seq.throughput_rps - 32.0).abs()
                < 1e-6);
        // same single-request latency either way
        assert_eq!(pipe.latency_ns, seq.latency_ns);
    }

    #[test]
    fn energy_is_deployment_independent() {
        let r = one_layer();
        let a = scale_to_model(&r, 32, Deployment::Sequential);
        let b = scale_to_model(&r, 32, Deployment::SpatialPipeline);
        assert_eq!(a.energy_nj, b.energy_nj);
    }
}
