//! Operator-level PIM simulator: [`engine::Simulator`] prices a whole
//! inference (prefill + decode) under any grouping/schedule/cache
//! configuration; [`metrics`] defines the report types.

pub mod engine;
pub mod metrics;
pub mod pipeline;

pub use engine::Simulator;
pub use metrics::{Breakdown, InferenceReport, StageMetrics};
