//! The operator-level PIM simulator (§IV-A: "an operator-accurate simulator
//! built on 3DCIM [7], where we implement the KVGO cache and our scheduling
//! methods").
//!
//! One [`Simulator`] couples a model shape, a hardware spec and a
//! [`SimConfig`]; [`Simulator::run`] produces the [`InferenceReport`] that
//! every figure/table regenerator consumes.  Stages:
//!
//! * **prefill** — routes the prompt (expert- or token-choice), builds the
//!   configured grouping + schedule, prices the MoE part from the schedule
//!   (makespan slots, activations, transfers) and the attention/gate parts
//!   from the digital-unit fits;
//! * **decode** — per generated token, prices the four cache regimes: the
//!   KV cache turns attention recompute into cached lookups (DRAM-priced),
//!   the GO cache turns feed-all-tokens gate+MoE into one-token work via
//!   `TopKUpdate` (§III-C).  Without the GO cache, every step re-routes the
//!   full batch and re-executes the MoE for all retained tokens.
//!
//! Latency composes serially (attn → gate → MoE → DRAM); pipelining between
//! operators is ignored uniformly across configs so ratios stay meaningful.

use crate::cache::{GoCache, KvCache};
use crate::config::{
    GroupingPolicy, HardwareConfig, MoeModelConfig, RoutingMode,
    SchedulePolicy, SimConfig,
};
use crate::grouping::Grouping;
use crate::hw::{AreaModel, EnergyModel};
use crate::moe::gate::{expert_choice_route, token_choice_route, Routing};
use crate::moe::{LayerLayout, TraceGenerator};
use crate::sched;

use super::metrics::{Breakdown, InferenceReport, StageMetrics};

#[derive(Debug, Clone)]
pub struct Simulator {
    pub model: MoeModelConfig,
    pub hw: HardwareConfig,
    pub cfg: SimConfig,
    layout: LayerLayout,
    energy: EnergyModel,
    area: AreaModel,
}

impl Simulator {
    pub fn new(model: MoeModelConfig, hw: HardwareConfig, cfg: SimConfig)
        -> Self {
        let layout = LayerLayout::new(&model, &hw);
        let energy = EnergyModel::new(&hw);
        let area = AreaModel::new(&hw);
        Simulator { model, hw, cfg, layout, energy, area }
    }

    pub fn paper(cfg: SimConfig) -> Self {
        Self::new(MoeModelConfig::llama_moe_4_16(), HardwareConfig::paper(),
                  cfg)
    }

    pub fn layout(&self) -> &LayerLayout {
        &self.layout
    }

    /// Fixed expert capacity (prefill value, kept static during generation
    /// so the GO output cache stays k x E x d — §III-C).
    pub fn capacity(&self) -> usize {
        self.model.expert_capacity(self.cfg.prompt_len)
    }

    /// Gate scores of the whole workload (prompt + generated), seeded; the
    /// C4-substitute trace of DESIGN.md §2.
    pub fn workload_scores(&self) -> Vec<f32> {
        let total = self.cfg.prompt_len + self.cfg.gen_len;
        TraceGenerator::new(self.model.n_experts, self.cfg.seed)
            .scores(total, self.cfg.skew)
    }

    /// Batch routing over the first `tokens` workload tokens.
    ///
    /// Expert-choice capacity follows Zhou et al.: `ceil(tokens*k/E)`,
    /// *growing* with the batch — recomputing the router over L retained
    /// tokens each decode step therefore does more MoE work as generation
    /// proceeds.  The GO cache deliberately pins capacity at the prefill
    /// value instead ("the storage ... is a static value", §III-C); that
    /// approximation is part of the paper's design, not of this simulator.
    pub fn route_batch(&self, scores: &[f32], tokens: usize) -> Routing {
        let e = self.model.n_experts;
        match self.cfg.routing {
            RoutingMode::ExpertChoice => expert_choice_route(
                &scores[..tokens * e], tokens, e,
                self.model.expert_capacity(tokens), None),
            RoutingMode::TokenChoice => token_choice_route(
                &scores[..tokens * e], tokens, e, self.model.top_k),
        }
    }

    /// Deployment-time grouping per policy.  Sorted grouping estimates
    /// per-expert loads from independent calibration traces (different seed
    /// stream than the workload — "traced from small samples", §III-B).
    pub fn make_grouping(&self) -> Grouping {
        let e = self.model.n_experts;
        if self.cfg.group_size <= 1 {
            return Grouping::singleton(e);
        }
        match self.cfg.grouping {
            GroupingPolicy::None => Grouping::singleton(e),
            GroupingPolicy::Uniform => {
                Grouping::uniform(e, self.cfg.group_size, self.cfg.seed)
            }
            GroupingPolicy::Sorted => {
                let mut gen =
                    TraceGenerator::new(e, self.cfg.seed ^ 0xCA11B5A7E);
                let loads = gen.calibration_loads(
                    8,
                    self.cfg.prompt_len.max(64),
                    self.model.top_k,
                    self.cfg.skew,
                );
                Grouping::sorted(&loads, self.cfg.group_size)
            }
        }
    }

    // -----------------------------------------------------------------------
    // MoE-part pricing
    // -----------------------------------------------------------------------

    /// Price a schedule on the PIM cores: latency from the makespan, energy
    /// from activations + broadcasts.
    fn price_schedule(&self, schedule: &sched::Schedule) -> StageMetrics {
        let slots = schedule.makespan_slots() as f64;
        let work = schedule.total_work() as u64;
        let transfers = schedule.transfers() as u64;
        let acts = work * self.layout.activations_per_token_expert();
        let moe_ns = slots
            * self.layout.rounds_per_token as f64
            * self.hw.core_latency_ns;
        let moe_nj = self.energy.activations_nj(acts)
            + self.energy.transfers_nj(transfers, self.model.d_model);
        StageMetrics {
            latency_ns: moe_ns,
            energy_nj: moe_nj,
            breakdown: Breakdown { moe_ns, moe_nj, ..Default::default() },
            activations: acts,
            transfers,
            macs: acts * self.hw.macs_per_activation(),
        }
    }

    // -----------------------------------------------------------------------
    // Prefill
    // -----------------------------------------------------------------------

    pub fn prefill(&self, routing: &Routing, grouping: &Grouping)
        -> StageMetrics {
        let t = self.cfg.prompt_len;
        let schedule =
            sched::build(&routing.choices, grouping, self.cfg.schedule);
        let mut m = self.price_schedule(&schedule);

        // digital attention + gate over the prompt
        let (attn_ns, attn_nj) = self.energy.attention(t, t);
        let (gate_ns, gate_nj) = self.energy.gate(t);
        m.latency_ns += attn_ns + gate_ns;
        m.energy_nj += attn_nj + gate_nj;
        m.breakdown.attn_ns = attn_ns;
        m.breakdown.attn_nj = attn_nj;
        m.breakdown.gate_ns = gate_ns;
        m.breakdown.gate_nj = gate_nj;
        m.macs += t as u64
            * (self.model.attn_macs_per_token(t)
                + self.model.gate_macs_per_token());

        // cache seeding traffic
        let mut dram_bytes = 0u64;
        if self.cfg.cache.kv {
            dram_bytes += t as u64
                * KvCache::bytes_per_token_write(self.model.n_heads,
                                                 self.model.d_head);
        }
        if self.cfg.cache.go {
            dram_bytes += t as u64
                * GoCache::score_bytes_per_token(self.model.n_experts);
            dram_bytes += GoCache::output_cache_bytes(
                self.capacity(), self.model.n_experts, self.model.d_model);
        }
        let (dram_ns, dram_nj) = self.hw.dram.transfer(dram_bytes);
        m.latency_ns += dram_ns;
        m.energy_nj += dram_nj;
        m.breakdown.dram_ns = dram_ns;
        m.breakdown.dram_nj = dram_nj;
        m
    }

    // -----------------------------------------------------------------------
    // Decode
    // -----------------------------------------------------------------------

    /// Price one decode step.  `ctx` = tokens before this step (prompt +
    /// already-generated); `scores` = full workload scores; `go_cache` holds
    /// streaming state when the GO cache is on.
    pub fn decode_step(&self, ctx: usize, scores: &[f32],
                       grouping: &Grouping,
                       go_cache: &mut Option<GoCache>) -> StageMetrics {
        let e = self.model.n_experts;
        let new_tok = ctx; // index of the token generated this step
        let mut m = StageMetrics::default();
        let mut dram_bytes = 0u64;

        // ---- attention ----
        if self.cfg.cache.kv {
            let (ns, nj) = self.energy.attention(1, ctx + 1);
            m.breakdown.attn_ns = ns;
            m.breakdown.attn_nj = nj;
            m.macs += self.model.attn_macs_per_token(ctx + 1);
            dram_bytes += KvCache::bytes_read_at(self.model.n_heads,
                                                 self.model.d_head, ctx)
                + KvCache::bytes_per_token_write(self.model.n_heads,
                                                 self.model.d_head);
        } else {
            // recompute attention for every retained token
            let (ns, nj) = self.energy.attention(ctx + 1, ctx + 1);
            m.breakdown.attn_ns = ns;
            m.breakdown.attn_nj = nj;
            m.macs += (ctx as u64 + 1)
                * self.model.attn_macs_per_token(ctx + 1);
        }

        // ---- gate + MoE ----
        let one_token_route: Vec<usize>; // experts running the new token
        let tokens_fed: usize;
        if self.cfg.cache.go || self.cfg.routing == RoutingMode::TokenChoice {
            tokens_fed = 1;
            let row = &scores[new_tok * e..(new_tok + 1) * e];
            one_token_route = match self.cfg.routing {
                RoutingMode::ExpertChoice => {
                    let cache = go_cache
                        .as_mut()
                        .expect("GO cache required for expert-choice decode");
                    let upd = cache.update_scores(new_tok, row);
                    // GO-cache DRAM traffic: score append + threshold read
                    // + one output-cache entry rewrite per changed expert
                    dram_bytes += GoCache::score_bytes_per_token(e) * 2;
                    dram_bytes += GoCache::output_write_bytes(
                        upd.selected.len(), self.model.d_model);
                    upd.selected
                }
                RoutingMode::TokenChoice => {
                    token_choice_route(row, 1, e, self.model.top_k)
                        .choices
                        .experts_of(0)
                }
            };
            // one-token MoE: selected experts, serialised inside groups
            let mut per_group = vec![0usize; grouping.n_groups()];
            for &x in &one_token_route {
                per_group[grouping.group_of[x]] += 1;
            }
            let slots = per_group.iter().copied().max().unwrap_or(0) as f64;
            let work = one_token_route.len() as u64;
            let acts = work * self.layout.activations_per_token_expert();
            m.breakdown.moe_ns = slots
                * self.layout.rounds_per_token as f64
                * self.hw.core_latency_ns;
            m.breakdown.moe_nj = self.energy.activations_nj(acts)
                + self.energy.transfers_nj(1, self.model.d_model);
            m.activations = acts;
            m.transfers = 1;
            m.macs += acts * self.hw.macs_per_activation();
        } else {
            // no GO cache: feed ALL retained tokens through gate + MoE
            tokens_fed = ctx + 1;
            let routing = self.route_batch(scores, ctx + 1);
            // decode stage is not rescheduled (§III-D: schedule applies to
            // prefill only) — token-wise
            let schedule = sched::build(&routing.choices, grouping,
                                        SchedulePolicy::TokenWise);
            let moe = self.price_schedule(&schedule);
            m.breakdown.moe_ns = moe.latency_ns;
            m.breakdown.moe_nj = moe.energy_nj;
            m.activations = moe.activations;
            m.transfers = moe.transfers;
            m.macs += moe.macs;
            // with the KV cache, past tokens' hidden states must still be
            // rebuilt for the gate (KV reuse skips their projections, the
            // attend term remains) and streamed into the PIM input buffers
            if self.cfg.cache.kv {
                let (rns, rnj) = self
                    .energy
                    .attention_cached_recompute(ctx, ctx + 1);
                m.breakdown.attn_ns += rns;
                m.breakdown.attn_nj += rnj;
                m.macs += (ctx as u64)
                    * 2 * (ctx as u64 + 1) * self.model.d_model as u64;
                dram_bytes += (ctx as u64) * self.model.d_model as u64;
            }
        }
        let (gate_ns, gate_nj) = self.energy.gate(tokens_fed);
        m.breakdown.gate_ns = gate_ns;
        m.breakdown.gate_nj = gate_nj;
        m.macs += tokens_fed as u64 * self.model.gate_macs_per_token();

        // ---- totals ----
        let (dram_ns, dram_nj) = self.hw.dram.transfer(dram_bytes);
        m.breakdown.dram_ns = dram_ns;
        m.breakdown.dram_nj = dram_nj;
        m.latency_ns = m.breakdown.attn_ns
            + m.breakdown.gate_ns
            + m.breakdown.moe_ns
            + dram_ns;
        m.energy_nj = m.breakdown.attn_nj
            + m.breakdown.gate_nj
            + m.breakdown.moe_nj
            + dram_nj;
        m
    }

    // -----------------------------------------------------------------------
    // Whole inference
    // -----------------------------------------------------------------------

    pub fn run(&self) -> InferenceReport {
        let scores = self.workload_scores();
        let grouping = self.make_grouping();
        let prefill_routing =
            self.route_batch(&scores, self.cfg.prompt_len);
        let prefill = self.prefill(&prefill_routing, &grouping);

        let mut go_cache = if self.cfg.routing == RoutingMode::ExpertChoice {
            let mut c = GoCache::new(self.model.n_experts, self.capacity(), 0);
            c.seed_from_routing(&prefill_routing);
            Some(c)
        } else {
            None
        };

        let mut decode_steps = Vec::with_capacity(self.cfg.gen_len);
        for s in 0..self.cfg.gen_len {
            let ctx = self.cfg.prompt_len + s;
            decode_steps.push(self.decode_step(ctx, &scores, &grouping,
                                               &mut go_cache));
        }

        InferenceReport {
            label: self.cfg.label(),
            cache_label: self.cfg.cache.label(),
            prefill,
            decode_steps,
            moe_area_mm2: self
                .area
                .moe_area_mm2(&self.layout, self.cfg.group_size),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CachePolicy;

    fn sim(cache: CachePolicy) -> Simulator {
        let mut cfg = SimConfig::baseline();
        cfg.cache = cache;
        Simulator::paper(cfg)
    }

    #[test]
    fn baseline_prefill_structure() {
        let s = sim(CachePolicy::NONE);
        let scores = s.workload_scores();
        let routing = s.route_batch(&scores, 32);
        let grouping = s.make_grouping();
        let m = s.prefill(&routing, &grouping);
        // expert-choice: 16 experts x 8 tokens = 128 work items x 96 tiles
        assert_eq!(m.activations, 128 * 96);
        // token-wise singleton: one slot per active token; makespan 32
        // blocks x 2 rounds x 130ns = 8320 ns of MoE time
        assert!((m.breakdown.moe_ns - 32.0 * 2.0 * 130.0).abs() < 1e-6);
        assert!(m.latency_ns > m.breakdown.moe_ns); // attention adds
        assert_eq!(m.breakdown.dram_ns, 0.0); // no caches
    }

    #[test]
    fn cache_regimes_order_latency() {
        // per-step decode latency must order: KVGO < KV < none, GO < none
        let mut lat = std::collections::BTreeMap::new();
        for (name, cache) in [
            ("none", CachePolicy::NONE),
            ("kv", CachePolicy::KV),
            ("go", CachePolicy::GO),
            ("kvgo", CachePolicy::KVGO),
        ] {
            let r = sim(cache).run();
            lat.insert(name, r.decode_total().latency_ns);
        }
        assert!(lat["kvgo"] < lat["kv"], "{lat:?}");
        assert!(lat["kv"] < lat["none"], "{lat:?}");
        assert!(lat["go"] < lat["none"], "{lat:?}");
        assert!(lat["kvgo"] < lat["go"], "{lat:?}");
    }

    #[test]
    fn kvgo_energy_improvement_grows_with_length() {
        let ratio_at = |gen: usize| {
            let mut c0 = SimConfig::baseline();
            c0.gen_len = gen;
            let mut c1 = c0.clone();
            c1.cache = CachePolicy::KVGO;
            let base = Simulator::paper(c0).run().decode_total();
            let kvgo = Simulator::paper(c1).run().decode_total();
            base.energy_nj / kvgo.energy_nj
        };
        let r8 = ratio_at(8);
        let r64 = ratio_at(64);
        assert!(r8 > 2.0, "expected large energy win at 8 tokens, got {r8}");
        assert!(r64 > r8, "win must grow with length: {r8} -> {r64}");
    }

    #[test]
    fn kvgo_step_growth_much_slower_than_baseline() {
        // KVGO per-step cost grows only via the KV stream (O(L), shallow);
        // the uncached baseline re-feeds and re-attends everything
        // (O(L^2)).  Growth factor over 64 steps must be far smaller.
        let growth = |cache: CachePolicy| {
            let mut cfg = SimConfig::baseline();
            cfg.cache = cache;
            cfg.gen_len = 64;
            let r = Simulator::paper(cfg).run();
            r.decode_steps.last().unwrap().latency_ns
                / r.decode_steps.first().unwrap().latency_ns
        };
        let g_kvgo = growth(CachePolicy::KVGO);
        let g_none = growth(CachePolicy::NONE);
        // KVGO's residual growth is the calibrated KV stream (O(L), shallow)
        assert!(g_kvgo < g_none * 0.75,
                "KVGO growth {g_kvgo} vs baseline {g_none}");
        assert!(g_kvgo < 4.0, "KVGO per-step growth {g_kvgo}");
    }

    #[test]
    fn sharing_shrinks_area_and_adds_contention() {
        let base = Simulator::paper(SimConfig::baseline()).run();
        let mut cfg = SimConfig::s2o_kvgo();
        cfg.cache = CachePolicy::NONE;
        let shared = Simulator::paper(cfg).run();
        assert!(shared.moe_area_mm2 < base.moe_area_mm2);
        // compact schedule means prefill MoE latency improves despite
        // sharing (bottleneck group < token count blocks)
        assert!(shared.prefill.breakdown.moe_ns
                <= base.prefill.breakdown.moe_ns);
    }

    #[test]
    fn deterministic_runs() {
        let a = Simulator::paper(SimConfig::s2o_kvgo()).run();
        let b = Simulator::paper(SimConfig::s2o_kvgo()).run();
        assert_eq!(a.total(), b.total());
    }

    #[test]
    fn token_choice_mode_runs() {
        let mut cfg = SimConfig::baseline();
        cfg.routing = RoutingMode::TokenChoice;
        cfg.skew = 1.2;
        let r = Simulator::paper(cfg).run();
        assert!(r.total().latency_ns > 0.0);
        assert_eq!(r.decode_steps.len(), 8);
    }
}
