//! Metric types produced by the simulator: per-stage latency/energy with an
//! operator breakdown, plus the derived efficiency figures the paper
//! reports (GOPS, GOPS/mm², GOPS/W/mm²).
//!
//! Conventions:
//! * latency in ns, energy in nJ, area in mm²;
//! * `macs` counts MAC operations *executed by the hardware* (recomputation
//!   included) — GOPS is hardware throughput, as an accelerator reports it;
//! * GOPS = 2·macs / latency_ns (multiply-accumulate = 2 ops, latency in ns
//!   makes the ratio come out in 1e9 ops/s);
//! * GOPS/W = 2·macs / energy_nj (ops per nJ == Gops/s per W);
//! * density GOPS/W/mm² divides by the MoE-linear-cores area (§IV-A scope).

/// Additive cost breakdown of one stage (prefill, one decode step, or sums).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    pub attn_ns: f64,
    pub attn_nj: f64,
    pub gate_ns: f64,
    pub gate_nj: f64,
    pub moe_ns: f64,
    pub moe_nj: f64,
    pub dram_ns: f64,
    pub dram_nj: f64,
}

impl Breakdown {
    pub fn add(&mut self, o: &Breakdown) {
        self.attn_ns += o.attn_ns;
        self.attn_nj += o.attn_nj;
        self.gate_ns += o.gate_ns;
        self.gate_nj += o.gate_nj;
        self.moe_ns += o.moe_ns;
        self.moe_nj += o.moe_nj;
        self.dram_ns += o.dram_ns;
        self.dram_nj += o.dram_nj;
    }
}

/// Cost of one simulated stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageMetrics {
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub breakdown: Breakdown,
    /// PIM core activations
    pub activations: u64,
    /// activation-vector broadcasts into group DACs
    pub transfers: u64,
    /// MACs executed (PIM + digital)
    pub macs: u64,
}

impl StageMetrics {
    pub fn add(&mut self, o: &StageMetrics) {
        self.latency_ns += o.latency_ns;
        self.energy_nj += o.energy_nj;
        self.breakdown.add(&o.breakdown);
        self.activations += o.activations;
        self.transfers += o.transfers;
        self.macs += o.macs;
    }

    pub fn gops(&self) -> f64 {
        if self.latency_ns == 0.0 {
            0.0
        } else {
            2.0 * self.macs as f64 / self.latency_ns
        }
    }

    pub fn gops_per_w(&self) -> f64 {
        if self.energy_nj == 0.0 {
            0.0
        } else {
            2.0 * self.macs as f64 / self.energy_nj
        }
    }
}

/// Full-inference report: prefill + decode totals plus area-derived
/// efficiency (what Table I prints).
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub label: String,
    pub cache_label: &'static str,
    pub prefill: StageMetrics,
    /// per-step decode metrics, in generation order
    pub decode_steps: Vec<StageMetrics>,
    pub moe_area_mm2: f64,
}

impl InferenceReport {
    pub fn decode_total(&self) -> StageMetrics {
        let mut total = StageMetrics::default();
        for s in &self.decode_steps {
            total.add(s);
        }
        total
    }

    pub fn total(&self) -> StageMetrics {
        let mut t = self.prefill;
        t.add(&self.decode_total());
        t
    }

    /// Area efficiency over the whole inference, GOPS/mm² (Fig. 5's y-axis).
    pub fn gops_per_mm2(&self) -> f64 {
        self.total().gops() / self.moe_area_mm2
    }

    /// Performance density, GOPS/W/mm² (Table I's bottom row).
    pub fn density(&self) -> f64 {
        self.total().gops_per_w() / self.moe_area_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(lat: f64, nj: f64, macs: u64) -> StageMetrics {
        StageMetrics {
            latency_ns: lat,
            energy_nj: nj,
            macs,
            ..Default::default()
        }
    }

    #[test]
    fn addition() {
        let mut a = stage(10.0, 5.0, 100);
        a.add(&stage(5.0, 2.0, 50));
        assert_eq!(a.latency_ns, 15.0);
        assert_eq!(a.energy_nj, 7.0);
        assert_eq!(a.macs, 150);
    }

    #[test]
    fn gops_definition() {
        let s = stage(100.0, 50.0, 1000);
        assert!((s.gops() - 20.0).abs() < 1e-9); // 2*1000/100
        assert!((s.gops_per_w() - 40.0).abs() < 1e-9); // 2*1000/50
        assert_eq!(stage(0.0, 0.0, 10).gops(), 0.0);
    }

    #[test]
    fn report_totals() {
        let r = InferenceReport {
            label: "test".into(),
            cache_label: "no cache",
            prefill: stage(100.0, 10.0, 500),
            decode_steps: vec![stage(10.0, 1.0, 50), stage(10.0, 1.0, 50)],
            moe_area_mm2: 2.0,
        };
        assert_eq!(r.total().latency_ns, 120.0);
        assert_eq!(r.decode_total().macs, 100);
        assert!((r.gops_per_mm2() - (2.0 * 600.0 / 120.0) / 2.0).abs() < 1e-9);
        assert!((r.density() - (2.0 * 600.0 / 12.0) / 2.0).abs() < 1e-9);
    }
}
