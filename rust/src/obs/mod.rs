//! Observability: request-lifecycle span tracing, per-thread ring-buffer
//! sinks, Chrome/Perfetto trace export, and the unified metrics registry.
//!
//! This is the telemetry substrate for the serving stack.  The hot router
//! loops record typed [`Event`]s into a per-thread [`TraceSink`] (a no-op
//! when tracing is off); drained [`TraceShard`]s merge into one
//! `moepim.spans.v1` Chrome trace-event document via
//! [`export::chrome_trace`] (`--trace-out`); and [`MetricsRegistry`]
//! renders the same run as a Prometheus-style text snapshot
//! (`--metrics-file`) and as the `metrics` section of the SLO reports.
//!
//! Clock domains: `Server`/`Cluster` stamp events with [`span::now_ns`]
//! (process-global monotonic); the virtual simulator stamps them with its
//! own event clock, so virtual traces are byte-identical per seed.  See
//! DESIGN.md §Observability for the event taxonomy and schema tables.

pub mod export;
pub mod registry;
pub mod sink;
pub mod span;

pub use export::{chrome_trace, check_conservation, SPANS_SCHEMA};
pub use registry::MetricsRegistry;
pub use sink::{TraceShard, TraceSink, DEFAULT_CAPACITY};
pub use span::{now_ns, Event, EventKind, SpanOutcome};
