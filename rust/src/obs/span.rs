//! Typed request-lifecycle and router-cycle trace events.
//!
//! One [`Event`] is a timestamped point (or span, when `dur_ns > 0`) in a
//! request's life or in a router thread's cycle loop.  The same event
//! vocabulary is recorded in three places:
//!
//! * the real [`crate::coordinator::Server`] router thread and the
//!   [`crate::coordinator::Cluster`] placement thread, stamped on the
//!   process-global monotonic clock ([`now_ns`]);
//! * the virtual-time simulator (`workload::vsim`), stamped on the virtual
//!   event clock directly — so a virtual trace dump is byte-identical
//!   across reruns at the same seed.
//!
//! The numbers carried by each variant are deliberately plain (`u64` /
//! `usize`) so recording is a couple of field copies on the hot path; all
//! string rendering happens at export time (`obs::export`).

use std::sync::OnceLock;
use std::time::Instant;

/// How a request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Finished with a full token stream.
    Ok,
    /// Finished with a terminal error (engine failure, rejected size, …).
    Error,
    /// Shed by backpressure before reaching a slot (immediate terminal
    /// `overloaded` reply).
    Shed,
}

impl SpanOutcome {
    /// Stable label used in the exported trace (`args.outcome`).
    pub fn label(self) -> &'static str {
        match self {
            SpanOutcome::Ok => "ok",
            SpanOutcome::Error => "error",
            SpanOutcome::Shed => "shed",
        }
    }
}

/// The event vocabulary — request-lifecycle points plus router-cycle spans
/// and queue-depth counter samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the cluster front door's intake queue.
    Intake {
        /// Request id.
        id: u64,
    },
    /// The placement thread picked a backend shard for the request.
    Placed {
        /// Request id.
        id: u64,
        /// Chosen backend shard.
        shard: usize,
    },
    /// Request entered a server's admission queue.
    Queued {
        /// Request id.
        id: u64,
    },
    /// The admission policy granted the request a batch slot.
    SlotGrant {
        /// Request id.
        id: u64,
        /// Granted slot index.
        slot: usize,
    },
    /// One chunked-prefill advance for a filling slot.
    PrefillChunk {
        /// Request id.
        id: u64,
        /// Slot being filled.
        slot: usize,
        /// Prompt tokens consumed by this chunk.
        advanced: usize,
        /// Prompt tokens still to prefill after this chunk.
        remaining: usize,
    },
    /// First generated token left the slot (TTFT point).
    FirstToken {
        /// Request id.
        id: u64,
    },
    /// A lower-priority session was checkpointed out of its slot
    /// (QoS preemption); the request goes back to the waiting queue with
    /// its resumable state attached.  Recorded as a span when the
    /// checkpoint has a modeled cost (`dur_ns` covers it).
    Preempt {
        /// Request id of the evicted session.
        id: u64,
        /// Slot the session was evicted from.
        slot: usize,
    },
    /// A checkpointed session was restored into a slot and resumed
    /// decoding.  Recorded as a span when the restore has a modeled cost.
    Restore {
        /// Request id of the resumed session.
        id: u64,
        /// Slot the session was restored into.
        slot: usize,
    },
    /// A queued (not yet admitted) request was migrated between shards by
    /// a dynamic-placement rebalance pass.  The request's arrival instant
    /// travels with it, so downstream queue-wait accounting is unchanged.
    Migrate {
        /// Request id of the moved entry.
        id: u64,
        /// Shard it was stolen from.
        from: usize,
        /// Shard it re-queued on.
        to: usize,
    },
    /// A hot expert group was replicated onto an additional shard by the
    /// dynamic-placement control loop (charged against the area ledger).
    /// Carries no request id: replication is a fleet-level action.
    Replicate {
        /// Expert-group id (group-size granularity of `moe::grouping`).
        group: usize,
        /// Shard that now also hosts the group.
        shard: usize,
    },
    /// Terminal reply sent — exactly one per submitted request.
    Terminal {
        /// Request id.
        id: u64,
        /// How the request left the system.
        outcome: SpanOutcome,
    },
    /// One router cycle (recorded as a span: `dur_ns` covers the cycle).
    Cycle {
        /// Monotone per-router cycle counter.
        index: u64,
        /// Slots holding live decode sessions this cycle.
        live: usize,
        /// Slots still prefilling (chunked admission) this cycle.
        filling: usize,
        /// Requests still waiting in the admission queue.
        waiting: usize,
        /// Planner layer steps dispatched this cycle.
        layer_steps: usize,
        /// Planner crossbar cycles priced for this router cycle.
        plan_cycles: u64,
        /// Contention (peripheral-sharing stall) cycles within them.
        contention: u64,
    },
    /// Queue-depth counter sample (rendered as a Perfetto counter track).
    Depth {
        /// Admission-queue depth.
        waiting: usize,
        /// Live decode slots.
        live: usize,
        /// Filling (chunked-prefill) slots.
        filling: usize,
        /// Front-door intake depth (0 on plain servers).
        intake: usize,
    },
}

impl EventKind {
    /// Stable event name used in the exported trace.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Intake { .. } => "intake",
            EventKind::Placed { .. } => "placed",
            EventKind::Queued { .. } => "queued",
            EventKind::SlotGrant { .. } => "slot_grant",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Restore { .. } => "restore",
            EventKind::Migrate { .. } => "migrate",
            EventKind::Replicate { .. } => "replicate",
            EventKind::Terminal { .. } => "terminal",
            EventKind::Cycle { .. } => "cycle",
            EventKind::Depth { .. } => "depth",
        }
    }

    /// The request id this event belongs to, if it is a lifecycle event.
    pub fn request_id(&self) -> Option<u64> {
        match *self {
            EventKind::Intake { id }
            | EventKind::Placed { id, .. }
            | EventKind::Queued { id }
            | EventKind::SlotGrant { id, .. }
            | EventKind::PrefillChunk { id, .. }
            | EventKind::FirstToken { id }
            | EventKind::Preempt { id, .. }
            | EventKind::Restore { id, .. }
            | EventKind::Migrate { id, .. }
            | EventKind::Terminal { id, .. } => Some(id),
            EventKind::Replicate { .. }
            | EventKind::Cycle { .. }
            | EventKind::Depth { .. } => None,
        }
    }
}

/// One recorded trace event: a timestamp (ns, clock domain owned by the
/// recorder), an optional duration, and the typed payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Event timestamp in nanoseconds (virtual clock or [`now_ns`]).
    pub t_ns: u64,
    /// Span duration in nanoseconds (0 for instant events).
    pub dur_ns: u64,
    /// The typed payload.
    pub kind: EventKind,
}

/// Process-global epoch for real-clock tracing.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-global trace epoch (first call wins).
///
/// Monotonic (`Instant`-backed) and shared across threads, so server
/// router threads and the cluster placement thread stamp events on one
/// comparable axis — per-thread epochs would misalign the merged trace.
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_id_covers_lifecycle_events_only() {
        assert_eq!(EventKind::Queued { id: 7 }.request_id(), Some(7));
        assert_eq!(
            EventKind::Terminal { id: 9, outcome: SpanOutcome::Shed }
                .request_id(),
            Some(9)
        );
        assert_eq!(
            EventKind::Depth { waiting: 0, live: 0, filling: 0, intake: 0 }
                .request_id(),
            None
        );
    }

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }
}
