//! Chrome trace-event JSON export (`moepim.spans.v1`) — Perfetto-loadable.
//!
//! [`chrome_trace`] merges drained [`TraceShard`]s into one JSON document
//! in the Chrome trace-event format (object form, `traceEvents` +
//! `otherData`), which `ui.perfetto.dev` and `chrome://tracing` both load
//! directly:
//!
//! * **pid** = backend shard index (the cluster front door gets its own
//!   pid one past the last shard), labelled via `process_name` metadata;
//! * **tid** = one lane per recording thread within a pid (`router`,
//!   `placement`, `vsim`), labelled via `thread_name` metadata;
//! * request-lifecycle events are instants (`ph:"i"`), with a derived
//!   async span (`ph:"b"`/`"e"`, cat `request`, id = request id) from a
//!   request's first recorded event to its terminal;
//! * router cycles are complete spans (`ph:"X"`) with real durations;
//! * queue depths are counter tracks (`ph:"C"`).
//!
//! Timestamps are rebased to the earliest event and emitted in
//! microseconds (fractional — the source clocks are ns).  All maps are
//! ordered, so a virtual-clock trace serialises byte-identically per seed.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::sink::TraceShard;
use super::span::{Event, EventKind};

/// Schema tag carried in `otherData.schema`.
pub const SPANS_SCHEMA: &str = "moepim.spans.v1";

fn n(v: usize) -> Json {
    Json::num(v as f64)
}

fn n64(v: u64) -> Json {
    Json::num(v as f64)
}

/// Lane (pid / tid) assignment for one shard's events.
struct Lane {
    pid: usize,
    tid: usize,
}

fn event_args(kind: &EventKind) -> Json {
    match *kind {
        EventKind::Intake { id } => Json::obj(vec![("id", n64(id))]),
        EventKind::Placed { id, shard } => {
            Json::obj(vec![("id", n64(id)), ("shard", n(shard))])
        }
        EventKind::Queued { id } => Json::obj(vec![("id", n64(id))]),
        EventKind::SlotGrant { id, slot } => {
            Json::obj(vec![("id", n64(id)), ("slot", n(slot))])
        }
        EventKind::PrefillChunk { id, slot, advanced, remaining } => {
            Json::obj(vec![
                ("advanced", n(advanced)),
                ("id", n64(id)),
                ("remaining", n(remaining)),
                ("slot", n(slot)),
            ])
        }
        EventKind::FirstToken { id } => Json::obj(vec![("id", n64(id))]),
        EventKind::Preempt { id, slot } => {
            Json::obj(vec![("id", n64(id)), ("slot", n(slot))])
        }
        EventKind::Restore { id, slot } => {
            Json::obj(vec![("id", n64(id)), ("slot", n(slot))])
        }
        EventKind::Migrate { id, from, to } => Json::obj(vec![
            ("from", n(from)),
            ("id", n64(id)),
            ("to", n(to)),
        ]),
        // no "id" key: replication is a fleet action, and the
        // conservation checker must not expect a terminal for it
        EventKind::Replicate { group, shard } => {
            Json::obj(vec![("group", n(group)), ("shard", n(shard))])
        }
        EventKind::Terminal { id, outcome } => Json::obj(vec![
            ("id", n64(id)),
            ("outcome", Json::str(outcome.label())),
        ]),
        EventKind::Cycle {
            index,
            live,
            filling,
            waiting,
            layer_steps,
            plan_cycles,
            contention,
        } => Json::obj(vec![
            ("contention", n64(contention)),
            ("filling", n(filling)),
            ("index", n64(index)),
            ("layer_steps", n(layer_steps)),
            ("live", n(live)),
            ("plan_cycles", n64(plan_cycles)),
            ("waiting", n(waiting)),
        ]),
        EventKind::Depth { waiting, live, filling, intake } => Json::obj(vec![
            ("filling", n(filling)),
            ("intake", n(intake)),
            ("live", n(live)),
            ("waiting", n(waiting)),
        ]),
    }
}

fn instant(name: &str, lane: &Lane, ts_us: f64, args: Json) -> Json {
    Json::obj(vec![
        ("args", args),
        ("name", Json::str(name)),
        ("ph", Json::str("i")),
        ("pid", n(lane.pid)),
        ("s", Json::str("t")),
        ("tid", n(lane.tid)),
        ("ts", Json::num(ts_us)),
    ])
}

/// Merge drained shards into one Chrome trace-event JSON document.
///
/// `clock` labels the time domain in `otherData.clock` — `"virtual"` for
/// vsim traces (byte-identical per seed) or `"real"` for wall-clock runs.
pub fn chrome_trace(shards: &[TraceShard], clock: &str) -> Json {
    // lane assignment: pid = shard index, front door (shard: None) one
    // past the largest shard pid; tids sequential per pid
    let front_pid = shards
        .iter()
        .filter_map(|s| s.shard)
        .max()
        .map(|m| m + 1)
        .unwrap_or(0);
    let mut next_tid: BTreeMap<usize, usize> = BTreeMap::new();
    let mut events: Vec<Json> = Vec::new();

    let t_min = shards
        .iter()
        .flat_map(|s| s.events.iter())
        .map(|e| e.t_ns)
        .min()
        .unwrap_or(0);
    let us = |t_ns: u64| (t_ns - t_min) as f64 / 1000.0;

    // request index for the derived async spans: id -> (first, terminal)
    // with the lane the request was last seen on
    struct ReqTrack {
        first_t: u64,
        first_lane: (usize, usize),
        terminal: Option<(u64, (usize, usize))>,
    }
    let mut requests: BTreeMap<u64, ReqTrack> = BTreeMap::new();
    let mut dropped_total: u64 = 0;

    let mut lanes: Vec<Lane> = Vec::with_capacity(shards.len());
    for shard in shards {
        let pid = shard.shard.unwrap_or(front_pid);
        let tid_slot = next_tid.entry(pid).or_insert(0);
        let lane = Lane { pid, tid: *tid_slot };
        *tid_slot += 1;
        // metadata: label the process and thread lanes
        let pname = match shard.shard {
            Some(s) => format!("shard {s}"),
            None => "front-door".to_string(),
        };
        events.push(Json::obj(vec![
            ("args", Json::obj(vec![("name", Json::str(&pname))])),
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", n(lane.pid)),
            ("tid", n(lane.tid)),
        ]));
        events.push(Json::obj(vec![
            ("args", Json::obj(vec![("name", Json::str(shard.thread))])),
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", n(lane.pid)),
            ("tid", n(lane.tid)),
        ]));
        dropped_total += shard.dropped_events;
        lanes.push(lane);
    }

    for (shard, lane) in shards.iter().zip(&lanes) {
        for ev in &shard.events {
            let Event { t_ns, dur_ns, ref kind } = *ev;
            if let Some(id) = kind.request_id() {
                let track = requests.entry(id).or_insert(ReqTrack {
                    first_t: t_ns,
                    first_lane: (lane.pid, lane.tid),
                    terminal: None,
                });
                if t_ns < track.first_t {
                    track.first_t = t_ns;
                    track.first_lane = (lane.pid, lane.tid);
                }
                if matches!(kind, EventKind::Terminal { .. }) {
                    track.terminal = Some((t_ns, (lane.pid, lane.tid)));
                }
            }
            match kind {
                EventKind::Cycle { .. } => events.push(Json::obj(vec![
                    ("args", event_args(kind)),
                    ("dur", Json::num(dur_ns as f64 / 1000.0)),
                    ("name", Json::str("cycle")),
                    ("ph", Json::str("X")),
                    ("pid", n(lane.pid)),
                    ("tid", n(lane.tid)),
                    ("ts", Json::num(us(t_ns))),
                ])),
                EventKind::Depth { .. } => events.push(Json::obj(vec![
                    ("args", event_args(kind)),
                    ("name", Json::str("depth")),
                    ("ph", Json::str("C")),
                    ("pid", n(lane.pid)),
                    ("tid", n(lane.tid)),
                    ("ts", Json::num(us(t_ns))),
                ])),
                // checkpoint/restore churn carries a modeled cost on the
                // virtual clock: draw it as a complete span so the stall
                // is visible on the lane (real-clock recordings stamp
                // dur 0 and fall through to the instant form)
                EventKind::Preempt { .. } | EventKind::Restore { .. }
                    if dur_ns > 0 =>
                {
                    events.push(Json::obj(vec![
                        ("args", event_args(kind)),
                        ("dur", Json::num(dur_ns as f64 / 1000.0)),
                        ("name", Json::str(kind.name())),
                        ("ph", Json::str("X")),
                        ("pid", n(lane.pid)),
                        ("tid", n(lane.tid)),
                        ("ts", Json::num(us(t_ns))),
                    ]))
                }
                _ => events.push(instant(kind.name(), lane, us(t_ns), event_args(kind))),
            }
        }
    }

    // derived request spans: first event -> terminal, where both survived
    // the ring (drop-oldest can shed a request's early events; the span is
    // only drawn when its endpoints exist)
    for (&id, track) in &requests {
        if let Some((term_t, term_lane)) = track.terminal {
            for (ph, t, (pid, tid)) in [
                ("b", track.first_t, track.first_lane),
                ("e", term_t, term_lane),
            ] {
                events.push(Json::obj(vec![
                    ("cat", Json::str("request")),
                    ("id", n64(id)),
                    ("name", Json::str("request")),
                    ("ph", Json::str(ph)),
                    ("pid", n(pid)),
                    ("tid", n(tid)),
                    ("ts", Json::num(us(t))),
                ]));
            }
        }
    }

    Json::obj(vec![
        ("otherData", Json::obj(vec![
            ("clock", Json::str(clock)),
            ("dropped_events", n64(dropped_total)),
            ("requests", n(requests.len())),
            ("schema", Json::str(SPANS_SCHEMA)),
        ])),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Request-count conservation check over an exported document: every
/// request id appearing in any lifecycle event has exactly one `terminal`
/// event, and the id count matches `otherData.requests`.  Returns the
/// number of requests on success.
pub fn check_conservation(doc: &Json) -> Result<usize, String> {
    let schema = doc
        .path(&["otherData", "schema"])
        .and_then(Json::as_str)
        .ok_or_else(|| "missing otherData.schema".to_string())?;
    if schema != SPANS_SCHEMA {
        return Err(format!("schema {schema:?}, expected {SPANS_SCHEMA:?}"));
    }
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing traceEvents".to_string())?;
    let mut terminals: BTreeMap<u64, u64> = BTreeMap::new();
    for ev in events {
        if ev.get("ph").and_then(Json::as_str) != Some("i") {
            continue;
        }
        let Some(id) = ev.path(&["args", "id"]).and_then(Json::as_f64) else {
            continue;
        };
        let entry = terminals.entry(id as u64).or_insert(0);
        if ev.get("name").and_then(Json::as_str) == Some("terminal") {
            *entry += 1;
        }
    }
    for (id, count) in &terminals {
        if *count != 1 {
            return Err(format!(
                "request {id} has {count} terminal events, expected 1"
            ));
        }
    }
    let declared = doc
        .path(&["otherData", "requests"])
        .and_then(Json::as_usize)
        .ok_or_else(|| "missing otherData.requests".to_string())?;
    if declared != terminals.len() {
        return Err(format!(
            "otherData.requests = {declared} but {} ids seen",
            terminals.len()
        ));
    }
    Ok(terminals.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::sink::TraceSink;
    use crate::obs::span::SpanOutcome;
    use crate::util::json;

    fn demo_shards() -> Vec<TraceShard> {
        let mut router = TraceSink::ring(64);
        router.record(100, EventKind::Queued { id: 1 });
        router.record(200, EventKind::SlotGrant { id: 1, slot: 0 });
        router.record_span(
            200,
            400,
            EventKind::Cycle {
                index: 0,
                live: 1,
                filling: 0,
                waiting: 0,
                layer_steps: 1,
                plan_cycles: 12,
                contention: 3,
            },
        );
        router.record(350, EventKind::FirstToken { id: 1 });
        router.record(
            600,
            EventKind::Terminal { id: 1, outcome: SpanOutcome::Ok },
        );
        router.record(
            650,
            EventKind::Depth { waiting: 0, live: 0, filling: 0, intake: 0 },
        );
        let mut front = TraceSink::ring(64);
        front.record(50, EventKind::Intake { id: 1 });
        front.record(90, EventKind::Placed { id: 1, shard: 0 });
        vec![front.drain(None, "placement"), router.drain(Some(0), "router")]
    }

    #[test]
    fn export_round_trips_and_conserves() {
        let doc = chrome_trace(&demo_shards(), "virtual");
        let text = doc.to_string_pretty();
        let parsed = json::parse(&text).expect("valid JSON");
        assert_eq!(parsed, doc);
        assert_eq!(check_conservation(&parsed), Ok(1));
        // ts is rebased: the earliest event lands at 0
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let min_ts = evs
            .iter()
            .filter_map(|e| e.get("ts").and_then(Json::as_f64))
            .fold(f64::INFINITY, f64::min);
        assert_eq!(min_ts, 0.0);
    }

    #[test]
    fn migration_conserves_and_replication_carries_no_id() {
        let mut front = TraceSink::ring(16);
        front.record(0, EventKind::Intake { id: 3 });
        front.record(1, EventKind::Placed { id: 3, shard: 0 });
        front.record(5, EventKind::Migrate { id: 3, from: 0, to: 1 });
        front.record(6, EventKind::Replicate { group: 2, shard: 1 });
        let mut router = TraceSink::ring(16);
        router.record(
            9,
            EventKind::Terminal { id: 3, outcome: SpanOutcome::Ok },
        );
        let doc = chrome_trace(
            &[front.drain(None, "placement"), router.drain(Some(1), "vsim")],
            "virtual",
        );
        // the migrated request has exactly one terminal (on the target
        // shard's lane) and the replicate instant introduces no phantom id
        assert_eq!(check_conservation(&doc), Ok(1));
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let rep = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("replicate"))
            .unwrap();
        assert!(rep.path(&["args", "id"]).is_none());
        assert_eq!(rep.path(&["args", "group"]).and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn missing_terminal_fails_conservation() {
        let mut sink = TraceSink::ring(16);
        sink.record(0, EventKind::Queued { id: 5 });
        let doc = chrome_trace(&[sink.drain(Some(0), "router")], "virtual");
        assert!(check_conservation(&doc).is_err());
    }

    #[test]
    fn front_door_gets_its_own_pid() {
        let doc = chrome_trace(&demo_shards(), "virtual");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("process_name"))
            .filter_map(|e| e.path(&["args", "name"]).and_then(Json::as_str))
            .collect();
        assert!(names.contains(&"front-door"));
        assert!(names.contains(&"shard 0"));
        // front door pid sits one past the largest shard pid
        let front = evs
            .iter()
            .find(|e| {
                e.path(&["args", "name"]).and_then(Json::as_str)
                    == Some("front-door")
            })
            .unwrap();
        assert_eq!(front.get("pid").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn derived_request_span_pairs_b_and_e() {
        let doc = chrome_trace(&demo_shards(), "virtual");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let b = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("b"))
            .count();
        let e = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("e"))
            .count();
        assert_eq!((b, e), (1, 1));
    }
}
