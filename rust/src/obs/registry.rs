//! Unified metrics registry: named counters / gauges / latency summaries.
//!
//! One [`MetricsRegistry`] holds everything a run wants to expose, keyed
//! by metric name in ordered maps, and renders two deterministic views of
//! the same data:
//!
//! * [`MetricsRegistry::render_text`] — a Prometheus-style text snapshot
//!   (`# HELP` / `# TYPE` plus samples; histograms as summaries with
//!   `quantile` labels) for `--metrics-file` and the `moepim serve`
//!   shutdown dump;
//! * [`MetricsRegistry::to_json`] — the additive `metrics` section
//!   embedded in the v1/v2 SLO reports.
//!
//! Latency distributions reuse [`LatencyHistogram`] (log-bucketed,
//! mergeable), so the registry's quantiles are exactly the report's.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::workload::hist::LatencyHistogram;

/// Summary quantiles rendered for every histogram.
const QUANTILES: [(f64, &str); 3] = [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")];

/// A named collection of counters, gauges, and latency summaries.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, (String, u64)>,
    gauges: BTreeMap<String, (String, f64)>,
    hists: BTreeMap<String, (String, LatencyHistogram)>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `v` to the counter `name` (registered with `help` on first use).
    pub fn counter(&mut self, name: &str, help: &str, v: u64) {
        let entry = self
            .counters
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), 0));
        entry.1 += v;
    }

    /// Set the gauge `name` to `v` (registered with `help` on first use).
    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.gauges.insert(name.to_string(), (help.to_string(), v));
    }

    /// Merge `hist` into the summary `name` (registered with `help` on
    /// first use) — merging is exact on the bucket level.
    pub fn histogram(&mut self, name: &str, help: &str, hist: &LatencyHistogram) {
        let entry = self
            .hists
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), LatencyHistogram::new()));
        entry.1.merge(hist);
    }

    /// Prometheus-style text exposition of the whole registry.  Ordered by
    /// metric name within each family kind, so the snapshot is
    /// deterministic for deterministic inputs.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, (help, v)) in &self.counters {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, (help, v)) in &self.gauges {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, (help, h)) in &self.hists {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (q, label) in QUANTILES {
                out.push_str(&format!(
                    "{name}{{quantile=\"{label}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!(
                "{name}_sum {}\n",
                h.mean_us() * h.count() as f64
            ));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }

    /// The registry as a JSON object — the `metrics` section of the SLO
    /// reports.  Counters and gauges map name → value; summaries map
    /// name → `{count, mean_us, p50_us, p95_us, p99_us, max_us}`.
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(k, (_, v))| (k.clone(), Json::num(*v as f64)))
            .collect();
        let gauges = self
            .gauges
            .iter()
            .map(|(k, (_, v))| (k.clone(), Json::num(*v)))
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, (_, h))| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(h.count() as f64)),
                        ("max_us", Json::num(h.max_us())),
                        ("mean_us", Json::num(h.mean_us())),
                        ("p50_us", Json::num(h.quantile(0.5))),
                        ("p95_us", Json::num(h.quantile(0.95))),
                        ("p99_us", Json::num(h.quantile(0.99))),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("summaries", Json::Obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> MetricsRegistry {
        let mut reg = MetricsRegistry::new();
        reg.counter("moepim_requests_total", "requests submitted", 8);
        reg.counter("moepim_requests_total", "requests submitted", 2);
        reg.gauge("moepim_peak_waiting", "admission queue high-water mark", 3.0);
        let mut h = LatencyHistogram::new();
        for v in [10.0, 20.0, 30.0] {
            h.record(v);
        }
        reg.histogram("moepim_e2e_us", "end-to-end latency", &h);
        reg
    }

    #[test]
    fn counters_accumulate() {
        let reg = demo();
        let j = reg.to_json();
        assert_eq!(
            j.path(&["counters", "moepim_requests_total"])
                .and_then(Json::as_f64),
            Some(10.0)
        );
    }

    #[test]
    fn text_render_is_deterministic_and_complete() {
        let a = demo().render_text();
        let b = demo().render_text();
        assert_eq!(a, b);
        assert!(a.contains("# TYPE moepim_requests_total counter"));
        assert!(a.contains("moepim_requests_total 10"));
        assert!(a.contains("# TYPE moepim_peak_waiting gauge"));
        assert!(a.contains("# TYPE moepim_e2e_us summary"));
        assert!(a.contains("moepim_e2e_us{quantile=\"0.99\"}"));
        assert!(a.contains("moepim_e2e_us_count 3"));
        assert!(a.contains("moepim_e2e_us_sum 60"));
    }

    #[test]
    fn histogram_merge_is_cumulative() {
        let mut reg = MetricsRegistry::new();
        let mut h1 = LatencyHistogram::new();
        h1.record(5.0);
        let mut h2 = LatencyHistogram::new();
        h2.record(7.0);
        reg.histogram("m", "help", &h1);
        reg.histogram("m", "help", &h2);
        assert_eq!(
            reg.to_json().path(&["summaries", "m", "count"]).and_then(Json::as_f64),
            Some(2.0)
        );
    }
}
