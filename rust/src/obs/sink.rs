//! Bounded per-thread ring-buffer trace sink.
//!
//! Each router / placement thread owns one [`TraceSink`] and records into
//! it without any locking or I/O; sinks are drained into [`TraceShard`]s
//! at shutdown and merged by the exporter.  When tracing is off the sink
//! is a no-op whose [`TraceSink::record`] is a single branch — the hot
//! router loop pays nothing.
//!
//! The ring is bounded (default [`DEFAULT_CAPACITY`] events) with
//! drop-oldest semantics: under overflow the newest events are kept (the
//! tail of a run is where terminals live) and the number of discarded
//! events is carried through to the export as `dropped_events`.

use std::collections::VecDeque;

use super::span::{Event, EventKind};

/// Default ring capacity per sink (events).
pub const DEFAULT_CAPACITY: usize = 65_536;

/// The drained contents of one sink: the thread's surviving events plus
/// where they came from.
#[derive(Debug, Clone)]
pub struct TraceShard {
    /// Backend shard index, or `None` for the cluster front door.
    pub shard: Option<usize>,
    /// Thread label (`"router"`, `"placement"`, `"vsim"`, …).
    pub thread: &'static str,
    /// Surviving events in record order (oldest first).
    pub events: Vec<Event>,
    /// Events discarded by ring overflow (drop-oldest).
    pub dropped_events: u64,
}

/// A per-thread event sink: either off (no-op) or a bounded ring.
#[derive(Debug)]
pub struct TraceSink {
    ring: Option<Ring>,
}

#[derive(Debug)]
struct Ring {
    buf: VecDeque<Event>,
    cap: usize,
    dropped: u64,
}

impl TraceSink {
    /// A disabled sink: every record is a no-op.
    pub fn off() -> TraceSink {
        TraceSink { ring: None }
    }

    /// An enabled sink holding at most `cap` events (drop-oldest).
    /// `cap == 0` falls back to [`DEFAULT_CAPACITY`].
    pub fn ring(cap: usize) -> TraceSink {
        let cap = if cap == 0 { DEFAULT_CAPACITY } else { cap };
        TraceSink {
            ring: Some(Ring { buf: VecDeque::new(), cap, dropped: 0 }),
        }
    }

    /// Enabled sink at the default capacity when `on`, otherwise off.
    pub fn on(on: bool) -> TraceSink {
        if on {
            TraceSink::ring(DEFAULT_CAPACITY)
        } else {
            TraceSink::off()
        }
    }

    /// `true` iff events are being kept.  Use to gate any extra work spent
    /// only on computing event payloads.
    pub fn enabled(&self) -> bool {
        self.ring.is_some()
    }

    /// Record an instant event at `t_ns`.
    #[inline]
    pub fn record(&mut self, t_ns: u64, kind: EventKind) {
        self.record_span(t_ns, 0, kind);
    }

    /// Record a span event covering `[t_ns, t_ns + dur_ns)`.
    #[inline]
    pub fn record_span(&mut self, t_ns: u64, dur_ns: u64, kind: EventKind) {
        if let Some(ring) = &mut self.ring {
            if ring.buf.len() == ring.cap {
                ring.buf.pop_front();
                ring.dropped += 1;
            }
            ring.buf.push_back(Event { t_ns, dur_ns, kind });
        }
    }

    /// Drain the surviving events into a [`TraceShard`] tagged with its
    /// origin.  The sink is left empty (and still enabled/disabled as
    /// before); a disabled sink drains to an empty shard.
    pub fn drain(
        &mut self,
        shard: Option<usize>,
        thread: &'static str,
    ) -> TraceShard {
        match &mut self.ring {
            Some(ring) => TraceShard {
                shard,
                thread,
                events: std::mem::take(&mut ring.buf).into(),
                dropped_events: std::mem::take(&mut ring.dropped),
            },
            None => {
                TraceShard { shard, thread, events: Vec::new(), dropped_events: 0 }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::SpanOutcome;

    #[test]
    fn disabled_sink_records_nothing() {
        let mut sink = TraceSink::off();
        assert!(!sink.enabled());
        sink.record(1, EventKind::Queued { id: 1 });
        let shard = sink.drain(Some(0), "router");
        assert!(shard.events.is_empty());
        assert_eq!(shard.dropped_events, 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let mut sink = TraceSink::ring(4);
        for id in 0..10u64 {
            sink.record(id, EventKind::Queued { id });
        }
        sink.record(
            10,
            EventKind::Terminal { id: 9, outcome: SpanOutcome::Ok },
        );
        let shard = sink.drain(None, "test");
        // 11 recorded into a 4-slot ring: 7 dropped, newest 4 kept in order
        assert_eq!(shard.dropped_events, 7);
        assert_eq!(shard.events.len(), 4);
        assert_eq!(shard.events[0].kind, EventKind::Queued { id: 7 });
        assert_eq!(
            shard.events[3].kind,
            EventKind::Terminal { id: 9, outcome: SpanOutcome::Ok }
        );
    }

    #[test]
    fn drain_resets_but_keeps_enabled() {
        let mut sink = TraceSink::ring(8);
        sink.record(0, EventKind::Queued { id: 0 });
        assert_eq!(sink.drain(Some(1), "router").events.len(), 1);
        assert!(sink.enabled());
        assert!(sink.drain(Some(1), "router").events.is_empty());
    }

    #[test]
    fn zero_capacity_falls_back_to_default() {
        let sink = TraceSink::ring(0);
        assert!(sink.enabled());
    }
}
