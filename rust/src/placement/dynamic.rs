//! The closed-loop placer: route-aware homes, periodic rebalance passes
//! that migrate *queued* requests off capacity-weighted hot shards, and
//! budgeted replication of hot expert groups (DESIGN.md §Placement).
//!
//! The placer itself is execution-path-agnostic: it reads and updates a
//! [`RoutingFeedback`] view and emits *plans* (migration moves, replica
//! additions).  The vsim dynamic runner and the real cluster's
//! placement thread own the mechanics — stealing queued entries,
//! re-enqueueing them on the target, emitting `Migrate`/`Replicate`
//! span events — and report what happened through the shared
//! [`PlacementReport`].

use crate::placement::{
    Arrival, Placer, PlacementReport, ReplicaLedger, RoutingFeedback,
};
use crate::workload::vsim::{route_rng, sample_experts, VirtualConfig};

/// Knobs of the dynamic control loop.  The routing knobs must match the
/// serving config's (`n_experts`/`experts_per_token`/`skew`/
/// `group_size`) so the placer's route peek agrees with what the
/// backends will actually route.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DynamicConfig {
    /// run a rebalance pass every this many arrivals (0 disables
    /// migration; replication still runs at would-be ticks)
    pub rebalance_every: usize,
    /// mm² the replica ledger may spend (0 disables replication)
    pub replicate_budget_mm2: f64,
    /// experts in the routed layer
    pub n_experts: usize,
    /// top-k experts per token
    pub experts_per_token: usize,
    /// Zipf-ish routing skew (matches the workload's `route_skew`)
    pub skew: f64,
    /// experts per peripheral-sharing group
    pub group_size: usize,
}

impl DynamicConfig {
    /// Derive the routing knobs from the serving [`VirtualConfig`].
    pub fn from_virtual(
        cfg: &VirtualConfig, rebalance_every: usize,
        replicate_budget_mm2: f64,
    ) -> Self {
        DynamicConfig {
            rebalance_every,
            replicate_budget_mm2,
            n_experts: cfg.n_experts,
            experts_per_token: cfg.experts_per_token,
            skew: cfg.route_skew,
            group_size: cfg.group_size,
        }
    }

    /// Number of expert groups the routing histogram tracks.
    pub fn n_groups(&self) -> usize {
        let g = self.group_size.max(1);
        (self.n_experts.max(1) + g - 1) / g
    }
}

/// The dynamic [`Placer`]: routes each arrival to the capacity-weighted
/// least-loaded host of its expert group (home + any replicas), counts
/// arrivals toward rebalance ticks, and plans migrations/replications
/// when asked.
#[derive(Debug, Clone)]
pub struct DynamicPlacer {
    cfg: DynamicConfig,
    seed: u64,
    ledger: ReplicaLedger,
    arrivals: usize,
    /// control-loop telemetry, harvested into the report's `placement`
    /// block at the end of the run
    pub report: PlacementReport,
}

impl DynamicPlacer {
    /// A dynamic placer for one run; `seed` keys the same routing
    /// stream the backends draw from.
    pub fn new(cfg: DynamicConfig, seed: u64) -> Self {
        DynamicPlacer {
            ledger: ReplicaLedger::paper(
                cfg.replicate_budget_mm2,
                cfg.group_size,
            ),
            cfg,
            seed,
            arrivals: 0,
            report: PlacementReport::default(),
        }
    }

    /// The expert group request `id` routes to — the same
    /// dominant-expert peek static route-aware placement uses, so a
    /// dynamic run with no migrations and no replicas is byte-identical
    /// to the static mapping.
    pub fn group_of(&self, id: u64) -> usize {
        let mut rng = route_rng(self.seed, id);
        let sel = sample_experts(
            &mut rng,
            self.cfg.n_experts.max(1),
            self.cfg.experts_per_token.max(1),
            self.cfg.skew,
        );
        let dominant = sel.first().copied().unwrap_or(0);
        dominant / self.cfg.group_size.max(1)
    }

    /// `true` when the arrival counter just crossed a rebalance tick.
    pub fn due(&self) -> bool {
        self.cfg.rebalance_every > 0
            && self.arrivals > 0
            && self.arrivals % self.cfg.rebalance_every == 0
    }

    /// Plan queued-request migrations for one rebalance tick:
    /// repeatedly move one queued request from the capacity-weighted
    /// hottest shard that still has stealable entries to the
    /// capacity-weighted coldest, while the move *strictly* lowers the
    /// source above the destination (`(load_cold + 1)/slots_cold <
    /// load_hot/slots_hot`, compared exactly).  Each accepted move
    /// lowers a maximal shard and raises a minimal one, so the
    /// normalized spread never increases — the report's
    /// `imbalance_after <= imbalance_before` invariant is structural.
    ///
    /// `stealable[s]` bounds how many entries may leave shard `s` (its
    /// queued, not-yet-admitted, non-resuming count).  Returns
    /// `(from, to)` moves in plan order.
    pub fn plan_migrations(
        &self, fb: &RoutingFeedback, stealable: &[usize],
    ) -> Vec<(usize, usize)> {
        let n = fb.shards().min(stealable.len());
        if n < 2 {
            return Vec::new();
        }
        let slots = |s: usize| fb.spec(s).slots.max(1) as u128;
        let mut loads: Vec<u128> =
            (0..n).map(|s| fb.load(s) as u128).collect();
        let mut avail: Vec<usize> = stealable[..n].to_vec();
        let mut moves = Vec::new();
        loop {
            let mut hot: Option<usize> = None;
            for s in 0..n {
                if avail[s] == 0 {
                    continue;
                }
                hot = Some(match hot {
                    None => s,
                    Some(h) if loads[s] * slots(h) > loads[h] * slots(s) => s,
                    Some(h) => h,
                });
            }
            let Some(hot) = hot else { break };
            let mut cold = 0;
            for s in 1..n {
                if loads[s] * slots(cold) < loads[cold] * slots(s) {
                    cold = s;
                }
            }
            if cold == hot
                || (loads[cold] + 1) * slots(hot) >= loads[hot] * slots(cold)
            {
                break;
            }
            moves.push((hot, cold));
            loads[hot] -= 1;
            loads[cold] += 1;
            avail[hot] -= 1;
        }
        moves
    }

    /// Replicate hot expert groups while the area budget allows: each
    /// pass takes the hottest not-fully-replicated group and adds a
    /// replica on the capacity-weighted least-loaded non-host.  Returns
    /// the `(group, shard)` additions; the ledger's spend lands in
    /// `report.area_mm2_delta`.
    pub fn maybe_replicate(
        &mut self, fb: &mut RoutingFeedback,
    ) -> Vec<(usize, usize)> {
        let mut added = Vec::new();
        while let Some(g) = fb.hottest_unreplicated() {
            if !self.ledger.try_charge() {
                break;
            }
            let hosts = fb.hosts(g).to_vec();
            let candidates: Vec<usize> = (0..fb.shards())
                .filter(|s| !hosts.contains(s))
                .collect();
            let target = fb.least_loaded_among(&candidates);
            if !fb.add_replica(g, target) {
                break;
            }
            self.report.replicas += 1;
            added.push((g, target));
        }
        self.report.area_mm2_delta = self.ledger.spent_mm2();
        added
    }

    /// Record one rebalance tick's pre/post-migration normalized
    /// spread; the report keeps the worst tick's pair (so `before` is
    /// the run's worst observed imbalance and `after` is what the same
    /// tick's migrations left behind).
    pub fn note_imbalance(&mut self, before: f64, after: f64) {
        if before >= self.report.imbalance_before {
            self.report.imbalance_before = before;
            self.report.imbalance_after = after;
        }
    }
}

impl Placer for DynamicPlacer {
    fn label(&self) -> &'static str {
        "dynamic"
    }

    fn place(&mut self, arrival: &Arrival, fb: &mut RoutingFeedback)
        -> usize {
        self.arrivals += 1;
        let g = self.group_of(arrival.id);
        fb.observe(g);
        let hosts = fb.hosts(g).to_vec();
        fb.least_loaded_among(&hosts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{ShardSpec, StaticPlacer};
    use crate::workload::arrival::{
        ArrivalProcess, SizeModel, WorkloadSpec,
    };
    use crate::workload::shard::PlacementPolicy;

    fn dcfg() -> DynamicConfig {
        DynamicConfig::from_virtual(&VirtualConfig::default(), 8, 0.0)
    }

    fn fb(slot_counts: &[usize], groups: usize) -> RoutingFeedback {
        let specs: Vec<ShardSpec> =
            slot_counts.iter().map(|&s| ShardSpec::real(s)).collect();
        RoutingFeedback::new(specs, groups)
    }

    #[test]
    fn unperturbed_dynamic_matches_static_route_aware() {
        let spec = WorkloadSpec {
            seed: 19,
            requests: 32,
            arrival: ArrivalProcess::Poisson { rate_rps: 2_000.0 },
            sizes: SizeModel::Uniform { prompt: (4, 12), gen: (1, 8) },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 200,
            interactive_mix: 1.0,
        };
        let cfg = VirtualConfig::default();
        let d = DynamicConfig::from_virtual(&cfg, 0, 0.0);
        let n = 3usize;
        let mut dynp = DynamicPlacer::new(d, spec.seed);
        let mut f = fb(&[4, 4, 4], d.n_groups());
        let mut stat = StaticPlacer::new(
            PlacementPolicy::route_aware(&cfg),
            spec.seed,
            n,
        );
        for r in spec.materialize() {
            let a = Arrival::of(&r);
            assert_eq!(dynp.place(&a, &mut f), stat.place_next(&a));
        }
    }

    #[test]
    fn rebalance_ticks_follow_the_arrival_counter() {
        let mut p = DynamicPlacer::new(dcfg(), 7);
        let mut f = fb(&[4, 4], dcfg().n_groups());
        assert!(!p.due());
        for i in 0..16u64 {
            let a = Arrival {
                id: i,
                prompt_len: 4,
                gen_len: 2,
                arrival_ns: i * 1_000,
            };
            p.place(&a, &mut f);
            assert_eq!(p.due(), (i + 1) % 8 == 0, "arrival {i}");
        }
    }

    #[test]
    fn migration_plan_drains_hot_toward_cold() {
        let p = DynamicPlacer::new(dcfg(), 7);
        let mut f = fb(&[4, 4], dcfg().n_groups());
        f.set_load(0, 6);
        f.set_load(1, 0);
        let moves = p.plan_migrations(&f, &[6, 0]);
        // 6/0 balances to 3/3: strictly-improving moves only
        assert_eq!(moves, vec![(0, 1), (0, 1), (0, 1)]);
        // stealable bound caps the plan
        let capped = p.plan_migrations(&f, &[1, 0]);
        assert_eq!(capped, vec![(0, 1)]);
        // balanced loads plan nothing
        f.set_load(0, 3);
        f.set_load(1, 3);
        assert!(p.plan_migrations(&f, &[3, 3]).is_empty());
    }

    #[test]
    fn migration_plan_weights_by_capacity() {
        let p = DynamicPlacer::new(dcfg(), 7);
        // shard 0: 2 slots / load 4 (norm 2.0); shard 1: 8 slots /
        // load 6 (norm 0.75): raw counts would call shard 1 hot.
        let mut f = fb(&[2, 8], dcfg().n_groups());
        f.set_load(0, 4);
        f.set_load(1, 6);
        let moves = p.plan_migrations(&f, &[4, 6]);
        assert!(!moves.is_empty());
        assert!(moves.iter().all(|&m| m == (0, 1)), "{moves:?}");
    }

    #[test]
    fn replication_respects_the_ledger_budget() {
        let mut cfg = dcfg();
        cfg.replicate_budget_mm2 = 200.0;
        let mut p = DynamicPlacer::new(cfg, 7);
        let mut f = fb(&[4, 4, 4], cfg.n_groups());
        f.observe(5);
        f.observe(5);
        f.observe(2);
        let added = p.maybe_replicate(&mut f);
        assert!(!added.is_empty());
        assert_eq!(added[0].0, 5, "hottest group replicates first");
        assert_eq!(p.report.replicas, added.len() as u64);
        assert!(p.report.area_mm2_delta <= 200.0 + 1e-9);
        assert!(p.report.area_mm2_delta > 0.0);
        // zero budget: no replicas, no spend
        let mut z = DynamicPlacer::new(dcfg(), 7);
        let mut fz = fb(&[4, 4], dcfg().n_groups());
        fz.observe(1);
        assert!(z.maybe_replicate(&mut fz).is_empty());
        assert_eq!(z.report.area_mm2_delta, 0.0);
    }

    #[test]
    fn worst_tick_wins_the_imbalance_pair() {
        let mut p = DynamicPlacer::new(dcfg(), 7);
        p.note_imbalance(0.5, 0.25);
        p.note_imbalance(0.2, 0.0);
        assert_eq!(p.report.imbalance_before, 0.5);
        assert_eq!(p.report.imbalance_after, 0.25);
        p.note_imbalance(1.5, 0.75);
        assert_eq!(p.report.imbalance_before, 1.5);
        assert_eq!(p.report.imbalance_after, 0.75);
    }
}
