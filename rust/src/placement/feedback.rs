//! The feedback half of the placement control loop: per-shard capacity
//! and live load signals, the expert-group routing histogram, and the
//! group→hosts replica map a dynamic placer routes against.
//!
//! A [`RoutingFeedback`] is a *view*, not a policy: the execution paths
//! (the vsim dynamic runner and the real cluster's placement thread)
//! refresh its loads from their backends, the placer reads and updates
//! it.  Capacities come as [`ShardSpec`]s, one per shard, so mixed
//! fleets — shards with different slot counts or cost constants — are
//! first-class: every load comparison is capacity-weighted
//! (`load / slots`, compared exactly via integer cross-multiplication).

use crate::workload::shard::{
    REAL_EST_DECODE_NS_PER_TOKEN, REAL_EST_PREFILL_NS_PER_TOKEN,
};
use crate::workload::vsim::VirtualConfig;

/// Capacity description of one shard's backend — the heterogeneous
/// replacement for the all-shards-identical assumption the static
/// fan-out baked in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// serving slots (continuous-batching width) on this shard
    pub slots: usize,
    /// estimated prefill cost per prompt token (ns)
    pub prefill_ns_per_token: u64,
    /// estimated cost per generated token (ns)
    pub decode_ns_per_token: u64,
}

impl ShardSpec {
    /// Derive the spec from the [`VirtualConfig`] serving this shard —
    /// the same estimate math as
    /// [`crate::workload::PlacementPolicy::least_outstanding`], so the
    /// capacity weights agree with the split-time estimates.
    pub fn from_virtual(cfg: &VirtualConfig) -> Self {
        let per_token_cycles = 2 * cfg.n_layers.max(1) as u64
            * cfg.experts_per_token.max(1) as u64;
        ShardSpec {
            slots: cfg.slots.max(1),
            prefill_ns_per_token: cfg.prefill_ns_per_token,
            decode_ns_per_token: cfg.dispatch_overhead_ns
                + per_token_cycles * cfg.cycle_ns,
        }
    }

    /// The `--real` threaded-server calibration estimates with an
    /// explicit slot count.
    pub fn real(slots: usize) -> Self {
        ShardSpec {
            slots: slots.max(1),
            prefill_ns_per_token: REAL_EST_PREFILL_NS_PER_TOKEN,
            decode_ns_per_token: REAL_EST_DECODE_NS_PER_TOKEN,
        }
    }
}

/// The live feedback view a [`crate::placement::Placer`] decides from:
/// per-shard loads over per-shard capacities, the expert-group routing
/// histogram, and which shards host each group (home + replicas).
///
/// The histogram is the online mirror of what `moe::trace` calibration
/// samples predict offline — [`RoutingFeedback::prime`] seeds it from a
/// calibration run so replication decisions are informed before the
/// first rebalance tick, then [`RoutingFeedback::observe`] keeps it
/// current per arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingFeedback {
    specs: Vec<ShardSpec>,
    loads: Vec<usize>,
    hist: Vec<u64>,
    hosts: Vec<Vec<usize>>,
}

impl RoutingFeedback {
    /// A feedback view over `specs.len()` shards and `n_groups` expert
    /// groups.  Group `g`'s home shard is `g % shards` (matching the
    /// static route-aware mapping, so a dynamic run with no migrations
    /// and no replicas routes exactly like the static policy).
    pub fn new(specs: Vec<ShardSpec>, n_groups: usize) -> Self {
        let specs = if specs.is_empty() {
            vec![ShardSpec::from_virtual(&VirtualConfig::default())]
        } else {
            specs
        };
        let n = specs.len();
        let groups = n_groups.max(1);
        RoutingFeedback {
            loads: vec![0; n],
            hist: vec![0; groups],
            hosts: (0..groups).map(|g| vec![g % n]).collect(),
            specs,
        }
    }

    /// A homogeneous fleet: `n` shards of the same [`ShardSpec`].
    pub fn uniform(n: usize, spec: ShardSpec, n_groups: usize) -> Self {
        Self::new(vec![spec; n.max(1)], n_groups)
    }

    /// Number of shards in the view.
    pub fn shards(&self) -> usize {
        self.specs.len()
    }

    /// Number of expert groups in the view.
    pub fn groups(&self) -> usize {
        self.hist.len()
    }

    /// One shard's capacity spec.
    pub fn spec(&self, shard: usize) -> &ShardSpec {
        &self.specs[shard.min(self.specs.len() - 1)]
    }

    /// Refresh one shard's live load (requests in flight, however the
    /// execution path counts them).
    pub fn set_load(&mut self, shard: usize, load: usize) {
        if shard < self.loads.len() {
            self.loads[shard] = load;
        }
    }

    /// One shard's last-set live load.
    pub fn load(&self, shard: usize) -> usize {
        self.loads.get(shard).copied().unwrap_or(0)
    }

    /// Record one arrival routed to expert group `group`.
    pub fn observe(&mut self, group: usize) {
        if group < self.hist.len() {
            self.hist[group] += 1;
        }
    }

    /// Seed the histogram with expected per-group loads (e.g. from a
    /// `moe::trace` calibration sample, collapsed by
    /// [`crate::moe::trace::group_loads`]); fractional loads round to
    /// the nearest count.
    pub fn prime(&mut self, expected: &[f64]) {
        for (g, &w) in expected.iter().enumerate().take(self.hist.len()) {
            self.hist[g] += w.max(0.0).round() as u64;
        }
    }

    /// The routing histogram count of one group.
    pub fn hist(&self, group: usize) -> u64 {
        self.hist.get(group).copied().unwrap_or(0)
    }

    /// The shards hosting `group`, home first, replicas in the order
    /// they were added.
    pub fn hosts(&self, group: usize) -> &[usize] {
        &self.hosts[group.min(self.hosts.len() - 1)]
    }

    /// Add a replica of `group` on `shard`; `false` (no change) when the
    /// shard already hosts the group.
    pub fn add_replica(&mut self, group: usize, shard: usize) -> bool {
        let g = group.min(self.hosts.len() - 1);
        if self.hosts[g].contains(&shard) {
            return false;
        }
        self.hosts[g].push(shard);
        true
    }

    /// Total replicas across all groups (hosts beyond each group's home).
    pub fn replicas(&self) -> u64 {
        self.hosts.iter().map(|h| (h.len() - 1) as u64).sum()
    }

    /// `true` when shard `a`'s capacity-weighted load strictly exceeds
    /// shard `b`'s: `load_a / slots_a > load_b / slots_b`, compared
    /// exactly as `load_a · slots_b > load_b · slots_a` (no float ties).
    pub fn heavier(&self, a: usize, b: usize) -> bool {
        let la = self.loads[a] as u128 * self.specs[b].slots.max(1) as u128;
        let lb = self.loads[b] as u128 * self.specs[a].slots.max(1) as u128;
        la > lb
    }

    /// The capacity-weighted least-loaded shard among `candidates`
    /// (ties to the lowest shard id).  Falls back to shard 0 on an
    /// empty candidate list.
    pub fn least_loaded_among(&self, candidates: &[usize]) -> usize {
        let mut best: Option<usize> = None;
        for &c in candidates {
            if c >= self.specs.len() {
                continue;
            }
            best = Some(match best {
                None => c,
                Some(b) if self.heavier(b, c) => c,
                Some(b) => b,
            });
        }
        best.unwrap_or(0)
    }

    /// The capacity-weighted least-loaded shard overall.
    pub fn least_loaded(&self) -> usize {
        let all: Vec<usize> = (0..self.shards()).collect();
        self.least_loaded_among(&all)
    }

    /// One shard's normalized load: `load / slots`.
    pub fn norm_load(&self, shard: usize) -> f64 {
        self.loads[shard] as f64 / self.specs[shard].slots.max(1) as f64
    }

    /// The normalized load spread: `max − min` of `load / slots` across
    /// shards — the imbalance measure the rebalance pass drives down and
    /// the report's `imbalance_before`/`imbalance_after` carry.
    pub fn spread(&self) -> f64 {
        let mut max = f64::MIN;
        let mut min = f64::MAX;
        for s in 0..self.shards() {
            let v = self.norm_load(s);
            max = max.max(v);
            min = min.min(v);
        }
        if self.shards() == 0 { 0.0 } else { max - min }
    }

    /// The hottest group (by histogram count) that is observed and not
    /// yet hosted on every shard — the next replication candidate.
    /// Ties break to the lowest group id; `None` when every observed
    /// group is fully replicated or the histogram is empty.
    pub fn hottest_unreplicated(&self) -> Option<usize> {
        let n = self.shards();
        (0..self.hist.len())
            .filter(|&g| self.hist[g] > 0 && self.hosts[g].len() < n)
            .max_by(|&a, &b| {
                self.hist[a].cmp(&self.hist[b]).then(b.cmp(&a))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(slot_counts: &[usize], groups: usize) -> RoutingFeedback {
        let specs: Vec<ShardSpec> = slot_counts
            .iter()
            .map(|&s| ShardSpec { slots: s, ..ShardSpec::real(s) })
            .collect();
        RoutingFeedback::new(specs, groups)
    }

    #[test]
    fn homes_match_the_static_route_aware_mapping() {
        let f = fb(&[4, 4, 4], 8);
        for g in 0..8 {
            assert_eq!(f.hosts(g), &[g % 3]);
        }
    }

    #[test]
    fn weighted_comparison_respects_capacity() {
        // shard 0: 4 slots / load 4 (norm 1.0); shard 1: 8 slots /
        // load 6 (norm 0.75) — the raw-count argmin would pick shard 0.
        let mut f = fb(&[4, 8], 4);
        f.set_load(0, 4);
        f.set_load(1, 6);
        assert!(f.heavier(0, 1));
        assert_eq!(f.least_loaded(), 1);
        assert!((f.spread() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ties_go_to_the_lowest_shard() {
        let mut f = fb(&[4, 4, 4], 4);
        f.set_load(0, 2);
        f.set_load(1, 2);
        f.set_load(2, 3);
        assert_eq!(f.least_loaded(), 0);
        assert_eq!(f.least_loaded_among(&[2, 1]), 1);
    }

    #[test]
    fn replicas_extend_hosts_without_duplicates() {
        let mut f = fb(&[4, 4], 4);
        assert!(f.add_replica(2, 1));
        assert!(!f.add_replica(2, 1));
        assert_eq!(f.hosts(2), &[0, 1]);
        assert_eq!(f.replicas(), 1);
    }

    #[test]
    fn hottest_unreplicated_follows_the_histogram() {
        let mut f = fb(&[4, 4], 4);
        assert_eq!(f.hottest_unreplicated(), None);
        f.observe(3);
        f.observe(3);
        f.observe(1);
        assert_eq!(f.hottest_unreplicated(), Some(3));
        f.add_replica(3, 1);
        // group 3 now lives everywhere; group 1 is next
        assert_eq!(f.hottest_unreplicated(), Some(1));
    }

    #[test]
    fn prime_seeds_rounded_counts() {
        let mut f = fb(&[4], 3);
        f.prime(&[1.4, 2.6, 0.0]);
        assert_eq!(f.hist(0), 1);
        assert_eq!(f.hist(1), 3);
        assert_eq!(f.hist(2), 0);
    }
}
