//! The static placement policies as trivial [`Placer`] impls — the
//! delegation targets of the two legacy enums.
//!
//! [`StaticPlacer`] carries the per-arrival state machine behind
//! [`crate::workload::PlacementPolicy::assign`] (split-time assignment
//! over a materialized request stream); [`LivePlacer`] carries the
//! candidate rule behind the cluster placement thread's
//! [`crate::coordinator::ClusterPlacement`] modes (submit-time choice
//! over live inflight counters).  Both are *exact* ports: the enums'
//! adapters fold over these placers, so refactoring placement into this
//! module changed no assignment byte (pinned by the existing
//! `rust/tests/shard_virtual.rs` determinism suites).

use crate::coordinator::ClusterPlacement;
use crate::placement::{Arrival, Placer, RoutingFeedback};
use crate::util::rng::splitmix64;
use crate::workload::shard::PlacementPolicy;
use crate::workload::vsim::{route_rng, sample_experts};

/// Per-arrival state machine for one split-time
/// [`PlacementPolicy`]: round-robin keeps a counter, least-outstanding
/// keeps the per-shard estimated-in-flight sets, size-hash and
/// route-aware are stateless.  Feeding arrivals in order reproduces
/// [`PlacementPolicy::assign`] exactly.
#[derive(Debug, Clone)]
pub struct StaticPlacer {
    policy: PlacementPolicy,
    seed: u64,
    shards: usize,
    next: usize,
    /// per-shard (est completion time, est service) in flight —
    /// least-outstanding only
    inflight: Vec<Vec<(u64, u64)>>,
}

impl StaticPlacer {
    /// A placer for `policy` over `shards` backends; `seed` keys the
    /// route-aware peek (the workload spec's seed).
    pub fn new(policy: PlacementPolicy, seed: u64, shards: usize) -> Self {
        let n = shards.max(1);
        StaticPlacer {
            policy,
            seed,
            shards: n,
            next: 0,
            inflight: vec![Vec::new(); n],
        }
    }

    /// Place the next arrival (arrivals must come in arrival order,
    /// which [`crate::workload::WorkloadSpec::materialize`] guarantees).
    pub fn place_next(&mut self, a: &Arrival) -> usize {
        let n = self.shards;
        match self.policy {
            PlacementPolicy::RoundRobin => {
                let s = self.next % n;
                self.next += 1;
                s
            }
            PlacementPolicy::LeastOutstanding {
                prefill_ns_per_token,
                decode_ns_per_token,
            } => {
                let t = a.arrival_ns;
                for f in self.inflight.iter_mut() {
                    f.retain(|&(done, _)| done > t);
                }
                let best = (0..n)
                    .min_by_key(|&s| {
                        let work: u64 = self.inflight[s]
                            .iter()
                            .map(|&(_, w)| w)
                            .sum();
                        (self.inflight[s].len(), work, s)
                    })
                    .unwrap_or(0);
                let service = a.prompt_len as u64 * prefill_ns_per_token
                    + a.gen_len as u64 * decode_ns_per_token;
                self.inflight[best].push((t + service, service));
                best
            }
            PlacementPolicy::SizeHash => {
                // stateless SplitMix64 hash of the size pair (the same
                // mix Pcg32 seeds with)
                let mut key = ((a.prompt_len as u64) << 32)
                    | (a.gen_len as u64 & 0xFFFF_FFFF);
                (splitmix64(&mut key) % n as u64) as usize
            }
            PlacementPolicy::RouteAware {
                n_experts,
                experts_per_token,
                skew,
                group_size,
            } => {
                let mut rng = route_rng(self.seed, a.id);
                let sel = sample_experts(
                    &mut rng,
                    n_experts.max(1),
                    experts_per_token.max(1),
                    skew,
                );
                let dominant = sel.first().copied().unwrap_or(0);
                (dominant / group_size.max(1)) % n
            }
        }
    }
}

impl Placer for StaticPlacer {
    fn label(&self) -> &'static str {
        self.policy.label()
    }

    fn place(&mut self, arrival: &Arrival, _fb: &mut RoutingFeedback)
        -> usize {
        self.place_next(arrival)
    }
}

/// The cluster placement thread's candidate rule as a [`Placer`]: one
/// pick per submission over the live per-shard inflight counters.  The
/// real [`crate::coordinator::Cluster`]'s placement loop delegates its
/// candidate choice here (an exact port of its former inline rules).
#[derive(Debug, Clone)]
pub struct LivePlacer {
    mode: ClusterPlacement,
    rr: usize,
}

impl LivePlacer {
    /// A live placer in `mode` (round-robin keeps its own counter).
    pub fn new(mode: ClusterPlacement) -> Self {
        LivePlacer { mode, rr: 0 }
    }

    /// Candidate shard for the next submission given the live inflight
    /// counts (one entry per shard).  Dynamic mode picks like
    /// live-least-outstanding — migration/replication happen in the
    /// rebalance pass, not in the per-submission candidate rule.
    pub fn pick(&mut self, inflight: &[usize]) -> usize {
        let n = inflight.len().max(1);
        match self.mode {
            ClusterPlacement::RoundRobin => {
                let c = self.rr % n;
                self.rr += 1;
                c
            }
            ClusterPlacement::LiveLeastOutstanding
            | ClusterPlacement::Dynamic { .. } => (0..inflight.len())
                .min_by_key(|&i| (inflight[i], i))
                .unwrap_or(0),
        }
    }
}

impl Placer for LivePlacer {
    fn label(&self) -> &'static str {
        self.mode.label()
    }

    fn place(&mut self, _arrival: &Arrival, fb: &mut RoutingFeedback)
        -> usize {
        let loads: Vec<usize> =
            (0..fb.shards()).map(|s| fb.load(s)).collect();
        self.pick(&loads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrival::{
        ArrivalProcess, SizeModel, WorkloadSpec,
    };
    use crate::workload::vsim::VirtualConfig;

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            seed: 51,
            requests: 40,
            arrival: ArrivalProcess::Poisson { rate_rps: 1_500.0 },
            sizes: SizeModel::Uniform { prompt: (4, 12), gen: (1, 8) },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 200,
            interactive_mix: 1.0,
        }
    }

    #[test]
    fn static_placer_reproduces_enum_assign() {
        let spec = spec();
        let reqs = spec.materialize();
        for policy in [
            PlacementPolicy::RoundRobin,
            PlacementPolicy::least_outstanding(&VirtualConfig::default()),
            PlacementPolicy::SizeHash,
            PlacementPolicy::route_aware(&VirtualConfig::default()),
        ] {
            for n in [1usize, 2, 4] {
                let via_enum = policy.assign(&spec, &reqs, n);
                let mut p = StaticPlacer::new(policy, spec.seed, n);
                let via_placer: Vec<usize> = reqs
                    .iter()
                    .map(|r| p.place_next(&Arrival::of(r)))
                    .collect();
                assert_eq!(via_enum, via_placer, "{}", policy.label());
            }
        }
    }

    #[test]
    fn live_placer_matches_the_cluster_rules() {
        let mut rr = LivePlacer::new(ClusterPlacement::RoundRobin);
        assert_eq!(rr.pick(&[5, 0, 0]), 0);
        assert_eq!(rr.pick(&[5, 0, 0]), 1);
        assert_eq!(rr.pick(&[5, 0, 0]), 2);
        assert_eq!(rr.pick(&[5, 0, 0]), 0);
        let mut lo =
            LivePlacer::new(ClusterPlacement::LiveLeastOutstanding);
        assert_eq!(lo.pick(&[2, 1, 1]), 1, "ties to the lowest shard");
        assert_eq!(lo.pick(&[0, 1, 1]), 0);
    }
}
