//! Placement as a first-class subsystem: one [`Placer`] interface over
//! every way the stack maps requests onto shards, plus the live control
//! loop that keeps that mapping aligned with an *evolving* routing
//! distribution (DESIGN.md §Placement).
//!
//! Before this module, placement lived in three disconnected places —
//! the split-time [`crate::workload::PlacementPolicy`] enum, the
//! cluster-thread [`crate::coordinator::ClusterPlacement`] enum, and
//! their vsim mirrors — all frozen at split/submit time.  The paper's
//! area-efficiency story hinges on how expert groups map onto crossbars
//! that share peripherals; one level up, the same tension appears as
//! hot-shard contention when the routing histogram drifts.  This module
//! closes that loop:
//!
//! * [`policy`] — the static policies as trivial [`Placer`] impls
//!   ([`StaticPlacer`] for split-time assignment, [`LivePlacer`] for the
//!   cluster's live-signal thread); the legacy enums delegate here.
//! * [`feedback`] — [`RoutingFeedback`]: per-shard load/capacity signals
//!   ([`ShardSpec`], heterogeneous fleets included) plus the expert-group
//!   routing histogram (primed from `moe::trace` calibration samples,
//!   updated online per arrival) and the group→hosts replica map.
//! * [`dynamic`] — [`DynamicPlacer`]: route-aware homes, periodic
//!   rebalance passes that migrate *queued* requests off hot shards, and
//!   replication of hot expert groups within an area budget.
//! * [`ledger`] — [`ReplicaLedger`]: every replica priced in mm² through
//!   [`crate::hw::AreaModel`], so the replication-vs-area frontier ties
//!   back to the paper's core metric.
//!
//! The control loop runs in both execution paths: the virtual mirror
//! ([`crate::workload::run_virtual_dynamic`]) and the real cluster's
//! placement thread ([`crate::coordinator::ClusterPlacement::Dynamic`]).
//! Its telemetry lands in every v2 report as the `placement` block
//! (see [`PlacementReport`]).

pub mod dynamic;
pub mod feedback;
pub mod ledger;
pub mod policy;

pub use dynamic::{DynamicConfig, DynamicPlacer};
pub use feedback::{RoutingFeedback, ShardSpec};
pub use ledger::{checkpoint_spill_mm2, ReplicaLedger};
pub use policy::{LivePlacer, StaticPlacer};

use crate::workload::arrival::RequestSpec;

/// One arriving request, as placement sees it: the id keys the seeded
/// routing stream a route-aware placer peeks, the sizes feed cost
/// estimates, and the arrival instant orders the online decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// workload-global request id (routing/prompt streams key off
    /// `(spec.seed, id)`, so placement never perturbs request behaviour)
    pub id: u64,
    /// prompt tokens to prefill
    pub prompt_len: usize,
    /// tokens to generate
    pub gen_len: usize,
    /// arrival offset from experiment start (ns)
    pub arrival_ns: u64,
}

impl Arrival {
    /// The placement view of a materialized request.
    pub fn of(r: &RequestSpec) -> Self {
        Arrival {
            id: r.id,
            prompt_len: r.prompt_len,
            gen_len: r.gen_len,
            arrival_ns: r.arrival_ns,
        }
    }
}

/// The one placement interface: given an arrival and the current
/// feedback view, choose the shard in `[0, fb.shards())` that serves it.
///
/// Static policies ignore the feedback (their state is internal and
/// deterministic in the arrival sequence); the dynamic placer reads the
/// live loads and the replica map, and records the arrival's expert
/// group into the routing histogram.  Every impl must be deterministic
/// in `(seed, arrival sequence, feedback sequence)` — byte-identical
/// reports per seed are the contract the whole workload layer keeps.
pub trait Placer {
    /// Stable CLI/report spelling of this placer.
    fn label(&self) -> &'static str;

    /// Choose a shard for one arrival.  Called once per request, in
    /// global arrival order.
    fn place(&mut self, arrival: &Arrival, fb: &mut RoutingFeedback)
        -> usize;
}

/// Control-loop telemetry for one run — the `placement` block of the v2
/// report (`moepim.slo_report.v2`).  Static placements report all-zero
/// counters; the block is always present so report consumers never probe
/// for it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PlacementReport {
    /// queued (not yet admitted) requests migrated off hot shards by
    /// rebalance passes
    pub migrations: u64,
    /// hot expert-group replicas instantiated across shards
    pub replicas: u64,
    /// mm² charged to the area ledger for those replicas (never exceeds
    /// the `--replicate-budget-mm2` budget)
    pub area_mm2_delta: f64,
    /// the worst normalized load spread (max − min of load/slots) seen
    /// at any rebalance tick, measured *before* that tick's migrations
    pub imbalance_before: f64,
    /// the spread immediately after the same tick's migrations — the
    /// per-tick pairing guarantees `imbalance_after <= imbalance_before`
    pub imbalance_after: f64,
}
