//! The area ledger behind replication decisions: every hot-expert-group
//! replica costs silicon, and the budget is denominated in the paper's
//! core metric (mm² of MoE linear cores, [`crate::hw::AreaModel`]).
//!
//! The ledger prices one replica as one expert group's share of the
//! shared-peripheral layer area on the paper chip
//! ([`crate::config::HardwareConfig::paper`] +
//! [`crate::config::MoeModelConfig::llama_moe_4_16`]) and refuses
//! charges past the `--replicate-budget-mm2` budget, so the report's
//! `area_mm2_delta` is within budget by construction.  The same chip
//! model prices the preemption checkpoint store's spill
//! ([`checkpoint_spill_mm2`]) so both area side-channels land in one
//! currency.

use crate::config::{HardwareConfig, MoeModelConfig};
use crate::hw::AreaModel;
use crate::moe::LayerLayout;

/// A budgeted mm² account for expert-group replicas.  `try_charge`
/// either books one replica or declines; spent never exceeds budget.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaLedger {
    budget_mm2: f64,
    per_replica_mm2: f64,
    spent_mm2: f64,
}

impl ReplicaLedger {
    /// A ledger with `budget_mm2` to spend, pricing replicas on the
    /// paper chip at `group_size` experts per peripheral group.  Group
    /// sizes that don't divide the paper model's expert count fall back
    /// to unshared pricing (`g = 1`) rather than panicking — the ledger
    /// prices virtual what-if fleets whose `group_size` knob is free.
    pub fn paper(budget_mm2: f64, group_size: usize) -> Self {
        let hw = HardwareConfig::paper();
        let layout = LayerLayout::new(&MoeModelConfig::llama_moe_4_16(), &hw);
        let g = if group_size >= 1 && layout.n_experts % group_size == 0 {
            group_size
        } else {
            1
        };
        ReplicaLedger {
            budget_mm2: budget_mm2.max(0.0),
            per_replica_mm2: AreaModel::new(&hw)
                .group_replica_area_mm2(&layout, g),
            spent_mm2: 0.0,
        }
    }

    /// Book one replica if the budget allows; `true` when charged.
    pub fn try_charge(&mut self) -> bool {
        if self.spent_mm2 + self.per_replica_mm2 <= self.budget_mm2 + 1e-9 {
            self.spent_mm2 += self.per_replica_mm2;
            true
        } else {
            false
        }
    }

    /// mm² spent so far.
    pub fn spent_mm2(&self) -> f64 {
        self.spent_mm2
    }

    /// mm² one replica costs.
    pub fn per_replica_mm2(&self) -> f64 {
        self.per_replica_mm2
    }

    /// The configured budget, mm².
    pub fn budget_mm2(&self) -> f64 {
        self.budget_mm2
    }
}

/// Checkpoint-store spill area on the paper chip for a peak of
/// `peak_checkpoints` simultaneous preemption snapshots (first snapshot
/// fits in the slot's own banks and is free) — the report-time pricing
/// of the server/vsim `peak_checkpoints` counter.
pub fn checkpoint_spill_mm2(peak_checkpoints: usize) -> f64 {
    let hw = HardwareConfig::paper();
    let layout = LayerLayout::new(&MoeModelConfig::llama_moe_4_16(), &hw);
    AreaModel::new(&hw).checkpoint_spill_mm2(&layout, peak_checkpoints)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_until_the_budget_is_exhausted() {
        let mut l = ReplicaLedger::paper(1000.0, 2);
        let per = l.per_replica_mm2();
        assert!(per > 0.0);
        let mut n = 0;
        while l.try_charge() {
            n += 1;
            assert!(n < 10_000, "ledger never declined");
        }
        assert_eq!(n as f64, (1000.0 / per).floor());
        assert!(l.spent_mm2() <= l.budget_mm2() + 1e-9);
        // once declined, it stays declined
        assert!(!l.try_charge());
    }

    #[test]
    fn zero_budget_charges_nothing() {
        let mut l = ReplicaLedger::paper(0.0, 2);
        assert!(!l.try_charge());
        assert_eq!(l.spent_mm2(), 0.0);
    }

    #[test]
    fn indivisible_group_size_prices_unshared() {
        // 5 doesn't divide 16 experts → falls back to g=1 pricing
        let odd = ReplicaLedger::paper(100.0, 5);
        let unshared = ReplicaLedger::paper(100.0, 1);
        assert_eq!(odd.per_replica_mm2(), unshared.per_replica_mm2());
        // sharing makes replicas cheaper per group... per *group* area
        // at g=2 is (xbar + periph/2) * 96 * 2 vs g=1's (xbar + periph)
        // * 96 — fewer mm² per expert, more experts per group
        let shared = ReplicaLedger::paper(100.0, 2);
        assert!(shared.per_replica_mm2() < 2.0 * unshared.per_replica_mm2());
    }

    #[test]
    fn spill_grows_past_one_snapshot() {
        assert_eq!(checkpoint_spill_mm2(0), 0.0);
        assert_eq!(checkpoint_spill_mm2(1), 0.0);
        let two = checkpoint_spill_mm2(2);
        assert!(two > 0.0);
        assert!((checkpoint_spill_mm2(3) - 2.0 * two).abs() < 1e-9);
    }
}
