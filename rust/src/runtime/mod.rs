//! PJRT runtime: loads `artifacts/*.hlo.txt`, compiles on the CPU PJRT
//! client, executes from the coordinator's hot path.

pub mod executor;

pub use executor::{Executable, Runtime, TensorIn, TensorView};
