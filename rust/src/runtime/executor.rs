//! PJRT execution of the AOT artifacts.
//!
//! [`Runtime`] owns one CPU PJRT client; [`Executable`]s are compiled once
//! at startup from `artifacts/*.hlo.txt` (HLO *text* — the interchange
//! format that survives the jax≥0.5 / xla_extension 0.5.1 version gap, see
//! python/compile/aot.py) and then executed from the coordinator's hot path
//! with plain f32/i32 host buffers.  Python is never involved at runtime.
//!
//! ## Concurrency contract
//!
//! A `Runtime` (and the client inside it) is **single-threaded**: it is
//! constructed on its router thread and never crosses threads — the
//! client type is not `Send`, so the compiler enforces this.  There is no
//! process-wide exclusivity, though: *independent* `Runtime`s on
//! *different* threads execute concurrently, each against its own CPU
//! PJRT client.  That is exactly how the multi-backend
//! `coordinator::Cluster` gets real parallelism — one `Runtime` per
//! backend router thread, N backends decoding at once.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::manifest::{ArtifactEntry, Manifest};

/// A typed host-side tensor returned from an executable (owned).
#[derive(Debug, Clone)]
pub enum TensorView {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A borrowed host-side tensor staged as an executable input.
///
/// Inputs borrow (instead of taking the owned [`TensorView`]) so the
/// coordinator can hand cache-pool buffers straight to PJRT without the
/// per-decode-step `to_vec()` clones the old API forced — at serving dims
/// that is 2 x `max_seq * n_heads * d_head` floats per token that no longer
/// get copied.
#[derive(Debug, Clone, Copy)]
pub enum TensorIn<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> TensorIn<'a> {
    pub fn len(&self) -> usize {
        match self {
            TensorIn::F32(v) => v.len(),
            TensorIn::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<'a> From<&'a TensorView> for TensorIn<'a> {
    fn from(v: &'a TensorView) -> TensorIn<'a> {
        match v {
            TensorView::F32(v) => TensorIn::F32(v),
            TensorView::I32(v) => TensorIn::I32(v),
        }
    }
}

impl TensorView {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorView::F32(v) => Ok(v),
            TensorView::I32(_) => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            TensorView::F32(v) => Ok(v),
            TensorView::I32(_) => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorView::F32(v) => v.len(),
            TensorView::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One compiled HLO module.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// expected input element counts + dtypes (from the manifest)
    inputs: Vec<(usize, bool)>, // (elems, is_i32)
    input_shapes: Vec<Vec<usize>>,
}

impl Executable {
    /// Execute with borrowed host buffers; returns the flattened tuple
    /// elements.
    ///
    /// Inputs are validated against the manifest spec before staging so a
    /// stale `artifacts/` directory fails loudly rather than numerically.
    pub fn run(&self, inputs: &[TensorIn<'_>]) -> Result<Vec<TensorView>> {
        if inputs.len() != self.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (input, &(elems, is_i32))) in
            inputs.iter().zip(&self.inputs).enumerate()
        {
            if input.len() != elems {
                return Err(anyhow!(
                    "{}: input {i} has {} elements, manifest says {elems}",
                    self.name,
                    input.len()
                ));
            }
            let dims: Vec<i64> =
                self.input_shapes[i].iter().map(|&d| d as i64).collect();
            let lit = match (input, is_i32) {
                (TensorIn::F32(v), false) => {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                (TensorIn::I32(v), true) => {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                _ => {
                    return Err(anyhow!(
                        "{}: input {i} dtype mismatch",
                        self.name
                    ))
                }
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: empty result", self.name))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            // outputs of the functional model are all f32
            out.push(TensorView::F32(part.to_vec::<f32>()?));
        }
        Ok(out)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The PJRT client plus all compiled artifacts.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: BTreeMap<String, Executable>,
}

impl Runtime {
    /// Load + compile every artifact in the manifest directory.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Load from the default artifacts location (`$MOEPIM_ARTIFACTS` or
    /// `<crate>/artifacts`).
    pub fn load_default() -> Result<Runtime> {
        Self::from_manifest(Manifest::load_default()?)
    }

    pub fn from_manifest(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let mut executables = BTreeMap::new();
        for entry in manifest.artifacts.values() {
            let exe = Self::compile_entry(&client, entry)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), exe);
        }
        Ok(Runtime { manifest, client, executables })
    }

    fn compile_entry(client: &xla::PjRtClient, entry: &ArtifactEntry)
        -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(&entry.file)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let inputs = entry
            .inputs
            .iter()
            .map(|spec| {
                (spec.shape.iter().product::<usize>().max(1),
                 spec.dtype == "int32")
            })
            .collect();
        let input_shapes =
            entry.inputs.iter().map(|s| s.shape.clone()).collect();
        Ok(Executable {
            name: entry.name.clone(),
            exe,
            inputs,
            input_shapes,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("no compiled executable '{name}'"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn n_executables(&self) -> usize {
        self.executables.len()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in
    // rust/tests/runtime_roundtrip.rs (integration), since `cargo test`
    // unit runs should not depend on `make artifacts` having run.
    use super::*;

    #[test]
    fn tensorview_accessors() {
        let f = TensorView::F32(vec![1.0, 2.0]);
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(f.len(), 2);
        let i = TensorView::I32(vec![3]);
        assert!(i.as_f32().is_err());
        assert!(!i.is_empty());
        assert_eq!(TensorView::F32(vec![]).len(), 0);
    }

    #[test]
    fn tensorin_borrows_and_converts() {
        let owned = TensorView::F32(vec![1.0, 2.0, 3.0]);
        let brw: TensorIn<'_> = (&owned).into();
        assert_eq!(brw.len(), 3);
        assert!(!brw.is_empty());
        let ids = [1i32, 2];
        let i = TensorIn::I32(&ids);
        assert_eq!(i.len(), 2);
        assert!(TensorIn::F32(&[]).is_empty());
    }
}
