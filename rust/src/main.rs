//! `moepim` launcher — CLI over the simulator, the evaluation harness and
//! the serving coordinator.
//!
//! All usage text lives in `moepim::util::cli::usage` (one definition per
//! subcommand); this file only dispatches.  `moepim` prints the root
//! usage, `moepim <subcommand> --help` the per-subcommand one.

use moepim::config::{
    CachePolicy, GroupingPolicy, RoutingMode, SchedulePolicy, SimConfig,
};
use moepim::sim::Simulator;
use moepim::util::cli::{usage, Args};
use moepim::util::fmt_thousands;
use moepim::{eval, moe};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Some(sub) = args.subcommand.as_deref() {
        if args.bool_flag("help") {
            match usage::help_for(sub) {
                Some(text) => {
                    println!("{text}");
                    std::process::exit(0);
                }
                None => {
                    eprintln!("unknown subcommand '{sub}'\n{}", usage::ROOT);
                    std::process::exit(2);
                }
            }
        }
    }
    let code = match args.subcommand.as_deref() {
        Some("eval") => cmd_eval(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("trace") => cmd_trace(&args),
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("loadtest") => cmd_loadtest(&args),
        Some("shardtest") => cmd_shardtest(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("perfcmp") => cmd_perfcmp(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n{}", usage::ROOT);
            2
        }
        None => {
            println!("{}", usage::ROOT);
            0
        }
    };
    std::process::exit(code);
}

fn cmd_eval(args: &Args) -> i32 {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let gen = args.usize_flag("gen", 8);
    match what {
        "fig4a" => print!("{}", eval::fig4::render_fig4a(gen)),
        "fig4b" => print!("{}", eval::fig4::render_fig4b()),
        "fig5" => print!("{}", eval::fig5::render()),
        "table1" => print!("{}", eval::table1::render()),
        "ratio-sweep" => print!("{}", eval::sweep::render()),
        "calibration" => print!("{}", eval::calibration::render()),
        "ablation" => print!("{}", eval::ablation::render()),
        "all" => {
            print!("{}", eval::fig4::render_fig4a(gen));
            println!();
            print!("{}", eval::fig4::render_fig4b());
            println!();
            print!("{}", eval::fig5::render());
            println!();
            print!("{}", eval::table1::render());
            println!();
            print!("{}", eval::sweep::render());
            println!();
            print!("{}", eval::calibration::render());
            println!();
            print!("{}", eval::ablation::render());
        }
        other => {
            eprintln!("unknown eval target '{other}'");
            return 2;
        }
    }
    0
}

fn sim_config_from(args: &Args) -> SimConfig {
    let mut cfg = SimConfig::baseline();
    cfg.group_size = args.usize_flag("group-size", 1);
    cfg.grouping = match args.str_flag("grouping", "S").as_str() {
        "U" | "u" | "uniform" => GroupingPolicy::Uniform,
        "S" | "s" | "sorted" => GroupingPolicy::Sorted,
        _ => GroupingPolicy::None,
    };
    cfg.schedule = match args.str_flag("sched", "T").as_str() {
        "C" | "c" | "compact" => SchedulePolicy::Compact,
        "O" | "o" | "resched" => SchedulePolicy::Reschedule,
        _ => SchedulePolicy::TokenWise,
    };
    cfg.cache = CachePolicy {
        kv: args.bool_flag("kv"),
        go: args.bool_flag("go"),
    };
    cfg.prompt_len = args.usize_flag("prompt", 32);
    cfg.gen_len = args.usize_flag("gen", 8);
    cfg.seed = args.usize_flag("seed", 2026) as u64;
    cfg.routing = match args.str_flag("routing", "expert").as_str() {
        "token" => RoutingMode::TokenChoice,
        _ => RoutingMode::ExpertChoice,
    };
    cfg.skew = args.f64_flag("skew", 1.0);
    cfg
}

fn cmd_simulate(args: &Args) -> i32 {
    // --config file.json overrides flags wholesale (reproducible runs)
    let config_path = args.str_flag("config", "");
    let sim = if config_path.is_empty() {
        Simulator::paper(sim_config_from(args))
    } else {
        match moepim::config::Experiment::load(std::path::Path::new(
            &config_path,
        )) {
            Ok(exp) => Simulator::new(
                moepim::config::MoeModelConfig::llama_moe_4_16(),
                exp.hw,
                exp.sim,
            ),
            Err(e) => {
                eprintln!("bad config: {e:#}");
                return 2;
            }
        }
    };
    let r = sim.run();
    let t = r.total();
    println!("config {} / {}", r.label, r.cache_label);
    println!("prefill : {:>12} ns  {:>12} nJ",
             fmt_thousands(r.prefill.latency_ns.round() as u64),
             fmt_thousands(r.prefill.energy_nj.round() as u64));
    let d = r.decode_total();
    println!("decode  : {:>12} ns  {:>12} nJ  ({} steps)",
             fmt_thousands(d.latency_ns.round() as u64),
             fmt_thousands(d.energy_nj.round() as u64),
             r.decode_steps.len());
    println!("total   : {:>12} ns  {:>12} nJ",
             fmt_thousands(t.latency_ns.round() as u64),
             fmt_thousands(t.energy_nj.round() as u64));
    println!("breakdown (ns): attn {:.0} gate {:.0} moe {:.0} dram {:.0}",
             t.breakdown.attn_ns, t.breakdown.gate_ns, t.breakdown.moe_ns,
             t.breakdown.dram_ns);
    println!("breakdown (nJ): attn {:.0} gate {:.0} moe {:.0} dram {:.0}",
             t.breakdown.attn_nj, t.breakdown.gate_nj, t.breakdown.moe_nj,
             t.breakdown.dram_nj);
    println!("area {:.1} mm² | {:.2} GOPS | {:.2} GOPS/mm² | {:.2} GOPS/W/mm²",
             r.moe_area_mm2, t.gops(), r.gops_per_mm2(), r.density());
    println!("activations {} transfers {}", t.activations, t.transfers);
    0
}

fn cmd_trace(args: &Args) -> i32 {
    let cfg = sim_config_from(args);
    let tokens = args.usize_flag("tokens", cfg.prompt_len);
    let e = 16;
    let mut gen = moe::TraceGenerator::new(e, cfg.seed);
    let m = match cfg.routing {
        RoutingMode::ExpertChoice => {
            gen.expert_choice(tokens, (tokens * 4).div_ceil(e), cfg.skew)
        }
        RoutingMode::TokenChoice => {
            gen.token_choice_zipf(tokens, 4, cfg.skew)
        }
    };
    println!("trace: {} tokens x {} experts, total work {}", tokens, e,
             m.total_work());
    println!("expert loads: {:?}", m.expert_loads());
    0
}

fn artifacts_dir(args: &Args) -> std::path::PathBuf {
    let flag = args.str_flag("artifacts", "");
    if !flag.is_empty() {
        return flag.into();
    }
    std::env::var("MOEPIM_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn toy_prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = moepim::util::rng::Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

fn cmd_serve(args: &Args) -> i32 {
    use moepim::coordinator::{Server, ServerOptions};
    let n = args.usize_flag("prompts", 4);
    let gen = args.usize_flag("gen", 8);
    let prefill_chunk = args.usize_flag("prefill-chunk", 0);
    let qos = args.bool_flag("qos");
    let mix = args.f64_flag("priority-mix", 1.0);
    let trace_out = args.str_flag("trace-out", "");
    let metrics_file = args.str_flag("metrics-file", "");
    let server = match Server::spawn_opts(artifacts_dir(args),
                                          ServerOptions {
                                              prefill_chunk,
                                              qos,
                                              trace: !trace_out.is_empty(),
                                              ..ServerOptions::default()
                                          }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start server: {e:#}");
            return 1;
        }
    };
    if prefill_chunk > 0 {
        println!("chunked prefill on: {prefill_chunk} prompt tokens per \
                  slot per cycle");
    }
    if qos {
        println!("qos on: priority mix {mix:.2} (interactive share, \
                  strided over request ids)");
    }
    println!("server up; submitting {n} requests (gen {gen})");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(
                moepim::coordinator::Request::new(
                    i as u64,
                    toy_prompt(32, 1000 + i as u64, 512),
                    gen,
                )
                .with_priority(moepim::workload::Priority::assign(
                    i as u64,
                    mix,
                )),
            )
        })
        .collect();
    let mut total_tokens = 0usize;
    let mut failed = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("terminal response");
        match &resp.result {
            Ok(tokens) => {
                total_tokens += tokens.len();
                println!(
                    "req {}: {} tokens ({} batched / {} single), ttft \
                     {:.1} ms, latency {:.1} ms, {:?}",
                    resp.id,
                    tokens.len(),
                    resp.batched_steps,
                    resp.single_steps,
                    resp.ttft_us.unwrap_or(0.0) / 1e3,
                    resp.latency_us / 1e3,
                    &tokens[..tokens.len().min(8)]
                );
            }
            Err(e) => {
                failed += 1;
                eprintln!("req {} failed: {e}", resp.id);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n} requests / {total_tokens} tokens in {wall:.2}s          ({:.1} tok/s)",
        total_tokens as f64 / wall
    );
    if let Ok(stats) = server.stats() {
        // the full shutdown dump: the same pretty-printer the shardtest
        // paths use, so interactive runs and fan-out runs read off one
        // vocabulary (it subsumes the loadtest report's counter set)
        print!("{}", stats.pretty(""));
        if !metrics_file.is_empty() {
            let code =
                write_metrics_file(&metrics_file, &serve_metrics(&stats));
            if code != 0 {
                return code;
            }
        }
    }
    if !trace_out.is_empty() {
        match server.take_trace() {
            Ok(shard) => {
                let code = write_trace_out(&trace_out, &[shard], "real");
                if code != 0 {
                    return code;
                }
            }
            Err(e) => {
                eprintln!("failed to drain the span trace: {e:#}");
                return 1;
            }
        }
    }
    if failed > 0 {
        return 1;
    }
    0
}

fn cmd_generate(args: &Args) -> i32 {
    use moepim::coordinator::{DecodeMode, ModelEngine};
    use moepim::runtime::Runtime;
    let gen = args.usize_flag("gen", 8);
    let plen = args.usize_flag("prompt-len", 32);
    let rt = match Runtime::load(&artifacts_dir(args)) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("failed to load artifacts: {e:#}");
            return 1;
        }
    };
    println!("platform {}, {} executables", rt.platform(),
             rt.n_executables());
    let engine = ModelEngine::new(rt);
    let prompt = toy_prompt(plen, args.usize_flag("seed", 7) as u64,
                            engine.model.vocab);
    let r = engine
        .generate(&prompt, gen, DecodeMode::Cached)
        .expect("cached generation");
    println!("cached   : {:?}  (prefill {:.1} ms, decode {:.1} ms)",
             r.tokens, r.prefill_us / 1e3, r.decode_us / 1e3);
    if args.bool_flag("check") {
        // cached == recompute is a depth-1 statement: at L >= 2 a batch
        // re-route rewrites past tokens' mid-stack hiddens the cached
        // path froze (see coordinator::engine docs), so the comparison
        // would false-fail on a perfectly good deep artifact set
        if engine.model.n_layers != 1 {
            println!(
                "--check skipped: cached-vs-recompute equivalence is \
                 defined at depth 1 only (artifact set has {} layers); \
                 deep stacks are pinned by the batched-vs-per-session \
                 test suites",
                engine.model.n_layers
            );
            return 0;
        }
        let r2 = engine
            .generate(&prompt, gen, DecodeMode::Recompute)
            .expect("recompute generation");
        println!("recompute: {:?}  (decode {:.1} ms)", r2.tokens,
                 r2.decode_us / 1e3);
        if r.tokens == r2.tokens {
            println!("MATCH: GO-cached decode == full recompute");
        } else {
            println!("MISMATCH");
            return 1;
        }
    }
    0
}

// ---------------------------------------------------------------------------
// loadtest: seeded load experiment -> JSON SloReport (DESIGN.md E8)
// ---------------------------------------------------------------------------

fn cmd_loadtest(args: &Args) -> i32 {
    use moepim::workload::{report, run_virtual, AdmissionPolicy};
    if args.bool_flag("smoke") {
        return loadtest_smoke(args);
    }
    if args.bool_flag("bench-scenarios") {
        return scenario_bench(args);
    }
    if args.bool_flag("bench-qos") {
        return qos_bench(args);
    }
    // --replay FILE: drive a recorded moepim.trace.v1 document instead of
    // generating a workload (single-backend; exact ns-precision arrivals)
    let replay_path = args.str_flag("replay", "");
    if !replay_path.is_empty() {
        return run_trace_replay(args, &replay_path);
    }
    // --shards N >= 2 promotes the run to the sharded fan-out (merged v2
    // report); --shards 1 / absent keeps the classic single-backend v1
    let shards = args.usize_flag("shards", 1);
    if shards > 1 {
        return run_sharded(args, shards);
    }
    let Some(policy) =
        AdmissionPolicy::parse(&args.str_flag("policy", "fifo"))
    else {
        eprintln!("unknown --policy (expected fifo|sjf|edf)");
        return 2;
    };
    let spec = match loadtest_spec(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let report = if args.bool_flag("real") {
        // wall-clock run against the threaded server (not byte-repeatable)
        match run_real_loadtest(args, &spec, policy) {
            Ok(r) => r,
            Err(code) => return code,
        }
    } else {
        // virtual clock: byte-identical output for a given seed (the
        // span trace under --trace-out is byte-identical too: the sink
        // never touches the virtual clock or the workload RNG)
        let cfg = loadtest_vcfg(args);
        let trace_out = args.str_flag("trace-out", "");
        let out = if trace_out.is_empty() {
            run_virtual(&cfg, &spec, policy)
        } else {
            let mut sink = moepim::obs::TraceSink::on(true);
            let out = moepim::workload::run_virtual_traced(
                &cfg, &spec, policy, &mut sink);
            let shards = [sink.drain(Some(0), "vsim")];
            let code = write_trace_out(&trace_out, &shards, "virtual");
            if code != 0 {
                return code;
            }
            out
        };
        let record_path = args.str_flag("record", "");
        if !record_path.is_empty() {
            let trace = moepim::workload::TraceRecorder::new(&spec, policy)
                .finish(
                    &out,
                    moepim::workload::TraceBackend::from_virtual(&cfg),
                );
            if let Err(code) = write_trace(&trace, &record_path) {
                return code;
            }
        }
        let metrics_file = args.str_flag("metrics-file", "");
        if !metrics_file.is_empty() {
            let s = report::summarize(&spec, &out);
            let code = write_metrics_file(
                &metrics_file,
                &moepim::workload::metrics_registry(&s, &out),
            );
            if code != 0 {
                return code;
            }
        }
        report::build(&spec, policy, &out)
    };
    print_report(args, &report)
}

/// Write `trace` as a pretty-printed `moepim.trace.v1` file.  The notice
/// goes to stderr so `--record` composes with report redirection.
fn write_trace(trace: &moepim::workload::RecordedTrace, path: &str)
    -> Result<(), i32> {
    let text = trace.to_json().to_string_pretty();
    if let Err(e) = std::fs::write(path, format!("{text}\n")) {
        eprintln!("failed to write trace {path}: {e}");
        return Err(1);
    }
    eprintln!("recorded {} requests -> {path}", trace.requests.len());
    Ok(())
}

/// Load a `moepim.trace.v1` file, or explain why it didn't.
fn load_trace(path: &str) -> Result<moepim::workload::RecordedTrace, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("failed to read {path}: {e}"))?;
    let doc = moepim::util::json::parse(&text)
        .map_err(|e| format!("{path}: {e}"))?;
    moepim::workload::RecordedTrace::from_json(&doc)
        .map_err(|e| format!("{path}: {e}"))
}

/// `loadtest --replay FILE`: re-drive a recorded request stream.  The
/// backend shape and policy default to what the trace recorded (flags
/// still override); a virtual-clock replay of a virtual-clock recording
/// reproduces the recorded report byte for byte.
fn run_trace_replay(args: &Args, path: &str) -> i32 {
    use moepim::workload::{
        report, run_requests_against_server, run_virtual_requests,
        AdmissionPolicy, VirtualConfig,
    };
    let trace = match load_trace(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let policy_flag = args.str_flag("policy", &trace.policy);
    let Some(policy) = AdmissionPolicy::parse(&policy_flag) else {
        eprintln!("unknown --policy (expected fifo|sjf|edf)");
        return 2;
    };
    let spec = trace.original_spec().clone();
    let reqs = trace.replay_requests();
    let report = if args.bool_flag("real") {
        let opts = moepim::coordinator::ServerOptions {
            policy,
            prefill_chunk: args
                .usize_flag("prefill-chunk", trace.backend.prefill_chunk),
            queue_cap: args.usize_flag("queue-cap", trace.backend.queue_cap),
            qos: args.bool_flag("qos"),
            ..moepim::coordinator::ServerOptions::default()
        };
        let server = match moepim::coordinator::Server::spawn_opts(
            artifacts_dir(args),
            opts,
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("failed to start server: {e:#}");
                return 1;
            }
        };
        match run_requests_against_server(&server, &spec, &reqs) {
            Ok(out) => report::build(&spec, policy, &out),
            Err(e) => {
                eprintln!("replay failed: {e:#}");
                return 1;
            }
        }
    } else {
        let d = VirtualConfig::default();
        let cfg = VirtualConfig {
            slots: args
                .usize_flag("slots", trace.backend.slots.max(1))
                .max(1),
            n_experts: args.usize_flag("experts", d.n_experts).max(1),
            n_layers: args.usize_flag("layers", d.n_layers).max(1),
            prefill_chunk: args
                .usize_flag("prefill-chunk", trace.backend.prefill_chunk),
            qos: args.bool_flag("qos"),
            ..d
        };
        let out = run_virtual_requests(&cfg, &spec, &reqs, policy);
        report::build(&spec, policy, &out)
    };
    print_report(args, &report)
}

// ---------------------------------------------------------------------------
// calibrate: fit virtual cost constants against a recorded trace (E11)
// ---------------------------------------------------------------------------

fn cmd_calibrate(args: &Args) -> i32 {
    use moepim::workload::calibrate;
    let trace_path = args.str_flag("trace", "");
    if trace_path.is_empty() {
        eprintln!("--trace FILE is required\n{}", usage::CALIBRATE);
        return 2;
    }
    let trace = match load_trace(&trace_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let base = loadtest_vcfg(args);
    match calibrate(&trace, &base) {
        Ok(cal) => {
            eprintln!(
                "calibrate: {} samples, prefill {:.0} ns/token, decode \
                 step {:.0} ns (scale {:.3}); p50 err {:.2}%, p99 err \
                 {:.2}%",
                cal.n_samples,
                cal.prefill_ns_per_token,
                cal.decode_step_ns,
                cal.scale,
                cal.p50_err_pct,
                cal.p99_err_pct,
            );
            let code = print_report(args, &cal.to_json());
            if code != 0 {
                return code;
            }
            // --max-err-pct X: CI's accuracy gate — the fitted model
            // must re-predict the recorded trace within the bound
            let max_err = args.f64_flag("max-err-pct", 0.0);
            if max_err > 0.0
                && (cal.p50_err_pct > max_err
                    || cal.p99_err_pct > max_err)
            {
                eprintln!(
                    "calibrate: re-prediction error beyond \
                     {max_err:.1}% (p50 {:.2}%, p99 {:.2}%)",
                    cal.p50_err_pct, cal.p99_err_pct
                );
                return 3;
            }
            0
        }
        Err(e) => {
            eprintln!("calibration failed: {e}");
            1
        }
    }
}

fn loadtest_spec(args: &Args)
    -> Result<moepim::workload::WorkloadSpec, String> {
    use moepim::workload::{ArrivalProcess, SizeModel, WorkloadSpec};
    // --scenario NAME: a named preset replaces flag composition wholesale
    // (the preset *is* the experiment); --seed and --requests still apply
    let scenario = args.str_flag("scenario", "");
    if !scenario.is_empty() {
        let mut spec = moepim::workload::scenario_spec(
            &scenario,
            args.u64_flag("seed", 2026),
        )
        .ok_or_else(|| {
            format!(
                "unknown --scenario '{scenario}' (expected {})",
                moepim::workload::scenario_names()
                    .collect::<Vec<_>>()
                    .join("|")
            )
        })?;
        spec.requests = args.usize_flag("requests", spec.requests);
        // presets carry their own tier split; --priority-mix overrides it
        spec.interactive_mix =
            args.f64_flag("priority-mix", spec.interactive_mix);
        return Ok(spec);
    }
    let rate = args.f64_flag("rate", 64.0);
    if rate <= 0.0 {
        return Err("--rate must be > 0".into());
    }
    let arrival = match args.str_flag("process", "poisson").as_str() {
        "poisson" => ArrivalProcess::Poisson { rate_rps: rate },
        "bursty" => ArrivalProcess::Bursty {
            rate_rps: rate,
            mean_on_ms: args.f64_flag("on-ms", 25.0),
            mean_off_ms: args.f64_flag("off-ms", 75.0),
        },
        "closed" => ArrivalProcess::Closed {
            users: args.usize_flag("users", 8).max(1),
            think_ms: args.f64_flag("think-ms", 2.0),
        },
        "replay" => {
            let raw = args.str_flag("replay-us", "");
            let mut times: Vec<u64> = Vec::new();
            for tok in raw.split(',').map(str::trim) {
                if tok.is_empty() {
                    continue;
                }
                match tok.parse() {
                    Ok(t) => times.push(t),
                    Err(_) => {
                        return Err(format!(
                            "--replay-us: '{tok}' is not a µs offset"
                        ));
                    }
                }
            }
            if times.is_empty() {
                return Err(
                    "--replay-us takes comma-separated ascending µs \
                     offsets (e.g. --replay-us 0,1000,2500)"
                        .into(),
                );
            }
            if times.windows(2).any(|w| w[0] > w[1]) {
                return Err(
                    "--replay-us offsets must be ascending (past the last \
                     offset the timeline repeats after a mean-gap seam)"
                        .into(),
                );
            }
            ArrivalProcess::Replay { times_us: times }
        }
        other => return Err(format!("unknown --process '{other}'")),
    };
    let pmax = args.usize_flag("prompt", 24).max(1);
    let gmax = args.usize_flag("gen", 12);
    let sizes = match args.str_flag("sizes", "trace").as_str() {
        "fixed" => SizeModel::Fixed { prompt_len: pmax, gen_len: gmax },
        "uniform" => SizeModel::Uniform {
            prompt: (pmax.min(4), pmax),
            gen: (gmax.min(1), gmax.max(1)),
        },
        "trace" => SizeModel::TraceSeeded {
            n_experts: 16,
            skew: args.f64_flag("skew", 1.2),
            prompt: (pmax.min(4), pmax),
            gen: (gmax.min(1), gmax.max(1)),
        },
        other => return Err(format!("unknown --sizes '{other}'")),
    };
    Ok(WorkloadSpec {
        seed: args.u64_flag("seed", 2026),
        requests: args.usize_flag("requests", 64),
        arrival,
        sizes,
        slo_e2e_ms: args.f64_flag("slo-ms", 250.0),
        deadline_slack_us_per_token: args.u64_flag("deadline-slack-us", 500),
        interactive_mix: args.f64_flag("priority-mix", 1.0),
    })
}

fn loadtest_vcfg(args: &Args) -> moepim::workload::VirtualConfig {
    let d = moepim::workload::VirtualConfig::default();
    moepim::workload::VirtualConfig {
        slots: args.usize_flag("slots", d.slots).max(1),
        n_experts: args.usize_flag("experts", d.n_experts).max(1),
        n_layers: args.usize_flag("layers", d.n_layers).max(1),
        prefill_chunk: args.usize_flag("prefill-chunk", d.prefill_chunk),
        qos: args.bool_flag("qos"),
        ..d
    }
}

fn run_real_loadtest(args: &Args, spec: &moepim::workload::WorkloadSpec,
                     policy: moepim::workload::AdmissionPolicy)
    -> Result<moepim::util::json::Json, i32> {
    use moepim::coordinator::{Server, ServerOptions};
    use moepim::workload::{report, run_against_server};
    let trace_out = args.str_flag("trace-out", "");
    let opts = ServerOptions {
        policy,
        prefill_chunk: args.usize_flag("prefill-chunk", 0),
        queue_cap: args.usize_flag("queue-cap", 0),
        trace: !trace_out.is_empty(),
        ..ServerOptions::default()
    };
    let server = match Server::spawn_opts(artifacts_dir(args), opts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start server: {e:#}");
            return Err(1);
        }
    };
    match run_against_server(&server, spec) {
        Ok(out) => {
            let record_path = args.str_flag("record", "");
            if !record_path.is_empty() {
                // backend block read off the live server's recording hooks
                let backend = match server.stats() {
                    Ok(stats) => {
                        moepim::workload::TraceBackend::from_server_stats(
                            &stats,
                        )
                    }
                    Err(e) => {
                        eprintln!(
                            "failed to read server stats for the trace: \
                             {e:#}"
                        );
                        return Err(1);
                    }
                };
                let trace =
                    moepim::workload::TraceRecorder::new(spec, policy)
                        .finish(&out, backend);
                write_trace(&trace, &record_path)?;
            }
            if !trace_out.is_empty() {
                match server.take_trace() {
                    Ok(shard) => {
                        let code =
                            write_trace_out(&trace_out, &[shard], "real");
                        if code != 0 {
                            return Err(code);
                        }
                    }
                    Err(e) => {
                        eprintln!("failed to drain the span trace: {e:#}");
                        return Err(1);
                    }
                }
            }
            let metrics_file = args.str_flag("metrics-file", "");
            if !metrics_file.is_empty() {
                let s = report::summarize(spec, &out);
                let code = write_metrics_file(
                    &metrics_file,
                    &moepim::workload::metrics_registry(&s, &out),
                );
                if code != 0 {
                    return Err(code);
                }
            }
            Ok(report::build(spec, policy, &out))
        }
        Err(e) => {
            eprintln!("loadtest failed: {e:#}");
            Err(1)
        }
    }
}

// ---------------------------------------------------------------------------
// shardtest: sharded fan-out -> merged JSON SloReport v2 (DESIGN.md E9)
// ---------------------------------------------------------------------------

fn cmd_shardtest(args: &Args) -> i32 {
    if args.bool_flag("bench-cluster") {
        return cluster_bench(args);
    }
    if args.bool_flag("bench-placement") {
        return placement_bench(args);
    }
    run_sharded(args, args.usize_flag("shards", 2).max(1))
}

/// Shared by `shardtest` and `loadtest --shards`: split the spec across
/// `shards` backends (virtual clusters by default, concurrently-running
/// real servers with `--real`), merge shard-exactly, and print the
/// `moepim.slo_report.v2` document.  `--placement live` switches from
/// the static split to online live-signal placement (a `Cluster` front
/// door under `--real`, incrementally-advanced virtual backends
/// otherwise); `--serial` keeps the legacy one-shard-at-a-time real
/// fan-out as the concurrency bench's A/B baseline.
fn run_sharded(args: &Args, shards: usize) -> i32 {
    use moepim::workload::{
        report, run_requests_against_server, AdmissionPolicy,
        PlacementPolicy, ShardedDriver,
    };
    if args.bool_flag("virtual") && args.bool_flag("real") {
        eprintln!("--virtual and --real are mutually exclusive");
        return 2;
    }
    let Some(policy) =
        AdmissionPolicy::parse(&args.str_flag("policy", "fifo"))
    else {
        eprintln!("unknown --policy (expected fifo|sjf|edf)");
        return 2;
    };
    let spec = match loadtest_spec(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let vcfg = loadtest_vcfg(args);
    let placement_flag = args.str_flag("placement", "round-robin");
    if matches!(placement_flag.as_str(),
                "live" | "live-least-outstanding" | "live-lo") {
        return run_sharded_live(args, shards, policy, &spec, &vcfg);
    }
    if placement_flag == "dynamic" {
        return run_sharded_dynamic(args, shards, policy, &spec, &vcfg);
    }
    // parse against the run's actual virtual config: the parse-time
    // defaults silently mis-estimated any non-default chip shape
    let Some(mut placement) =
        PlacementPolicy::parse(&placement_flag, &vcfg)
    else {
        eprintln!(
            "unknown --placement '{placement_flag}' (expected round-robin|\
             least-outstanding|size-hash|route-aware|live|dynamic)"
        );
        return 2;
    };
    if args.bool_flag("real")
        && matches!(placement, PlacementPolicy::LeastOutstanding { .. })
    {
        // real shards are priced by the calibration constants, not the
        // virtual config the parse derived its estimates from
        placement = PlacementPolicy::least_outstanding_real();
    }
    let placement_label = placement.label();
    let driver = ShardedDriver::new(shards, placement);
    let trace_out = args.str_flag("trace-out", "");
    let (run, span_shards) = if args.bool_flag("real") {
        let opts = real_server_opts(args, policy);
        let result = if args.bool_flag("serial") {
            if !trace_out.is_empty() {
                eprintln!(
                    "--trace-out: the serial fan-out drops each backend \
                     before the next spawn, so there is no merged trace \
                     to dump — ignoring (use the concurrent path)"
                );
            }
            // legacy one-shard-at-a-time fan-out, kept only as the A/B
            // baseline the concurrency bench compares against: each
            // shard runs against a fresh server serving its own subset,
            // dropped before the next spawn
            driver
                .run_with(&spec, |shard, sspec, reqs| {
                    let server = moepim::coordinator::Server::spawn_opts(
                        artifacts_dir(args),
                        moepim::coordinator::ServerOptions {
                            shard: Some(shard),
                            trace: false,
                            ..opts.clone()
                        },
                    )?;
                    run_requests_against_server(&server, sspec, reqs)
                })
                .map(|run| (run, Vec::new()))
        } else {
            // N real backends, each with its own engine and PJRT client
            // on its own router thread, driven genuinely in parallel
            driver.run_real_concurrent_traced(&artifacts_dir(args), &spec,
                                              &opts)
        };
        match result {
            Ok(pair) => pair,
            Err(e) => {
                eprintln!("shardtest failed: {e:#}");
                return 1;
            }
        }
    } else if trace_out.is_empty() {
        // N independent virtual clusters: byte-identical output per seed
        (driver.run_virtual(&vcfg, &spec, policy), Vec::new())
    } else {
        // same run with per-shard span sinks; still byte-identical
        driver.run_virtual_traced(&vcfg, &spec, policy)
    };
    if !trace_out.is_empty() && !span_shards.is_empty() {
        let clock = if args.bool_flag("real") { "real" } else { "virtual" };
        let code = write_trace_out(&trace_out, &span_shards, clock);
        if code != 0 {
            return code;
        }
    }
    let metrics_file = args.str_flag("metrics-file", "");
    if !metrics_file.is_empty() {
        let m = moepim::workload::shard::merge(&spec, &run.shards);
        let code = write_metrics_file(
            &metrics_file,
            &moepim::workload::metrics_registry_merged(&m),
        );
        if code != 0 {
            return code;
        }
    }
    let record_path = args.str_flag("record", "");
    if !record_path.is_empty() {
        let backend = moepim::workload::TraceBackend {
            // per-backend slot count read off the run itself (real shards
            // report their spawned shape, virtual ones echo the config)
            slots: run
                .shards
                .first()
                .map_or(vcfg.slots.max(1), |s| s.outcome.slots),
            prefill_chunk: vcfg.prefill_chunk,
            queue_cap: args.usize_flag("queue-cap", 0),
            shards,
            placement: Some(placement_label.to_string()),
        };
        let trace = moepim::workload::TraceRecorder::new(&spec, policy)
            .finish_sharded(&run, backend);
        if let Err(code) = write_trace(&trace, &record_path) {
            return code;
        }
    }
    print_report(args, &report::build_sharded(&spec, policy, &driver, &run))
}

/// `--placement live`: online least-outstanding placement from live
/// per-shard signals instead of split-time estimates.  Real runs go
/// through the `Cluster` front door (with `--intake-cap` backpressure
/// and `--shed-depth` load shedding); virtual runs advance N virtual
/// backends in lock-step, which requires an open-loop arrival process.
fn run_sharded_live(args: &Args, shards: usize,
                    policy: moepim::workload::AdmissionPolicy,
                    spec: &moepim::workload::WorkloadSpec,
                    vcfg: &moepim::workload::VirtualConfig) -> i32 {
    use moepim::coordinator::{Cluster, ClusterOptions, ClusterPlacement};
    use moepim::workload::{
        report, run_against_cluster, run_virtual_live,
        run_virtual_live_traced,
    };
    let record_path = args.str_flag("record", "");
    let trace_out = args.str_flag("trace-out", "");
    let (run, record_backend) = if args.bool_flag("real") {
        let cluster = match Cluster::spawn(&artifacts_dir(args),
                                           ClusterOptions {
            shards,
            server: real_server_opts(args, policy),
            placement: ClusterPlacement::LiveLeastOutstanding,
            intake_cap: args.usize_flag("intake-cap", 0),
            shed_depth: args.usize_flag("shed-depth", 0),
        }) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("failed to start cluster: {e:#}");
                return 1;
            }
        };
        let run = match run_against_cluster(&cluster, spec) {
            Ok(run) => run,
            Err(e) => {
                eprintln!("shardtest failed: {e:#}");
                return 1;
            }
        };
        let backend = if record_path.is_empty() {
            None
        } else {
            // backend block read off the cluster's recording hooks
            match cluster.stats() {
                Ok(stats) => Some(
                    moepim::workload::TraceBackend::from_cluster_stats(
                        &stats,
                    ),
                ),
                Err(e) => {
                    eprintln!(
                        "failed to read cluster stats for the trace: {e:#}"
                    );
                    return 1;
                }
            }
        };
        if !trace_out.is_empty() {
            // placement-thread shard first, then the backends in shard
            // order — one merged document across the whole front door
            match cluster.take_trace() {
                Ok(span_shards) => {
                    let code =
                        write_trace_out(&trace_out, &span_shards, "real");
                    if code != 0 {
                        return code;
                    }
                }
                Err(e) => {
                    eprintln!("failed to drain the span trace: {e:#}");
                    return 1;
                }
            }
        }
        (run, backend)
    } else {
        if matches!(spec.arrival,
                    moepim::workload::ArrivalProcess::Closed { .. }) {
            eprintln!(
                "--placement live requires an open-loop arrival process \
                 (poisson|bursty|replay): live placement decides per \
                 arrival, and closed-loop arrivals are completion-driven"
            );
            return 2;
        }
        let run = if trace_out.is_empty() {
            run_virtual_live(vcfg, spec, policy, shards)
        } else {
            // same lock-step advance with per-backend span sinks; the
            // trace rides the virtual clock, so it is byte-identical
            // per seed like the report
            let (run, span_shards) =
                run_virtual_live_traced(vcfg, spec, policy, shards, true);
            let code = write_trace_out(&trace_out, &span_shards, "virtual");
            if code != 0 {
                return code;
            }
            run
        };
        let backend = (!record_path.is_empty()).then(|| {
            let mut b = moepim::workload::TraceBackend::from_virtual(vcfg);
            b.shards = shards;
            b.placement = Some("live-least-outstanding".to_string());
            b
        });
        (run, backend)
    };
    if let Some(backend) = record_backend {
        let trace = moepim::workload::TraceRecorder::new(spec, policy)
            .finish_sharded(&run, backend);
        if let Err(code) = write_trace(&trace, &record_path) {
            return code;
        }
    }
    let metrics_file = args.str_flag("metrics-file", "");
    if !metrics_file.is_empty() {
        let m = moepim::workload::shard::merge(spec, &run.shards);
        let code = write_metrics_file(
            &metrics_file,
            &moepim::workload::metrics_registry_merged(&m),
        );
        if code != 0 {
            return code;
        }
    }
    print_report(args, &report::build_sharded_labeled(
        spec, policy, shards, "live-least-outstanding", &run))
}

/// `--placement dynamic`: the full placement control loop.  Virtual runs
/// drive N (possibly heterogeneous, see `--shard-slots`) virtual
/// backends through `run_virtual_dynamic` — capacity-weighted routing,
/// periodic queued-request migration every `--rebalance-every` arrivals,
/// and hot-expert-group replication priced against the
/// `--replicate-budget-mm2` area ledger.  Real runs go through the
/// `Cluster` front door with `ClusterPlacement::Dynamic`, which holds
/// arrivals while every backend is saturated and re-places them (the
/// migration analogue) at rebalance ticks.
fn run_sharded_dynamic(args: &Args, shards: usize,
                       policy: moepim::workload::AdmissionPolicy,
                       spec: &moepim::workload::WorkloadSpec,
                       vcfg: &moepim::workload::VirtualConfig) -> i32 {
    use moepim::coordinator::{Cluster, ClusterOptions, ClusterPlacement};
    use moepim::placement::{DynamicConfig, PlacementReport};
    use moepim::workload::{
        report, run_against_cluster, run_virtual_dynamic,
        run_virtual_dynamic_traced,
    };
    let rebalance_every = args.usize_flag("rebalance-every", 16);
    let budget = args.f64_flag("replicate-budget-mm2", 0.0);
    let record_path = args.str_flag("record", "");
    let trace_out = args.str_flag("trace-out", "");
    let (run, pr, record_backend) = if args.bool_flag("real") {
        if !args.str_flag("shard-slots", "").is_empty() {
            eprintln!(
                "--shard-slots shapes virtual fleets; real shards take \
                 their shape from the artifact set — ignoring"
            );
        }
        let cluster = match Cluster::spawn(&artifacts_dir(args),
                                           ClusterOptions {
            shards,
            server: real_server_opts(args, policy),
            placement: ClusterPlacement::Dynamic { rebalance_every },
            intake_cap: args.usize_flag("intake-cap", 0),
            shed_depth: args.usize_flag("shed-depth", 0),
        }) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("failed to start cluster: {e:#}");
                return 1;
            }
        };
        let run = match run_against_cluster(&cluster, spec) {
            Ok(run) => run,
            Err(e) => {
                eprintln!("shardtest failed: {e:#}");
                return 1;
            }
        };
        let stats = match cluster.stats() {
            Ok(stats) => stats,
            Err(e) => {
                eprintln!("failed to read cluster stats: {e:#}");
                return 1;
            }
        };
        // the real front door migrates held arrivals but models no
        // replication (expert layouts are fixed at engine build time),
        // so only the migration counter is live; the imbalance pair
        // stays 0/0 rather than faking a structural measurement
        let pr = PlacementReport {
            migrations: stats.migrations,
            ..PlacementReport::default()
        };
        let backend = (!record_path.is_empty()).then(|| {
            moepim::workload::TraceBackend::from_cluster_stats(&stats)
        });
        if !trace_out.is_empty() {
            match cluster.take_trace() {
                Ok(span_shards) => {
                    let code =
                        write_trace_out(&trace_out, &span_shards, "real");
                    if code != 0 {
                        return code;
                    }
                }
                Err(e) => {
                    eprintln!("failed to drain the span trace: {e:#}");
                    return 1;
                }
            }
        }
        (run, pr, backend)
    } else {
        if matches!(spec.arrival,
                    moepim::workload::ArrivalProcess::Closed { .. }) {
            eprintln!(
                "--placement dynamic requires an open-loop arrival \
                 process (poisson|bursty|replay): the control loop \
                 decides per arrival, and closed-loop arrivals are \
                 completion-driven"
            );
            return 2;
        }
        // heterogeneous fleets: --shard-slots 2,4,2 overrides the slot
        // count per shard (everything else inherits the shared config)
        let mut cfgs = vec![vcfg.clone(); shards];
        let slots_csv = args.str_flag("shard-slots", "");
        if !slots_csv.is_empty() {
            let parsed: Result<Vec<usize>, _> = slots_csv
                .split(',')
                .map(|t| t.trim().parse::<usize>())
                .collect();
            match parsed {
                Ok(v) if v.len() == shards && v.iter().all(|&s| s > 0) => {
                    for (c, s) in cfgs.iter_mut().zip(&v) {
                        c.slots = *s;
                    }
                }
                _ => {
                    eprintln!(
                        "--shard-slots wants {shards} comma-separated \
                         positive slot counts (one per shard)"
                    );
                    return 2;
                }
            }
        }
        let dcfg = DynamicConfig::from_virtual(vcfg, rebalance_every,
                                               budget);
        let (run, pr) = if trace_out.is_empty() {
            run_virtual_dynamic(&cfgs, spec, policy, &dcfg)
        } else {
            // the trace rides the virtual clock: byte-identical per seed
            let (run, pr, span_shards) =
                run_virtual_dynamic_traced(&cfgs, spec, policy, &dcfg,
                                           true);
            let code = write_trace_out(&trace_out, &span_shards, "virtual");
            if code != 0 {
                return code;
            }
            (run, pr)
        };
        let backend = (!record_path.is_empty()).then(|| {
            let mut b = moepim::workload::TraceBackend::from_virtual(vcfg);
            b.shards = shards;
            b.placement = Some("dynamic".to_string());
            b
        });
        (run, pr, backend)
    };
    if let Some(backend) = record_backend {
        let trace = moepim::workload::TraceRecorder::new(spec, policy)
            .finish_sharded(&run, backend);
        if let Err(code) = write_trace(&trace, &record_path) {
            return code;
        }
    }
    let metrics_file = args.str_flag("metrics-file", "");
    if !metrics_file.is_empty() {
        let m = moepim::workload::shard::merge(spec, &run.shards);
        let code = write_metrics_file(
            &metrics_file,
            &moepim::workload::metrics_registry_merged(&m),
        );
        if code != 0 {
            return code;
        }
    }
    println!(
        "placement: {} migrations, {} replicas, +{:.3} mm2 \
         (imbalance {:.3} -> {:.3})",
        pr.migrations, pr.replicas, pr.area_mm2_delta,
        pr.imbalance_before, pr.imbalance_after
    );
    print_report(args, &report::build_sharded_placed(
        spec, policy, shards, "dynamic", &run, &pr))
}

/// The real-backend `ServerOptions` every `--real` path shares: policy
/// plus the `--prefill-chunk` and `--queue-cap` knobs (shard tags are
/// filled in per backend by the fan-out).  Span tracing turns on iff
/// `--trace-out` was given — an untraced router never pays for the sink.
fn real_server_opts(args: &Args,
                    policy: moepim::workload::AdmissionPolicy)
    -> moepim::coordinator::ServerOptions {
    moepim::coordinator::ServerOptions {
        policy,
        shard: None,
        prefill_chunk: args.usize_flag("prefill-chunk", 0),
        queue_cap: args.usize_flag("queue-cap", 0),
        qos: args.bool_flag("qos"),
        trace: !args.str_flag("trace-out", "").is_empty(),
    }
}

// ---------------------------------------------------------------------------
// observability plumbing: --trace-out / --metrics-file across
// serve/loadtest/shardtest, and the perfcmp subcommand (DESIGN.md
// §Observability)
// ---------------------------------------------------------------------------

/// Write the merged `moepim.spans.v1` Chrome trace document.  The notice
/// goes to stderr so `--trace-out` composes with report redirection.
fn write_trace_out(path: &str, shards: &[moepim::obs::TraceShard],
                   clock: &str) -> i32 {
    let text = moepim::obs::chrome_trace(shards, clock).to_string_pretty();
    if let Err(e) = std::fs::write(path, format!("{text}\n")) {
        eprintln!("failed to write trace {path}: {e}");
        return 1;
    }
    let events: usize = shards.iter().map(|s| s.events.len()).sum();
    let dropped: u64 = shards.iter().map(|s| s.dropped_events).sum();
    eprintln!(
        "trace: {events} events from {} threads ({dropped} dropped) -> \
         {path}",
        shards.len()
    );
    0
}

/// Write a Prometheus-style text snapshot of `registry`.
fn write_metrics_file(path: &str,
                      registry: &moepim::obs::MetricsRegistry) -> i32 {
    if let Err(e) = std::fs::write(path, registry.render_text()) {
        eprintln!("failed to write metrics {path}: {e}");
        return 1;
    }
    eprintln!("metrics: snapshot -> {path}");
    0
}

/// The `moepim serve` shutdown metrics snapshot, built straight off
/// [`moepim::coordinator::ServerStats`].  `serve` has no workload spec,
/// so there are no SLO/latency series here — those ride the loadtest
/// paths, which share this vocabulary via the report builders.
fn serve_metrics(stats: &moepim::coordinator::ServerStats)
    -> moepim::obs::MetricsRegistry {
    let mut reg = moepim::obs::MetricsRegistry::new();
    reg.counter("moepim_requests_completed_total",
                "requests finished with a token stream", stats.completed);
    reg.counter("moepim_requests_errored_total",
                "requests finished with an error", stats.errored);
    reg.counter("moepim_requests_shed_total",
                "requests rejected by admission backpressure",
                stats.shed_requests);
    reg.counter("moepim_tokens_generated_total",
                "decode tokens produced", stats.tokens_generated);
    reg.counter("moepim_batch_dispatches_total",
                "batched decode dispatches", stats.batch_dispatches);
    reg.counter("moepim_single_dispatches_total",
                "single-request dispatches", stats.single_dispatches);
    reg.counter("moepim_prefill_chunks_total",
                "chunked prefill steps", stats.prefill_chunks);
    reg.counter("moepim_preemptions_total",
                "batch-tier slots preempted for interactive arrivals",
                stats.preemptions);
    reg.counter("moepim_restores_total",
                "checkpointed slots restored and resumed",
                stats.restores);
    reg.counter("moepim_preempted_wait_us_total",
                "total microseconds preempted requests spent requeued",
                stats.preempted_wait_us);
    reg.counter("moepim_planner_steps_total",
                "planner layer steps", stats.planner.steps);
    reg.counter("moepim_planner_cycles_total",
                "planner modeled cycles", stats.planner.cycles);
    reg.counter("moepim_planner_contention_cycles_total",
                "planner cycles lost to bank contention",
                stats.planner.contention_cycles);
    reg.counter("moepim_planner_transfers_total",
                "planner modeled activation transfers",
                stats.planner.transfers);
    reg.gauge("moepim_slots", "decode slots", stats.slots as f64);
    reg.gauge("moepim_peak_waiting", "admission queue high-water mark",
              stats.peak_waiting as f64);
    reg.gauge("moepim_mean_batch_occupancy",
              "mean live slots per batched dispatch",
              stats.mean_batch_occupancy());
    reg
}

/// `moepim perfcmp OLD.json NEW.json`: compare two bench artifacts of
/// the same schema leg by leg and exit 3 if any shared metric regressed
/// beyond `--threshold` percent — CI's perf-trajectory gate between
/// successive `BENCH_*.json` uploads.
fn cmd_perfcmp(args: &Args) -> i32 {
    use moepim::workload::{perf_compare, perfcmp, DEFAULT_THRESHOLD_PCT};
    let (Some(old_path), Some(new_path)) =
        (args.positional.first(), args.positional.get(1))
    else {
        eprintln!("perfcmp needs OLD.json and NEW.json\n{}", usage::PERFCMP);
        return 2;
    };
    let threshold = args.f64_flag("threshold", DEFAULT_THRESHOLD_PCT);
    let load = |path: &str| -> Result<moepim::util::json::Json, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("failed to read {path}: {e}"))?;
        moepim::util::json::parse(&text).map_err(|e| format!("{path}: {e}"))
    };
    let (old, new) = match (load(old_path), load(new_path)) {
        (Ok(a), Ok(b)) => (a, b),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("{e}");
            return 1;
        }
    };
    match perf_compare(&old, &new, threshold) {
        Ok(deltas) => {
            print!("{}", perfcmp::render(&deltas));
            let regressions =
                deltas.iter().filter(|d| d.regression).count();
            if regressions > 0 {
                eprintln!(
                    "perfcmp: {regressions} regression(s) beyond \
                     {threshold:.1}%"
                );
                3
            } else {
                println!("perfcmp: no regressions beyond {threshold:.1}%");
                0
            }
        }
        Err(e) => {
            eprintln!("perfcmp: {e}");
            1
        }
    }
}

/// Print `doc` and honour `--out`; the shared tail of every report path.
fn print_report(args: &Args, doc: &moepim::util::json::Json) -> i32 {
    let text = doc.to_string_pretty();
    println!("{text}");
    let out_path = args.str_flag("out", "");
    if !out_path.is_empty() {
        if let Err(e) = std::fs::write(&out_path, format!("{text}\n")) {
            eprintln!("failed to write {out_path}: {e}");
            return 1;
        }
    }
    0
}

/// `--bench-cluster`: the concurrency perf artifact (CI's
/// `BENCH_cluster.json`).  Three legs over the same workload and the
/// same artifact set: `single` (the whole spec on one backend),
/// `serial` (the legacy one-shard-at-a-time fan-out; its duration is
/// the *sum* of per-shard drive times), and `concurrent` (N backends on
/// their own router threads; its duration is the slowest shard's).
/// Record-only: the JSON carries throughput and p99 e2e per leg plus
/// the concurrent-vs-serial speedup, and CI uploads it as an artifact
/// instead of gating on a wall-clock threshold (shared runners would
/// make such a gate flaky).
fn cluster_bench(args: &Args) -> i32 {
    use moepim::util::json::Json;
    use moepim::workload::{
        run_requests_against_server, AdmissionPolicy, PlacementPolicy,
        ShardedDriver, ShardedRun,
    };
    let dir = artifacts_dir(args);
    if !dir.join("manifest.json").exists() {
        println!("bench-cluster: no artifact set at {} — skipped",
                 dir.display());
        return 0;
    }
    let Some(policy) =
        AdmissionPolicy::parse(&args.str_flag("policy", "fifo"))
    else {
        eprintln!("unknown --policy (expected fifo|sjf|edf)");
        return 2;
    };
    let spec = match loadtest_spec(args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let shards = args.usize_flag("shards", 2).max(2);
    let opts = real_server_opts(args, policy);

    // duration per leg: serial legs cost the sum of per-shard drive
    // times (they ran back to back), concurrent legs the max (they
    // overlapped); tokens and latency samples merge the same either way
    fn leg_json(mode: &str, nshards: usize, run: &ShardedRun,
                serial: bool) -> (f64, Json) {
        let duration_s = if serial {
            run.shards.iter().map(|s| s.outcome.duration_s).sum::<f64>()
        } else {
            run.shards
                .iter()
                .map(|s| s.outcome.duration_s)
                .fold(0.0_f64, f64::max)
        }
        .max(1e-9);
        let tokens: u64 = run
            .shards
            .iter()
            .map(|s| s.outcome.tokens_generated())
            .sum();
        let mut e2e: Vec<f64> = run
            .shards
            .iter()
            .flat_map(|s| s.outcome.samples.iter())
            .map(|s| s.e2e_us)
            .collect();
        e2e.sort_by(f64::total_cmp);
        let p99 = if e2e.is_empty() {
            0.0
        } else {
            e2e[((e2e.len() - 1) as f64 * 0.99).round() as usize]
        };
        let doc = Json::obj(vec![
            ("mode", Json::str(mode)),
            ("shards", Json::num(nshards as f64)),
            ("duration_s", Json::num(duration_s)),
            ("tokens", Json::num(tokens as f64)),
            ("tokens_per_s", Json::num(tokens as f64 / duration_s)),
            ("p99_e2e_us", Json::num(p99)),
        ]);
        (duration_s, doc)
    }
    let spawn_serial = |shard: usize,
                        sspec: &moepim::workload::WorkloadSpec,
                        reqs: &[moepim::workload::RequestSpec]| {
        let server = moepim::coordinator::Server::spawn_opts(
            dir.clone(),
            moepim::coordinator::ServerOptions {
                shard: Some(shard),
                ..opts.clone()
            },
        )?;
        run_requests_against_server(&server, sspec, reqs)
    };
    let mut legs = Vec::new();
    println!("bench-cluster: single backend, {} requests", spec.requests);
    let single = match ShardedDriver::new(1, PlacementPolicy::RoundRobin)
        .run_real_concurrent(&dir, &spec, &opts)
    {
        Ok(run) => run,
        Err(e) => {
            eprintln!("bench-cluster: single leg failed: {e:#}");
            return 1;
        }
    };
    legs.push(leg_json("single", 1, &single, false).1);
    println!("bench-cluster: {shards}-shard serial fan-out");
    let serial = match ShardedDriver::new(shards,
                                          PlacementPolicy::RoundRobin)
        .run_with(&spec, spawn_serial)
    {
        Ok(run) => run,
        Err(e) => {
            eprintln!("bench-cluster: serial leg failed: {e:#}");
            return 1;
        }
    };
    let (serial_s, serial_doc) = leg_json("serial", shards, &serial, true);
    legs.push(serial_doc);
    println!("bench-cluster: {shards}-shard concurrent fan-out");
    let conc = match ShardedDriver::new(shards, PlacementPolicy::RoundRobin)
        .run_real_concurrent(&dir, &spec, &opts)
    {
        Ok(run) => run,
        Err(e) => {
            eprintln!("bench-cluster: concurrent leg failed: {e:#}");
            return 1;
        }
    };
    let (conc_s, conc_doc) = leg_json("concurrent", shards, &conc, false);
    legs.push(conc_doc);
    let doc = Json::obj(vec![
        ("schema", Json::str("moepim.bench_cluster.v1")),
        ("policy", Json::str(policy.label())),
        ("shards", Json::num(shards as f64)),
        ("requests", Json::num(spec.requests as f64)),
        ("seed", Json::num(spec.seed as f64)),
        ("legs", Json::Arr(legs)),
        ("speedup_concurrent_vs_serial",
         Json::num(serial_s / conc_s.max(1e-9))),
    ]);
    let text = doc.to_string_pretty();
    println!("{text}");
    let out_path = args.str_flag("out", "BENCH_cluster.json");
    if let Err(e) = std::fs::write(&out_path, format!("{text}\n")) {
        eprintln!("failed to write {out_path}: {e}");
        return 1;
    }
    println!("bench-cluster: wrote {out_path} (speedup {:.2}x)",
             serial_s / conc_s.max(1e-9));
    0
}

/// `--bench-scenarios`: the scenario perf artifact (CI's
/// `BENCH_scenarios.json`).  Runs every preset on the virtual backend
/// (byte-repeatable, no artifact set needed) and records throughput and
/// tail latency per scenario.  Record-only like `--bench-cluster`: CI
/// uploads the document instead of gating on thresholds, but a repeat
/// run must still match byte for byte — a nondeterministic artifact
/// would be useless as a regression reference.
fn scenario_bench(args: &Args) -> i32 {
    use moepim::util::json::Json;
    use moepim::workload::{
        report, run_virtual, scenario_names, scenario_spec, AdmissionPolicy,
    };
    let Some(policy) =
        AdmissionPolicy::parse(&args.str_flag("policy", "fifo"))
    else {
        eprintln!("unknown --policy (expected fifo|sjf|edf)");
        return 2;
    };
    let seed = args.u64_flag("seed", 2026);
    let cfg = loadtest_vcfg(args);
    let mut legs = Vec::new();
    for name in scenario_names() {
        let spec = scenario_spec(name, seed).expect("known preset");
        let out = run_virtual(&cfg, &spec, policy);
        let a = report::build(&spec, policy, &out).to_string_pretty();
        let b = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        if a != b {
            eprintln!("bench-scenarios: {name} not deterministic");
            return 1;
        }
        let mut e2e: Vec<f64> =
            out.samples.iter().map(|s| s.e2e_us).collect();
        e2e.sort_by(f64::total_cmp);
        let pct = |q: f64| {
            if e2e.is_empty() {
                0.0
            } else {
                e2e[((e2e.len() - 1) as f64 * q).round() as usize]
            }
        };
        let ok = out.samples.iter().filter(|s| s.ok).count();
        let tokens = out.tokens_generated();
        let duration_s = out.duration_s.max(1e-9);
        legs.push(Json::obj(vec![
            ("scenario", Json::str(name)),
            ("requests", Json::num(spec.requests as f64)),
            ("ok", Json::num(ok as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("duration_s", Json::num(duration_s)),
            ("tokens_per_s", Json::num(tokens as f64 / duration_s)),
            ("p50_e2e_us", Json::num(pct(0.50))),
            ("p99_e2e_us", Json::num(pct(0.99))),
        ]));
        println!(
            "bench-scenarios: {name} OK ({} requests, {tokens} tokens)",
            spec.requests
        );
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("moepim.bench_scenarios.v1")),
        ("policy", Json::str(policy.label())),
        ("seed", Json::str(&seed.to_string())),
        ("slots", Json::num(cfg.slots as f64)),
        ("scenarios", Json::Arr(legs)),
    ]);
    let text = doc.to_string_pretty();
    println!("{text}");
    let out_path = args.str_flag("out", "BENCH_scenarios.json");
    if let Err(e) = std::fs::write(&out_path, format!("{text}\n")) {
        eprintln!("failed to write {out_path}: {e}");
        return 1;
    }
    println!("bench-scenarios: wrote {out_path}");
    0
}

/// `--bench-qos`: the preemption perf artifact (CI's `BENCH_qos.json`).
/// Runs the mixed-tenants scenario on the virtual backend twice — QoS
/// off, then QoS on — under the deadline policy and records per-leg
/// interactive-tier p99 TTFT, batch-tier p99 e2e, throughput, and the
/// preemption counters.  Record-only like `--bench-scenarios` (CI
/// uploads the document and `moepim perfcmp` compares successive runs),
/// but each leg must still be byte-repeatable per seed.
fn qos_bench(args: &Args) -> i32 {
    use moepim::util::json::Json;
    use moepim::workload::{
        report, run_virtual, scenario_spec, AdmissionPolicy, Priority,
        VirtualConfig,
    };
    let seed = args.u64_flag("seed", 2026);
    let policy = AdmissionPolicy::deadline();
    let spec = scenario_spec("mixed-tenants", seed).expect("known preset");
    let mut legs = Vec::new();
    for qos in [false, true] {
        let cfg = VirtualConfig { qos, ..loadtest_vcfg(args) };
        let out = run_virtual(&cfg, &spec, policy);
        let a = report::build(&spec, policy, &out).to_string_pretty();
        let b = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        if a != b {
            eprintln!("bench-qos: qos={qos} leg not deterministic");
            return 1;
        }
        let pct = |mut xs: Vec<f64>, q: f64| {
            xs.sort_by(f64::total_cmp);
            if xs.is_empty() {
                0.0
            } else {
                xs[((xs.len() - 1) as f64 * q).round() as usize]
            }
        };
        let tier = |p: Priority| {
            out.samples
                .iter()
                .filter(move |s| {
                    Priority::assign(s.id, spec.interactive_mix) == p
                })
        };
        let interactive_ttft: Vec<f64> =
            tier(Priority::Interactive).filter_map(|s| s.ttft_us).collect();
        let batch_e2e: Vec<f64> =
            tier(Priority::Batch).map(|s| s.e2e_us).collect();
        let e2e: Vec<f64> = out.samples.iter().map(|s| s.e2e_us).collect();
        let tokens = out.tokens_generated();
        let duration_s = out.duration_s.max(1e-9);
        legs.push(Json::obj(vec![
            // `mode` is the leg key perfcmp matches across artifacts
            ("mode", Json::str(if qos { "qos-on" } else { "qos-off" })),
            ("qos", Json::Bool(qos)),
            ("requests", Json::num(spec.requests as f64)),
            ("ok", Json::num(
                out.samples.iter().filter(|s| s.ok).count() as f64,
            )),
            ("tokens", Json::num(tokens as f64)),
            ("duration_s", Json::num(duration_s)),
            ("tokens_per_s", Json::num(tokens as f64 / duration_s)),
            ("p50_e2e_us", Json::num(pct(e2e.clone(), 0.50))),
            ("p99_e2e_us", Json::num(pct(e2e, 0.99))),
            ("interactive_p99_ttft_us",
             Json::num(pct(interactive_ttft, 0.99))),
            ("batch_p99_e2e_us", Json::num(pct(batch_e2e, 0.99))),
            ("preemptions", Json::num(out.preemptions as f64)),
            ("restores", Json::num(out.restores as f64)),
            ("preempted_wait_us",
             Json::num(out.preempted_wait_us as f64)),
        ]));
        println!(
            "bench-qos: qos={qos} OK ({} preemptions, {tokens} tokens)",
            out.preemptions
        );
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("moepim.bench_qos.v1")),
        ("scenario", Json::str("mixed-tenants")),
        ("policy", Json::str(policy.label())),
        ("seed", Json::str(&seed.to_string())),
        ("interactive_mix", Json::num(spec.interactive_mix)),
        ("legs", Json::Arr(legs)),
    ]);
    let text = doc.to_string_pretty();
    println!("{text}");
    let out_path = args.str_flag("out", "BENCH_qos.json");
    if let Err(e) = std::fs::write(&out_path, format!("{text}\n")) {
        eprintln!("failed to write {out_path}: {e}");
        return 1;
    }
    println!("bench-qos: wrote {out_path}");
    0
}

/// `--bench-placement`: the placement-control-loop perf artifact (CI's
/// `BENCH_placement.json`).  Three legs over the same skewed flash-crowd
/// workload on the virtual backend: `static-route-aware` (split-time
/// routing-aware placement), `dynamic` (the live control loop with a
/// zero replication budget — migration only), and `dynamic-replicate`
/// (the same loop with an area budget that buys hot-group replicas).
/// Record-only like the other benches — CI uploads the document and
/// `moepim perfcmp` compares successive runs keyed on `mode` — but each
/// leg must still be byte-repeatable per seed.
fn placement_bench(args: &Args) -> i32 {
    use moepim::placement::{DynamicConfig, PlacementReport};
    use moepim::util::json::Json;
    use moepim::workload::{
        report, run_virtual_dynamic, scenario_spec, AdmissionPolicy,
        PlacementPolicy, ShardedDriver, ShardedRun, VirtualConfig,
        WorkloadSpec,
    };
    let seed = args.u64_flag("seed", 2026);
    let shards = args.usize_flag("shards", 3).max(2);
    let budget = args.f64_flag("replicate-budget-mm2", 100.0);
    let policy = AdmissionPolicy::fifo();
    // flash-crowd preset with the routing skew turned up so expert
    // groups actually develop hot spots worth migrating away from
    let spec = WorkloadSpec {
        requests: 96,
        sizes: moepim::workload::SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 2.0,
            prompt: (4, 48),
            gen: (1, 24),
        },
        ..scenario_spec("flash-crowd", seed).expect("known preset")
    };
    let cfg = VirtualConfig { route_skew: 2.0, ..loadtest_vcfg(args) };

    // merged leg metrics: concurrent semantics (slowest shard bounds
    // the wall clock), samples merged across shards
    fn leg_json(mode: &str, run: &ShardedRun, pr: &PlacementReport)
        -> Json {
        let duration_s = run
            .shards
            .iter()
            .map(|s| s.outcome.duration_s)
            .fold(0.0_f64, f64::max)
            .max(1e-9);
        let tokens: u64 = run
            .shards
            .iter()
            .map(|s| s.outcome.tokens_generated())
            .sum();
        let samples: Vec<&moepim::workload::Sample> = run
            .shards
            .iter()
            .flat_map(|s| s.outcome.samples.iter())
            .collect();
        let pct = |mut xs: Vec<f64>, q: f64| {
            xs.sort_by(f64::total_cmp);
            if xs.is_empty() {
                0.0
            } else {
                xs[((xs.len() - 1) as f64 * q).round() as usize]
            }
        };
        let e2e: Vec<f64> = samples.iter().map(|s| s.e2e_us).collect();
        let ttft: Vec<f64> =
            samples.iter().filter_map(|s| s.ttft_us).collect();
        Json::obj(vec![
            // `mode` is the leg key perfcmp matches across artifacts
            ("mode", Json::str(mode)),
            ("ok", Json::num(
                samples.iter().filter(|s| s.ok).count() as f64,
            )),
            ("tokens", Json::num(tokens as f64)),
            ("duration_s", Json::num(duration_s)),
            ("tokens_per_s", Json::num(tokens as f64 / duration_s)),
            ("p50_e2e_us", Json::num(pct(e2e.clone(), 0.50))),
            ("p99_e2e_us", Json::num(pct(e2e, 0.99))),
            ("p99_ttft_us", Json::num(pct(ttft, 0.99))),
            ("migrations", Json::num(pr.migrations as f64)),
            ("replicas", Json::num(pr.replicas as f64)),
            ("area_mm2_delta", Json::num(pr.area_mm2_delta)),
            ("imbalance_before", Json::num(pr.imbalance_before)),
            ("imbalance_after", Json::num(pr.imbalance_after)),
        ])
    }

    let mut legs = Vec::new();
    // leg 1: the static baseline the control loop must beat
    {
        let driver =
            ShardedDriver::new(shards, PlacementPolicy::route_aware(&cfg));
        let run = driver.run_virtual(&cfg, &spec, policy);
        let a = report::build_sharded(&spec, policy, &driver, &run)
            .to_string_pretty();
        let b = report::build_sharded(
            &spec, policy, &driver,
            &driver.run_virtual(&cfg, &spec, policy),
        )
        .to_string_pretty();
        if a != b {
            eprintln!("bench-placement: static leg not deterministic");
            return 1;
        }
        legs.push(leg_json("static-route-aware", &run,
                           &PlacementReport::default()));
        println!("bench-placement: static-route-aware OK");
    }
    // legs 2+3: the control loop, migration-only then with replication
    for (mode, mm2) in [("dynamic", 0.0), ("dynamic-replicate", budget)] {
        let cfgs = vec![cfg.clone(); shards];
        let dcfg = DynamicConfig::from_virtual(
            &cfg, args.usize_flag("rebalance-every", 8), mm2);
        let (run, pr) = run_virtual_dynamic(&cfgs, &spec, policy, &dcfg);
        let a = report::build_sharded_placed(
            &spec, policy, shards, "dynamic", &run, &pr)
            .to_string_pretty();
        let (run2, pr2) = run_virtual_dynamic(&cfgs, &spec, policy, &dcfg);
        let b = report::build_sharded_placed(
            &spec, policy, shards, "dynamic", &run2, &pr2)
            .to_string_pretty();
        if a != b {
            eprintln!("bench-placement: {mode} leg not deterministic");
            return 1;
        }
        legs.push(leg_json(mode, &run, &pr));
        println!(
            "bench-placement: {mode} OK ({} migrations, {} replicas, \
             +{:.3} mm2)",
            pr.migrations, pr.replicas, pr.area_mm2_delta
        );
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("moepim.bench_placement.v1")),
        ("scenario", Json::str("skewed-flash-crowd")),
        ("policy", Json::str(policy.label())),
        ("seed", Json::str(&seed.to_string())),
        ("shards", Json::num(shards as f64)),
        ("replicate_budget_mm2", Json::num(budget)),
        ("legs", Json::Arr(legs)),
    ]);
    let text = doc.to_string_pretty();
    println!("{text}");
    let out_path = args.str_flag("out", "BENCH_placement.json");
    if let Err(e) = std::fs::write(&out_path, format!("{text}\n")) {
        eprintln!("failed to write {out_path}: {e}");
        return 1;
    }
    println!("bench-placement: wrote {out_path}");
    0
}

/// `--smoke`: the CI gate.  Virtual leg: every (process × policy ×
/// prefill-chunk) cell of the acceptance matrix must emit a
/// byte-identical report twice in a row — chunked admission exactly as
/// repeatable as monolithic.  Then the trace-lifecycle legs: a recorded
/// virtual run must replay byte-identically through its JSON round trip,
/// and every scenario preset must be report-deterministic per seed.
/// Real legs (when an artifact set is
/// present): short closed-loop runs against the threaded server under
/// FIFO, SJF, and FIFO with chunked prefill, every request terminal and
/// successful; then a 2-shard concurrent cluster flooded into its
/// shedding threshold — every request must still get exactly one
/// terminal reply, and shed replies must come back immediately.
fn loadtest_smoke(args: &Args) -> i32 {
    use moepim::workload::{
        report, run_against_server, run_virtual, AdmissionPolicy,
        ArrivalProcess, SizeModel, VirtualConfig, WorkloadSpec,
    };
    let seed = args.u64_flag("seed", 2026);
    let processes = [
        ArrivalProcess::Poisson { rate_rps: 400.0 },
        ArrivalProcess::Bursty {
            rate_rps: 1200.0,
            mean_on_ms: 10.0,
            mean_off_ms: 30.0,
        },
    ];
    let policies = [AdmissionPolicy::fifo(), AdmissionPolicy::sjf()];
    // the chunked-admission leg rides the same matrix: chunked virtual
    // prefill must be exactly as byte-repeatable per seed as monolithic
    let chunks = [0usize, 4];
    for arrival in &processes {
        for &policy in &policies {
            for &prefill_chunk in &chunks {
                let cfg = VirtualConfig {
                    prefill_chunk,
                    ..VirtualConfig::default()
                };
                let spec = WorkloadSpec {
                    seed,
                    requests: 32,
                    arrival: arrival.clone(),
                    sizes: SizeModel::TraceSeeded {
                        n_experts: 16,
                        skew: 1.2,
                        prompt: (4, 24),
                        gen: (1, 12),
                    },
                    slo_e2e_ms: 50.0,
                    deadline_slack_us_per_token: 500,
                    interactive_mix: 1.0,
                };
                let a = report::build(&spec, policy,
                                      &run_virtual(&cfg, &spec, policy))
                    .to_string_pretty();
                let b = report::build(&spec, policy,
                                      &run_virtual(&cfg, &spec, policy))
                    .to_string_pretty();
                if a != b {
                    eprintln!(
                        "smoke: NONDETERMINISTIC report for {} x {} x \
                         chunk {}",
                        arrival.label(), policy.label(), prefill_chunk
                    );
                    return 1;
                }
                println!(
                    "smoke: virtual {} x {} x chunk {} deterministic \
                     ({} bytes)",
                    arrival.label(), policy.label(), prefill_chunk,
                    a.len()
                );
            }
        }
    }
    // record -> replay -> compare leg: a trace recorded off a virtual run
    // must survive its JSON round trip and replay byte-identically
    // through the exact-request path (the lifecycle the CLI exposes as
    // `--record` / `--replay`)
    {
        use moepim::workload::record::{
            RecordedTrace, TraceBackend, TraceRecorder,
        };
        use moepim::workload::run_virtual_requests;
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec {
            seed,
            requests: 32,
            arrival: ArrivalProcess::Poisson { rate_rps: 400.0 },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 24),
                gen: (1, 12),
            },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        };
        let policy = AdmissionPolicy::fifo();
        let out = run_virtual(&cfg, &spec, policy);
        let recorded = report::build(&spec, policy, &out).to_string_pretty();
        let trace = TraceRecorder::new(&spec, policy)
            .finish(&out, TraceBackend::from_virtual(&cfg));
        let text = trace.to_json().to_string_pretty();
        let loaded = match moepim::util::json::parse(&text)
            .map_err(|e| e.to_string())
            .and_then(|doc| RecordedTrace::from_json(&doc))
        {
            Ok(t) => t,
            Err(e) => {
                eprintln!("smoke: trace round-trip failed: {e}");
                return 1;
            }
        };
        let replay = run_virtual_requests(
            &cfg,
            loaded.original_spec(),
            &loaded.replay_requests(),
            policy,
        );
        let replayed = report::build(loaded.original_spec(), policy, &replay)
            .to_string_pretty();
        if replayed != recorded {
            eprintln!(
                "smoke: record->replay report diverged ({} vs {} bytes)",
                replayed.len(),
                recorded.len()
            );
            return 1;
        }
        println!(
            "smoke: record->replay byte-identical ({} bytes)",
            recorded.len()
        );
    }
    // scenario sweep: every preset must run clean and emit a
    // byte-identical report twice in a row on the virtual backend
    for name in moepim::workload::scenario_names() {
        let Some(spec) = moepim::workload::scenario_spec(name, seed) else {
            eprintln!("smoke: scenario {name} missing");
            return 1;
        };
        let cfg = VirtualConfig::default();
        let policy = AdmissionPolicy::fifo();
        let a = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        let b = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        if a != b {
            eprintln!("smoke: NONDETERMINISTIC scenario {name}");
            return 1;
        }
        println!("smoke: scenario {name} deterministic ({} bytes)", a.len());
    }
    // observability leg: a traced virtual run must (1) leave the outcome
    // byte-identical to the untraced run, (2) dump a byte-identical
    // moepim.spans.v1 document twice in a row, and (3) pass the
    // exactly-one-terminal-per-request conservation check through a JSON
    // round trip
    {
        use moepim::obs::{check_conservation, chrome_trace, TraceSink};
        use moepim::workload::run_virtual_traced;
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec {
            seed,
            requests: 32,
            arrival: ArrivalProcess::Poisson { rate_rps: 400.0 },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 24),
                gen: (1, 12),
            },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        };
        let policy = AdmissionPolicy::fifo();
        let baseline = report::build(&spec, policy,
                                     &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        let run_traced = || {
            let mut sink = TraceSink::on(true);
            let out = run_virtual_traced(&cfg, &spec, policy, &mut sink);
            let trace =
                chrome_trace(&[sink.drain(Some(0), "vsim")], "virtual")
                    .to_string_pretty();
            (report::build(&spec, policy, &out).to_string_pretty(), trace)
        };
        let (report_a, trace_a) = run_traced();
        let (report_b, trace_b) = run_traced();
        if report_a != baseline {
            eprintln!("smoke: tracing perturbed the virtual outcome");
            return 1;
        }
        if trace_a != trace_b || report_a != report_b {
            eprintln!("smoke: traced virtual run not byte-repeatable");
            return 1;
        }
        let doc = match moepim::util::json::parse(&trace_a) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("smoke: span dump is not valid JSON: {e}");
                return 1;
            }
        };
        match check_conservation(&doc) {
            Ok(n) => println!(
                "smoke: span trace deterministic, conservation OK \
                 ({n} requests, {} bytes)",
                trace_a.len()
            ),
            Err(e) => {
                eprintln!("smoke: span conservation violated: {e}");
                return 1;
            }
        }
    }
    // mixed-tenant QoS preemption leg: four batch requests fill every
    // slot at t=0; interactive arrivals at t=300 µs (ids 4 and 9 under
    // mix 0.2) must preempt a batch slot, every preempted slot must be
    // restored, every request must still get exactly one terminal reply,
    // and the report must stay byte-repeatable per seed
    {
        let cfg = VirtualConfig { qos: true, ..VirtualConfig::default() };
        let spec = WorkloadSpec {
            seed,
            requests: 10,
            arrival: ArrivalProcess::Replay {
                times_us: vec![0, 0, 0, 0, 300, 300, 300, 300, 300, 300],
            },
            sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 32 },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 0.2,
        };
        let policy = AdmissionPolicy::deadline();
        let out = run_virtual(&cfg, &spec, policy);
        let ok = out.samples.iter().filter(|s| s.ok).count();
        if out.samples.len() != spec.requests || ok != out.samples.len() {
            eprintln!(
                "smoke: qos leg lost replies ({} terminal, {ok} ok of {})",
                out.samples.len(),
                spec.requests
            );
            return 1;
        }
        if out.preemptions == 0 || out.restores != out.preemptions {
            eprintln!(
                "smoke: qos leg never preempted cleanly (preemptions {}, \
                 restores {})",
                out.preemptions, out.restores
            );
            return 1;
        }
        let a = report::build(&spec, policy, &out).to_string_pretty();
        let b = report::build(&spec, policy,
                              &run_virtual(&cfg, &spec, policy))
            .to_string_pretty();
        if a != b {
            eprintln!("smoke: NONDETERMINISTIC qos preemption report");
            return 1;
        }
        println!(
            "smoke: qos preemption leg OK ({} preemptions, {} restores, \
             {} bytes)",
            out.preemptions,
            out.restores,
            a.len()
        );
    }
    let dir = artifacts_dir(args);
    if !dir.join("manifest.json").exists() {
        println!("smoke: no artifact set at {} — real-server leg skipped",
                 dir.display());
        return 0;
    }
    // real-server legs: FIFO and SJF monolithic, plus one chunked FIFO
    // run so the chunked router loop is exercised against real artifacts
    let real_legs = [
        (AdmissionPolicy::fifo(), 0usize),
        (AdmissionPolicy::sjf(), 0),
        (AdmissionPolicy::fifo(), 3),
    ];
    for &(policy, prefill_chunk) in &real_legs {
        let server = match moepim::coordinator::Server::spawn_opts(
            dir.clone(),
            moepim::coordinator::ServerOptions {
                policy,
                prefill_chunk,
                ..moepim::coordinator::ServerOptions::default()
            },
        ) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("smoke: server spawn failed: {e:#}");
                return 1;
            }
        };
        let spec = WorkloadSpec {
            seed,
            requests: 8,
            arrival: ArrivalProcess::Closed { users: 3, think_ms: 0.0 },
            sizes: SizeModel::Uniform { prompt: (6, 12), gen: (1, 6) },
            slo_e2e_ms: 60_000.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        };
        match run_against_server(&server, &spec) {
            Ok(out) => {
                let ok = out.samples.iter().filter(|s| s.ok).count();
                if out.samples.len() != spec.requests
                    || ok != out.samples.len()
                {
                    eprintln!(
                        "smoke: real {} (chunk {}) run incomplete \
                         ({}/{} ok)",
                        policy.label(), prefill_chunk, ok,
                        out.samples.len()
                    );
                    return 1;
                }
                if prefill_chunk > 0 && out.prefill_chunks == 0 {
                    eprintln!(
                        "smoke: chunked real run never advanced a chunk"
                    );
                    return 1;
                }
                println!(
                    "smoke: real closed-loop x {} x chunk {} OK \
                     ({} requests, {:.1} tok/s)",
                    policy.label(),
                    prefill_chunk,
                    out.samples.len(),
                    out.tokens_generated() as f64 / out.duration_s
                );
            }
            Err(e) => {
                eprintln!("smoke: real {} (chunk {}) run failed: {e:#}",
                          policy.label(), prefill_chunk);
                return 1;
            }
        }
        // `server` drops here before the next spawn, keeping each leg's
        // telemetry independent (concurrent servers are exercised by the
        // cluster leg below)
    }
    // concurrent-cluster backpressure leg: two real backends behind the
    // front door, shedding forced by a near-simultaneous open-loop flood
    // (shed_depth 1 saturates once every backend holds slots+1 requests).
    // Every request must get exactly one terminal reply; sheds must be
    // nonzero, match the error count, and come back immediately — the
    // interactive-latency guarantee under overload.
    let cluster = match moepim::coordinator::Cluster::spawn(
        &dir,
        moepim::coordinator::ClusterOptions {
            shards: 2,
            shed_depth: 1,
            ..moepim::coordinator::ClusterOptions::default()
        },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("smoke: cluster spawn failed: {e:#}");
            return 1;
        }
    };
    let spec = WorkloadSpec {
        seed,
        requests: 24,
        arrival: ArrivalProcess::Poisson { rate_rps: 50_000.0 },
        sizes: SizeModel::Uniform { prompt: (6, 12), gen: (1, 6) },
        slo_e2e_ms: 60_000.0,
        deadline_slack_us_per_token: 500,
        interactive_mix: 1.0,
    };
    match moepim::workload::run_against_cluster(&cluster, &spec) {
        Ok(run) => {
            let total: usize = run
                .shards
                .iter()
                .map(|s| s.outcome.samples.len())
                .sum();
            let shed: u64 = run
                .shards
                .iter()
                .map(|s| s.outcome.shed_requests)
                .sum();
            let ok = run
                .shards
                .iter()
                .flat_map(|s| s.outcome.samples.iter())
                .filter(|s| s.ok)
                .count();
            let slow_shed = run
                .shards
                .iter()
                .flat_map(|s| s.outcome.samples.iter())
                .any(|s| !s.ok && s.e2e_us > 1_000_000.0);
            if total != spec.requests {
                eprintln!(
                    "smoke: cluster leg lost replies ({total}/{} terminal)",
                    spec.requests
                );
                return 1;
            }
            if shed == 0 {
                eprintln!(
                    "smoke: cluster leg shed nothing under a {}-request \
                     flood",
                    spec.requests
                );
                return 1;
            }
            if ok + shed as usize != total {
                eprintln!(
                    "smoke: cluster leg bookkeeping off (ok {ok} + shed \
                     {shed} != {total})"
                );
                return 1;
            }
            if slow_shed {
                eprintln!(
                    "smoke: a shed reply took > 1 s — shedding must be \
                     immediate"
                );
                return 1;
            }
            println!(
                "smoke: cluster 2-shard backpressure OK ({ok} served, \
                 {shed} shed, peak intake depth {})",
                run.shards
                    .first()
                    .map(|s| s.outcome.peak_intake_depth)
                    .unwrap_or(0)
            );
        }
        Err(e) => {
            eprintln!("smoke: cluster leg failed: {e:#}");
            return 1;
        }
    }
    println!("smoke: PASS");
    0
}
