//! Expert grouping for peripheral sharing (§III-B).
//!
//! A [`Grouping`] partitions the E experts into E/g groups of g; every
//! crossbar of every expert in a group shares that group's peripheral set
//! (ADC column).  Two deployment-time heuristics from the paper:
//!
//! * **uniform** ("U"): random assignment;
//! * **workload-sorted** ("S"): experts sorted by traced load, then paired
//!   lowest-with-highest (snake/zigzag fill for g > 2) so every group's
//!   expected total load is near the mean.

pub mod stats;

use crate::util::rng::Pcg32;

/// A partition of experts into equal-size peripheral-sharing groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Grouping {
    /// groups[i] = expert ids in group i (sorted ascending)
    pub groups: Vec<Vec<usize>>,
    /// group_of[e] = index of e's group
    pub group_of: Vec<usize>,
}

impl Grouping {
    fn from_groups(mut groups: Vec<Vec<usize>>, n_experts: usize) -> Self {
        for g in groups.iter_mut() {
            g.sort_unstable();
        }
        let mut group_of = vec![usize::MAX; n_experts];
        for (i, g) in groups.iter().enumerate() {
            for &e in g {
                group_of[e] = i;
            }
        }
        assert!(
            group_of.iter().all(|&g| g != usize::MAX),
            "every expert must be grouped"
        );
        Grouping { groups, group_of }
    }

    /// Explicit grouping from given expert sets (must partition 0..E).
    pub fn custom(groups: Vec<Vec<usize>>) -> Self {
        let n: usize = groups.iter().map(Vec::len).sum();
        Self::from_groups(groups, n)
    }

    /// Identity grouping: each expert alone (exclusive peripherals — the
    /// paper's baseline).
    pub fn singleton(n_experts: usize) -> Self {
        Self::from_groups((0..n_experts).map(|e| vec![e]).collect(), n_experts)
    }

    /// Uniform/random grouping ("U").
    pub fn uniform(n_experts: usize, group_size: usize, seed: u64) -> Self {
        assert!(group_size >= 1 && n_experts % group_size == 0,
                "E={n_experts} not divisible by g={group_size}");
        let mut order: Vec<usize> = (0..n_experts).collect();
        Pcg32::new(seed).shuffle(&mut order);
        let groups = order
            .chunks(group_size)
            .map(|c| c.to_vec())
            .collect();
        Self::from_groups(groups, n_experts)
    }

    /// Workload-sorted grouping ("S"): sort experts by traced load, then
    /// fill groups by repeatedly taking one from the light end and one from
    /// the heavy end (g=2 == the paper's lowest-with-highest pairing; for
    /// g=4 each group takes two light + two heavy).
    pub fn sorted(loads: &[f64], group_size: usize) -> Self {
        let n = loads.len();
        assert!(group_size >= 1 && n % group_size == 0,
                "E={n} not divisible by g={group_size}");
        let mut order: Vec<usize> = (0..n).collect();
        // stable sort by load ascending, ties by expert id
        order.sort_by(|&a, &b| {
            loads[a].partial_cmp(&loads[b]).unwrap().then(a.cmp(&b))
        });
        let n_groups = n / group_size;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        let (mut lo, mut hi) = (0usize, n - 1);
        let mut take_lo = true;
        // deal alternately from both ends, round-robin over groups so each
        // group receives matched light/heavy pairs
        'outer: loop {
            for g in groups.iter_mut() {
                if lo > hi {
                    break 'outer;
                }
                if take_lo {
                    g.push(order[lo]);
                    lo += 1;
                } else {
                    g.push(order[hi]);
                    hi = hi.wrapping_sub(1);
                    if hi == usize::MAX {
                        break 'outer;
                    }
                }
            }
            take_lo = !take_lo;
        }
        Self::from_groups(groups, n)
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn group_size(&self) -> usize {
        self.groups.first().map_or(1, Vec::len)
    }

    /// Expected per-group total load under `loads`.
    pub fn group_loads(&self, loads: &[f64]) -> Vec<f64> {
        self.groups
            .iter()
            .map(|g| g.iter().map(|&e| loads[e]).sum())
            .collect()
    }

    /// Max/mean group-load ratio — the imbalance metric the sorted policy
    /// minimises (1.0 == perfectly balanced).
    pub fn imbalance(&self, loads: &[f64]) -> f64 {
        let gl = self.group_loads(loads);
        let max = gl.iter().copied().fold(f64::MIN, f64::max);
        let mean = gl.iter().sum::<f64>() / gl.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_shapes() {
        let g = Grouping::singleton(4);
        assert_eq!(g.n_groups(), 4);
        assert_eq!(g.group_size(), 1);
        assert_eq!(g.group_of, vec![0, 1, 2, 3]);
    }

    #[test]
    fn uniform_is_partition() {
        let g = Grouping::uniform(16, 4, 3);
        assert_eq!(g.n_groups(), 4);
        let mut all: Vec<usize> = g.groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
        for (e, &gi) in g.group_of.iter().enumerate() {
            assert!(g.groups[gi].contains(&e));
        }
    }

    #[test]
    fn uniform_seed_determinism() {
        assert_eq!(Grouping::uniform(16, 2, 5), Grouping::uniform(16, 2, 5));
        assert_ne!(Grouping::uniform(16, 2, 5), Grouping::uniform(16, 2, 6));
    }

    #[test]
    fn sorted_pairs_light_with_heavy() {
        // loads 0..7 ascending: expect pairs (0,7), (1,6), (2,5), (3,4)
        let loads: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let g = Grouping::sorted(&loads, 2);
        let mut pair_sums: Vec<f64> = g.group_loads(&loads);
        pair_sums.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(pair_sums, vec![7.0; 4]);
        assert!((g.imbalance(&loads) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sorted_beats_worst_case_grouping() {
        // strongly skewed loads; sorted grouping must beat the adversarial
        // "heavy-with-heavy" grouping on imbalance
        let loads = vec![1.0, 1.0, 1.0, 1.0, 10.0, 10.0, 10.0, 10.0];
        let sorted = Grouping::sorted(&loads, 2);
        let adversarial = Grouping::custom(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
        );
        assert!(sorted.imbalance(&loads) < adversarial.imbalance(&loads));
    }

    #[test]
    fn sorted_group4_partition_valid() {
        let loads: Vec<f64> = (0..16).map(|i| (i * i) as f64).collect();
        let g = Grouping::sorted(&loads, 4);
        assert_eq!(g.n_groups(), 4);
        let mut all: Vec<usize> = g.groups.concat();
        all.sort_unstable();
        assert_eq!(all, (0..16).collect::<Vec<_>>());
        // balanced within 2x of mean even for quadratic skew
        assert!(g.imbalance(&loads) < 1.6, "{}", g.imbalance(&loads));
    }

    #[test]
    #[should_panic]
    fn indivisible_group_size_panics() {
        Grouping::uniform(10, 4, 0);
    }
}
