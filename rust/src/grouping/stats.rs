//! Deployment-time load estimation (the "workload is traced from small
//! samples of datasets" step of §III-B) and summary statistics shared by
//! the grouping policies and the eval harness.

use crate::moe::ChoiceMatrix;

/// Per-expert loads of a single trace, as f64 (grouping works on averaged
/// fractional loads).
pub fn loads_of(m: &ChoiceMatrix) -> Vec<f64> {
    m.expert_loads().into_iter().map(|l| l as f64).collect()
}

/// Average per-expert loads over several traces.
pub fn mean_loads(traces: &[ChoiceMatrix]) -> Vec<f64> {
    assert!(!traces.is_empty());
    let e = traces[0].experts();
    let mut acc = vec![0f64; e];
    for t in traces {
        assert_eq!(t.experts(), e, "traces must share expert count");
        for (j, l) in t.expert_loads().into_iter().enumerate() {
            acc[j] += l as f64;
        }
    }
    for a in acc.iter_mut() {
        *a /= traces.len() as f64;
    }
    acc
}

/// Coefficient of variation of a load vector (0 == perfectly balanced).
pub fn load_cv(loads: &[f64]) -> f64 {
    let n = loads.len() as f64;
    let mean = loads.iter().sum::<f64>() / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = loads.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / n;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ChoiceMatrix;

    #[test]
    fn loads_and_mean() {
        let a = ChoiceMatrix::from_rows(&[vec![0], vec![0], vec![1]], 2);
        let b = ChoiceMatrix::from_rows(&[vec![0], vec![1], vec![1]], 2);
        assert_eq!(loads_of(&a), vec![2.0, 1.0]);
        assert_eq!(mean_loads(&[a, b]), vec![1.5, 1.5]);
    }

    #[test]
    fn cv_zero_when_balanced() {
        assert_eq!(load_cv(&[3.0, 3.0, 3.0]), 0.0);
        assert!(load_cv(&[1.0, 5.0]) > 0.5);
        assert_eq!(load_cv(&[0.0, 0.0]), 0.0);
    }
}
