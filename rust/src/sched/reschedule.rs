//! Reschedule-by-inserting-idle ("O", §III-D step 2, Algorithm 1).
//!
//! Starting from the compact schedule, the longest (bottleneck) group is
//! the anchor: it runs with no idles, so the makespan cannot improve —
//! Algorithm 1 instead spends the *slack* of every shorter group
//! (`res[i, t] = csum[max_id, t] - csum[i, t]`, the number of idles group i
//! can afford by token t) on alignment: an item is delayed to start exactly
//! when the anchor broadcasts the same token, making its fetch free under
//! the shared-bus rule, provided the delay never pushes the group's
//! remaining work past the anchor's finish line.
//!
//! The result keeps compact's latency (pinned by proptest) while removing
//! repeated transfers; the paper's Fig. 2 example drops 16 -> 12.
//!
//! The greedy walk below is the paper's "iteratively checking whether there
//! is a data reuse opportunity", implemented per group in one linear pass
//! (the paper notes the algorithm is linear in token length and pipelined
//! in hardware, so its latency is hidden — we likewise exclude it from the
//! simulated critical path and bench its host cost in `benches/hotpath`).

use std::collections::HashMap;

use crate::grouping::Grouping;
use crate::moe::ChoiceMatrix;

use super::compact::group_queues;
use super::schedule::{Schedule, Slot};

pub fn build(choices: &ChoiceMatrix, grouping: &Grouping) -> Schedule {
    let queues = group_queues(choices, grouping);
    let n_groups = queues.len();
    if n_groups == 0 {
        return Schedule::new(vec![]);
    }

    // Anchor = longest queue (first on ties) — Algorithm 1 line 2.
    let anchor = (0..n_groups)
        .max_by_key(|&i| (queues[i].len(), usize::MAX - i))
        .unwrap();
    let horizon = queues[anchor].len();

    // Anchor lane is compact; record the slot range of each token's run.
    let mut anchor_runs: HashMap<usize, (usize, usize)> = HashMap::new();
    for (s, &(t, _)) in queues[anchor].iter().enumerate() {
        anchor_runs
            .entry(t)
            .and_modify(|(_, hi)| *hi = s)
            .or_insert((s, s));
    }

    let mut lanes: Vec<Vec<Slot>> = Vec::with_capacity(n_groups);
    for (i, queue) in queues.iter().enumerate() {
        if i == anchor {
            lanes.push(
                queue
                    .iter()
                    .map(|&(token, expert)| Slot::Work { token, expert })
                    .collect(),
            );
            continue;
        }
        let mut lane: Vec<Slot> = Vec::with_capacity(horizon);
        let mut next_free = 0usize;
        let mut prev_token = usize::MAX;
        for (idx, &(token, expert)) in queue.iter().enumerate() {
            let remaining_after = queue.len() - idx - 1;
            // Data-reuse opportunity: start this item inside the anchor's
            // run of the same token (>= next_free), if the remaining work
            // still fits before the anchor finishes — the res[i, t] > 0
            // check of Algorithm 1 line 6.  Never delay an item that
            // continues the lane's current token run: the local latch is
            // already a free transfer and an idle would split the run.
            let mut start = next_free;
            if prev_token != token {
                if let Some(&(lo, hi)) = anchor_runs.get(&token) {
                    let aligned = next_free.max(lo);
                    if aligned <= hi
                        && aligned + 1 + remaining_after <= horizon
                    {
                        start = aligned;
                    }
                }
            }
            while lane.len() < start {
                lane.push(Slot::Idle); // Algorithm 1 line 7: insert idles
            }
            lane.push(Slot::Work { token, expert });
            next_free = start + 1;
            prev_token = token;
        }
        lanes.push(lane);
    }
    let aligned = Schedule::new(lanes);
    // Anchor alignment can still lose the occasional *accidental* same-slot
    // sharing the compact layout had between two non-anchor lanes; the
    // scheduler measures both and keeps the cheaper one (same makespan
    // either way), so "reschedule never transfers more than compact" is an
    // invariant rather than a heuristic hope (pinned by proptest).
    let compact = super::compact::build(choices, grouping);
    debug_assert_eq!(aligned.makespan_slots(), compact.makespan_slots());
    if aligned.transfers() <= compact.transfers() {
        aligned
    } else {
        compact
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::compact;

    fn skewed_trace(seed: u64) -> (ChoiceMatrix, Grouping) {
        let mut g = crate::moe::TraceGenerator::new(8, seed);
        let m = g.expert_choice(24, 6, 1.2);
        let grouping = Grouping::uniform(8, 2, seed);
        (m, grouping)
    }

    #[test]
    fn keeps_compact_latency() {
        for seed in 0..20 {
            let (m, g) = skewed_trace(seed);
            let c = compact::build(&m, &g);
            let o = build(&m, &g);
            assert_eq!(
                o.makespan_slots(),
                c.makespan_slots(),
                "seed {seed}: reschedule must not extend the makespan"
            );
        }
    }

    #[test]
    fn never_more_transfers_than_compact() {
        for seed in 0..20 {
            let (m, g) = skewed_trace(seed);
            let c = compact::build(&m, &g);
            let o = build(&m, &g);
            assert!(
                o.transfers() <= c.transfers(),
                "seed {seed}: {} > {}",
                o.transfers(),
                c.transfers()
            );
        }
    }

    #[test]
    fn strictly_improves_on_misaligned_example() {
        // Anchor group {0,1} works tokens 0,1,2 (slots 0,1,2); group {2,3}
        // works tokens 1,2 — compact runs them at slots 0,1, misaligned
        // with the anchor's broadcasts of the same tokens (5 transfers).
        // Algorithm 1 inserts one idle so both items ride the anchor's
        // broadcasts (3 transfers), same makespan.
        let m = ChoiceMatrix::from_rows(
            &[vec![0], vec![1, 2], vec![0, 3]],
            4,
        );
        let g = Grouping::custom(vec![vec![0, 1], vec![2, 3]]);
        let c = compact::build(&m, &g);
        let o = build(&m, &g);
        assert_eq!(c.transfers(), 5);
        assert_eq!(o.transfers(), 3);
        assert_eq!(o.makespan_slots(), c.makespan_slots());
        // the idle was inserted before group 1's first item
        assert_eq!(o.lanes[1][0], Slot::Idle);
    }

    #[test]
    fn preserves_per_group_order_and_work() {
        for seed in 0..10 {
            let (m, g) = skewed_trace(seed);
            let c = compact::build(&m, &g);
            let o = build(&m, &g);
            for lane in 0..g.n_groups() {
                assert_eq!(c.lane_work(lane), o.lane_work(lane));
            }
        }
    }

    #[test]
    fn empty_input() {
        let m = ChoiceMatrix::new(0, 4);
        let g = Grouping::singleton(4);
        let s = build(&m, &g);
        assert_eq!(s.makespan_slots(), 0);
        assert_eq!(s.transfers(), 0);
    }
}
