//! Schedule representation and its cost metrics (makespan, transfers).
//!
//! A [`Schedule`] assigns every peripheral-sharing group a sequence of
//! slots on a global slot clock; slot = one token-expert execution on that
//! group's shared peripherals (`rounds_per_token` serial MVM rounds).
//!
//! **Transfer counting** (the energy-side objective of §III-D): a group
//! needs a token's activation vector latched into its DAC inputs to run a
//! slot.  A transfer is *free* when
//!   (a) the group's previous slot used the same token (still latched), or
//!   (b) another group starts the same token at the same slot (the
//!       broadcast bus serves all of them at once).
//! Otherwise the fetch costs one transfer.  Formally:
//! `transfers = |{(s, t) : some group begins a maximal run of token t at
//! slot s}|`.  Token-wise scheduling gives exactly one transfer per token;
//! compact scheduling pays for its misalignment; Algorithm 1 inserts idles
//! to re-align and win transfers back without extending the makespan.

/// One slot of one group's sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slot {
    Idle,
    /// token-expert execution
    Work { token: usize, expert: usize },
}

impl Slot {
    pub fn token(&self) -> Option<usize> {
        match self {
            Slot::Idle => None,
            Slot::Work { token, .. } => Some(*token),
        }
    }
}

/// A complete prefill schedule over all groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// lanes[i] = group i's slot sequence (trailing idles trimmed)
    pub lanes: Vec<Vec<Slot>>,
}

impl Schedule {
    pub fn new(mut lanes: Vec<Vec<Slot>>) -> Self {
        for lane in lanes.iter_mut() {
            while lane.last() == Some(&Slot::Idle) {
                lane.pop();
            }
        }
        Schedule { lanes }
    }

    /// Global makespan in slots.
    pub fn makespan_slots(&self) -> usize {
        self.lanes.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Total work items across groups.
    pub fn total_work(&self) -> usize {
        self.lanes
            .iter()
            .map(|l| l.iter().filter(|s| matches!(s, Slot::Work { .. })).count())
            .sum()
    }

    /// Work items per group, in order — for order-preservation checks.
    pub fn lane_work(&self, lane: usize) -> Vec<(usize, usize)> {
        self.lanes[lane]
            .iter()
            .filter_map(|s| match s {
                Slot::Work { token, expert } => Some((*token, *expert)),
                Slot::Idle => None,
            })
            .collect()
    }

    /// Count activation-vector transfers under the run/broadcast rule.
    ///
    /// Perf note (§Perf L3-1): collect run starts into a Vec and
    /// sort+dedup once instead of inserting into a BTreeSet — ~3x faster
    /// at 1024-token schedules, and this is the hot half of the
    /// reschedule builder (it prices both candidate layouts).
    pub fn transfers(&self) -> usize {
        let mut starts: Vec<u64> = Vec::with_capacity(self.total_work());
        for lane in &self.lanes {
            let mut prev: Option<usize> = None;
            for (s, slot) in lane.iter().enumerate() {
                match slot.token() {
                    Some(t) => {
                        if prev != Some(t) {
                            starts.push(((s as u64) << 32) | t as u64);
                        }
                        prev = Some(t);
                    }
                    None => prev = None,
                }
            }
        }
        starts.sort_unstable();
        starts.dedup();
        starts.len()
    }

    /// Transfers counted WITHOUT the shared broadcast bus (ablation:
    /// every lane pays for its own run starts; cross-lane same-slot
    /// sharing is disabled).  Used by `eval::ablation` to quantify how
    /// much of Algorithm 1's win depends on the bus.
    pub fn transfers_local_only(&self) -> usize {
        let mut n = 0usize;
        for lane in &self.lanes {
            let mut prev: Option<usize> = None;
            for slot in lane {
                match slot.token() {
                    Some(t) => {
                        if prev != Some(t) {
                            n += 1;
                        }
                        prev = Some(t);
                    }
                    None => prev = None,
                }
            }
        }
        n
    }

    /// Fraction of non-idle slots up to the makespan (hardware utilisation).
    pub fn utilization(&self) -> f64 {
        let span = self.makespan_slots();
        if span == 0 || self.lanes.is_empty() {
            return 0.0;
        }
        self.total_work() as f64 / (span * self.lanes.len()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(token: usize, expert: usize) -> Slot {
        Slot::Work { token, expert }
    }

    #[test]
    fn makespan_and_trim() {
        let s = Schedule::new(vec![
            vec![w(0, 0), Slot::Idle, w(1, 0), Slot::Idle],
            vec![w(0, 1)],
        ]);
        assert_eq!(s.makespan_slots(), 3); // trailing idle trimmed
        assert_eq!(s.total_work(), 3);
    }

    #[test]
    fn transfers_counts_runs() {
        // one lane, same token twice then a new token: 2 transfers
        let s = Schedule::new(vec![vec![w(5, 0), w(5, 1), w(6, 0)]]);
        assert_eq!(s.transfers(), 2);
    }

    #[test]
    fn transfers_shared_broadcast() {
        // two lanes start token 3 at slot 0 simultaneously: 1 transfer
        let s = Schedule::new(vec![vec![w(3, 0)], vec![w(3, 1)]]);
        assert_eq!(s.transfers(), 1);
        // misaligned: 2 transfers
        let s2 = Schedule::new(vec![vec![w(3, 0)], vec![Slot::Idle, w(3, 1)]]);
        assert_eq!(s2.transfers(), 2);
    }

    #[test]
    fn idle_breaks_latch() {
        // same token resumed after an idle costs a new transfer
        let s = Schedule::new(vec![vec![w(1, 0), Slot::Idle, w(1, 1)]]);
        assert_eq!(s.transfers(), 2);
    }

    #[test]
    fn utilization_bounds() {
        let s = Schedule::new(vec![vec![w(0, 0), w(1, 0)], vec![w(0, 1)]]);
        let u = s.utilization();
        assert!(u > 0.0 && u <= 1.0);
        assert!((u - 0.75).abs() < 1e-9);
        assert_eq!(Schedule::new(vec![]).utilization(), 0.0);
    }

    #[test]
    fn local_only_counts_each_lane() {
        // two lanes sharing a broadcast: 1 shared transfer, 2 local
        let s = Schedule::new(vec![vec![w(3, 0)], vec![w(3, 1)]]);
        assert_eq!(s.transfers(), 1);
        assert_eq!(s.transfers_local_only(), 2);
    }

    #[test]
    fn lane_work_skips_idles() {
        let s = Schedule::new(vec![vec![Slot::Idle, w(2, 1), Slot::Idle, w(3, 1)]]);
        assert_eq!(s.lane_work(0), vec![(2, 1), (3, 1)]);
    }
}
