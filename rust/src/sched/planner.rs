//! Per-step batch planning for the serving engine (§III-B applied online).
//!
//! The simulator prices peripheral-sharing contention offline; the
//! [`BatchPlanner`] moves that model onto the live decode path.  Each batch
//! step the serving engine hands the planner one expert set per active slot
//! (what the GO caches just selected); the planner lays the step out on the
//! grouped peripherals with the configured [`SchedulePolicy`] and returns a
//! [`BatchPlan`]: the cycle-by-cycle execution order on the modeled chip
//! plus the step's contention telemetry.
//!
//! * `cycles` — the step's makespan in slot cycles under peripheral
//!   sharing (experts in one group serialise on the shared ADC column);
//! * `contention_cycles` — how many of those cycles exist *only* because
//!   of sharing (makespan minus the exclusive-peripherals makespan, i.e.
//!   the same step priced against `Grouping::singleton`);
//! * `transfers` — activation-vector fetches under the latch/broadcast
//!   rule of [`Schedule::transfers`].
//!
//! Cumulative counters aggregate across steps so the server can export
//! serving-lifetime telemetry without keeping every plan alive.

use crate::config::SchedulePolicy;
use crate::grouping::Grouping;
use crate::moe::ChoiceMatrix;
use crate::sched::{self, Schedule};

/// One batch step's execution layout + contention stats.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    /// lane-per-group execution order (rows of the batch are "tokens")
    pub schedule: Schedule,
    /// makespan in slot cycles under the planner's grouping
    pub cycles: usize,
    /// cycles attributable to peripheral sharing alone
    pub contention_cycles: usize,
    /// activation transfers under the latch/broadcast rule
    pub transfers: usize,
    /// non-idle fraction of the grouped schedule
    pub utilization: f64,
    /// total token-expert executions in the step
    pub work: usize,
}

/// Cumulative serving-lifetime planner telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlannerStats {
    pub steps: u64,
    pub work: u64,
    pub cycles: u64,
    pub contention_cycles: u64,
    pub transfers: u64,
}

impl PlannerStats {
    /// Mean makespan per planned step.
    pub fn mean_cycles(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.cycles as f64 / self.steps as f64
        }
    }

    /// Fraction of all cycles caused by peripheral sharing.
    pub fn contention_ratio(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.contention_cycles as f64 / self.cycles as f64
        }
    }
}

/// Group-aware planner for batched decode steps.
pub struct BatchPlanner {
    grouping: Grouping,
    policy: SchedulePolicy,
    n_experts: usize,
    stats: PlannerStats,
}

impl BatchPlanner {
    /// Planner over a uniform grouping of `n_experts` into groups of
    /// `group_size` (seeded — deployment-time assignment is fixed).
    pub fn new(n_experts: usize, group_size: usize, policy: SchedulePolicy)
        -> Self {
        Self::with_grouping(
            Grouping::uniform(n_experts, group_size, 0xB47C),
            policy,
        )
    }

    /// Planner over an explicit grouping (e.g. workload-sorted from traced
    /// loads).
    pub fn with_grouping(grouping: Grouping, policy: SchedulePolicy) -> Self {
        let n_experts = grouping.group_of.len();
        BatchPlanner { grouping, policy, n_experts, stats: PlannerStats::default() }
    }

    pub fn grouping(&self) -> &Grouping {
        &self.grouping
    }

    pub fn policy(&self) -> SchedulePolicy {
        self.policy
    }

    pub fn stats(&self) -> PlannerStats {
        self.stats
    }

    /// Plan one batch step: `expert_sets[i]` is the expert set the GO cache
    /// selected for the i-th active slot's token.
    pub fn plan(&mut self, expert_sets: &[Vec<usize>]) -> BatchPlan {
        let choices = ChoiceMatrix::from_rows(expert_sets, self.n_experts);
        let grouped = sched::build(&choices, &self.grouping, self.policy);
        // exclusive-peripherals reference: same step, singleton grouping
        let exclusive = sched::build(
            &choices,
            &Grouping::singleton(self.n_experts),
            self.policy,
        );
        let cycles = grouped.makespan_slots();
        let contention_cycles =
            cycles.saturating_sub(exclusive.makespan_slots());
        let transfers = grouped.transfers();
        let utilization = grouped.utilization();
        let work = grouped.total_work();

        self.stats.steps += 1;
        self.stats.work += work as u64;
        self.stats.cycles += cycles as u64;
        self.stats.contention_cycles += contention_cycles as u64;
        self.stats.transfers += transfers as u64;

        BatchPlan {
            schedule: grouped,
            cycles,
            contention_cycles,
            transfers,
            utilization,
            work,
        }
    }

    /// Plan one depth-L decode step: `layer_sets[l][i]` is the expert set
    /// layer `l`'s GO bank selected for the i-th active slot's token.
    ///
    /// The modeled chip executes the stack sequentially, re-laying each
    /// layer's expert sets out on the grouped peripherals, so a depth-L
    /// step is priced as L planned *layer-steps*: `stats().steps` advances
    /// by L per decode cycle and the serving telemetry reflects real depth
    /// (`rust/tests/props_sched.rs` pins the linear scaling).
    pub fn plan_layers(&mut self, layer_sets: &[Vec<Vec<usize>>])
        -> Vec<BatchPlan> {
        layer_sets.iter().map(|sets| self.plan(sets)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_all_work() {
        let mut p = BatchPlanner::new(8, 2, SchedulePolicy::Reschedule);
        let sets = vec![vec![0, 1, 2], vec![3, 4], vec![5]];
        let plan = p.plan(&sets);
        assert_eq!(plan.work, 6);
        assert_eq!(plan.schedule.total_work(), 6);
        assert!(plan.cycles >= 1);
        assert!(plan.utilization > 0.0 && plan.utilization <= 1.0);
    }

    #[test]
    fn contention_zero_under_singleton_grouping() {
        let mut p = BatchPlanner::with_grouping(
            Grouping::singleton(4),
            SchedulePolicy::Compact,
        );
        let plan = p.plan(&[vec![0, 1], vec![2, 3]]);
        assert_eq!(plan.contention_cycles, 0);
    }

    #[test]
    fn grouped_colliding_experts_serialise() {
        // experts 0 and 1 share peripherals; two slots hit both => the
        // grouped makespan must exceed the exclusive one
        let mut p = BatchPlanner::with_grouping(
            Grouping::custom(vec![vec![0, 1]]),
            SchedulePolicy::Compact,
        );
        let plan = p.plan(&[vec![0, 1], vec![0, 1]]);
        // 4 work items on one shared lane: 4 cycles; exclusive would be 2
        assert_eq!(plan.cycles, 4);
        assert_eq!(plan.contention_cycles, 2);
    }

    #[test]
    fn stats_accumulate_across_steps() {
        let mut p = BatchPlanner::new(8, 2, SchedulePolicy::TokenWise);
        assert_eq!(p.stats(), PlannerStats::default());
        p.plan(&[vec![0, 1]]);
        p.plan(&[vec![2], vec![3]]);
        let s = p.stats();
        assert_eq!(s.steps, 2);
        assert_eq!(s.work, 4);
        assert!(s.cycles >= 2);
        assert!(s.mean_cycles() >= 1.0);
        assert!(s.contention_ratio() >= 0.0 && s.contention_ratio() <= 1.0);
    }

    #[test]
    fn plan_layers_prices_each_layer() {
        let mut p = BatchPlanner::new(8, 2, SchedulePolicy::Reschedule);
        let layer_sets = vec![
            vec![vec![0, 1], vec![2]],
            vec![vec![3], vec![4, 5]],
            vec![vec![6, 7], vec![0]],
        ];
        let plans = p.plan_layers(&layer_sets);
        assert_eq!(plans.len(), 3);
        let s = p.stats();
        assert_eq!(s.steps, 3, "one planned layer-step per layer");
        assert_eq!(s.work, 8);
    }

    #[test]
    fn empty_step_is_harmless() {
        let mut p = BatchPlanner::new(4, 2, SchedulePolicy::Reschedule);
        let plan = p.plan(&[]);
        assert_eq!(plan.work, 0);
        assert_eq!(plan.cycles, 0);
        assert_eq!(plan.contention_cycles, 0);
    }
}
