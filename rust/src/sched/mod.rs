//! Dynamic prefill scheduling (§III-D): token-wise baseline, compact
//! dispatch, and Algorithm 1's reschedule-by-inserting-idle — plus the
//! online [`BatchPlanner`] that prices each serving batch step against the
//! peripheral-sharing model.

pub mod compact;
pub mod planner;
pub mod reschedule;
pub mod schedule;
pub mod tokenwise;

pub use planner::{BatchPlan, BatchPlanner, PlannerStats};
pub use schedule::{Schedule, Slot};

use crate::config::SchedulePolicy;
use crate::grouping::Grouping;
use crate::moe::ChoiceMatrix;

/// Build the schedule selected by `policy`.
pub fn build(choices: &ChoiceMatrix, grouping: &Grouping,
             policy: SchedulePolicy) -> Schedule {
    match policy {
        SchedulePolicy::TokenWise => tokenwise::build(choices, grouping),
        SchedulePolicy::Compact => compact::build(choices, grouping),
        SchedulePolicy::Reschedule => reschedule::build(choices, grouping),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulePolicy as P;

    #[test]
    fn policy_dispatch_consistency() {
        let mut g = crate::moe::TraceGenerator::new(8, 3);
        let m = g.expert_choice(16, 4, 1.0);
        let grouping = Grouping::uniform(8, 2, 3);
        let t = build(&m, &grouping, P::TokenWise);
        let c = build(&m, &grouping, P::Compact);
        let o = build(&m, &grouping, P::Reschedule);
        // same work everywhere
        assert_eq!(t.total_work(), m.total_work());
        assert_eq!(c.total_work(), m.total_work());
        assert_eq!(o.total_work(), m.total_work());
        // paper ordering: latency C == O <= tokenwise; transfers O <= C
        assert_eq!(c.makespan_slots(), o.makespan_slots());
        assert!(c.makespan_slots() <= t.makespan_slots());
        assert!(o.transfers() <= c.transfers());
        // token-wise is transfer-optimal: one broadcast per active token
        let active = (0..m.tokens()).filter(|&tk| m.token_fanout(tk) > 0).count();
        assert_eq!(t.transfers(), active);
    }
}
