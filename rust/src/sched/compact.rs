//! Compact schedule ("C", §III-D step 1): dispatch multiple tokens to
//! different groups simultaneously — every group drains its own work queue
//! back-to-back with no idles.
//!
//! Latency drops to `max_i Σ_t load[i, t]` slots (the bottleneck group),
//! but groups fall out of alignment, so the same token may be fetched
//! repeatedly across groups at different times — the repeated-data-transfer
//! cost that Algorithm 1 then claws back.

use crate::grouping::Grouping;
use crate::moe::ChoiceMatrix;

use super::schedule::{Schedule, Slot};

/// Per-group work queues in token order (expert order within a token
/// follows the group's sorted expert list) — shared by compact and
/// reschedule builders.
pub fn group_queues(choices: &ChoiceMatrix, grouping: &Grouping)
    -> Vec<Vec<(usize, usize)>> {
    grouping
        .groups
        .iter()
        .map(|g| {
            let mut q = Vec::new();
            for t in 0..choices.tokens() {
                for &e in g {
                    if choices.get(t, e) {
                        q.push((t, e));
                    }
                }
            }
            q
        })
        .collect()
}

pub fn build(choices: &ChoiceMatrix, grouping: &Grouping) -> Schedule {
    let lanes = group_queues(choices, grouping)
        .into_iter()
        .map(|q| {
            q.into_iter()
                .map(|(token, expert)| Slot::Work { token, expert })
                .collect()
        })
        .collect();
    Schedule::new(lanes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tokenwise;

    fn trace() -> (ChoiceMatrix, Grouping) {
        // 6 tokens, 4 experts; skewed: expert 0 takes everything
        let m = ChoiceMatrix::from_rows(
            &[
                vec![0, 1],
                vec![0],
                vec![0, 2],
                vec![0, 3],
                vec![0],
                vec![0, 1],
            ],
            4,
        );
        (m, Grouping::uniform(4, 2, 1))
    }

    #[test]
    fn compact_never_slower_than_tokenwise() {
        let (m, g) = trace();
        let c = build(&m, &g);
        let t = tokenwise::build(&m, &g);
        assert!(c.makespan_slots() <= t.makespan_slots());
        assert_eq!(c.total_work(), t.total_work());
    }

    #[test]
    fn makespan_equals_bottleneck_group() {
        let (m, g) = trace();
        let c = build(&m, &g);
        let bottleneck = group_queues(&m, &g)
            .iter()
            .map(Vec::len)
            .max()
            .unwrap();
        assert_eq!(c.makespan_slots(), bottleneck);
    }

    #[test]
    fn no_idles_in_lanes() {
        let (m, g) = trace();
        for lane in &build(&m, &g).lanes {
            assert!(lane.iter().all(|s| matches!(s, Slot::Work { .. })));
        }
    }

    #[test]
    fn queues_preserve_token_order() {
        let (m, g) = trace();
        for q in group_queues(&m, &g) {
            for pair in q.windows(2) {
                assert!(pair[0].0 <= pair[1].0);
            }
        }
    }
}
