//! Token-wise schedule: the conventional MoE dataflow — tokens fed to the
//! hardware strictly one at a time (§III-D's starting point).
//!
//! Token t occupies a block of `max_i load[i, t]` consecutive slots; within
//! the block every group serialises its own experts for t on its shared
//! peripherals while other groups idle once they are done.  With singleton
//! groups (no sharing) every block is one slot — the paper's baseline.

use crate::grouping::Grouping;
use crate::moe::ChoiceMatrix;

use super::schedule::{Schedule, Slot};

pub fn build(choices: &ChoiceMatrix, grouping: &Grouping) -> Schedule {
    let n_groups = grouping.n_groups();
    let mut lanes: Vec<Vec<Slot>> = vec![Vec::new(); n_groups];
    // §Perf L3-3: one reusable scratch buffer instead of a fresh
    // Vec<Vec<usize>> per token (~2x on 1024-token builds; this builder
    // also runs once per decode step in the un-GO-cached regimes).
    let mut scratch: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
    for t in 0..choices.tokens() {
        let mut block = 0usize;
        for (gi, g) in grouping.groups.iter().enumerate() {
            scratch[gi].clear();
            for &e in g {
                if choices.get(t, e) {
                    scratch[gi].push(e);
                }
            }
            block = block.max(scratch[gi].len());
        }
        if block == 0 {
            continue; // token selected by nobody: skip entirely
        }
        for (lane, experts) in lanes.iter_mut().zip(&scratch) {
            for s in 0..block {
                lane.push(match experts.get(s) {
                    Some(&e) => Slot::Work { token: t, expert: e },
                    None => Slot::Idle,
                });
            }
        }
    }
    Schedule::new(lanes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ChoiceMatrix;

    #[test]
    fn singleton_grouping_one_slot_per_token() {
        // 4 tokens, 4 experts, each token picks 2 experts
        let m = ChoiceMatrix::from_rows(
            &[vec![0, 1], vec![2, 3], vec![0, 3], vec![1, 2]],
            4,
        );
        let s = build(&m, &Grouping::singleton(4));
        assert_eq!(s.makespan_slots(), 4); // one slot per token
        assert_eq!(s.total_work(), 8);
        assert_eq!(s.transfers(), 4); // one broadcast per token
    }

    #[test]
    fn shared_group_serialises_contended_token() {
        // both experts of group {0,1} pick token 0 => 2-slot block
        let m = ChoiceMatrix::from_rows(&[vec![0, 1], vec![2]], 4);
        let g = Grouping::uniform(4, 2, 0); // arbitrary partition
        let s = build(&m, &g);
        let contended = g.group_of[0] == g.group_of[1];
        if contended {
            assert_eq!(s.makespan_slots(), 3); // 2 slots for t0 + 1 for t1
        } else {
            assert_eq!(s.makespan_slots(), 2);
        }
        assert_eq!(s.total_work(), 3);
    }

    #[test]
    fn block_structure_keeps_broadcast_shared() {
        // two groups each work token 0 at the same block start: 1 transfer
        let m = ChoiceMatrix::from_rows(&[vec![0, 2]], 4);
        let g = Grouping::sorted(&[1.0, 0.0, 1.0, 0.0], 2); // {0,1},{2,3}-ish
        let s = build(&m, &g);
        assert_eq!(s.transfers(), 1);
    }

    #[test]
    fn empty_tokens_skipped() {
        let m = ChoiceMatrix::new(5, 4); // nobody selected
        let s = build(&m, &Grouping::singleton(4));
        assert_eq!(s.makespan_slots(), 0);
        assert_eq!(s.total_work(), 0);
    }
}
