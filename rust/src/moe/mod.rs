//! MoE domain model: token↔expert choice matrices, routing (token-choice
//! and expert-choice), the expert→crossbar mapping, and workload-trace
//! generation.

pub mod choices;
pub mod gate;
pub mod layout;
pub mod trace;

pub use choices::ChoiceMatrix;
pub use gate::{expert_choice_route, softmax_rows, token_choice_route, Routing};
pub use layout::LayerLayout;
pub use trace::{group_loads, TraceGenerator};
