//! Workload-trace generation (substitution for the paper's RedPajama-C4
//! samples, DESIGN.md §2).
//!
//! The paper uses dataset traces for two things: (a) estimating per-expert
//! loads for workload-sorted grouping, and (b) driving the simulator.  Both
//! consume a `choices[T, E]` matrix.  We provide:
//!
//! * [`TraceGenerator::expert_choice`] — balanced expert-choice traces with
//!   a *popularity-correlated token overlap* knob (which tokens collide on
//!   which experts is what grouping/scheduling react to);
//! * [`TraceGenerator::token_choice_zipf`] — token-choice traces with
//!   Zipf-skewed expert popularity (the classic load-imbalance regime the
//!   grouping study needs);
//! * gate-derived traces come from the functional model via the
//!   coordinator (real HLO execution), not from this module.

use crate::moe::choices::ChoiceMatrix;
use crate::moe::gate::{expert_choice_route, token_choice_route};
use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct TraceGenerator {
    rng: Pcg32,
    n_experts: usize,
}

impl TraceGenerator {
    pub fn new(n_experts: usize, seed: u64) -> Self {
        TraceGenerator { rng: Pcg32::new(seed), n_experts }
    }

    /// Synthetic gate scores where expert popularity follows a Zipf-ish
    /// profile: expert j has mean score `-skew * ln(j+1)` plus unit
    /// Gaussian noise.  The popularity *ranking is stable across traces*
    /// (expert specialisation is a property of the trained model + corpus,
    /// not of the batch) — that stability is exactly what lets §III-B's
    /// deployment-time sorted grouping predict inference-time loads from
    /// small calibration samples.  Running real routing over these scores
    /// gives traces whose load shapes match skewed corpora.
    pub fn scores(&mut self, tokens: usize, skew: f64) -> Vec<f32> {
        let e = self.n_experts;
        let mut s = vec![0f32; tokens * e];
        for t in 0..tokens {
            for j in 0..e {
                let mean = -skew * ((j + 1) as f64).ln();
                s[t * e + j] = (mean + self.rng.gen_normal()) as f32;
            }
        }
        s
    }

    /// Expert-choice trace: balanced per-expert load (capacity each), with
    /// overlap structure from the skewed scores.
    pub fn expert_choice(&mut self, tokens: usize, capacity: usize,
                         skew: f64) -> ChoiceMatrix {
        let s = self.scores(tokens, skew);
        expert_choice_route(&s, tokens, self.n_experts, capacity, None).choices
    }

    /// Token-choice trace with Zipf-skewed expert popularity — the
    /// load-imbalanced regime (expert collapse) used for the grouping
    /// ablation.
    pub fn token_choice_zipf(&mut self, tokens: usize, k: usize,
                             skew: f64) -> ChoiceMatrix {
        let s = self.scores(tokens, skew);
        token_choice_route(&s, tokens, self.n_experts, k).choices
    }

    /// A small calibration sample (the "traced from small samples of
    /// datasets" step of §III-B): mean per-expert loads over `n_samples`
    /// independent token-choice batches.
    pub fn calibration_loads(&mut self, n_samples: usize, tokens: usize,
                             k: usize, skew: f64) -> Vec<f64> {
        let mut acc = vec![0f64; self.n_experts];
        for _ in 0..n_samples {
            let m = self.token_choice_zipf(tokens, k, skew);
            for (j, l) in m.expert_loads().into_iter().enumerate() {
                acc[j] += l as f64;
            }
        }
        for a in acc.iter_mut() {
            *a /= n_samples as f64;
        }
        acc
    }
}

/// Collapse per-expert calibration loads into per-group loads under the
/// contiguous grouping the layout uses (`group g` = experts
/// `[g*group_size, (g+1)*group_size)`).  This is how the placement
/// control loop primes its expert-group routing histogram from a
/// calibration sample.
pub fn group_loads(expert_loads: &[f64], group_size: usize) -> Vec<f64> {
    let g = group_size.max(1);
    expert_loads
        .chunks(g)
        .map(|c| c.iter().sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expert_choice_is_balanced() {
        let mut g = TraceGenerator::new(16, 7);
        let m = g.expert_choice(32, 8, 1.0);
        assert_eq!(m.expert_loads(), vec![8; 16]);
    }

    #[test]
    fn token_choice_zipf_is_imbalanced() {
        let mut g = TraceGenerator::new(16, 11);
        let m = g.token_choice_zipf(256, 4, 1.5);
        let loads = m.expert_loads();
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        assert!(max >= 3 * (min + 1), "expected heavy skew, got {loads:?}");
        assert_eq!(loads.iter().sum::<usize>(), 256 * 4);
    }

    #[test]
    fn zero_skew_is_roughly_uniform() {
        let mut g = TraceGenerator::new(8, 13);
        let loads = g.calibration_loads(8, 128, 2, 0.0);
        let mean = loads.iter().sum::<f64>() / 8.0;
        for l in &loads {
            assert!((l - mean).abs() < mean * 0.5, "{loads:?}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGenerator::new(16, 99).expert_choice(32, 8, 1.0);
        let b = TraceGenerator::new(16, 99).expert_choice(32, 8, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn calibration_loads_shape() {
        let mut g = TraceGenerator::new(4, 5);
        let loads = g.calibration_loads(3, 64, 2, 1.0);
        assert_eq!(loads.len(), 4);
        let total: f64 = loads.iter().sum();
        assert!((total - 128.0).abs() < 1e-9); // 64 tokens * k=2
    }

    #[test]
    fn group_loads_sum_contiguous_chunks() {
        let per_expert = [3.0, 1.0, 2.0, 2.0, 5.0, 0.0];
        assert_eq!(group_loads(&per_expert, 2), vec![4.0, 4.0, 5.0]);
        assert_eq!(group_loads(&per_expert, 3), vec![6.0, 7.0]);
        // degenerate group sizes: 0 clamps to 1 (identity)
        assert_eq!(group_loads(&per_expert, 0).len(), 6);
        let total: f64 = group_loads(&per_expert, 4).iter().sum();
        assert!((total - 13.0).abs() < 1e-12);
    }
}
