//! Expert → crossbar mapping: how many physical crossbars one layer's MoE
//! occupies, and how many serial MVM rounds one token-expert execution
//! takes — the bridge between model dims and the hardware model.

use crate::config::{HardwareConfig, MoeModelConfig};

/// Physical layout of one MoE layer's experts on PIM crossbars.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerLayout {
    /// crossbar tiles holding the up-projection (D x F) per expert
    pub up_tiles: usize,
    /// crossbar tiles holding the down-projection (F x D) per expert
    pub down_tiles: usize,
    /// serial MVM rounds for one token through one expert (up then down —
    /// the down MVM consumes the up MVM's output, so they cannot overlap
    /// for the same token)
    pub rounds_per_token: usize,
    pub n_experts: usize,
}

impl LayerLayout {
    pub fn new(model: &MoeModelConfig, hw: &HardwareConfig) -> Self {
        let tiles = |rows: usize, cols: usize| {
            rows.div_ceil(hw.xbar_rows) * cols.div_ceil(hw.xbar_cols)
        };
        LayerLayout {
            up_tiles: tiles(model.d_model, model.d_ff),
            down_tiles: tiles(model.d_ff, model.d_model),
            rounds_per_token: 2,
            n_experts: model.n_experts,
        }
    }

    /// Crossbars per expert (up + down tiles).
    pub fn xbars_per_expert(&self) -> usize {
        self.up_tiles + self.down_tiles
    }

    /// Total crossbars for the layer's MoE part.
    pub fn total_xbars(&self) -> usize {
        self.xbars_per_expert() * self.n_experts
    }

    /// Core activations consumed by one token-expert execution: every tile
    /// of the up matrix fires in the first round, every down tile in the
    /// second.
    pub fn activations_per_token_expert(&self) -> u64 {
        (self.up_tiles + self.down_tiles) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_crossbar_count() {
        // §IV-A: "Our model requires 1536 crossbars for 16 experts for one
        // layer" => 96/expert => 48 up + 48 down (DESIGN.md §7).
        let layout = LayerLayout::new(
            &MoeModelConfig::llama_moe_4_16(),
            &HardwareConfig::paper(),
        );
        assert_eq!(layout.up_tiles, 48); // ceil(4096/256)*ceil(688/256)=16*3
        assert_eq!(layout.down_tiles, 48);
        assert_eq!(layout.xbars_per_expert(), 96);
        assert_eq!(layout.total_xbars(), 1536);
        assert_eq!(layout.rounds_per_token, 2);
    }

    #[test]
    fn functional_dims_layout() {
        let m = MoeModelConfig {
            d_model: 256,
            n_experts: 16,
            top_k: 4,
            d_ff: 128,
            n_heads: 4,
            d_head: 64,
            n_layers: 1,
            vocab: 512,
        };
        let mut hw = HardwareConfig::paper();
        hw.xbar_rows = 128;
        hw.xbar_cols = 128;
        let layout = LayerLayout::new(&m, &hw);
        assert_eq!(layout.up_tiles, 2); // 2x1
        assert_eq!(layout.down_tiles, 2); // 1x2
        assert_eq!(layout.total_xbars(), 64);
    }

    #[test]
    fn activations_match_tiles() {
        let layout = LayerLayout::new(
            &MoeModelConfig::llama_moe_4_16(),
            &HardwareConfig::paper(),
        );
        assert_eq!(layout.activations_per_token_expert(), 96);
    }
}
