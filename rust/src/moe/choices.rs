//! The token→expert choice matrix `choices[T, E]` — the single input to
//! grouping, scheduling, and the PIM simulator (Algorithm 1's `Require`).

/// Dense boolean T x E matrix; T is tokens, E is experts.  Kept dense (a
/// `Vec<bool>`): T ≤ a few hundred and E ≤ 64 in every workload here, and
/// dense scans are what the schedule builders iterate over.
#[derive(Debug, Clone, PartialEq)]
pub struct ChoiceMatrix {
    t: usize,
    e: usize,
    bits: Vec<bool>,
}

impl ChoiceMatrix {
    pub fn new(t: usize, e: usize) -> Self {
        ChoiceMatrix { t, e, bits: vec![false; t * e] }
    }

    pub fn from_rows(rows: &[Vec<usize>], e: usize) -> Self {
        let mut m = ChoiceMatrix::new(rows.len(), e);
        for (t, experts) in rows.iter().enumerate() {
            for &x in experts {
                m.set(t, x, true);
            }
        }
        m
    }

    pub fn tokens(&self) -> usize {
        self.t
    }

    pub fn experts(&self) -> usize {
        self.e
    }

    #[inline]
    pub fn get(&self, token: usize, expert: usize) -> bool {
        self.bits[token * self.e + expert]
    }

    #[inline]
    pub fn set(&mut self, token: usize, expert: usize, v: bool) {
        self.bits[token * self.e + expert] = v;
    }

    /// Tokens selected by `expert`, in token order.
    pub fn tokens_of(&self, expert: usize) -> Vec<usize> {
        (0..self.t).filter(|&t| self.get(t, expert)).collect()
    }

    /// Experts selected for `token`, in expert order.
    pub fn experts_of(&self, token: usize) -> Vec<usize> {
        (0..self.e).filter(|&e| self.get(token, e)).collect()
    }

    /// Per-expert load (number of selected tokens).
    pub fn expert_loads(&self) -> Vec<usize> {
        (0..self.e).map(|e| self.tokens_of(e).len()).collect()
    }

    /// Total active (token, expert) pairs.
    pub fn total_work(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Per-token number of active experts.
    pub fn token_fanout(&self, token: usize) -> usize {
        (0..self.e).filter(|&e| self.get(token, e)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut m = ChoiceMatrix::new(4, 3);
        m.set(2, 1, true);
        assert!(m.get(2, 1));
        assert!(!m.get(1, 2));
        assert_eq!(m.total_work(), 1);
    }

    #[test]
    fn from_rows() {
        let m = ChoiceMatrix::from_rows(&[vec![0, 2], vec![1], vec![]], 3);
        assert_eq!(m.tokens(), 3);
        assert_eq!(m.experts_of(0), vec![0, 2]);
        assert_eq!(m.tokens_of(1), vec![1]);
        assert_eq!(m.expert_loads(), vec![1, 1, 1]);
        assert_eq!(m.token_fanout(2), 0);
    }

    #[test]
    fn loads_sum_to_work() {
        let m = ChoiceMatrix::from_rows(&[vec![0, 1], vec![0], vec![0, 1]], 2);
        assert_eq!(m.expert_loads().iter().sum::<usize>(), m.total_work());
    }
}
