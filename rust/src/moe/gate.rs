//! Routing: token-choice (Eq. 1-3) and expert-choice (Zhou et al. [12],
//! Eq. 4-5) over raw gate scores.
//!
//! This is the *reference* batch routing used for prefill and for the
//! uncached decode baseline; the streaming equivalent lives in
//! [`crate::cache::go`] and must select identical sets (pinned by proptest
//! in `rust/tests/props_cache.rs` and mirrored by the python suite).
//!
//! Semantics shared with python (`kernels/ref.py::expert_choice_gates_ref`):
//! ranking is over the per-token softmax probs, ties break toward the
//! earlier token, capacity is fixed.

use super::choices::ChoiceMatrix;

/// Routing result: the selection matrix plus dense gate weights
/// (softmax prob where selected, 0 elsewhere) — what `moe_apply` consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct Routing {
    pub choices: ChoiceMatrix,
    /// [T, E] row-major
    pub gates: Vec<f32>,
}

impl Routing {
    pub fn gate(&self, token: usize, expert: usize) -> f32 {
        self.gates[token * self.choices.experts() + expert]
    }
}

/// Row-wise softmax of a [T, E] score matrix (numerically stable).
pub fn softmax_rows(scores: &[f32], t: usize, e: usize) -> Vec<f32> {
    assert_eq!(scores.len(), t * e, "scores must be T x E");
    let mut probs = vec![0f32; t * e];
    for row in 0..t {
        let s = &scores[row * e..(row + 1) * e];
        let max = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f64;
        for (j, &v) in s.iter().enumerate() {
            let ex = ((v - max) as f64).exp();
            probs[row * e + j] = ex as f32;
            denom += ex;
        }
        for j in 0..e {
            probs[row * e + j] = (probs[row * e + j] as f64 / denom) as f32;
        }
    }
    probs
}

/// Expert-choice routing: each expert selects its top-`capacity` tokens by
/// softmax prob (earlier token wins ties).  `valid_len` masks padding rows.
///
/// Perf note (§Perf L3-2): per-expert ranking works on a transposed
/// (column-contiguous) copy of the probs and uses `select_nth_unstable`
/// to find the capacity boundary in O(T) before sorting only the kept
/// prefix — ~4x faster than full per-column sorts at 1024x64.  The
/// comparator is the same (prob desc, token asc), so selections are
/// bit-identical to the naive implementation (pinned by unit test).
pub fn expert_choice_route(
    scores: &[f32],
    t: usize,
    e: usize,
    capacity: usize,
    valid_len: Option<usize>,
) -> Routing {
    let valid = valid_len.unwrap_or(t).min(t);
    let probs = softmax_rows(scores, t, e);
    let mut choices = ChoiceMatrix::new(t, e);
    let mut gates = vec![0f32; t * e];
    let cap = capacity.min(valid);
    if cap == 0 {
        return Routing { choices, gates };
    }
    // transpose the valid region once: column[expert][token]
    let mut col = vec![0f32; valid];
    let mut order: Vec<usize> = Vec::with_capacity(valid);
    for expert in 0..e {
        for tok in 0..valid {
            col[tok] = probs[tok * e + expert];
        }
        order.clear();
        order.extend(0..valid);
        let cmp = |a: &usize, b: &usize| {
            col[*b]
                .partial_cmp(&col[*a])
                .unwrap()
                .then(a.cmp(b))
        };
        if cap < valid {
            order.select_nth_unstable_by(cap - 1, cmp);
        }
        order[..cap].sort_unstable_by(cmp);
        for &tok in order.iter().take(cap) {
            choices.set(tok, expert, true);
            gates[tok * e + expert] = probs[tok * e + expert];
        }
    }
    Routing { choices, gates }
}

/// Token-choice routing (Eq. 1-3): each token keeps its top-k experts; gate
/// weights are the softmax over the kept scores only (KeepTopK then
/// softmax, as in Shazeer et al. [1]).
pub fn token_choice_route(scores: &[f32], t: usize, e: usize, k: usize)
    -> Routing {
    assert_eq!(scores.len(), t * e);
    let k = k.min(e);
    let mut choices = ChoiceMatrix::new(t, e);
    let mut gates = vec![0f32; t * e];
    for tok in 0..t {
        let row = &scores[tok * e..(tok + 1) * e];
        let mut order: Vec<usize> = (0..e).collect();
        order.sort_by(|&a, &b| {
            row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b))
        });
        let kept = &order[..k];
        let max = kept.iter().map(|&j| row[j]).fold(f32::NEG_INFINITY, f32::max);
        let denom: f64 =
            kept.iter().map(|&j| ((row[j] - max) as f64).exp()).sum();
        for &j in kept {
            choices.set(tok, j, true);
            gates[tok * e + j] =
                (((row[j] - max) as f64).exp() / denom) as f32;
        }
    }
    Routing { choices, gates }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores(t: usize, e: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg32::new(seed);
        (0..t * e).map(|_| rng.gen_normal() as f32).collect()
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let s = scores(5, 8, 1);
        let p = softmax_rows(&s, 5, 8);
        for row in 0..5 {
            let sum: f32 = p[row * 8..(row + 1) * 8].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn expert_choice_exact_capacity() {
        let s = scores(32, 16, 2);
        let r = expert_choice_route(&s, 32, 16, 8, None);
        for e in 0..16 {
            assert_eq!(r.choices.tokens_of(e).len(), 8);
        }
        assert_eq!(r.choices.total_work(), 16 * 8);
    }

    #[test]
    fn expert_choice_respects_valid_len() {
        let s = scores(96, 16, 3);
        let r = expert_choice_route(&s, 96, 16, 8, Some(32));
        for t in 32..96 {
            assert_eq!(r.choices.token_fanout(t), 0);
        }
        for e in 0..16 {
            assert_eq!(r.choices.tokens_of(e).len(), 8);
        }
    }

    #[test]
    fn expert_choice_gate_values_are_probs() {
        let s = scores(16, 4, 4);
        let p = softmax_rows(&s, 16, 4);
        let r = expert_choice_route(&s, 16, 4, 4, None);
        for t in 0..16 {
            for e in 0..4 {
                if r.choices.get(t, e) {
                    assert_eq!(r.gate(t, e), p[t * 4 + e]);
                } else {
                    assert_eq!(r.gate(t, e), 0.0);
                }
            }
        }
    }

    #[test]
    fn expert_choice_tie_breaks_earlier_token() {
        // all-equal scores: softmax probs all equal; experts must take the
        // first `capacity` tokens
        let s = vec![0f32; 10 * 3];
        let r = expert_choice_route(&s, 10, 3, 4, None);
        for e in 0..3 {
            assert_eq!(r.choices.tokens_of(e), vec![0, 1, 2, 3]);
        }
    }

    /// §Perf L3-2 regression pin: the select_nth-based router must select
    /// exactly what the naive full-sort router selects.
    #[test]
    fn optimized_route_matches_naive() {
        for seed in 0..10u64 {
            let (t, e, cap) = (96, 16, 8);
            let s = scores(t, e, seed);
            let fast = expert_choice_route(&s, t, e, cap, Some(32));
            // naive reference
            let probs = softmax_rows(&s, t, e);
            for expert in 0..e {
                let mut order: Vec<usize> = (0..32).collect();
                order.sort_by(|&a, &b| {
                    probs[b * e + expert]
                        .partial_cmp(&probs[a * e + expert])
                        .unwrap()
                        .then(a.cmp(&b))
                });
                let want: Vec<usize> = {
                    let mut w = order[..cap].to_vec();
                    w.sort_unstable();
                    w
                };
                assert_eq!(fast.choices.tokens_of(expert), want,
                           "seed {seed} expert {expert}");
            }
        }
    }

    #[test]
    fn token_choice_exact_k() {
        let s = scores(32, 16, 5);
        let r = token_choice_route(&s, 32, 16, 4);
        for t in 0..32 {
            assert_eq!(r.choices.token_fanout(t), 4);
            let sum: f32 = (0..16).map(|e| r.gate(t, e)).sum();
            assert!((sum - 1.0).abs() < 1e-5, "kept gates renormalise");
        }
    }

    #[test]
    fn token_choice_selects_highest_scores() {
        let s = vec![0.1, 0.9, 0.5, 0.2]; // 1 token, 4 experts
        let r = token_choice_route(&s, 1, 4, 2);
        assert!(r.choices.get(0, 1) && r.choices.get(0, 2));
        assert!(!r.choices.get(0, 0) && !r.choices.get(0, 3));
        assert!(r.gate(0, 1) > r.gate(0, 2));
    }
}
