//! # moepim — Area-Efficient In-Memory Computing for MoE
//!
//! Reproduction of *"Area-Efficient In-Memory Computing for
//! Mixture-of-Experts via Multiplexing and Caching"* (Gao & Yang, 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution plus the
//!   serving runtime grown around it;
//! * **L2 (python/compile/model.py)** — the functional depth-L MoE
//!   transformer stack, AOT-lowered to `artifacts/*.hlo.txt` at build
//!   time (per-layer artifact families, `n_layers_functional` in the
//!   manifest);
//! * **L1 (python/compile/kernels/)** — Pallas crossbar/FFN/gate kernels.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! ## Module ↔ paper map
//!
//! | Module | Paper anchor | Role |
//! |--------|--------------|------|
//! | [`hw`] | §III-A | crossbar area/energy model, peripheral multiplexing, PCM read noise |
//! | [`grouping`] | §III-B | peripheral-sharing expert groups (uniform / workload-sorted) |
//! | [`cache`] | §III-C | KV cache + GO (gating-output) cache, per-session and pooled |
//! | [`sched`] | §III-D | prefill schedules (token-wise / compact / Algorithm 1) + the online `BatchPlanner` |
//! | [`moe`] | §II, §III-B | routing modes, choice matrices, seeded trace generation |
//! | [`sim`] | §IV | operator-level PIM simulator (latency/energy/area pipeline) |
//! | [`eval`] | §IV figures | regenerates every paper figure/table (`moepim eval all`) |
//! | [`config`] | Table 1 | model dims, hardware constants, sim knobs, manifest reader |
//!
//! Beyond the paper, the serving stack scales the same ideas up from one
//! chip to a service:
//!
//! | Module | Role |
//! |--------|------|
//! | [`runtime`] | PJRT client owning the AOT-compiled artifacts (one client per router thread; independent clients run concurrently) |
//! | [`coordinator`] | per-session engine, slot-batched `BatchEngine`, threaded `Server` with pluggable admission, and the multi-backend `Cluster` front door (live placement, streaming replies, backpressure) |
//! | [`workload`] | seeded traffic generation, SLO telemetry, admission policies, virtual-time cluster, and the sharded multi-server fan-out — static placement splits or live-signal cluster runs, concurrent real backends by default |
//! | [`placement`] | the unified `Placer` interface (static policies + live cluster rules) and the dynamic control loop: routing-feedback-driven migration of queued requests, heterogeneous capacity-weighted fleets, and area-ledgered hot-expert replication |
//! | [`obs`] | request-lifecycle span tracing (per-thread ring sinks, Chrome/Perfetto `moepim.spans.v1` export) and the unified metrics registry behind `--trace-out` / `--metrics-file` |
//! | [`util`] | in-tree substitutes for serde/rand/clap/criterion (offline image) |
//!
//! The serving-facing API surface ([`workload`] and [`coordinator`]) is
//! fully documented and doctested; `cargo doc --no-deps` runs in CI with
//! `-D warnings`, so broken intra-doc links and undocumented items in
//! those modules fail the build.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod cache;
pub mod config;
#[warn(missing_docs)]
pub mod coordinator;
pub mod eval;
pub mod grouping;
pub mod hw;
pub mod moe;
#[warn(missing_docs)]
pub mod obs;
#[warn(missing_docs)]
pub mod placement;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
#[warn(missing_docs)]
pub mod workload;
