//! # moepim — Area-Efficient In-Memory Computing for MoE
//!
//! Reproduction of *"Area-Efficient In-Memory Computing for
//! Mixture-of-Experts via Multiplexing and Caching"* (Gao & Yang, 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: crossbar-level
//!   peripheral multiplexing ([`hw`]), load-aware expert grouping
//!   ([`grouping`]), dynamic prefill scheduling ([`sched`]), the KV + GO
//!   caches ([`cache`]), the operator-level PIM simulator ([`sim`]), the
//!   evaluation harness regenerating every paper figure/table ([`eval`]),
//!   a slot-batched serving coordinator driving the real AOT-compiled
//!   model ([`coordinator`]) through the PJRT runtime ([`runtime`]), and
//!   the load-testing subsystem ([`workload`]): seeded traffic
//!   generation, policy-driven admission, and SLO telemetry over either
//!   the real server or a deterministic virtual-time cluster.
//! * **L2 (python/compile/model.py)** — the functional depth-L MoE
//!   transformer stack, AOT-lowered to `artifacts/*.hlo.txt` at build
//!   time (per-layer artifact families, `n_layers_functional` in the
//!   manifest).
//! * **L1 (python/compile/kernels/)** — Pallas crossbar/FFN/gate kernels.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod cache;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod grouping;
pub mod hw;
pub mod moe;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;
pub mod workload;
