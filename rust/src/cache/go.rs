//! The gate-output (GO) cache (§III-C, Eq. 4-5) — the paper's answer to
//! expert-choice routing's generation inefficiency.
//!
//! **Score cache**: per expert, the top-`capacity` (token, prob) entries
//! seen so far.  A new token's gate runs on *one* token; `TopKUpdate`
//! (Eq. 5) compares its prob against each expert's cached minimum: if it
//! displaces the minimum, the expert selects the token (and, in
//! retain-all-tokens mode, rewrites exactly one output-cache entry —
//! "each generation step will result in at most one change per expert").
//!
//! **Output cache**: the k cached `G(x) E(x)` contribution vectors per
//! expert (static `k x E x d` bytes — 512 KB at the paper's dims), used
//! when past tokens' MoE outputs must stay retrievable (constrained
//! decoding [15]).
//!
//! Equivalence contract (pinned by `rust/tests/props_cache.rs` and
//! mirrored in python's test_routing.py): seeding with a batch
//! expert-choice routing and streaming updates thereafter selects exactly
//! the sets a full batch top-k over all tokens would select, with the
//! earlier-token-wins tie-break.

use crate::moe::gate::{softmax_rows, Routing};

/// One expert's cached selection entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub token: usize,
    pub prob: f32,
}

/// Result of one TopKUpdate step.
#[derive(Debug, Clone, PartialEq)]
pub struct GoUpdate {
    /// experts that selected the new token (sorted ascending)
    pub selected: Vec<usize>,
    /// for each selected expert, the token its new entry evicted
    pub evicted: Vec<usize>,
    /// gate weight (softmax prob) per selected expert
    pub gates: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct GoCache {
    n_experts: usize,
    capacity: usize,
    /// per expert: entries kept sorted by (prob desc, token asc); the last
    /// element is the eviction candidate (minimum under the tie-break)
    entries: Vec<Vec<Entry>>,
    /// optional output cache: per expert, per slot, a d-dim contribution
    out_dim: usize,
    outputs: Vec<Vec<Vec<f32>>>,
}

impl GoCache {
    pub fn new(n_experts: usize, capacity: usize, out_dim: usize) -> Self {
        GoCache {
            n_experts,
            capacity,
            entries: vec![Vec::with_capacity(capacity); n_experts],
            out_dim,
            outputs: vec![vec![vec![0.0; out_dim]; capacity]; n_experts],
        }
    }

    /// One independent GO bank per functional layer (depth-L sessions):
    /// `capacities[l]` sizes layer `l`'s bank.  Banks are fully isolated —
    /// a layer's `TopKUpdate` can never perturb another layer's selections,
    /// matching the per-layer score/output caches of the paper's 32-block
    /// target model.
    pub fn banks(capacities: &[usize], n_experts: usize, out_dim: usize)
        -> Vec<GoCache> {
        assert!(!capacities.is_empty(), "need at least one layer");
        capacities
            .iter()
            .map(|&cap| GoCache::new(n_experts, cap, out_dim))
            .collect()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// Seed from a batch prefill routing (scores of the prompt tokens).
    pub fn seed_from_routing(&mut self, routing: &Routing) {
        let e = self.n_experts;
        assert_eq!(routing.choices.experts(), e);
        for expert in 0..e {
            let mut es: Vec<Entry> = routing
                .choices
                .tokens_of(expert)
                .into_iter()
                .map(|t| Entry { token: t, prob: routing.gate(t, expert) })
                .collect();
            assert!(
                es.len() <= self.capacity,
                "prefill selected more than capacity"
            );
            sort_entries(&mut es);
            self.entries[expert] = es;
        }
    }

    /// Recycle the cache for a new session (slot reuse in the serving
    /// pool): drops all score entries and zeroes the output cache.
    pub fn reset(&mut self) {
        for es in self.entries.iter_mut() {
            es.clear();
        }
        for per_expert in self.outputs.iter_mut() {
            for slot in per_expert.iter_mut() {
                slot.fill(0.0);
            }
        }
    }

    /// Current selection threshold of `expert` (the cached minimum prob),
    /// or `None` while the cache is underfull (every token is selected).
    pub fn threshold(&self, expert: usize) -> Option<Entry> {
        let es = &self.entries[expert];
        if es.len() < self.capacity {
            None
        } else {
            es.last().copied()
        }
    }

    /// TopKUpdate (Eq. 5) for a new token with raw gate scores `scores[E]`.
    /// Softmaxes internally (the cache stores softmaxed scores, matching
    /// the batch router's ranking space).
    pub fn update_scores(&mut self, token: usize, scores: &[f32]) -> GoUpdate {
        assert_eq!(scores.len(), self.n_experts);
        let probs = softmax_rows(scores, 1, self.n_experts);
        self.update_probs(token, &probs)
    }

    /// TopKUpdate with already-softmaxed probs.
    pub fn update_probs(&mut self, token: usize, probs: &[f32]) -> GoUpdate {
        let upd = self.peek_probs(token, probs);
        self.apply_update(token, &upd);
        upd
    }

    /// Compute a TopKUpdate *without* mutating the cache — the first half
    /// of the batched engine's two-phase step: selection is peeked for
    /// every slot, the (fallible) MoE dispatch runs, and only then are the
    /// updates applied, so a failed dispatch leaves every cache untouched.
    pub fn peek_probs(&self, token: usize, probs: &[f32]) -> GoUpdate {
        assert_eq!(probs.len(), self.n_experts);
        let mut upd =
            GoUpdate { selected: vec![], evicted: vec![], gates: vec![] };
        for expert in 0..self.n_experts {
            let p = probs[expert];
            let es = &self.entries[expert];
            let accept = if es.len() < self.capacity {
                true
            } else {
                // strict >: on a tie the incumbent (earlier token) stays
                p > es.last().unwrap().prob
            };
            if !accept {
                continue;
            }
            let evicted_token = if es.len() == self.capacity {
                es.last().unwrap().token
            } else {
                usize::MAX
            };
            upd.selected.push(expert);
            upd.evicted.push(evicted_token);
            upd.gates.push(p);
        }
        upd
    }

    /// Commit a previously peeked update (must have been computed against
    /// the current cache state).
    pub fn apply_update(&mut self, token: usize, upd: &GoUpdate) {
        for (i, &expert) in upd.selected.iter().enumerate() {
            let es = &mut self.entries[expert];
            if es.len() == self.capacity {
                let evicted = es.pop().unwrap().token;
                debug_assert_eq!(evicted, upd.evicted[i], "stale update");
            }
            es.push(Entry { token, prob: upd.gates[i] });
            sort_entries(es);
        }
    }

    /// Selected-token set of `expert`, sorted ascending.
    pub fn selected_tokens(&self, expert: usize) -> Vec<usize> {
        let mut ts: Vec<usize> =
            self.entries[expert].iter().map(|e| e.token).collect();
        ts.sort_unstable();
        ts
    }

    /// Store a contribution vector in the output cache (retain-all mode).
    /// `slot` addresses the expert's k-entry ring; the paper rewrites the
    /// evicted entry's slot.
    pub fn store_output(&mut self, expert: usize, slot: usize, v: &[f32]) {
        assert_eq!(v.len(), self.out_dim);
        self.outputs[expert][slot].copy_from_slice(v);
    }

    pub fn load_output(&self, expert: usize, slot: usize) -> &[f32] {
        &self.outputs[expert][slot]
    }

    /// Slot index of `token` in `expert`'s entry list (for output-cache
    /// addressing), if selected.
    pub fn slot_of(&self, expert: usize, token: usize) -> Option<usize> {
        self.entries[expert].iter().position(|e| e.token == token)
    }

    // ----- DRAM traffic accounting (simulator side) ------------------------

    /// Score-cache bytes appended per generated token: E scores at fp16
    /// (paper: "each newly generated token only adds 32 B of score data"
    /// with E = 16).
    pub fn score_bytes_per_token(n_experts: usize) -> u64 {
        2 * n_experts as u64
    }

    /// Static output-cache size: k x E x d at 8-bit precision (paper:
    /// "fixed at 512 KB" for k=8, E=16, d=4096).
    pub fn output_cache_bytes(capacity: usize, n_experts: usize,
                              d_model: usize) -> u64 {
        (capacity * n_experts * d_model) as u64
    }

    /// Worst-case output-cache bytes rewritten per step: one d-dim entry
    /// per expert that changed its selection.
    pub fn output_write_bytes(changed_experts: usize, d_model: usize) -> u64 {
        (changed_experts * d_model) as u64
    }
}

fn sort_entries(es: &mut [Entry]) {
    es.sort_by(|a, b| {
        b.prob
            .partial_cmp(&a.prob)
            .unwrap()
            .then(a.token.cmp(&b.token))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::gate::expert_choice_route;
    use crate::util::rng::Pcg32;

    fn scores(t: usize, e: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..t * e).map(|_| rng.gen_normal() as f32).collect()
    }

    #[test]
    fn streaming_equals_batch() {
        let (t_total, e, cap, prefix) = (24, 8, 4, 12);
        let s = scores(t_total, e, 42);
        // seed with batch routing over the prefix
        let pre = expert_choice_route(&s[..prefix * e], prefix, e, cap, None);
        let mut cache = GoCache::new(e, cap, 1);
        cache.seed_from_routing(&pre);
        // stream the rest
        for t in prefix..t_total {
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
        }
        // compare with full batch routing over everything
        let full = expert_choice_route(&s, t_total, e, cap, None);
        for expert in 0..e {
            assert_eq!(
                cache.selected_tokens(expert),
                full.choices.tokens_of(expert),
                "expert {expert}"
            );
        }
    }

    #[test]
    fn tie_keeps_earlier_token() {
        let e = 2;
        let mut cache = GoCache::new(e, 1, 1);
        cache.update_probs(0, &[0.5, 0.5]);
        let upd = cache.update_probs(1, &[0.5, 0.6]);
        // expert 0: tie -> incumbent token 0 stays; expert 1: displaced
        assert_eq!(cache.selected_tokens(0), vec![0]);
        assert_eq!(cache.selected_tokens(1), vec![1]);
        assert_eq!(upd.selected, vec![1]);
        assert_eq!(upd.evicted, vec![0]);
    }

    #[test]
    fn underfull_cache_accepts_everything() {
        let mut cache = GoCache::new(3, 2, 1);
        let u0 = cache.update_probs(0, &[0.1, 0.1, 0.1]);
        assert_eq!(u0.selected, vec![0, 1, 2]);
        assert_eq!(u0.evicted, vec![usize::MAX; 3]); // nothing evicted
        let u1 = cache.update_probs(1, &[0.05, 0.05, 0.05]);
        assert_eq!(u1.selected, vec![0, 1, 2]); // still filling
        let u2 = cache.update_probs(2, &[0.01, 0.01, 0.2]);
        assert_eq!(u2.selected, vec![2]); // now only a displacement counts
    }

    #[test]
    fn at_most_one_change_per_expert_per_step() {
        let e = 8;
        let mut cache = GoCache::new(e, 4, 1);
        let s = scores(30, e, 7);
        for t in 0..30 {
            let before: Vec<Vec<usize>> =
                (0..e).map(|x| cache.selected_tokens(x)).collect();
            cache.update_scores(t, &s[t * e..(t + 1) * e]);
            for x in 0..e {
                let after = cache.selected_tokens(x);
                let removed = before[x]
                    .iter()
                    .filter(|tk| !after.contains(tk))
                    .count();
                assert!(removed <= 1, "expert {x} changed {removed} entries");
            }
        }
    }

    #[test]
    fn output_cache_store_load() {
        let mut cache = GoCache::new(2, 2, 4);
        cache.update_probs(0, &[0.9, 0.1]);
        let slot = cache.slot_of(0, 0).unwrap();
        cache.store_output(0, slot, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(cache.load_output(0, slot), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(cache.slot_of(0, 99), None);
    }

    #[test]
    fn paper_traffic_numbers() {
        // §IV-A: 32 B of score data per token (E=16), 512 KB output cache
        assert_eq!(GoCache::score_bytes_per_token(16), 32);
        assert_eq!(GoCache::output_cache_bytes(8, 16, 4096), 512 * 1024);
        assert_eq!(GoCache::output_write_bytes(3, 4096), 3 * 4096);
    }

    #[test]
    fn peek_then_apply_equals_update() {
        let e = 8;
        let s = scores(20, e, 13);
        let mut a = GoCache::new(e, 3, 0);
        let mut b = GoCache::new(e, 3, 0);
        for t in 0..20 {
            let row = &s[t * e..(t + 1) * e];
            let probs = softmax_rows(row, 1, e);
            let upd_a = a.update_probs(t, &probs);
            let peeked = b.peek_probs(t, &probs);
            assert_eq!(peeked, upd_a);
            // peek alone must not change state
            assert_eq!(b.peek_probs(t, &probs), peeked);
            b.apply_update(t, &peeked);
            for x in 0..e {
                assert_eq!(a.selected_tokens(x), b.selected_tokens(x));
            }
        }
    }

    #[test]
    fn reset_recycles_state() {
        let mut cache = GoCache::new(2, 2, 3);
        cache.update_probs(0, &[0.9, 0.1]);
        cache.store_output(0, 0, &[1.0, 2.0, 3.0]);
        cache.reset();
        assert!(cache.selected_tokens(0).is_empty());
        assert!(cache.selected_tokens(1).is_empty());
        assert_eq!(cache.load_output(0, 0), &[0.0; 3]);
        // behaves like a fresh cache afterwards
        let upd = cache.update_probs(5, &[0.4, 0.6]);
        assert_eq!(upd.selected, vec![0, 1]);
        assert_eq!(cache.selected_tokens(0), vec![5]);
    }

    #[test]
    fn threshold_semantics() {
        let mut cache = GoCache::new(1, 2, 1);
        assert!(cache.threshold(0).is_none());
        cache.update_probs(0, &[0.3]);
        assert!(cache.threshold(0).is_none()); // still underfull
        cache.update_probs(1, &[0.5]);
        let th = cache.threshold(0).unwrap();
        assert_eq!(th.token, 0);
        assert_eq!(th.prob, 0.3);
    }
}
