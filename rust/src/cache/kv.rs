//! KV cache: per-sequence key/value buffers for attention decode.
//!
//! The coordinator owns one [`KvCache`] per live sequence; the
//! `attn_decode` executable receives the full (padded) buffers plus the
//! write position and returns the new token's K/V rows, which the
//! coordinator writes back — mirroring the DRAM-resident cache of the
//! paper's chip, where the PIM die streams K/V in per step.

/// Functional KV buffer of one sequence, padded to `max_seq`.
#[derive(Debug, Clone)]
pub struct KvCache {
    max_seq: usize,
    n_heads: usize,
    d_head: usize,
    len: usize,
    /// [max_seq, n_heads, d_head] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(max_seq: usize, n_heads: usize, d_head: usize) -> Self {
        let n = max_seq * n_heads * d_head;
        KvCache {
            max_seq,
            n_heads,
            d_head,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Full padded buffers (what `attn_decode` takes as inputs).
    pub fn k_buf(&self) -> &[f32] {
        &self.k
    }

    pub fn v_buf(&self) -> &[f32] {
        &self.v
    }

    /// Seed from a prefill's K/V outputs (padded [max_seq, H, Dh] buffers,
    /// `valid` rows meaningful).
    pub fn seed(&mut self, k: &[f32], v: &[f32], valid: usize) {
        assert_eq!(k.len(), self.k.len(), "k buffer shape mismatch");
        assert_eq!(v.len(), self.v.len(), "v buffer shape mismatch");
        assert!(valid <= self.max_seq);
        self.k.copy_from_slice(k);
        self.v.copy_from_slice(v);
        self.len = valid;
    }

    /// Append one decode step's K/V rows ([1, H, Dh] each).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        let r = self.row_elems();
        assert_eq!(k_row.len(), r, "k row shape mismatch");
        assert_eq!(v_row.len(), r, "v row shape mismatch");
        assert!(self.len < self.max_seq, "KV cache full");
        let off = self.len * r;
        self.k[off..off + r].copy_from_slice(k_row);
        self.v[off..off + r].copy_from_slice(v_row);
        self.len += 1;
    }

    pub fn row_k(&self, pos: usize) -> &[f32] {
        let r = self.row_elems();
        &self.k[pos * r..(pos + 1) * r]
    }

    /// Bytes written per generated token on the paper's chip (K + V rows at
    /// 8-bit I/O precision).
    pub fn bytes_per_token_write(n_heads: usize, d_head: usize) -> u64 {
        2 * (n_heads * d_head) as u64
    }

    /// Bytes read per decode step at context length `l` (stream all cached
    /// K and V rows).
    pub fn bytes_read_at(n_heads: usize, d_head: usize, l: usize) -> u64 {
        2 * (n_heads * d_head) as u64 * l as u64
    }
}

/// Pooled per-slot KV storage for the slot-batched serving engine.
///
/// One contiguous pair of `[B, S, H, Dh]` buffers instead of B separate
/// [`KvCache`]s: the batched `attn_decode_batch` artifact takes the whole
/// pool as its cache inputs, so a batch step borrows `k_all()` / `v_all()`
/// directly — zero copies, where the per-session path used to clone both
/// buffers every token.  Slots are recycled between requests with
/// [`KvPool::reset_slot`].
#[derive(Debug, Clone)]
pub struct KvPool {
    slots: usize,
    max_seq: usize,
    n_heads: usize,
    d_head: usize,
    len: Vec<usize>,
    /// [slots, max_seq, n_heads, d_head] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPool {
    pub fn new(slots: usize, max_seq: usize, n_heads: usize, d_head: usize)
        -> Self {
        assert!(slots >= 1, "pool needs at least one slot");
        let n = slots * max_seq * n_heads * d_head;
        KvPool {
            slots,
            max_seq,
            n_heads,
            d_head,
            len: vec![0; slots],
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    fn slot_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    /// Valid rows of `slot`.
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.len[slot] == 0
    }

    /// The whole pooled K buffer `[B, S, H, Dh]` — the batched decode
    /// artifact's cache input.
    pub fn k_all(&self) -> &[f32] {
        &self.k
    }

    pub fn v_all(&self) -> &[f32] {
        &self.v
    }

    /// One slot's padded K buffer `[S, H, Dh]` (single-token fallback path).
    pub fn slot_k(&self, slot: usize) -> &[f32] {
        let n = self.slot_elems();
        &self.k[slot * n..(slot + 1) * n]
    }

    pub fn slot_v(&self, slot: usize) -> &[f32] {
        let n = self.slot_elems();
        &self.v[slot * n..(slot + 1) * n]
    }

    /// Seed `slot` from a prefill's padded K/V outputs (`[S, H, Dh]` each,
    /// `valid` rows meaningful).
    pub fn seed_slot(&mut self, slot: usize, k: &[f32], v: &[f32],
                     valid: usize) {
        let n = self.slot_elems();
        assert_eq!(k.len(), n, "k buffer shape mismatch");
        assert_eq!(v.len(), n, "v buffer shape mismatch");
        assert!(valid <= self.max_seq);
        self.k[slot * n..(slot + 1) * n].copy_from_slice(k);
        self.v[slot * n..(slot + 1) * n].copy_from_slice(v);
        self.len[slot] = valid;
    }

    /// Append one decode step's K/V rows (`[1, H, Dh]` each) to `slot`.
    pub fn append_slot(&mut self, slot: usize, k_row: &[f32],
                       v_row: &[f32]) {
        let r = self.row_elems();
        assert_eq!(k_row.len(), r, "k row shape mismatch");
        assert_eq!(v_row.len(), r, "v row shape mismatch");
        assert!(self.len[slot] < self.max_seq, "KV slot full");
        let off = slot * self.slot_elems() + self.len[slot] * r;
        self.k[off..off + r].copy_from_slice(k_row);
        self.v[off..off + r].copy_from_slice(v_row);
        self.len[slot] += 1;
    }

    /// Recycle `slot` for a new request.  Zeroes the buffers so a stale
    /// session can never leak rows into the next one through the padded
    /// region the batched artifact reads.
    pub fn reset_slot(&mut self, slot: usize) {
        let n = self.slot_elems();
        self.k[slot * n..(slot + 1) * n].fill(0.0);
        self.v[slot * n..(slot + 1) * n].fill(0.0);
        self.len[slot] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_and_append() {
        let mut c = KvCache::new(4, 2, 3);
        let mut k = vec![0.0; 4 * 6];
        let v = vec![0.5; 4 * 6];
        k[0] = 1.0; // token 0, head 0, dim 0
        c.seed(&k, &v, 2);
        assert_eq!(c.len(), 2);
        c.append(&[9.0; 6], &[8.0; 6]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.row_k(2), &[9.0; 6]);
        assert_eq!(c.row_k(0)[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1);
        c.append(&[1.0], &[1.0]);
        c.append(&[2.0], &[2.0]);
    }

    #[test]
    fn traffic_accounting() {
        // Llama-MoE dims: 32 heads x 128 = 4096 per row, K+V = 8192 B/token
        assert_eq!(KvCache::bytes_per_token_write(32, 128), 8192);
        assert_eq!(KvCache::bytes_read_at(32, 128, 40), 8192 * 40);
        assert_eq!(KvCache::bytes_read_at(32, 128, 0), 0);
    }

    #[test]
    fn buffers_padded_to_max() {
        let c = KvCache::new(96, 4, 64);
        assert_eq!(c.k_buf().len(), 96 * 4 * 64);
        assert!(c.is_empty());
    }

    #[test]
    fn pool_slots_are_independent() {
        let mut p = KvPool::new(3, 4, 2, 3);
        assert_eq!(p.k_all().len(), 3 * 4 * 6);
        let mut k = vec![0.0; 4 * 6];
        k[0] = 2.0;
        let v = vec![0.5; 4 * 6];
        p.seed_slot(1, &k, &v, 2);
        assert_eq!(p.len(1), 2);
        assert_eq!(p.len(0), 0);
        p.append_slot(1, &[9.0; 6], &[8.0; 6]);
        assert_eq!(p.len(1), 3);
        // slot 1's view matches what was written; slot 0 untouched
        assert_eq!(p.slot_k(1)[0], 2.0);
        assert_eq!(p.slot_k(1)[2 * 6], 9.0);
        assert!(p.slot_k(0).iter().all(|&x| x == 0.0));
        // the pooled buffer is the slots concatenated
        let n = 4 * 6;
        assert_eq!(&p.k_all()[n..2 * n], p.slot_k(1));
    }

    #[test]
    fn pool_reset_zeroes_slot() {
        let mut p = KvPool::new(2, 2, 1, 2);
        p.append_slot(0, &[1.0, 2.0], &[3.0, 4.0]);
        p.append_slot(1, &[5.0, 6.0], &[7.0, 8.0]);
        p.reset_slot(0);
        assert_eq!(p.len(0), 0);
        assert!(p.slot_k(0).iter().all(|&x| x == 0.0));
        // neighbouring slot unaffected
        assert_eq!(p.slot_k(1)[0], 5.0);
        assert_eq!(p.len(1), 1);
    }

    #[test]
    #[should_panic(expected = "KV slot full")]
    fn pool_overflow_panics() {
        let mut p = KvPool::new(1, 1, 1, 1);
        p.append_slot(0, &[1.0], &[1.0]);
        p.append_slot(0, &[2.0], &[2.0]);
    }
}
