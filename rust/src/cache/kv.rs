//! KV cache: per-sequence, per-layer key/value banks for attention decode.
//!
//! The coordinator owns one [`KvCache`] per live sequence, holding one
//! bank per functional layer; the layer-`l` `attn_decode` executable
//! receives layer `l`'s full (padded) buffers plus the write position and
//! returns the new token's K/V rows, which the coordinator writes back —
//! mirroring the DRAM-resident cache of the paper's chip, where the PIM
//! die streams each layer's K/V in per step.
//!
//! Layout: one contiguous buffer with the *layer as the outermost
//! dimension* (`[L, S, H, Dh]` per-session, `[L, B, S, H, Dh]` pooled), so
//! a layer bank is a contiguous slice the attention artifacts borrow
//! zero-copy.

/// Functional KV banks of one sequence: `[n_layers, max_seq, H, Dh]`,
/// padded to `max_seq`.  All layers share one sequence length.
#[derive(Debug, Clone)]
pub struct KvCache {
    n_layers: usize,
    max_seq: usize,
    n_heads: usize,
    d_head: usize,
    len: usize,
    /// [n_layers, max_seq, n_heads, d_head] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(n_layers: usize, max_seq: usize, n_heads: usize,
               d_head: usize) -> Self {
        assert!(n_layers >= 1, "cache needs at least one layer");
        let n = n_layers * max_seq * n_heads * d_head;
        KvCache {
            n_layers,
            max_seq,
            n_heads,
            d_head,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    fn layer_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    /// Layer `layer`'s full padded K bank `[S, H, Dh]` (what the layer's
    /// `attn_decode` takes as its cache input).
    pub fn layer_k(&self, layer: usize) -> &[f32] {
        let n = self.layer_elems();
        &self.k[layer * n..(layer + 1) * n]
    }

    pub fn layer_v(&self, layer: usize) -> &[f32] {
        let n = self.layer_elems();
        &self.v[layer * n..(layer + 1) * n]
    }

    /// Seed from a prefill's per-layer K/V outputs (each a padded
    /// `[S, H, Dh]` buffer, `valid` rows meaningful).
    pub fn seed<R: AsRef<[f32]>>(&mut self, ks: &[R], vs: &[R],
                                 valid: usize) {
        assert_eq!(ks.len(), self.n_layers, "layer count mismatch");
        assert_eq!(vs.len(), self.n_layers, "layer count mismatch");
        assert!(valid <= self.max_seq);
        let n = self.layer_elems();
        for (layer, (k, v)) in ks.iter().zip(vs).enumerate() {
            let (k, v) = (k.as_ref(), v.as_ref());
            assert_eq!(k.len(), n, "k buffer shape mismatch");
            assert_eq!(v.len(), n, "v buffer shape mismatch");
            self.k[layer * n..(layer + 1) * n].copy_from_slice(k);
            self.v[layer * n..(layer + 1) * n].copy_from_slice(v);
        }
        self.len = valid;
    }

    /// Append one decode step's K/V rows (`[1, H, Dh]` per layer; any
    /// `AsRef<[f32]>` row — owned buffers or borrowed pool slices — so
    /// hot-path callers never clone).
    pub fn append<R: AsRef<[f32]>>(&mut self, k_rows: &[R], v_rows: &[R]) {
        assert_eq!(k_rows.len(), self.n_layers, "layer count mismatch");
        assert_eq!(v_rows.len(), self.n_layers, "layer count mismatch");
        assert!(self.len < self.max_seq, "KV cache full");
        let r = self.row_elems();
        let n = self.layer_elems();
        for (layer, (k_row, v_row)) in k_rows.iter().zip(v_rows).enumerate()
        {
            let (k_row, v_row) = (k_row.as_ref(), v_row.as_ref());
            assert_eq!(k_row.len(), r, "k row shape mismatch");
            assert_eq!(v_row.len(), r, "v row shape mismatch");
            let off = layer * n + self.len * r;
            self.k[off..off + r].copy_from_slice(k_row);
            self.v[off..off + r].copy_from_slice(v_row);
        }
        self.len += 1;
    }

    pub fn row_k(&self, layer: usize, pos: usize) -> &[f32] {
        let r = self.row_elems();
        let off = layer * self.layer_elems() + pos * r;
        &self.k[off..off + r]
    }

    /// Bytes written per generated token *per layer* on the paper's chip
    /// (K + V rows at 8-bit I/O precision).
    pub fn bytes_per_token_write(n_heads: usize, d_head: usize) -> u64 {
        2 * (n_heads * d_head) as u64
    }

    /// Bytes read per decode step *per layer* at context length `l`
    /// (stream all cached K and V rows).
    pub fn bytes_read_at(n_heads: usize, d_head: usize, l: usize) -> u64 {
        2 * (n_heads * d_head) as u64 * l as u64
    }
}

/// Pooled per-slot, per-layer KV storage for the slot-batched serving
/// engine.
///
/// One contiguous pair of `[L, B, S, H, Dh]` buffers instead of B separate
/// [`KvCache`]s: layer `l`'s bank (`layer_k(l)` / `layer_v(l)`) is exactly
/// the `[B, S, H, Dh]` tensor the layer's `attn_decode_batch` artifact
/// takes as its cache inputs, so a batch step borrows each bank directly —
/// zero copies at every depth.  Slots are recycled between requests with
/// [`KvPool::reset_slot`].
#[derive(Debug, Clone)]
pub struct KvPool {
    n_layers: usize,
    slots: usize,
    max_seq: usize,
    n_heads: usize,
    d_head: usize,
    len: Vec<usize>,
    /// [n_layers, slots, max_seq, n_heads, d_head] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPool {
    pub fn new(n_layers: usize, slots: usize, max_seq: usize,
               n_heads: usize, d_head: usize) -> Self {
        assert!(n_layers >= 1, "pool needs at least one layer");
        assert!(slots >= 1, "pool needs at least one slot");
        let n = n_layers * slots * max_seq * n_heads * d_head;
        KvPool {
            n_layers,
            slots,
            max_seq,
            n_heads,
            d_head,
            len: vec![0; slots],
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    fn slot_elems(&self) -> usize {
        self.max_seq * self.row_elems()
    }

    fn layer_elems(&self) -> usize {
        self.slots * self.slot_elems()
    }

    /// Valid rows of `slot` (shared by all layers).
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    pub fn is_empty(&self, slot: usize) -> bool {
        self.len[slot] == 0
    }

    /// Layer `layer`'s pooled K bank `[B, S, H, Dh]` — the batched decode
    /// artifact's cache input for that layer.
    pub fn layer_k(&self, layer: usize) -> &[f32] {
        let n = self.layer_elems();
        &self.k[layer * n..(layer + 1) * n]
    }

    pub fn layer_v(&self, layer: usize) -> &[f32] {
        let n = self.layer_elems();
        &self.v[layer * n..(layer + 1) * n]
    }

    /// One slot's padded K bank `[S, H, Dh]` at `layer` (single-token
    /// fallback path).
    pub fn slot_k(&self, layer: usize, slot: usize) -> &[f32] {
        let off = layer * self.layer_elems() + slot * self.slot_elems();
        &self.k[off..off + self.slot_elems()]
    }

    pub fn slot_v(&self, layer: usize, slot: usize) -> &[f32] {
        let off = layer * self.layer_elems() + slot * self.slot_elems();
        &self.v[off..off + self.slot_elems()]
    }

    /// Seed `slot` from a prefill's per-layer padded K/V outputs
    /// (`[S, H, Dh]` each, `valid` rows meaningful).
    pub fn seed_slot<R: AsRef<[f32]>>(&mut self, slot: usize, ks: &[R],
                                      vs: &[R], valid: usize) {
        assert_eq!(ks.len(), self.n_layers, "layer count mismatch");
        assert_eq!(vs.len(), self.n_layers, "layer count mismatch");
        assert!(valid <= self.max_seq);
        let n = self.slot_elems();
        for (layer, (k, v)) in ks.iter().zip(vs).enumerate() {
            let (k, v) = (k.as_ref(), v.as_ref());
            assert_eq!(k.len(), n, "k buffer shape mismatch");
            assert_eq!(v.len(), n, "v buffer shape mismatch");
            let off = layer * self.layer_elems() + slot * n;
            self.k[off..off + n].copy_from_slice(k);
            self.v[off..off + n].copy_from_slice(v);
        }
        self.len[slot] = valid;
    }

    /// Append one decode step's K/V rows (`[1, H, Dh]` per layer; any
    /// `AsRef<[f32]>` row, so the batched commit passes borrowed slices
    /// of the dispatch outputs without cloning) to `slot`.
    pub fn append_slot<R: AsRef<[f32]>>(&mut self, slot: usize,
                                        k_rows: &[R], v_rows: &[R]) {
        assert_eq!(k_rows.len(), self.n_layers, "layer count mismatch");
        assert_eq!(v_rows.len(), self.n_layers, "layer count mismatch");
        assert!(self.len[slot] < self.max_seq, "KV slot full");
        let r = self.row_elems();
        for (layer, (k_row, v_row)) in k_rows.iter().zip(v_rows).enumerate()
        {
            let (k_row, v_row) = (k_row.as_ref(), v_row.as_ref());
            assert_eq!(k_row.len(), r, "k row shape mismatch");
            assert_eq!(v_row.len(), r, "v row shape mismatch");
            let off = layer * self.layer_elems()
                + slot * self.slot_elems()
                + self.len[slot] * r;
            self.k[off..off + r].copy_from_slice(k_row);
            self.v[off..off + r].copy_from_slice(v_row);
        }
        self.len[slot] += 1;
    }

    /// Recycle `slot` for a new request.  Zeroes every layer's region so a
    /// stale session can never leak rows into the next one through the
    /// padded region the batched artifacts read.
    pub fn reset_slot(&mut self, slot: usize) {
        let n = self.slot_elems();
        for layer in 0..self.n_layers {
            let off = layer * self.layer_elems() + slot * n;
            self.k[off..off + n].fill(0.0);
            self.v[off..off + n].fill(0.0);
        }
        self.len[slot] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_and_append() {
        let mut c = KvCache::new(1, 4, 2, 3);
        let mut k = vec![0.0; 4 * 6];
        let v = vec![0.5; 4 * 6];
        k[0] = 1.0; // token 0, head 0, dim 0
        c.seed(&[k], &[v], 2);
        assert_eq!(c.len(), 2);
        c.append(&[vec![9.0; 6]], &[vec![8.0; 6]]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.row_k(0, 2), &[9.0; 6]);
        assert_eq!(c.row_k(0, 0)[0], 1.0);
    }

    #[test]
    fn layers_are_independent_banks() {
        let mut c = KvCache::new(3, 4, 1, 2);
        let ks: Vec<Vec<f32>> =
            (0..3).map(|l| vec![l as f32 + 1.0; 4 * 2]).collect();
        let vs = ks.clone();
        c.seed(&ks, &vs, 1);
        for l in 0..3 {
            assert_eq!(c.layer_k(l)[0], l as f32 + 1.0);
            assert_eq!(c.layer_k(l).len(), 4 * 2);
        }
        c.append(
            &(0..3).map(|l| vec![10.0 * (l as f32 + 1.0); 2]).collect::<Vec<_>>(),
            &(0..3).map(|_| vec![0.0; 2]).collect::<Vec<_>>(),
        );
        assert_eq!(c.len(), 2);
        assert_eq!(c.row_k(2, 1), &[30.0, 30.0]);
        assert_eq!(c.row_k(0, 1), &[10.0, 10.0]);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1, 1);
        c.append(&[vec![1.0]], &[vec![1.0]]);
        c.append(&[vec![2.0]], &[vec![2.0]]);
    }

    #[test]
    fn traffic_accounting() {
        // Llama-MoE dims: 32 heads x 128 = 4096 per row, K+V = 8192 B/token
        assert_eq!(KvCache::bytes_per_token_write(32, 128), 8192);
        assert_eq!(KvCache::bytes_read_at(32, 128, 40), 8192 * 40);
        assert_eq!(KvCache::bytes_read_at(32, 128, 0), 0);
    }

    #[test]
    fn buffers_padded_to_max() {
        let c = KvCache::new(2, 96, 4, 64);
        assert_eq!(c.layer_k(0).len(), 96 * 4 * 64);
        assert_eq!(c.layer_k(1).len(), 96 * 4 * 64);
        assert!(c.is_empty());
    }

    #[test]
    fn pool_slots_are_independent() {
        let mut p = KvPool::new(1, 3, 4, 2, 3);
        assert_eq!(p.layer_k(0).len(), 3 * 4 * 6);
        let mut k = vec![0.0; 4 * 6];
        k[0] = 2.0;
        let v = vec![0.5; 4 * 6];
        p.seed_slot(1, &[k], &[v], 2);
        assert_eq!(p.len(1), 2);
        assert_eq!(p.len(0), 0);
        p.append_slot(1, &[vec![9.0; 6]], &[vec![8.0; 6]]);
        assert_eq!(p.len(1), 3);
        // slot 1's view matches what was written; slot 0 untouched
        assert_eq!(p.slot_k(0, 1)[0], 2.0);
        assert_eq!(p.slot_k(0, 1)[2 * 6], 9.0);
        assert!(p.slot_k(0, 0).iter().all(|&x| x == 0.0));
        // a layer bank is the slots concatenated
        let n = 4 * 6;
        assert_eq!(&p.layer_k(0)[n..2 * n], p.slot_k(0, 1));
    }

    #[test]
    fn pool_layer_banks_are_contiguous_slot_major() {
        let mut p = KvPool::new(2, 2, 2, 1, 2);
        p.seed_slot(0, &[vec![1.0; 4], vec![2.0; 4]],
                    &[vec![0.0; 4], vec![0.0; 4]], 1);
        p.seed_slot(1, &[vec![3.0; 4], vec![4.0; 4]],
                    &[vec![0.0; 4], vec![0.0; 4]], 1);
        // layer 0 bank = [slot0 @ l0, slot1 @ l0]
        assert_eq!(p.layer_k(0), &[1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0, 3.0]);
        assert_eq!(p.layer_k(1), &[2.0, 2.0, 2.0, 2.0, 4.0, 4.0, 4.0, 4.0]);
        assert_eq!(p.slot_k(1, 1), &[4.0; 4]);
    }

    #[test]
    fn pool_reset_zeroes_slot_across_layers() {
        let mut p = KvPool::new(2, 2, 2, 1, 2);
        p.append_slot(0, &[vec![1.0, 2.0], vec![1.5, 2.5]],
                      &[vec![3.0, 4.0], vec![3.5, 4.5]]);
        p.append_slot(1, &[vec![5.0, 6.0], vec![5.5, 6.5]],
                      &[vec![7.0, 8.0], vec![7.5, 8.5]]);
        p.reset_slot(0);
        assert_eq!(p.len(0), 0);
        for l in 0..2 {
            assert!(p.slot_k(l, 0).iter().all(|&x| x == 0.0));
            assert!(p.slot_v(l, 0).iter().all(|&x| x == 0.0));
        }
        // neighbouring slot unaffected on every layer
        assert_eq!(p.slot_k(0, 1)[0], 5.0);
        assert_eq!(p.slot_k(1, 1)[0], 5.5);
        assert_eq!(p.len(1), 1);
    }

    #[test]
    #[should_panic(expected = "KV slot full")]
    fn pool_overflow_panics() {
        let mut p = KvPool::new(1, 1, 1, 1, 1);
        p.append_slot(0, &[vec![1.0]], &[vec![1.0]]);
        p.append_slot(0, &[vec![2.0]], &[vec![2.0]]);
    }
}
