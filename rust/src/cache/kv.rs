//! KV cache: per-sequence key/value buffers for attention decode.
//!
//! The coordinator owns one [`KvCache`] per live sequence; the
//! `attn_decode` executable receives the full (padded) buffers plus the
//! write position and returns the new token's K/V rows, which the
//! coordinator writes back — mirroring the DRAM-resident cache of the
//! paper's chip, where the PIM die streams K/V in per step.

/// Functional KV buffer of one sequence, padded to `max_seq`.
#[derive(Debug, Clone)]
pub struct KvCache {
    max_seq: usize,
    n_heads: usize,
    d_head: usize,
    len: usize,
    /// [max_seq, n_heads, d_head] row-major
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(max_seq: usize, n_heads: usize, d_head: usize) -> Self {
        let n = max_seq * n_heads * d_head;
        KvCache {
            max_seq,
            n_heads,
            d_head,
            len: 0,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.d_head
    }

    /// Full padded buffers (what `attn_decode` takes as inputs).
    pub fn k_buf(&self) -> &[f32] {
        &self.k
    }

    pub fn v_buf(&self) -> &[f32] {
        &self.v
    }

    /// Seed from a prefill's K/V outputs (padded [max_seq, H, Dh] buffers,
    /// `valid` rows meaningful).
    pub fn seed(&mut self, k: &[f32], v: &[f32], valid: usize) {
        assert_eq!(k.len(), self.k.len(), "k buffer shape mismatch");
        assert_eq!(v.len(), self.v.len(), "v buffer shape mismatch");
        assert!(valid <= self.max_seq);
        self.k.copy_from_slice(k);
        self.v.copy_from_slice(v);
        self.len = valid;
    }

    /// Append one decode step's K/V rows ([1, H, Dh] each).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        let r = self.row_elems();
        assert_eq!(k_row.len(), r, "k row shape mismatch");
        assert_eq!(v_row.len(), r, "v row shape mismatch");
        assert!(self.len < self.max_seq, "KV cache full");
        let off = self.len * r;
        self.k[off..off + r].copy_from_slice(k_row);
        self.v[off..off + r].copy_from_slice(v_row);
        self.len += 1;
    }

    pub fn row_k(&self, pos: usize) -> &[f32] {
        let r = self.row_elems();
        &self.k[pos * r..(pos + 1) * r]
    }

    /// Bytes written per generated token on the paper's chip (K + V rows at
    /// 8-bit I/O precision).
    pub fn bytes_per_token_write(n_heads: usize, d_head: usize) -> u64 {
        2 * (n_heads * d_head) as u64
    }

    /// Bytes read per decode step at context length `l` (stream all cached
    /// K and V rows).
    pub fn bytes_read_at(n_heads: usize, d_head: usize, l: usize) -> u64 {
        2 * (n_heads * d_head) as u64 * l as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_and_append() {
        let mut c = KvCache::new(4, 2, 3);
        let mut k = vec![0.0; 4 * 6];
        let v = vec![0.5; 4 * 6];
        k[0] = 1.0; // token 0, head 0, dim 0
        c.seed(&k, &v, 2);
        assert_eq!(c.len(), 2);
        c.append(&[9.0; 6], &[8.0; 6]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.row_k(2), &[9.0; 6]);
        assert_eq!(c.row_k(0)[0], 1.0);
    }

    #[test]
    #[should_panic(expected = "KV cache full")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1);
        c.append(&[1.0], &[1.0]);
        c.append(&[2.0], &[2.0]);
    }

    #[test]
    fn traffic_accounting() {
        // Llama-MoE dims: 32 heads x 128 = 4096 per row, K+V = 8192 B/token
        assert_eq!(KvCache::bytes_per_token_write(32, 128), 8192);
        assert_eq!(KvCache::bytes_read_at(32, 128, 40), 8192 * 40);
        assert_eq!(KvCache::bytes_read_at(32, 128, 0), 0);
    }

    #[test]
    fn buffers_padded_to_max() {
        let c = KvCache::new(96, 4, 64);
        assert_eq!(c.k_buf().len(), 96 * 4 * 64);
        assert!(c.is_empty());
    }
}
