//! Generation-stage caches (§III-C): the KV cache for attention and the
//! gate-output (GO) cache for expert-choice MoE, both resident in off-chip
//! DRAM on the paper's chip.
//!
//! Each cache plays two roles here:
//! * **functional state** for the serving coordinator (real buffers the
//!   runtime reads/writes between HLO calls);
//! * **traffic accounting** for the simulator (bytes moved per step, which
//!   the DRAM model prices).

pub mod go;
pub mod kv;

pub use go::{GoCache, GoUpdate};
pub use kv::{KvCache, KvPool};
