//! Hardware cost models derived from the HERMES/3DCIM constants in
//! [`crate::config::HardwareConfig`]: chip area under peripheral sharing,
//! and the energy price list the simulator consults.

pub mod area;
pub mod energy;
pub mod noise;

pub use area::AreaModel;
pub use energy::EnergyModel;
pub use noise::NoiseModel;
