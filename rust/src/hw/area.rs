//! Chip-area model under crossbar-level multiplexing (§III-A).
//!
//! Without sharing, every crossbar carries its own peripheral set (ADC
//! column etc.): `area = N * (xbar + periph)`.  With groups of `g` experts
//! sharing peripherals, each group of `g` corresponding crossbars keeps one
//! peripheral set: `area = N * xbar + (N / g) * periph` — the area win that
//! motivates the whole design, bought with the structural contention the
//! scheduler manages.
//!
//! Reported area covers the MoE linear cores only, 2-D layout, matching the
//! paper's evaluation scope (§IV-A: "we report only the MoE linear cores,
//! excluding off-chip DRAM and the digital part").

use crate::config::HardwareConfig;
use crate::moe::LayerLayout;

#[derive(Debug, Clone)]
pub struct AreaModel {
    hw: HardwareConfig,
}

impl AreaModel {
    pub fn new(hw: &HardwareConfig) -> Self {
        AreaModel { hw: hw.clone() }
    }

    /// MoE-linear-cores area for one layer with `group_size` experts per
    /// peripheral group, mm².
    pub fn moe_area_mm2(&self, layout: &LayerLayout, group_size: usize)
        -> f64 {
        assert!(group_size >= 1);
        assert_eq!(
            layout.n_experts % group_size,
            0,
            "expert count must divide by group size"
        );
        let n = layout.total_xbars() as f64;
        n * self.hw.xbar_area_mm2()
            + (n / group_size as f64) * self.hw.periph_area_mm2()
    }

    /// Area saving factor vs the unshared baseline (>= 1).
    pub fn saving_vs_baseline(&self, layout: &LayerLayout, group_size: usize)
        -> f64 {
        self.moe_area_mm2(layout, 1) / self.moe_area_mm2(layout, group_size)
    }

    /// Silicon cost of replicating *one* expert group onto another
    /// shard, mm²: the layer's shared-peripheral area divided over its
    /// `n_experts / group_size` groups.  This is what the placement
    /// replication ledger charges per hot-group replica.
    pub fn group_replica_area_mm2(
        &self, layout: &LayerLayout, group_size: usize,
    ) -> f64 {
        let groups = (layout.n_experts / group_size.max(1)).max(1);
        self.moe_area_mm2(layout, group_size) / groups as f64
    }

    /// Area charged to the preemption checkpoint store when it holds
    /// `peak` simultaneous slot snapshots, mm².  One snapshot fits in a
    /// slot's own banks (free); each one beyond that needs a spill copy
    /// sized like one expert's crossbar complement (no peripherals —
    /// spill banks are storage, not compute).
    pub fn checkpoint_spill_mm2(&self, layout: &LayerLayout, peak: usize)
        -> f64 {
        peak.saturating_sub(1) as f64
            * layout.xbars_per_expert() as f64
            * self.hw.xbar_area_mm2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MoeModelConfig;

    fn paper_layout() -> (AreaModel, LayerLayout) {
        let hw = HardwareConfig::paper();
        let layout =
            LayerLayout::new(&MoeModelConfig::llama_moe_4_16(), &hw);
        (AreaModel::new(&hw), layout)
    }

    #[test]
    fn baseline_area_is_full_cores() {
        let (a, l) = paper_layout();
        // 1536 crossbars * 0.635 mm² = 975.36 mm²
        assert!((a.moe_area_mm2(&l, 1) - 1536.0 * 0.635).abs() < 1e-6);
    }

    #[test]
    fn sharing_shrinks_area_monotonically() {
        let (a, l) = paper_layout();
        let a1 = a.moe_area_mm2(&l, 1);
        let a2 = a.moe_area_mm2(&l, 2);
        let a4 = a.moe_area_mm2(&l, 4);
        assert!(a1 > a2 && a2 > a4);
        // g=2 removes half the peripherals: expected 1536*(0.254+0.381/2)
        assert!((a2 - 1536.0 * (0.254 + 0.381 / 2.0)).abs() < 1e-6);
    }

    #[test]
    fn saving_bounded_by_periph_share() {
        let (a, l) = paper_layout();
        // as g -> inf the saving tends to 1/xbar_ratio = 2.5x; g=4 must be
        // below that and above g=2's saving
        let s2 = a.saving_vs_baseline(&l, 2);
        let s4 = a.saving_vs_baseline(&l, 4);
        assert!(s2 > 1.0 && s4 > s2 && s4 < 2.5);
    }

    #[test]
    fn isaac_ratio_amplifies_saving() {
        let hw = HardwareConfig::isaac_ratio();
        let layout = LayerLayout::new(&MoeModelConfig::llama_moe_4_16(), &hw);
        let a = AreaModel::new(&hw);
        // with 5% crossbar share, g=4 saving approaches 4x-ish
        let s4 = a.saving_vs_baseline(&layout, 4);
        assert!(s4 > 2.5, "saving {s4}");
        assert!(s4 < 4.0);
    }

    #[test]
    #[should_panic]
    fn indivisible_group_panics() {
        let (a, l) = paper_layout();
        a.moe_area_mm2(&l, 5);
    }

    #[test]
    fn replica_cost_is_one_group_share() {
        let (a, l) = paper_layout();
        // 16 experts / g=2 → 8 groups, so 8 replicas cost one layer
        let per = a.group_replica_area_mm2(&l, 2);
        assert!((per * 8.0 - a.moe_area_mm2(&l, 2)).abs() < 1e-9);
        assert!(per > 0.0);
    }

    #[test]
    fn checkpoint_spill_first_snapshot_is_free() {
        let (a, l) = paper_layout();
        assert_eq!(a.checkpoint_spill_mm2(&l, 0), 0.0);
        assert_eq!(a.checkpoint_spill_mm2(&l, 1), 0.0);
        // each extra snapshot costs one expert's crossbars, no periph:
        // 96 xbars/expert * 0.254 mm²
        let one = a.checkpoint_spill_mm2(&l, 2);
        assert!((one - 96.0 * 0.254).abs() < 1e-9);
        assert!((a.checkpoint_spill_mm2(&l, 4) - 3.0 * one).abs() < 1e-9);
    }
}
