//! Energy price list used by the simulator: PIM core activations, on-chip
//! activation broadcasts, DRAM cache traffic, and digital-unit work — all
//! in nanojoules, all derived from [`crate::config::HardwareConfig`].

use crate::config::HardwareConfig;

#[derive(Debug, Clone)]
pub struct EnergyModel {
    hw: HardwareConfig,
}

impl EnergyModel {
    pub fn new(hw: &HardwareConfig) -> Self {
        EnergyModel { hw: hw.clone() }
    }

    /// Energy of `n` core activations (MVM rounds), nJ.
    pub fn activations_nj(&self, n: u64) -> f64 {
        n as f64 * self.hw.core_energy_nj()
    }

    /// Energy of `n` activation-vector broadcasts of `d_model` 8-bit
    /// elements into group DACs, nJ.
    pub fn transfers_nj(&self, n: u64, d_model: usize) -> f64 {
        n as f64 * d_model as f64 * self.hw.input_nj_per_byte
    }

    /// DRAM traffic energy, nJ.
    pub fn dram_nj(&self, bytes: u64) -> f64 {
        self.hw.dram.transfer(bytes).1
    }

    /// DRAM traffic latency, ns.
    pub fn dram_ns(&self, bytes: u64) -> f64 {
        self.hw.dram.transfer(bytes).0
    }

    /// Attention on the digital units: (ns, nJ) for processing `tokens`
    /// tokens at context length `ctx` (3DCIM polynomial fit, DESIGN.md §8).
    pub fn attention(&self, tokens: usize, ctx: usize) -> (f64, f64) {
        let d = &self.hw.digital;
        let t = tokens as f64;
        let c = ctx as f64;
        (
            t * (d.attn_ns_per_token + d.attn_ns_per_token_ctx * c),
            t * (d.attn_nj_per_token + d.attn_nj_per_token_ctx * c),
        )
    }

    /// Re-processing `tokens` *past* tokens whose K/V is already cached
    /// (the no-GO decode path must rebuild every retained token's hidden
    /// state for the gate): the per-token constant shrinks by
    /// `kv_reuse_factor` (projections reused), the attend term remains.
    pub fn attention_cached_recompute(&self, tokens: usize, ctx: usize)
        -> (f64, f64) {
        let d = &self.hw.digital;
        let t = tokens as f64;
        let c = ctx as f64;
        (
            t * (d.kv_reuse_factor * d.attn_ns_per_token
                + d.attn_ns_per_token_ctx * c),
            t * (d.kv_reuse_factor * d.attn_nj_per_token
                + d.attn_nj_per_token_ctx * c),
        )
    }

    /// Gate MVM + routing decision for `tokens` tokens: (ns, nJ).
    pub fn gate(&self, tokens: usize) -> (f64, f64) {
        let d = &self.hw.digital;
        let t = tokens as f64;
        (
            t * (d.gate_ns_per_token + d.route_ns_per_token),
            t * (d.gate_nj_per_token + d.route_nj_per_token),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> EnergyModel {
        EnergyModel::new(&HardwareConfig::paper())
    }

    #[test]
    fn activation_energy_paper_value() {
        assert!((m().activations_nj(1) - 12.48).abs() < 1e-9);
        assert!((m().activations_nj(96) - 96.0 * 12.48).abs() < 1e-6);
    }

    #[test]
    fn attention_scales_with_context() {
        let (l1, e1) = m().attention(1, 32);
        let (l2, e2) = m().attention(1, 64);
        assert!(l2 > l1 && e2 > e1);
        let (l3, e3) = m().attention(2, 32);
        assert!((l3 - 2.0 * l1).abs() < 1e-9);
        assert!((e3 - 2.0 * e1).abs() < 1e-9);
    }

    #[test]
    fn zero_work_is_free() {
        assert_eq!(m().activations_nj(0), 0.0);
        assert_eq!(m().transfers_nj(0, 4096), 0.0);
        assert_eq!(m().dram_nj(0), 0.0);
        let (l, e) = m().attention(0, 100);
        assert_eq!((l, e), (0.0, 0.0));
    }

    #[test]
    fn transfer_energy_linear_in_width() {
        let e1 = m().transfers_nj(10, 2048);
        let e2 = m().transfers_nj(10, 4096);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }
}
