//! Analog noise model — the paper's stated future work ("Future works
//! focus on hardware-aware software design and noise analysis"), built
//! here as an extension.
//!
//! PCM crossbars suffer programming noise, conductance drift and read
//! noise.  Following the HERMES characterisation [17-19] we model the
//! *effective* per-MVM output perturbation as zero-mean Gaussian whose
//! stddev is a fraction of the per-slice analog full-scale, growing with
//! time since programming (drift):
//!
//! `sigma(t) = sigma0 * (1 + drift_rate * log10(1 + t_hours))`
//!
//! Two consumers:
//! * the L1 kernel mirror (`python/compile/kernels/crossbar.py` accepts a
//!   `noise_std` for noisy-inference studies; the seeds differ so only the
//!   *statistics* are comparable);
//! * [`NoiseModel::expected_snr_db`] and the accuracy-proxy sweep in
//!   `eval::ablation`, which report how much routing decisions move under
//!   noise — the metric that matters for MoE, since a flipped gate
//!   decision changes *which experts run*, not just output quality.

use crate::util::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct NoiseModel {
    /// read-noise stddev as a fraction of the ADC step at t=0
    pub sigma0_adc_steps: f64,
    /// drift growth per decade of hours
    pub drift_rate: f64,
    /// hours since cell programming
    pub t_hours: f64,
}

impl NoiseModel {
    /// HERMES-class defaults: ~0.4 ADC steps of read noise, mild drift.
    pub fn hermes() -> Self {
        NoiseModel { sigma0_adc_steps: 0.4, drift_rate: 0.3, t_hours: 0.0 }
    }

    pub fn noiseless() -> Self {
        NoiseModel { sigma0_adc_steps: 0.0, drift_rate: 0.0, t_hours: 0.0 }
    }

    /// Effective noise stddev in ADC steps at the configured drift time.
    pub fn sigma_adc_steps(&self) -> f64 {
        self.sigma0_adc_steps
            * (1.0 + self.drift_rate * (1.0 + self.t_hours).log10())
    }

    /// Expected output SNR of one crossbar MVM, dB, given the typical
    /// signal magnitude in ADC steps (per-column ranged readout keeps the
    /// signal at ~1/3 of the clip range, i.e. ~42 steps for 8-bit).
    pub fn expected_snr_db(&self, signal_adc_steps: f64) -> f64 {
        let sigma = self.sigma_adc_steps();
        if sigma == 0.0 {
            f64::INFINITY
        } else {
            20.0 * (signal_adc_steps / sigma).log10()
        }
    }

    /// Perturb a gate-score row in place (scores are post-MVM digital
    /// values; `score_scale` converts one ADC step into score units).
    /// Deterministic per (seed, token).
    pub fn perturb_scores(&self, scores: &mut [f32], score_scale: f64,
                          seed: u64, token: usize) {
        let sigma = self.sigma_adc_steps() * score_scale;
        if sigma == 0.0 {
            return;
        }
        let mut rng = Pcg32::new(seed ^ ((token as u64) << 20));
        for s in scores.iter_mut() {
            *s += (rng.gen_normal() * sigma) as f32;
        }
    }

    /// Fraction of expert-choice routing decisions that flip under noise,
    /// estimated over `trials` random score matrices — the MoE-specific
    /// noise metric (a flipped decision redirects a token to a different
    /// expert).
    pub fn routing_flip_rate(&self, tokens: usize, experts: usize,
                             capacity: usize, score_scale: f64,
                             trials: usize, seed: u64) -> f64 {
        use crate::moe::gate::expert_choice_route;
        let mut rng = Pcg32::new(seed);
        let mut flips = 0usize;
        let mut total = 0usize;
        for trial in 0..trials {
            let clean: Vec<f32> = (0..tokens * experts)
                .map(|_| rng.gen_normal() as f32)
                .collect();
            let mut noisy = clean.clone();
            for t in 0..tokens {
                self.perturb_scores(
                    &mut noisy[t * experts..(t + 1) * experts],
                    score_scale,
                    seed ^ (trial as u64),
                    t,
                );
            }
            let a = expert_choice_route(&clean, tokens, experts, capacity,
                                        None);
            let b = expert_choice_route(&noisy, tokens, experts, capacity,
                                        None);
            for t in 0..tokens {
                for e in 0..experts {
                    total += 1;
                    if a.choices.get(t, e) != b.choices.get(t, e) {
                        flips += 1;
                    }
                }
            }
        }
        flips as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_is_identity() {
        let n = NoiseModel::noiseless();
        let mut s = vec![1.0f32, -2.0, 3.0];
        n.perturb_scores(&mut s, 1.0, 7, 0);
        assert_eq!(s, vec![1.0, -2.0, 3.0]);
        assert_eq!(n.expected_snr_db(42.0), f64::INFINITY);
        assert_eq!(n.routing_flip_rate(8, 4, 2, 0.1, 3, 1), 0.0);
    }

    #[test]
    fn drift_grows_sigma() {
        let mut n = NoiseModel::hermes();
        let s0 = n.sigma_adc_steps();
        n.t_hours = 1000.0;
        assert!(n.sigma_adc_steps() > s0);
    }

    #[test]
    fn snr_decreases_with_noise() {
        let quiet = NoiseModel { sigma0_adc_steps: 0.1, ..NoiseModel::hermes() };
        let loud = NoiseModel { sigma0_adc_steps: 2.0, ..NoiseModel::hermes() };
        assert!(quiet.expected_snr_db(42.0) > loud.expected_snr_db(42.0));
    }

    #[test]
    fn flip_rate_monotone_in_noise() {
        let mk = |sigma| NoiseModel {
            sigma0_adc_steps: sigma,
            drift_rate: 0.0,
            t_hours: 0.0,
        };
        let low = mk(0.05).routing_flip_rate(16, 8, 4, 0.05, 8, 3);
        let high = mk(3.0).routing_flip_rate(16, 8, 4, 0.05, 8, 3);
        assert!(high > low, "{low} !< {high}");
        assert!(low < 0.25);
        assert!(high <= 1.0);
    }

    #[test]
    fn perturb_deterministic_per_token() {
        let n = NoiseModel::hermes();
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        n.perturb_scores(&mut a, 1.0, 5, 3);
        n.perturb_scores(&mut b, 1.0, 5, 3);
        assert_eq!(a, b);
        let mut c = vec![0.0f32; 8];
        n.perturb_scores(&mut c, 1.0, 5, 4);
        assert_ne!(a, c);
    }
}
