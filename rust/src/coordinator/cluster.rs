//! Cluster front door: N concurrently-live [`Server`] backends behind
//! one intake queue and a placement thread.
//!
//! Each backend is a full serving stack — its own router thread, its own
//! [`crate::runtime::Runtime`] and PJRT client (constructed inside the
//! thread; see `runtime::executor` for the concurrency contract) — so
//! the N shards decode genuinely in parallel.  The front door adds three
//! things on top of bare servers:
//!
//! * **Live placement.**  A dedicated placement thread assigns each
//!   arrival to a backend using the backends' live
//!   [`LoadSignal::inflight`] counters (queue depth + outstanding
//!   slots), not the split-time analytic estimates of
//!   [`crate::workload::PlacementPolicy::LeastOutstanding`] — the
//!   static estimate assumes service starts at arrival and never sees
//!   queueing feedback; the live signal *is* the queueing feedback.
//!   The per-submission candidate rule is the
//!   [`crate::placement::LivePlacer`] shared with the virtual mirror;
//!   [`ClusterPlacement::Dynamic`] adds the front-door half of the
//!   dynamic control loop (hold-while-saturated, periodic re-placement
//!   of held entries counted as [`ClusterStats::migrations`]).
//! * **Backpressure.**  The intake queue is bounded
//!   ([`ClusterOptions::intake_cap`]); a submitter that finds it full
//!   blocks until the placement thread drains — arrival pressure
//!   propagates to producers instead of growing an unbounded buffer.
//! * **Load shedding.**  With [`ClusterOptions::shed_depth`] > 0, an
//!   arrival that finds *every* backend saturated (in-flight ≥ slots +
//!   `shed_depth`) is answered immediately with a terminal `overloaded`
//!   error instead of queueing — the caller learns *now*, and interactive
//!   latency for admitted requests stays bounded.  Sheds are counted
//!   per candidate shard in [`ClusterStats::shed`].
//!
//! Reply delivery is per-request and direct: the placement thread hands
//! the caller's reply channel to the placed backend, so streamed tokens
//! ([`crate::coordinator::Reply::Token`]) flow router-thread → caller
//! without re-crossing the front door.
//!
//! ```text
//!   callers ──submit()──▶ bounded intake ──▶ placement thread
//!                                               │ argmin inflight / RR
//!                 ┌─────────────────────────────┼──────────────┐
//!                 ▼                             ▼              ▼
//!           Server shard 0               Server shard 1   … shard N-1
//!           router thread                router thread
//!           Runtime+PJRT client          Runtime+PJRT client
//!                 │                             │
//!                 └──── per-request reply channels ───▶ callers
//! ```
//!
//! Shutdown (dropping the [`Cluster`]) drops every backend in turn; each
//! backend's router terminally answers everything still waiting, filling,
//! or live, so no reply channel is ever left dangling (the exactly-once
//! pin in `rust/tests/cluster_concurrent.rs`).

use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::placement::LivePlacer;

use crate::coordinator::server::{
    LoadSignal, Reply, ReplyTo, Request, Response, Server, ServerOptions,
    ServerStats,
};
use crate::obs::sink::{TraceShard, TraceSink};
use crate::obs::span::{now_ns, EventKind, SpanOutcome};

/// Intake bound used when [`ClusterOptions::intake_cap`] is 0: deep
/// enough that open-loop drivers never block in steady state, finite so
/// a stalled placement thread surfaces as backpressure instead of
/// unbounded memory growth.
pub const DEFAULT_INTAKE_CAP: usize = 1024;

/// How the placement thread assigns arrivals to backends.
///
/// Distinct from [`crate::workload::PlacementPolicy`], which partitions
/// a *known* request list ahead of time from analytic cost estimates:
/// a `ClusterPlacement` decides per arrival, online, from live signals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPlacement {
    /// arrival k goes to shard k mod N — the live counterpart of
    /// [`crate::workload::PlacementPolicy::RoundRobin`]; with shedding
    /// off it reproduces the static round-robin split exactly, which is
    /// what makes concurrent-vs-serial equivalence testable
    RoundRobin,
    /// each arrival goes to the backend with the fewest in-flight
    /// requests right now ([`LoadSignal::inflight`]; ties to the lowest
    /// shard id) — the live control loop that replaces
    /// `PlacementPolicy::LeastOutstanding`'s split-time estimates
    LiveLeastOutstanding,
    /// live-least-outstanding placement plus the front-door half of the
    /// dynamic control loop (see `crate::placement`): while *every*
    /// backend is saturated (in-flight > slots), arrivals are held at
    /// the front door instead of committing to a shard's queue; every
    /// `rebalance_every` arrivals the held entries are re-placed against
    /// the live signals (each provisional-target change is a counted
    /// migration, traced as a `migrate` event), and entries forward the
    /// moment any backend frees capacity.  With no saturation it behaves
    /// exactly like [`ClusterPlacement::LiveLeastOutstanding`]
    Dynamic {
        /// arrivals between re-placement passes over the held entries
        /// (floored to 1)
        rebalance_every: usize,
    },
}

impl ClusterPlacement {
    /// Stable label for reports and artifact filenames.
    pub fn label(&self) -> &'static str {
        match self {
            ClusterPlacement::RoundRobin => "round-robin",
            ClusterPlacement::LiveLeastOutstanding => {
                "live-least-outstanding"
            }
            ClusterPlacement::Dynamic { .. } => "dynamic",
        }
    }

    /// Parse a CLI spelling (`"rr"`/`"round-robin"`,
    /// `"live"`/`"live-least-outstanding"`/`"live-lo"`,
    /// `"dynamic"` — the default rebalance cadence; pair with
    /// `--rebalance-every` to override it).
    pub fn parse(s: &str) -> Option<ClusterPlacement> {
        match s {
            "rr" | "round-robin" => Some(ClusterPlacement::RoundRobin),
            "live" | "live-least-outstanding" | "live-lo" => {
                Some(ClusterPlacement::LiveLeastOutstanding)
            }
            "dynamic" => {
                Some(ClusterPlacement::Dynamic { rebalance_every: 16 })
            }
            _ => None,
        }
    }
}

/// Spawn-time configuration for a [`Cluster`].
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// backend count (floored to 1)
    pub shards: usize,
    /// per-backend [`ServerOptions`]; the cluster overrides
    /// [`ServerOptions::shard`] with each backend's index
    pub server: ServerOptions,
    /// arrival-to-backend assignment policy
    pub placement: ClusterPlacement,
    /// intake queue bound (`0`: [`DEFAULT_INTAKE_CAP`]); submitters
    /// block while the queue is full — this is the backpressure surface
    pub intake_cap: usize,
    /// all-shards saturation threshold for load shedding: an arrival is
    /// shed iff every backend has in-flight ≥ its slots + `shed_depth`
    /// (`0`: never shed)
    pub shed_depth: usize,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            shards: 2,
            server: ServerOptions::default(),
            placement: ClusterPlacement::LiveLeastOutstanding,
            intake_cap: 0,
            shed_depth: 0,
        }
    }
}

/// Cluster-wide telemetry snapshot: every backend's [`ServerStats`] plus
/// the front door's own counters.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// per-backend serving stats, indexed by shard id
    pub shards: Vec<ServerStats>,
    /// requests the placement thread forwarded to each backend
    pub placed: Vec<u64>,
    /// requests shed at the front door, attributed to the shard that
    /// would have received them (per-backend `queue_cap` sheds are in
    /// `shards[i].shed_requests` instead)
    pub shed: Vec<u64>,
    /// high-water mark of the intake queue depth
    pub peak_intake_depth: usize,
    /// front-door-held arrivals whose target shard changed in a
    /// re-placement pass (0 unless the cluster runs
    /// [`ClusterPlacement::Dynamic`])
    pub migrations: u64,
    /// placement-policy label the front door runs
    /// ([`ClusterPlacement::label`]) — recorded into `moepim.trace.v1`
    /// documents (see [`crate::workload::record`])
    pub placement: String,
}

impl ClusterStats {
    /// Total requests shed anywhere in the cluster: front-door sheds
    /// plus every backend's own `queue_cap` sheds.
    pub fn shed_requests(&self) -> u64 {
        self.shed.iter().sum::<u64>()
            + self.shards.iter().map(|s| s.shed_requests).sum::<u64>()
    }
}

enum FrontMsg {
    Submit(Request, ReplyTo),
    Stats(mpsc::Sender<Result<ClusterStats>>),
    TakeTrace(mpsc::Sender<Result<Vec<TraceShard>>>),
    Shutdown,
}

/// Handle to a running cluster: the placement thread plus its N owned
/// backends.  Dropping it shuts the whole stack down (terminal replies
/// for everything in flight, then joins).
pub struct Cluster {
    tx: mpsc::SyncSender<FrontMsg>,
    depth: Arc<AtomicUsize>,
    peak: Arc<AtomicUsize>,
    shards: usize,
    handle: Option<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn `opts.shards` backends (serially — each spawn blocks on its
    /// artifact compilation) and the placement thread that owns them.
    /// Returns once every backend is serving.
    pub fn spawn(artifacts_dir: &Path, opts: ClusterOptions)
        -> Result<Cluster> {
        let n = opts.shards.max(1);
        let mut servers = Vec::with_capacity(n);
        let mut signals: Vec<Arc<LoadSignal>> = Vec::with_capacity(n);
        let mut slots = Vec::with_capacity(n);
        for shard in 0..n {
            let server = Server::spawn_opts(
                artifacts_dir.to_path_buf(),
                ServerOptions { shard: Some(shard), ..opts.server.clone() },
            )?;
            slots.push(server.stats()?.slots);
            signals.push(server.signal());
            servers.push(server);
        }
        let intake_cap = if opts.intake_cap == 0 {
            DEFAULT_INTAKE_CAP
        } else {
            opts.intake_cap
        };
        let (tx, rx) = mpsc::sync_channel::<FrontMsg>(intake_cap);
        let depth = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let thread_depth = Arc::clone(&depth);
        let thread_peak = Arc::clone(&peak);
        let placement = opts.placement;
        let shed_depth = opts.shed_depth;
        let trace = opts.server.trace;
        let handle = std::thread::spawn(move || {
            place_loop(servers, signals, slots, rx, placement, shed_depth,
                       thread_depth, thread_peak, trace);
        });
        Ok(Cluster { tx, depth, peak, shards: n, handle: Some(handle) })
    }

    /// Backend count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Submit a request; returns a receiver for the terminal
    /// [`Response`].  Blocks while the intake queue is full
    /// (backpressure).
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.send(req, ReplyTo::Terminal(tx));
        rx
    }

    /// Submit for streaming delivery: [`Reply::Token`] events from the
    /// placed backend's router thread, then exactly one
    /// [`Reply::Terminal`].  Blocks while the intake queue is full.
    pub fn submit_streaming(&self, req: Request) -> mpsc::Receiver<Reply> {
        let (tx, rx) = mpsc::channel();
        self.send(req, ReplyTo::Streaming(tx));
        rx
    }

    fn send(&self, req: Request, sink: ReplyTo) {
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(d, Ordering::Relaxed);
        self.tx
            .send(FrontMsg::Submit(req, sink))
            .expect("placement thread alive");
    }

    /// Snapshot cluster-wide telemetry (round-trips through the
    /// placement thread and every backend router).
    pub fn stats(&self) -> Result<ClusterStats> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(FrontMsg::Stats(tx))
            .map_err(|_| anyhow!("placement thread gone"))?;
        rx.recv()?
    }

    /// Drain the whole cluster's span-trace rings: the placement thread's
    /// own shard (front-door events — intake, placement, front-door
    /// sheds) followed by every backend router's shard, in shard order.
    /// Requires the cluster to have been spawned with
    /// [`ServerOptions::trace`] on `opts.server`; without it every shard
    /// comes back empty.
    pub fn take_trace(&self) -> Result<Vec<TraceShard>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(FrontMsg::TakeTrace(tx))
            .map_err(|_| anyhow!("placement thread gone"))?;
        rx.recv()?
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        let _ = self.tx.send(FrontMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Terminal `overloaded` reply issued at the front door (never reached a
/// backend, so there is no in-flight count to retire).
fn shed_reply(req: &Request, sink: ReplyTo, candidate: usize,
              shards: usize, shed_depth: usize) {
    let resp = Response {
        id: req.id,
        result: Err(format!(
            "overloaded: all {shards} shards saturated \
             (shed depth {shed_depth})"
        )),
        latency_us: 0.0,
        ttft_us: None,
        queue_us: None,
        admit_seq: None,
        batched_steps: 0,
        single_steps: 0,
        shard: Some(candidate),
    };
    match sink {
        ReplyTo::Terminal(tx) => {
            let _ = tx.send(resp);
        }
        ReplyTo::Streaming(tx) => {
            let _ = tx.send(Reply::Terminal(resp));
        }
    }
}

/// Forward every held arrival whose best shard has room, in hold order;
/// a forward to a shard other than the entry's provisional target is a
/// counted (and traced) migration.  Stops at the first entry that still
/// finds every backend saturated — the queue keeps FIFO fairness.
fn drain_pending(pending: &mut VecDeque<(Request, ReplyTo, usize)>,
                 servers: &[Server], signals: &[Arc<LoadSignal>],
                 slots: &[usize], placed: &mut [u64],
                 migrations: &mut u64, sink: &mut TraceSink) {
    let n = servers.len();
    while let Some((req, reply_sink, from)) = pending.pop_front() {
        let best = (0..n)
            .min_by_key(|&i| (signals[i].inflight(), i))
            .unwrap_or(0);
        if signals[best].inflight() > slots[best] {
            pending.push_front((req, reply_sink, from));
            break;
        }
        if best != from {
            *migrations += 1;
            if sink.enabled() {
                sink.record(now_ns(), EventKind::Migrate {
                    id: req.id,
                    from,
                    to: best,
                });
            }
        }
        placed[best] += 1;
        if sink.enabled() {
            sink.record(now_ns(), EventKind::Placed {
                id: req.id,
                shard: best,
            });
        }
        servers[best].forward(req, reply_sink);
    }
}

#[allow(clippy::too_many_arguments)]
fn place_loop(servers: Vec<Server>, signals: Vec<Arc<LoadSignal>>,
              slots: Vec<usize>, rx: mpsc::Receiver<FrontMsg>,
              placement: ClusterPlacement, shed_depth: usize,
              depth: Arc<AtomicUsize>, peak: Arc<AtomicUsize>,
              trace: bool) {
    let n = servers.len();
    let mut placer = LivePlacer::new(placement);
    let mut placed = vec![0u64; n];
    let mut shed = vec![0u64; n];
    // dynamic mode's front-door hold: (request, reply sink, provisional
    // target) entries parked while every backend is saturated
    let rebalance_every = match placement {
        ClusterPlacement::Dynamic { rebalance_every } => {
            rebalance_every.max(1) as u64
        }
        _ => 0,
    };
    let mut pending: VecDeque<(Request, ReplyTo, usize)> = VecDeque::new();
    let mut arrivals: u64 = 0;
    let mut migrations: u64 = 0;
    // front-door span sink: intake/placement/shed events on the same
    // process-global monotonic clock the backend routers stamp with
    let mut sink = TraceSink::on(trace);
    loop {
        // with held arrivals, poll instead of blocking: backends free
        // capacity asynchronously, and a driver waiting on a held
        // request's reply sends no further messages to wake us
        let msg = if pending.is_empty() {
            match rx.recv() {
                Ok(m) => m,
                // every Cluster handle gone: fall through to shutdown
                Err(_) => break,
            }
        } else {
            match rx.recv_timeout(std::time::Duration::from_millis(1)) {
                Ok(m) => m,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    drain_pending(&mut pending, &servers, &signals,
                                  &slots, &mut placed, &mut migrations,
                                  &mut sink);
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        match msg {
            FrontMsg::Shutdown => break,
            FrontMsg::TakeTrace(tx) => {
                let mut shards =
                    vec![sink.drain(None, "placement")];
                let drained = servers
                    .iter()
                    .map(|s| s.take_trace())
                    .collect::<Result<Vec<_>>>();
                let _ = tx.send(drained.map(|backend| {
                    shards.extend(backend);
                    shards
                }));
            }
            FrontMsg::Stats(tx) => {
                let snap = servers
                    .iter()
                    .map(|s| s.stats())
                    .collect::<Result<Vec<_>>>()
                    .map(|stats| ClusterStats {
                        shards: stats,
                        placed: placed.clone(),
                        shed: shed.clone(),
                        peak_intake_depth: peak.load(Ordering::Relaxed),
                        migrations,
                        placement: placement.label().to_string(),
                    });
                let _ = tx.send(snap);
            }
            FrontMsg::Submit(req, reply_sink) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                if sink.enabled() {
                    sink.record(now_ns(),
                                EventKind::Intake { id: req.id });
                }
                // candidate first (round-robin advances even on a shed,
                // least-outstanding re-reads signals per arrival), so a
                // shed is attributable to the backend it would have hit
                let inflight: Vec<usize> =
                    signals.iter().map(|s| s.inflight()).collect();
                let candidate = placer.pick(&inflight);
                let saturated = shed_depth > 0
                    && (0..n).all(|i| {
                        inflight[i] >= slots[i] + shed_depth
                    });
                if saturated {
                    shed[candidate] += 1;
                    if sink.enabled() {
                        sink.record(now_ns(), EventKind::Terminal {
                            id: req.id,
                            outcome: SpanOutcome::Shed,
                        });
                    }
                    shed_reply(&req, reply_sink, candidate, n, shed_depth);
                } else if rebalance_every > 0
                    && (0..n).all(|i| inflight[i] > slots[i])
                {
                    // dynamic mode, every backend saturated: hold at the
                    // front door instead of committing to a hot queue;
                    // drained (and possibly migrated) as capacity frees
                    arrivals += 1;
                    pending.push_back((req, reply_sink, candidate));
                    if arrivals % rebalance_every == 0 {
                        for entry in pending.iter_mut() {
                            let best = (0..n)
                                .min_by_key(|&i| {
                                    (signals[i].inflight(), i)
                                })
                                .unwrap_or(0);
                            if best != entry.2 {
                                migrations += 1;
                                if sink.enabled() {
                                    sink.record(
                                        now_ns(),
                                        EventKind::Migrate {
                                            id: entry.0.id,
                                            from: entry.2,
                                            to: best,
                                        },
                                    );
                                }
                                entry.2 = best;
                            }
                        }
                    }
                } else {
                    arrivals += 1;
                    placed[candidate] += 1;
                    if sink.enabled() {
                        sink.record(now_ns(), EventKind::Placed {
                            id: req.id,
                            shard: candidate,
                        });
                    }
                    servers[candidate].forward(req, reply_sink);
                }
                drain_pending(&mut pending, &servers, &signals, &slots,
                              &mut placed, &mut migrations, &mut sink);
            }
        }
    }
    // shutdown with held arrivals: commit each to its provisional shard
    // so the backend routers terminally answer them (the exactly-once
    // reply pin covers front-door-held requests too)
    while let Some((req, reply_sink, from)) = pending.pop_front() {
        placed[from] += 1;
        if sink.enabled() {
            sink.record(now_ns(), EventKind::Placed {
                id: req.id,
                shard: from,
            });
        }
        servers[from].forward(req, reply_sink);
    }
    // dropping the servers shuts each backend down in turn; their
    // routers terminally answer everything still in flight
    drop(servers);
}
