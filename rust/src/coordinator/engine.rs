//! Functional model engine: drives the AOT-compiled transformer block
//! end-to-end (embed → attention → gate → route → MoE → logits) with the
//! KV + GO caches owned on the rust side.
//!
//! Two decode paths exist on purpose:
//! * [`DecodeMode::Cached`] — the paper's path: KV-cached attention plus
//!   GO-cached routing (`TopKUpdate` on one token);
//! * [`DecodeMode::Recompute`] — the expert-choice reference: re-prefill
//!   everything each step and re-route the whole batch at the same fixed
//!   capacity.
//!
//! The integration test `rust/tests/functional_equivalence.rs` pins that
//! both paths generate the same token stream — the end-to-end correctness
//! statement for the GO cache (streaming top-k == batch top-k holds all
//! the way through real HLO numerics, not just in the abstract).

use anyhow::{anyhow, Result};

use crate::cache::{GoCache, KvCache};
use crate::config::manifest::FunctionalModel;
use crate::moe::gate::{expert_choice_route, softmax_rows, Routing};
use crate::runtime::executor::{Runtime, TensorIn};

/// How `decode_step` computes the next hidden state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    Cached,
    Recompute,
}

/// One live generation session.
pub struct Session {
    pub ids: Vec<i32>,
    kv: KvCache,
    go: GoCache,
    /// position of the next token to be written (== ids.len())
    pub pos: usize,
}

/// Output of one storage-agnostic decode step ([`ModelEngine::decode_core`]):
/// the sampled next token, the K/V rows the caller appends to its own
/// storage, and the expert set the GO cache selected (planner telemetry).
#[derive(Debug, Clone)]
pub(crate) struct DecodeStep {
    pub next: i32,
    pub k_row: Vec<f32>,
    pub v_row: Vec<f32>,
    pub selected: Vec<usize>,
}

/// Output of one generation run.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    pub tokens: Vec<i32>,
    /// wall-clock spent inside HLO executions, per stage
    pub prefill_us: f64,
    pub decode_us: f64,
}

pub struct ModelEngine {
    rt: Runtime,
    pub model: FunctionalModel,
    /// §Perf L2-1: use the sparse-gather MoE executable on the decode path
    /// (computes only up to `expert_capacity` selected experts instead of
    /// all E masked ones).  Off by default so the strict cached-vs-
    /// recompute equivalence compares identical HLO modules; the serving
    /// loop turns it on.
    sparse_moe: bool,
}

impl ModelEngine {
    pub fn new(rt: Runtime) -> Self {
        let model = rt.manifest.model.clone();
        ModelEngine { rt, model, sparse_moe: false }
    }

    pub fn with_sparse_moe(mut self, on: bool) -> Self {
        self.sparse_moe = on;
        self
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn pad_ids(&self, ids: &[i32]) -> Vec<i32> {
        let mut padded = ids.to_vec();
        padded.resize(self.model.max_seq, 0);
        padded
    }

    /// Run the padded prefill pipeline over `ids`, returning
    /// (moe output y [S, D], the expert-choice routing, k, v buffers).
    pub(crate) fn prefill_pipeline(&self, ids: &[i32])
        -> Result<(Vec<f32>, Routing, Vec<f32>, Vec<f32>)> {
        let m = &self.model;
        let t = ids.len();
        if t == 0 {
            return Err(anyhow!("empty prompt"));
        }
        if t > m.max_seq {
            return Err(anyhow!("prompt longer than max_seq"));
        }
        let padded = self.pad_ids(ids);
        let x = self
            .rt
            .get("embed_prefill")?
            .run(&[TensorIn::I32(&padded)])?
            .remove(0)
            .into_f32()?;
        let mut attn = self.rt.get("attn_prefill")?.run(&[
            TensorIn::F32(&x),
            TensorIn::I32(&[t as i32]),
        ])?;
        let h = attn.remove(0).into_f32()?;
        let k = attn.remove(0).into_f32()?;
        let v = attn.remove(0).into_f32()?;
        let scores = self
            .rt
            .get("gate_full")?
            .run(&[TensorIn::F32(&h)])?
            .remove(0)
            .into_f32()?;
        // expert-choice routing over the valid prefix, fixed capacity
        let routing = expert_choice_route(
            &scores, m.max_seq, m.n_experts, m.expert_capacity, Some(t));
        let y = self
            .rt
            .get("moe_full")?
            .run(&[TensorIn::F32(&h), TensorIn::F32(&routing.gates)])?
            .remove(0)
            .into_f32()?;
        Ok((y, routing, k, v))
    }

    /// Prefill a prompt into a fresh session (seeds both caches).
    pub fn prefill(&self, ids: &[i32]) -> Result<(Session, i32)> {
        let m = &self.model;
        let t = ids.len();
        let (y, routing, k, v) = self.prefill_pipeline(ids)?;

        let mut kv = KvCache::new(m.max_seq, m.n_heads, m.d_head);
        kv.seed(&k, &v, t);
        let mut go = GoCache::new(m.n_experts, m.expert_capacity, 0);
        go.seed_from_routing(&routing);

        let next =
            self.sample(&y[(t - 1) * m.d_model..t * m.d_model], t)?;
        Ok((Session { ids: ids.to_vec(), kv, go, pos: t }, next))
    }

    /// One cached decode step: append `token`, return the next token.
    pub fn decode_cached(&self, s: &mut Session, token: i32) -> Result<i32> {
        if s.pos >= self.model.max_seq {
            return Err(anyhow!("session at max_seq"));
        }
        let step = {
            // split the session borrows: KV buffers read-only into the HLO,
            // GO cache mutated by TopKUpdate
            let Session { ids: _, kv, go, pos } = s;
            self.decode_core(kv.k_buf(), kv.v_buf(), *pos, go, token)?
        };
        s.kv.append(&step.k_row, &step.v_row);
        s.ids.push(token);
        s.pos += 1;
        Ok(step.next)
    }

    /// The shared single-token decode pipeline, storage-agnostic: the KV
    /// buffers are *borrowed* (per-session [`KvCache`] or a serving-pool
    /// slot — no per-step clones either way) and the new K/V rows are
    /// returned for the caller to append to its own storage.
    pub(crate) fn decode_core(&self, k_buf: &[f32], v_buf: &[f32],
                              pos: usize, go: &mut GoCache, token: i32)
        -> Result<DecodeStep> {
        let m = &self.model;
        let x1 = self
            .rt
            .get("embed_one")?
            .run(&[TensorIn::I32(&[token])])?
            .remove(0)
            .into_f32()?;
        let mut attn = self.rt.get("attn_decode")?.run(&[
            TensorIn::F32(&x1),
            TensorIn::F32(k_buf),
            TensorIn::F32(v_buf),
            TensorIn::I32(&[pos as i32]),
        ])?;
        let h1 = attn.remove(0).into_f32()?;
        let k_row = attn.remove(0).into_f32()?;
        let v_row = attn.remove(0).into_f32()?;

        let scores1 = self
            .rt
            .get("gate_one")?
            .run(&[TensorIn::F32(&h1)])?
            .remove(0)
            .into_f32()?;
        // TopKUpdate: experts that admit this token compute it; gate
        // weights are the softmax probs, zero elsewhere
        let upd = go.update_scores(pos, &scores1);
        let probs = softmax_rows(&scores1, 1, m.n_experts);
        let y1 = if self.sparse_moe
            && upd.selected.len() <= m.expert_capacity
        {
            // gather only the selected experts (pad with gate 0.0 slots)
            let mut idx = vec![0i32; m.expert_capacity];
            let mut g = vec![0f32; m.expert_capacity];
            for (i, &e) in upd.selected.iter().enumerate() {
                idx[i] = e as i32;
                g[i] = probs[e];
            }
            self.rt
                .get("moe_one_sparse")?
                .run(&[
                    TensorIn::F32(&h1),
                    TensorIn::I32(&idx),
                    TensorIn::F32(&g),
                ])?
                .remove(0)
                .into_f32()?
        } else {
            let mut gates = vec![0f32; m.n_experts];
            for &e in &upd.selected {
                gates[e] = probs[e];
            }
            self.rt
                .get("moe_one")?
                .run(&[TensorIn::F32(&h1), TensorIn::F32(&gates)])?
                .remove(0)
                .into_f32()?
        };

        let next = self.sample(&y1, pos + 1)?;
        Ok(DecodeStep { next, k_row, v_row, selected: upd.selected })
    }

    /// One reference decode step: re-prefill everything (no caches), route
    /// the whole batch at fixed capacity, return the next token.
    pub fn decode_recompute(&self, s: &mut Session, token: i32)
        -> Result<i32> {
        let m = &self.model;
        if s.pos >= m.max_seq {
            return Err(anyhow!("session at max_seq"));
        }
        s.ids.push(token);
        s.pos += 1;
        let t = s.ids.len();
        let (y, _, _, _) = self.prefill_pipeline(&s.ids)?;
        self.sample(&y[(t - 1) * m.d_model..t * m.d_model], t)
    }

    /// Generate `gen_len` tokens greedily from `prompt`.
    pub fn generate(&self, prompt: &[i32], gen_len: usize, mode: DecodeMode)
        -> Result<GenerationResult> {
        let t0 = std::time::Instant::now();
        let (mut session, mut next) = self.prefill(prompt)?;
        let prefill_us = t0.elapsed().as_secs_f64() * 1e6;

        let t1 = std::time::Instant::now();
        let mut tokens = Vec::with_capacity(gen_len);
        for _ in 0..gen_len {
            tokens.push(next);
            if session.pos >= self.model.max_seq {
                break;
            }
            next = match mode {
                DecodeMode::Cached => self.decode_cached(&mut session, next)?,
                DecodeMode::Recompute => {
                    self.decode_recompute(&mut session, next)?
                }
            };
        }
        Ok(GenerationResult {
            tokens,
            prefill_us,
            decode_us: t1.elapsed().as_secs_f64() * 1e6,
        })
    }

    /// Deterministic Gumbel-max sampling: `argmax(logits/T + g(pos, i))`
    /// with the noise seeded by the *position*, so the cached and the
    /// recompute decode paths draw identical noise and the equivalence
    /// test compares real streams rather than a collapsed greedy fixpoint.
    pub(crate) fn sample(&self, h_row: &[f32], pos: usize) -> Result<i32> {
        let logits = self
            .rt
            .get("logits_one")?
            .run(&[TensorIn::F32(h_row)])?
            .remove(0)
            .into_f32()?;
        let mut rng =
            crate::util::rng::Pcg32::new(0x6_0D1_CE ^ (pos as u64) << 8);
        let temp = 1.0f64;
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            let u = rng.gen_f64().max(1e-12);
            let gumbel = -(-u.ln()).ln();
            let score = v as f64 / temp + gumbel;
            if score > best_v {
                best_v = score;
                best = i;
            }
        }
        Ok(best as i32)
    }
}
