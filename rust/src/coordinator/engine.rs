//! Functional model engine: drives the AOT-compiled depth-L transformer
//! stack end-to-end (embed → L × (attention → gate → route → MoE) →
//! logits) with the per-layer KV + GO cache banks owned on the rust side.
//!
//! Two decode paths exist on purpose:
//! * [`DecodeMode::Cached`] — the paper's path: KV-cached attention plus
//!   GO-cached routing (`TopKUpdate` on one token, per layer);
//! * [`DecodeMode::Recompute`] — the expert-choice reference: re-prefill
//!   everything each step and re-route the whole batch at the same fixed
//!   capacity.
//!
//! The integration test `rust/tests/functional_equivalence.rs` pins that
//! at depth 1 both paths generate the same token stream — the end-to-end
//! correctness statement for the GO cache (streaming top-k == batch top-k
//! holds all the way through real HLO numerics, not just in the abstract).
//!
//! **Depth caveat:** at L ≥ 2 the two modes are *not* stream-equivalent,
//! by construction of expert-choice routing rather than by bug: a batch
//! re-route at step t can displace an earlier token from a mid-stack
//! expert, rewriting that token's layer-l output and therefore its
//! layer-(l+1) K/V contribution — state the cached path deliberately froze
//! when the token was generated.  At L ≥ 2 the pinned references are
//! therefore streaming-vs-streaming (batched vs per-session, pooled vs
//! session storage, and an artifact-level manual reference).

use anyhow::{anyhow, Result};

use crate::cache::{GoCache, KvCache};
use crate::config::manifest::{layer_artifact, FunctionalModel};
use crate::moe::gate::{expert_choice_route, softmax_rows, Routing};
use crate::runtime::executor::{Runtime, TensorIn};

/// How the decode step computes the next hidden state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// The paper's path: KV-cached attention + GO-cached routing
    /// (streaming `TopKUpdate` on the new token, per layer).
    Cached,
    /// The expert-choice reference: re-prefill everything each step and
    /// re-route the whole batch at the same fixed capacity.
    Recompute,
}

/// One live generation session: per-layer KV banks and one GO bank per
/// layer.
pub struct Session {
    /// prompt + generated token ids so far
    pub ids: Vec<i32>,
    kv: KvCache,
    go: Vec<GoCache>,
    /// position of the next token to be written (== ids.len())
    pub pos: usize,
}

/// Output of one storage-agnostic decode step ([`ModelEngine::decode_core`]):
/// the sampled next token, the per-layer K/V rows the caller appends to its
/// own storage, and the per-layer expert sets the GO banks selected
/// (planner telemetry).
#[derive(Debug, Clone)]
pub(crate) struct DecodeStep {
    pub next: i32,
    /// `[L]` new K rows, one `[H * Dh]` row per layer
    pub k_rows: Vec<Vec<f32>>,
    pub v_rows: Vec<Vec<f32>>,
    /// `[L]` expert sets selected by each layer's TopKUpdate
    pub selected: Vec<Vec<usize>>,
}

/// Output of the padded prefill pipeline ([`ModelEngine::prefill_pipeline`]).
pub(crate) struct PrefillOut {
    /// final layer's MoE output `[S, D]`
    pub y: Vec<f32>,
    /// per-layer expert-choice routing over the valid prefix
    pub routings: Vec<Routing>,
    /// per-layer padded K/V buffers `[S, H, Dh]`
    pub ks: Vec<Vec<f32>>,
    pub vs: Vec<Vec<f32>>,
}

/// Output of one generation run.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// the generated token ids
    pub tokens: Vec<i32>,
    /// wall-clock spent inside prefill HLO executions (µs)
    pub prefill_us: f64,
    /// wall-clock spent inside decode HLO executions (µs)
    pub decode_us: f64,
}

/// Per-layer artifact names, resolved once at engine construction so the
/// decode hot path never formats strings.
#[derive(Debug, Clone)]
pub(crate) struct LayerNames {
    pub attn_prefill: String,
    pub attn_decode: String,
    pub gate_full: String,
    pub gate_one: String,
    pub moe_full: String,
    pub moe_one: String,
    pub moe_one_sparse: String,
    pub attn_decode_batch: String,
    pub gate_batch: String,
    pub moe_batch_sparse: String,
}

impl LayerNames {
    fn new(layer: usize) -> Self {
        LayerNames {
            attn_prefill: layer_artifact("attn_prefill", layer),
            attn_decode: layer_artifact("attn_decode", layer),
            gate_full: layer_artifact("gate_full", layer),
            gate_one: layer_artifact("gate_one", layer),
            moe_full: layer_artifact("moe_full", layer),
            moe_one: layer_artifact("moe_one", layer),
            moe_one_sparse: layer_artifact("moe_one_sparse", layer),
            attn_decode_batch: layer_artifact("attn_decode_batch", layer),
            gate_batch: layer_artifact("gate_batch", layer),
            moe_batch_sparse: layer_artifact("moe_batch_sparse", layer),
        }
    }
}

/// The per-session functional engine: drives the AOT-compiled depth-L
/// stack over the PJRT runtime, with KV/GO cache state owned host-side.
pub struct ModelEngine {
    rt: Runtime,
    /// the loaded model's manifest-derived shape
    pub model: FunctionalModel,
    /// per-layer artifact name table (len == `model.n_layers`)
    names: Vec<LayerNames>,
    /// §Perf L2-1: use the sparse-gather MoE executables on the decode
    /// path (computes only up to the layer's `expert_capacity` selected
    /// experts instead of all E masked ones).  Off by default so the
    /// strict cached-vs-recompute equivalence compares identical HLO
    /// modules; the serving loop turns it on.
    sparse_moe: bool,
}

impl ModelEngine {
    /// Wrap a loaded [`Runtime`] (dense decode MoE; see
    /// [`ModelEngine::with_sparse_moe`]).
    pub fn new(rt: Runtime) -> Self {
        let model = rt.manifest.model.clone();
        let names = (0..model.n_layers).map(LayerNames::new).collect();
        ModelEngine { rt, model, names, sparse_moe: false }
    }

    /// Toggle the sparse-gather MoE executables on the decode path
    /// (§Perf L2-1; the serving loop turns this on).
    pub fn with_sparse_moe(mut self, on: bool) -> Self {
        self.sparse_moe = on;
        self
    }

    /// The underlying PJRT runtime and its compiled artifacts.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub(crate) fn layer_names(&self, layer: usize) -> &LayerNames {
        &self.names[layer]
    }

    fn pad_ids(&self, ids: &[i32]) -> Vec<i32> {
        let mut padded = ids.to_vec();
        padded.resize(self.model.max_seq, 0);
        padded
    }

    /// The embedding stage of the padded prefill pipeline: validate `ids`,
    /// pad to `max_seq`, run `embed_prefill`.  The output is a pure
    /// function of the prompt (no valid-length input), so chunked prefill
    /// ([`crate::coordinator::BatchEngine::advance_prefill`]) computes it
    /// once and replays the layer stack at growing prefix lengths.
    pub(crate) fn prefill_embed(&self, ids: &[i32]) -> Result<Vec<f32>> {
        let m = &self.model;
        let t = ids.len();
        if t == 0 {
            return Err(anyhow!("empty prompt"));
        }
        if t > m.max_seq {
            return Err(anyhow!("prompt longer than max_seq"));
        }
        let padded = self.pad_ids(ids);
        self.rt
            .get("embed_prefill")?
            .run(&[TensorIn::I32(&padded)])?
            .remove(0)
            .into_f32()
    }

    /// Run the padded prefill pipeline over `ids` through all L layers.
    pub(crate) fn prefill_pipeline(&self, ids: &[i32]) -> Result<PrefillOut> {
        let x0 = self.prefill_embed(ids)?;
        self.prefill_layers(&x0, ids.len())
    }

    /// The layer stack of the padded prefill pipeline at valid prefix
    /// length `t`, from a cached [`ModelEngine::prefill_embed`] output.
    /// Every dispatch is identical to what a monolithic
    /// [`ModelEngine::prefill_pipeline`] over the length-`t` prefix would
    /// issue, which is what makes chunked prefill's final chunk (run at
    /// the full prompt length) bit-identical to the monolithic path.
    pub(crate) fn prefill_layers(&self, x0: &[f32], t: usize)
        -> Result<PrefillOut> {
        let m = &self.model;
        let mut x = x0.to_vec();
        let mut routings = Vec::with_capacity(m.n_layers);
        let mut ks = Vec::with_capacity(m.n_layers);
        let mut vs = Vec::with_capacity(m.n_layers);
        for layer in 0..m.n_layers {
            let nm = &self.names[layer];
            let mut attn = self.rt.get(&nm.attn_prefill)?.run(&[
                TensorIn::F32(&x),
                TensorIn::I32(&[t as i32]),
            ])?;
            let h = attn.remove(0).into_f32()?;
            let k = attn.remove(0).into_f32()?;
            let v = attn.remove(0).into_f32()?;
            let scores = self
                .rt
                .get(&nm.gate_full)?
                .run(&[TensorIn::F32(&h)])?
                .remove(0)
                .into_f32()?;
            // expert-choice routing over the valid prefix, fixed per-layer
            // capacity
            let routing = expert_choice_route(
                &scores, m.max_seq, m.n_experts, m.capacity(layer), Some(t));
            x = self
                .rt
                .get(&nm.moe_full)?
                .run(&[TensorIn::F32(&h), TensorIn::F32(&routing.gates)])?
                .remove(0)
                .into_f32()?;
            routings.push(routing);
            ks.push(k);
            vs.push(v);
        }
        Ok(PrefillOut { y: x, routings, ks, vs })
    }

    /// Prefill a prompt into a fresh session (seeds every layer's caches).
    pub fn prefill(&self, ids: &[i32]) -> Result<(Session, i32)> {
        let m = &self.model;
        let t = ids.len();
        let out = self.prefill_pipeline(ids)?;

        let mut kv = KvCache::new(m.n_layers, m.max_seq, m.n_heads, m.d_head);
        kv.seed(&out.ks, &out.vs, t);
        let mut go =
            GoCache::banks(&m.expert_capacity_per_layer, m.n_experts, 0);
        for (bank, routing) in go.iter_mut().zip(&out.routings) {
            bank.seed_from_routing(routing);
        }

        let next =
            self.sample(&out.y[(t - 1) * m.d_model..t * m.d_model], t)?;
        Ok((Session { ids: ids.to_vec(), kv, go, pos: t }, next))
    }

    /// One cached decode step: append `token`, return the next token.
    pub fn decode_cached(&self, s: &mut Session, token: i32) -> Result<i32> {
        if s.pos >= self.model.max_seq {
            return Err(anyhow!("session at max_seq"));
        }
        let step = {
            // split the session borrows: KV banks read-only into the HLO,
            // GO banks mutated by each layer's TopKUpdate
            let Session { ids: _, kv, go, pos } = s;
            let kv: &KvCache = kv; // shared borrow outliving the closure
            let kv_layers: Vec<(&[f32], &[f32])> = (0..kv.n_layers())
                .map(|l| (kv.layer_k(l), kv.layer_v(l)))
                .collect();
            self.decode_core(&kv_layers, *pos, go, token)?
        };
        s.kv.append(&step.k_rows, &step.v_rows);
        s.ids.push(token);
        s.pos += 1;
        Ok(step.next)
    }

    /// The shared single-token decode pipeline, storage-agnostic: one
    /// `(k, v)` bank borrow per layer (per-session [`KvCache`] or a
    /// serving-pool slot — no per-step clones either way) and the new
    /// per-layer K/V rows are returned for the caller to append to its own
    /// storage.  `go` holds one GO bank per layer and is updated in layer
    /// order as the stack executes.
    pub(crate) fn decode_core(&self, kv_layers: &[(&[f32], &[f32])],
                              pos: usize, go: &mut [GoCache], token: i32)
        -> Result<DecodeStep> {
        let m = &self.model;
        debug_assert_eq!(kv_layers.len(), m.n_layers);
        debug_assert_eq!(go.len(), m.n_layers);
        let mut x = self
            .rt
            .get("embed_one")?
            .run(&[TensorIn::I32(&[token])])?
            .remove(0)
            .into_f32()?;
        let mut k_rows = Vec::with_capacity(m.n_layers);
        let mut v_rows = Vec::with_capacity(m.n_layers);
        let mut selected = Vec::with_capacity(m.n_layers);
        for layer in 0..m.n_layers {
            let nm = &self.names[layer];
            let (k_buf, v_buf) = kv_layers[layer];
            let mut attn = self.rt.get(&nm.attn_decode)?.run(&[
                TensorIn::F32(&x),
                TensorIn::F32(k_buf),
                TensorIn::F32(v_buf),
                TensorIn::I32(&[pos as i32]),
            ])?;
            let h1 = attn.remove(0).into_f32()?;
            let k_row = attn.remove(0).into_f32()?;
            let v_row = attn.remove(0).into_f32()?;

            let scores1 = self
                .rt
                .get(&nm.gate_one)?
                .run(&[TensorIn::F32(&h1)])?
                .remove(0)
                .into_f32()?;
            // TopKUpdate: experts that admit this token compute it; gate
            // weights are the softmax probs, zero elsewhere
            let upd = go[layer].update_scores(pos, &scores1);
            let probs = softmax_rows(&scores1, 1, m.n_experts);
            let cap = m.capacity(layer);
            let y1 = if self.sparse_moe && upd.selected.len() <= cap {
                // gather only the selected experts (pad with gate 0.0
                // slots)
                let mut idx = vec![0i32; cap];
                let mut g = vec![0f32; cap];
                for (i, &e) in upd.selected.iter().enumerate() {
                    idx[i] = e as i32;
                    g[i] = probs[e];
                }
                self.rt
                    .get(&nm.moe_one_sparse)?
                    .run(&[
                        TensorIn::F32(&h1),
                        TensorIn::I32(&idx),
                        TensorIn::F32(&g),
                    ])?
                    .remove(0)
                    .into_f32()?
            } else {
                let mut gates = vec![0f32; m.n_experts];
                for &e in &upd.selected {
                    gates[e] = probs[e];
                }
                self.rt
                    .get(&nm.moe_one)?
                    .run(&[TensorIn::F32(&h1), TensorIn::F32(&gates)])?
                    .remove(0)
                    .into_f32()?
            };
            x = y1;
            k_rows.push(k_row);
            v_rows.push(v_row);
            selected.push(upd.selected);
        }

        let next = self.sample(&x, pos + 1)?;
        Ok(DecodeStep { next, k_rows, v_rows, selected })
    }

    /// One reference decode step: re-prefill everything (no caches), route
    /// the whole batch at fixed capacity per layer, return the next token.
    /// Stream-equivalent to [`DecodeMode::Cached`] at depth 1 only — see
    /// the module docs for why deeper stacks diverge.
    pub fn decode_recompute(&self, s: &mut Session, token: i32)
        -> Result<i32> {
        let m = &self.model;
        if s.pos >= m.max_seq {
            return Err(anyhow!("session at max_seq"));
        }
        s.ids.push(token);
        s.pos += 1;
        let t = s.ids.len();
        let out = self.prefill_pipeline(&s.ids)?;
        self.sample(&out.y[(t - 1) * m.d_model..t * m.d_model], t)
    }

    /// Generate `gen_len` tokens greedily from `prompt`.
    pub fn generate(&self, prompt: &[i32], gen_len: usize, mode: DecodeMode)
        -> Result<GenerationResult> {
        let t0 = std::time::Instant::now();
        let (mut session, mut next) = self.prefill(prompt)?;
        let prefill_us = t0.elapsed().as_secs_f64() * 1e6;

        let t1 = std::time::Instant::now();
        let mut tokens = Vec::with_capacity(gen_len);
        for _ in 0..gen_len {
            tokens.push(next);
            if session.pos >= self.model.max_seq {
                break;
            }
            next = match mode {
                DecodeMode::Cached => self.decode_cached(&mut session, next)?,
                DecodeMode::Recompute => {
                    self.decode_recompute(&mut session, next)?
                }
            };
        }
        Ok(GenerationResult {
            tokens,
            prefill_us,
            decode_us: t1.elapsed().as_secs_f64() * 1e6,
        })
    }

    /// Deterministic Gumbel-max sampling: `argmax(logits/T + g(pos, i))`
    /// with the noise seeded by the *position*, so the cached and the
    /// recompute decode paths draw identical noise and the equivalence
    /// test compares real streams rather than a collapsed greedy fixpoint.
    pub(crate) fn sample(&self, h_row: &[f32], pos: usize) -> Result<i32> {
        let logits = self
            .rt
            .get("logits_one")?
            .run(&[TensorIn::F32(h_row)])?
            .remove(0)
            .into_f32()?;
        let mut rng =
            crate::util::rng::Pcg32::new(0x6_0D1_CE ^ (pos as u64) << 8);
        let temp = 1.0f64;
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &v) in logits.iter().enumerate() {
            let u = rng.gen_f64().max(1e-12);
            let gumbel = -(-u.ln()).ln();
            let score = v as f64 / temp + gumbel;
            if score > best_v {
                best_v = score;
                best = i;
            }
        }
        Ok(best as i32)
    }
}
