//! Threaded serving loop: a router thread owns the [`ModelEngine`] (the
//! PJRT client is single-owner) and interleaves live sessions round-robin,
//! one decode step per session per cycle — continuous batching in the
//! vLLM-router sense, sized for the single-chip simulator testbed.
//!
//! (The image ships no tokio; the event loop is a plain mpsc channel +
//! worker thread, which for a single-device engine is the same topology a
//! tokio `spawn_blocking` worker would have.)

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::engine::{DecodeMode, ModelEngine, Session};
use crate::runtime::Runtime;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// time from submit to completion
    pub latency_us: f64,
    /// time from submit to first generated token
    pub ttft_us: f64,
}

enum Msg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

struct Live {
    req: Request,
    reply: mpsc::Sender<Response>,
    session: Session,
    next: i32,
    tokens: Vec<i32>,
    submitted: Instant,
    first_token: Option<Instant>,
}

/// Handle to the router thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn the router thread; the engine (and its PJRT client, which is
    /// not `Send`) is constructed *inside* the thread from the artifacts
    /// directory.  Blocks until compilation finished or failed.
    pub fn spawn(artifacts_dir: PathBuf) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
        let handle = std::thread::spawn(move || {
            let engine = match Runtime::load(&artifacts_dir) {
                Ok(rt) => {
                    let platform = rt.platform();
                    // serving always decodes through the sparse-gather MoE
                    // (§Perf L2-1)
                    let engine = ModelEngine::new(rt).with_sparse_moe(true);
                    let _ = ready_tx.send(Ok(platform));
                    engine
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            run_loop(engine, rx);
        });
        match ready_rx.recv() {
            Ok(Ok(_platform)) => Ok(Server { tx, handle: Some(handle) }),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(anyhow!("router thread died during startup")),
        }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, tx))
            .expect("router thread alive");
        rx
    }

    /// Submit-and-wait convenience.
    pub fn generate(&self, id: u64, prompt: Vec<i32>, gen_len: usize)
        -> Result<Response> {
        let rx = self.submit(Request { id, prompt, gen_len });
        Ok(rx.recv()?)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_loop(engine: ModelEngine, rx: mpsc::Receiver<Msg>) {
    let mut live: VecDeque<Live> = VecDeque::new();
    loop {
        // Admit all pending requests; block only when idle.
        loop {
            let msg = if live.is_empty() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match msg {
                Msg::Shutdown => return,
                Msg::Submit(req, reply) => {
                    let submitted = Instant::now();
                    match engine.prefill(&req.prompt) {
                        Ok((session, next)) => live.push_back(Live {
                            req,
                            reply,
                            session,
                            next,
                            tokens: Vec::new(),
                            submitted,
                            first_token: None,
                        }),
                        Err(e) => {
                            eprintln!("prefill failed for {}: {e}", req.id);
                        }
                    }
                }
            }
        }

        // One decode step per live session (round-robin batching).
        let mut still_live = VecDeque::new();
        while let Some(mut l) = live.pop_front() {
            l.tokens.push(l.next);
            l.first_token.get_or_insert_with(Instant::now);
            let done = l.tokens.len() >= l.req.gen_len
                || l.session.pos >= engine.model.max_seq;
            if done {
                let now = Instant::now();
                let resp = Response {
                    id: l.req.id,
                    tokens: std::mem::take(&mut l.tokens),
                    latency_us: now
                        .duration_since(l.submitted)
                        .as_secs_f64()
                        * 1e6,
                    ttft_us: l
                        .first_token
                        .unwrap()
                        .duration_since(l.submitted)
                        .as_secs_f64()
                        * 1e6,
                };
                let _ = l.reply.send(resp);
                continue;
            }
            match engine.decode_cached(&mut l.session, l.next) {
                Ok(next) => {
                    l.next = next;
                    still_live.push_back(l);
                }
                Err(e) => eprintln!("decode failed for {}: {e}", l.req.id),
            }
        }
        live = still_live;
        let _ = DecodeMode::Cached; // the serving path is always cached
    }
}
