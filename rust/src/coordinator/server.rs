//! Threaded serving loop: a router thread owns the [`BatchEngine`] — the
//! engine and its PJRT client are constructed *inside* the thread, so
//! every [`Server`] is a self-contained serving stack and N servers run
//! genuinely concurrently (each on its own router thread with its own
//! client; the cluster front door in [`crate::coordinator::cluster`]
//! builds on exactly this).  The router serves live sessions with
//! slot-based continuous batching — waiting requests are admitted into
//! free serving slots by a pluggable [`AdmissionPolicy`] (FIFO by
//! default; SJF and deadline-aware variants for loadtest comparison),
//! and every decode cycle advances *all* live slots with one batched
//! dispatch per pipeline stage (single-token fallback when only one
//! session is live).  With [`ServerOptions::prefill_chunk`] > 0 the
//! router interleaves bounded prefill chunks of admitted-but-still-
//! filling slots with those decode dispatches, so one long prompt no
//! longer stalls every live decode slot (see DESIGN.md §Chunked
//! prefill).
//!
//! Every submitted request gets a terminal [`Response`]: generation
//! results and failures (oversized prompt, engine errors, shed on a full
//! queue, shutdown) all travel the same reply channel, so `submit()`
//! callers never see an opaque `RecvError` for a request the router
//! accepted.  [`Server::submit_streaming`] returns the same lifecycle as
//! a stream: zero or more [`Reply::Token`] events as tokens are banked,
//! then exactly one [`Reply::Terminal`] carrying the full [`Response`]
//! (see DESIGN.md §Concurrent cluster for the lifecycle diagram).
//!
//! With [`ServerOptions::queue_cap`] > 0 the router sheds load instead
//! of queueing without bound: a submit that finds the admission queue at
//! the cap gets an immediate terminal `overloaded` error, counted in
//! [`ServerStats::shed_requests`].
//!
//! (The image ships no tokio; the event loop is a plain mpsc channel +
//! worker thread, which for a single-device engine is the same topology a
//! tokio `spawn_blocking` worker would have.)

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::batch::BatchEngine;
use crate::coordinator::engine::ModelEngine;
use crate::obs::sink::{TraceShard, TraceSink};
use crate::obs::span::{now_ns, EventKind, SpanOutcome};
use crate::runtime::Runtime;
use crate::sched::PlannerStats;
use crate::workload::{AdmissionPolicy, Priority, QueuedMeta};

use super::batch::SlotCheckpoint;

/// Spawn-time configuration for a [`Server`].
///
/// `prefill_chunk == 0` (the default) keeps the seed behaviour: admission
/// runs the whole prefill pipeline monolithically before the next decode
/// dispatch.  `prefill_chunk == N > 0` enables chunked prefill: admission
/// only *claims* a slot, and each router cycle advances every claimed
/// slot's prefill by at most `N` prompt tokens before dispatching the
/// batched decode — so one long prompt can no longer stall every live
/// decode slot (the head-of-line blocking fix; see DESIGN.md §Chunked
/// prefill).  Chunked and monolithic admission produce bit-identical
/// token streams for every prompt.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// which waiting request each freed slot goes to
    pub policy: AdmissionPolicy,
    /// shard id tag for multi-server fan-outs (`None`: standalone);
    /// echoed on every [`Response`] and [`ServerStats`] snapshot, see
    /// [`Server::spawn_sharded`]
    pub shard: Option<usize>,
    /// prefill chunk budget in prompt tokens per slot per router cycle
    /// (`0`: monolithic prefill at admission, the seed behaviour)
    pub prefill_chunk: usize,
    /// admission-queue cap: a submit that finds `queue_cap` requests
    /// already waiting is shed with an immediate terminal `overloaded`
    /// error instead of queueing (`0`: unbounded, the seed behaviour).
    /// Shed requests count in [`ServerStats::shed_requests`]
    pub queue_cap: usize,
    /// record request-lifecycle span events into a per-router
    /// [`TraceSink`] ring buffer, drained by [`Server::take_trace`] for
    /// `--trace-out` export (`false`, the default: the sink is a no-op
    /// and the router's timing/behaviour is bit-identical to a server
    /// without the flag)
    pub trace: bool,
    /// priority QoS: reserve freed slots for waiting interactive
    /// requests and preempt (checkpoint → requeue) batch-tier slots when
    /// an interactive request would otherwise wait behind them (`false`,
    /// the default: priorities are carried but ignored — the seed
    /// scheduling behaviour.  See DESIGN.md §Preemption & QoS)
    pub qos: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            policy: AdmissionPolicy::Fifo,
            shard: None,
            prefill_chunk: 0,
            queue_cap: 0,
            trace: false,
            qos: false,
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// caller-chosen id, echoed on the terminal [`Response`]
    pub id: u64,
    /// prompt token ids to prefill
    pub prompt: Vec<i32>,
    /// tokens to generate (0: immediate empty terminal reply)
    pub gen_len: usize,
    /// end-to-end deadline budget from submit, for deadline-aware
    /// admission (`None`: no deadline — sorts last under EDF)
    pub deadline_us: Option<u64>,
    /// QoS tier ([`Priority::Interactive`] by default — the legacy
    /// single-tier behaviour).  Only consulted when the server runs with
    /// [`ServerOptions::qos`]
    pub priority: Priority,
}

impl Request {
    /// A deadline-less request (EDF sorts it last; FIFO/SJF ignore it).
    pub fn new(id: u64, prompt: Vec<i32>, gen_len: usize) -> Request {
        Request {
            id,
            prompt,
            gen_len,
            deadline_us: None,
            priority: Priority::Interactive,
        }
    }

    /// Attach an end-to-end deadline budget (µs from submit).
    pub fn with_deadline_us(mut self, deadline_us: u64) -> Request {
        self.deadline_us = Some(deadline_us);
        self
    }

    /// Set the QoS tier.
    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }
}

/// A terminal reply: every submitted request receives exactly one.
///
/// Events that may never have happened are `Option`s rather than sentinel
/// values (`None` == "never happened"), so a caller can't mistake an
/// errored request's timings for real zero-latency measurements.
#[derive(Debug, Clone)]
pub struct Response {
    /// the submitted request's id
    pub id: u64,
    /// generated tokens, or the error that terminated the request
    pub result: Result<Vec<i32>, String>,
    /// time from submit to completion
    pub latency_us: f64,
    /// time from submit to first generated token; `None` when the request
    /// errored before producing one
    pub ttft_us: Option<f64>,
    /// time from submit to slot admission; `None` when never admitted
    pub queue_us: Option<f64>,
    /// admission sequence number — strictly increasing in *admission*
    /// order; under the default FIFO policy that is also submit order
    /// (the monotonicity pin in `tests/serving.rs`).  `None` when never
    /// admitted
    pub admit_seq: Option<u64>,
    /// decode steps this request rode in a batched dispatch
    pub batched_steps: u64,
    /// decode steps served by the single-token fallback
    pub single_steps: u64,
    /// shard tag of the backend that replied (`None`: standalone server).
    /// Set on every reply path — including sheds and shutdown — so a
    /// cluster front door's callers can attribute each terminal reply to
    /// the backend (or shed candidate) that produced it
    pub shard: Option<usize>,
}

impl Response {
    /// Generated tokens (empty on error).
    pub fn tokens(&self) -> &[i32] {
        self.result.as_deref().unwrap_or(&[])
    }

    /// `true` iff the request completed successfully.
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }
}

/// One event on a streaming reply channel
/// ([`Server::submit_streaming`] / `Cluster::submit_streaming`): the
/// streaming variant of [`Response`].
///
/// Lifecycle per request: zero or more `Token` events in generation
/// order, then exactly one `Terminal` — always last, always present
/// (errors and shutdown included).  The terminal response's token vector
/// equals the concatenation of the streamed tokens, so a streaming
/// consumer can render incrementally and still reconcile against the
/// terminal reply.  A request that errors mid-stream has streamed a
/// prefix and then receives `Terminal` with the error.
#[derive(Debug, Clone)]
pub enum Reply {
    /// one generated token, delivered as soon as the router banks it
    Token {
        /// the submitted request's id
        id: u64,
        /// 0-based position of this token in the generated stream
        index: u64,
        /// the token id
        token: i32,
    },
    /// the terminal reply (exactly one per request, always last)
    Terminal(Response),
}

/// Live load signals a backend publishes for placement decisions —
/// the feedback that replaces
/// [`crate::workload::PlacementPolicy::LeastOutstanding`]'s split-time
/// analytic estimates in the cluster front door
/// ([`crate::coordinator::cluster`]).
///
/// The one counter that matters for placement is `inflight`: requests
/// submitted but not yet terminally replied (queue depth + occupied
/// slots).  It is incremented synchronously on the submit path and
/// decremented by the router on every terminal reply, so a placement
/// thread reading it sees its *own* recent assignments immediately —
/// no round-trip to the router, no stale-snapshot race.
#[derive(Debug, Default)]
pub struct LoadSignal {
    inflight: AtomicUsize,
}

impl LoadSignal {
    /// Requests submitted to this backend but not yet terminally
    /// replied: admission-queue depth plus outstanding (filling + live)
    /// slots.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    fn inc(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    fn dec(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Serving-lifetime telemetry (see DESIGN.md §Batched-Serving).
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    /// serving slots (batch width B)
    pub slots: usize,
    /// requests that completed successfully
    pub completed: u64,
    /// requests that ended in a terminal error
    pub errored: u64,
    /// total generated tokens across completed requests
    pub tokens_generated: u64,
    /// batched decode dispatches
    pub batch_dispatches: u64,
    /// tokens advanced by batched dispatches
    pub batched_tokens: u64,
    /// single-token fallback dispatches
    pub single_dispatches: u64,
    /// prefill chunk advances dispatched (0 unless the server was spawned
    /// with [`ServerOptions::prefill_chunk`] > 0)
    pub prefill_chunks: u64,
    /// high-water mark of the waiting queue
    pub peak_waiting: usize,
    /// requests shed with an immediate terminal `overloaded` error
    /// because the waiting queue was at [`ServerOptions::queue_cap`]
    /// (0 when the cap is unbounded)
    pub shed_requests: u64,
    /// batch-tier slots evicted (checkpoint → requeue) so a waiting
    /// interactive request could take the slot (0 unless
    /// [`ServerOptions::qos`])
    pub preemptions: u64,
    /// checkpointed sessions resumed into a slot; every preempted decode
    /// session is restored or terminally replied exactly once, so
    /// `restores <= preemptions` with the difference being requests
    /// still parked (or shut down) when the snapshot was taken
    pub restores: u64,
    /// total µs preempted requests spent back in the waiting queue
    /// between eviction and resume (the preemption-churn latency bill)
    pub preempted_wait_us: u64,
    /// high-water mark of simultaneously parked checkpoints (preempted
    /// sessions waiting to resume).  One snapshot fits in a slot's own
    /// banks; each simultaneous extra needs a spill copy, which the
    /// report prices in mm² via
    /// [`crate::placement::checkpoint_spill_mm2`]
    pub peak_checkpoints: usize,
    /// wall-clock µs since the unix epoch of the first decode/prefill
    /// dispatch this server issued (`None`: never dispatched).  Together
    /// with [`ServerStats::last_dispatch_unix_us`] this gives each
    /// router thread's busy interval on a *common* clock, which is how
    /// the concurrent-cluster tests check that shards' router cycles
    /// genuinely overlap in time
    pub first_dispatch_unix_us: Option<u64>,
    /// wall-clock µs since the unix epoch of the most recent
    /// decode/prefill dispatch (`None`: never dispatched)
    pub last_dispatch_unix_us: Option<u64>,
    /// cumulative group-aware planner telemetry (peripheral contention)
    pub planner: PlannerStats,
    /// shard id this server serves in a fan-out (`None`: standalone).
    /// Set by [`Server::spawn_sharded`]; flows into
    /// [`crate::workload::LoadOutcome`] and the per-shard sections of the
    /// `moepim.slo_report.v2` document.
    pub shard: Option<usize>,
    /// admission-policy label this server was spawned with (the
    /// [`AdmissionPolicy::label`] spelling).  Recorded so a served
    /// workload's trace (`moepim.trace.v1`, see
    /// [`crate::workload::record`]) names the configuration that
    /// produced it without the caller re-threading its options.
    pub policy: String,
    /// [`ServerOptions::prefill_chunk`] this server runs under
    pub prefill_chunk: usize,
    /// [`ServerOptions::queue_cap`] this server runs under
    pub queue_cap: usize,
}

impl ServerStats {
    /// Mean live slots per batched dispatch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_dispatches == 0 {
            0.0
        } else {
            self.batched_tokens as f64 / self.batch_dispatches as f64
        }
    }

    /// Human-readable multi-line rendering of the full snapshot — the one
    /// shared pretty-printer behind `moepim serve`'s shutdown dump and
    /// `moepim shardtest`'s per-shard stats, so the two surfaces can't
    /// drift apart.  Every field of the snapshot appears; `indent` is
    /// prefixed to each line (`""` for top-level output).
    pub fn pretty(&self, indent: &str) -> String {
        let mut out = String::new();
        let mut line = |s: String| {
            out.push_str(indent);
            out.push_str(&s);
            out.push('\n');
        };
        match self.shard {
            Some(s) => line(format!("shard:               {s}")),
            None => line("shard:               standalone".to_string()),
        }
        line(format!("policy:              {}", self.policy));
        line(format!("slots:               {}", self.slots));
        line(format!("prefill_chunk:       {}", self.prefill_chunk));
        line(format!("queue_cap:           {}", self.queue_cap));
        line(format!("completed:           {}", self.completed));
        line(format!("errored:             {}", self.errored));
        line(format!("shed_requests:       {}", self.shed_requests));
        line(format!("tokens_generated:    {}", self.tokens_generated));
        line(format!("batch_dispatches:    {}", self.batch_dispatches));
        line(format!("batched_tokens:      {}", self.batched_tokens));
        line(format!("mean_batch_occupancy: {:.2}",
                     self.mean_batch_occupancy()));
        line(format!("single_dispatches:   {}", self.single_dispatches));
        line(format!("prefill_chunks:      {}", self.prefill_chunks));
        line(format!("peak_waiting:        {}", self.peak_waiting));
        line(format!("preemptions:         {}", self.preemptions));
        line(format!("restores:            {}", self.restores));
        line(format!("preempted_wait_us:   {}", self.preempted_wait_us));
        line(format!("peak_checkpoints:    {}", self.peak_checkpoints));
        match (self.first_dispatch_unix_us, self.last_dispatch_unix_us) {
            (Some(a), Some(b)) => line(format!(
                "busy_interval_us:    {} .. {} ({} us)", a, b,
                b.saturating_sub(a))),
            _ => line("busy_interval_us:    never dispatched".to_string()),
        }
        line(format!(
            "planner:             steps={} work={} cycles={} \
             contention_cycles={} transfers={}",
            self.planner.steps, self.planner.work, self.planner.cycles,
            self.planner.contention_cycles, self.planner.transfers));
        out
    }
}

/// Where a request's replies go: a terminal-only channel (the classic
/// [`Server::submit`] surface) or a streaming channel that also carries
/// per-token [`Reply::Token`] events.  Shared with the cluster front
/// door, which forwards its callers' sinks to the placed backend.
pub(crate) enum ReplyTo {
    /// terminal [`Response`] only
    Terminal(mpsc::Sender<Response>),
    /// [`Reply::Token`] events followed by one [`Reply::Terminal`]
    Streaming(mpsc::Sender<Reply>),
}

/// A reply sink bound to its backend's [`LoadSignal`]: every terminal
/// reply decrements `inflight` exactly once (the type consumes itself on
/// `finish`, so a double terminal reply is unrepresentable).
struct Replier {
    sink: ReplyTo,
    signal: Arc<LoadSignal>,
}

impl Replier {
    /// Send the terminal reply and retire the in-flight count.
    fn finish(self, resp: Response) {
        self.signal.dec();
        match self.sink {
            ReplyTo::Terminal(tx) => {
                let _ = tx.send(resp);
            }
            ReplyTo::Streaming(tx) => {
                let _ = tx.send(Reply::Terminal(resp));
            }
        }
    }

    /// Send one streamed token (no-op on terminal-only sinks).
    fn token(&self, id: u64, index: u64, token: i32) {
        if let ReplyTo::Streaming(tx) = &self.sink {
            let _ = tx.send(Reply::Token { id, index, token });
        }
    }
}

enum Msg {
    Submit(Request, ReplyTo),
    Stats(mpsc::Sender<ServerStats>),
    TakeTrace(mpsc::Sender<TraceShard>),
    Shutdown,
}

/// One live serving slot.
struct Live {
    req: Request,
    reply: Replier,
    slot: usize,
    next: i32,
    tokens: Vec<i32>,
    submitted: Instant,
    admitted: Instant,
    admit_seq: u64,
    first_token: Option<Instant>,
    batched_steps: u64,
    single_steps: u64,
}

impl Live {
    fn respond(self, result: Result<Vec<i32>, String>,
               shard: Option<usize>) {
        let now = Instant::now();
        let resp = Response {
            id: self.req.id,
            result,
            latency_us: us(now, self.submitted),
            ttft_us: self.first_token.map(|t| us(t, self.submitted)),
            queue_us: Some(us(self.admitted, self.submitted)),
            admit_seq: Some(self.admit_seq),
            batched_steps: self.batched_steps,
            single_steps: self.single_steps,
            shard,
        };
        self.reply.finish(resp);
    }
}

fn us(later: Instant, earlier: Instant) -> f64 {
    later.duration_since(earlier).as_secs_f64() * 1e6
}

fn unix_us() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Terminal error reply for a request that never reached a slot: it was
/// never admitted and never produced a token, so those fields are `None`.
fn reject(id: u64, reply: Replier, submitted: Instant,
          shard: Option<usize>, err: String) {
    reply.finish(Response {
        id,
        result: Err(err),
        latency_us: us(Instant::now(), submitted),
        ttft_us: None,
        queue_us: None,
        admit_seq: None,
        batched_steps: 0,
        single_steps: 0,
        shard,
    });
}

/// Handle to the router thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    signal: Arc<LoadSignal>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn with the default FIFO admission policy.
    pub fn spawn(artifacts_dir: PathBuf) -> Result<Server> {
        Self::spawn_with(artifacts_dir, AdmissionPolicy::Fifo)
    }

    /// Spawn the router thread; the engine (and its PJRT client, which is
    /// not `Send`) is constructed *inside* the thread from the artifacts
    /// directory.  Blocks until compilation finished or failed.  `policy`
    /// decides which waiting request each freed slot goes to.
    pub fn spawn_with(artifacts_dir: PathBuf, policy: AdmissionPolicy)
        -> Result<Server> {
        Self::spawn_opts(artifacts_dir,
                         ServerOptions { policy, ..ServerOptions::default() })
    }

    /// [`Server::spawn_with`], tagged as shard `shard` of a multi-server
    /// fan-out: the id travels on every [`ServerStats`] snapshot and every
    /// [`Response`] so load outcomes collected from this server are
    /// attributable to their shard in the merged `moepim.slo_report.v2`.
    /// The tag changes telemetry only — admission and decode behave
    /// exactly as in an untagged server.
    pub fn spawn_sharded(artifacts_dir: PathBuf, policy: AdmissionPolicy,
                         shard: usize) -> Result<Server> {
        Self::spawn_opts(artifacts_dir, ServerOptions {
            policy,
            shard: Some(shard),
            ..ServerOptions::default()
        })
    }

    /// Spawn with explicit [`ServerOptions`] — the full surface: admission
    /// policy, shard tag, chunked-prefill budget, and the shedding cap.
    pub fn spawn_opts(artifacts_dir: PathBuf, opts: ServerOptions)
        -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<String>>();
        let signal = Arc::new(LoadSignal::default());
        let thread_signal = Arc::clone(&signal);
        let handle = std::thread::spawn(move || {
            let engine = match Runtime::load(&artifacts_dir) {
                Ok(rt) => {
                    // BatchEngine forces sparse-gather MoE decode on both
                    // of its paths (§Perf L2-1)
                    let engine = ModelEngine::new(rt);
                    let _ = ready_tx.send(Ok(engine.runtime().platform()));
                    BatchEngine::new(engine)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            run_loop(engine, rx, opts, thread_signal);
        });
        match ready_rx.recv() {
            Ok(Ok(_platform)) => {
                Ok(Server { tx, signal, handle: Some(handle) })
            }
            Ok(Err(e)) => Err(e),
            Err(_) => Err(anyhow!("router thread died during startup")),
        }
    }

    /// Submit a request; returns a receiver for the terminal response.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.forward(req, ReplyTo::Terminal(tx));
        rx
    }

    /// Submit a request for streaming delivery: the receiver yields each
    /// generated token as the router banks it ([`Reply::Token`]), then
    /// exactly one [`Reply::Terminal`] with the full [`Response`].
    pub fn submit_streaming(&self, req: Request) -> mpsc::Receiver<Reply> {
        let (tx, rx) = mpsc::channel();
        self.forward(req, ReplyTo::Streaming(tx));
        rx
    }

    /// Forward a request with a caller-built reply sink (the cluster
    /// front door's path), charging the live in-flight signal.
    pub(crate) fn forward(&self, req: Request, reply: ReplyTo) {
        self.signal.inc();
        self.tx
            .send(Msg::Submit(req, reply))
            .expect("router thread alive");
    }

    /// Submit-and-wait convenience.
    pub fn generate(&self, id: u64, prompt: Vec<i32>, gen_len: usize)
        -> Result<Response> {
        let rx = self.submit(Request::new(id, prompt, gen_len));
        Ok(rx.recv()?)
    }

    /// Snapshot of the serving telemetry.
    pub fn stats(&self) -> Result<ServerStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Stats(tx)).map_err(|_| {
            anyhow!("router thread gone")
        })?;
        Ok(rx.recv()?)
    }

    /// This backend's live load signal (in-flight request count), for
    /// cluster placement.  The `Arc` can be cloned and read from any
    /// thread without round-tripping through the router.
    pub fn signal(&self) -> Arc<LoadSignal> {
        Arc::clone(&self.signal)
    }

    /// Drain the router thread's span-trace ring buffer (see
    /// [`ServerOptions::trace`]).  Returns the events recorded since the
    /// last drain; the sink keeps recording afterwards.  On a server
    /// spawned without tracing the shard is empty with
    /// `dropped_events == 0`.
    pub fn take_trace(&self) -> Result<TraceShard> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::TakeTrace(tx))
            .map_err(|_| anyhow!("router thread gone"))?;
        Ok(rx.recv()?)
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One waiting request, in arrival order, plus the bookkeeping the
/// admission policy's starvation guard needs.  A preempted decode session
/// waits here too, carrying its resumable state in `resume` — requeued at
/// its arrival-order position, so the queue invariant (oldest first)
/// survives preemption churn.
struct Waiting {
    req: Request,
    reply: Replier,
    submitted: Instant,
    passed_over: u32,
    resume: Option<Resume>,
}

/// The suspended half of a preempted live session: the engine-side
/// [`SlotCheckpoint`] plus the router-side bookkeeping (streamed tokens,
/// original admission timings) that must survive the slot round-trip so a
/// resumed request reports the same `queue_us`/`ttft_us`/`admit_seq` it
/// would have unpreempted, and never re-streams a token.
struct Resume {
    ckpt: SlotCheckpoint,
    /// the pending (already banked + streamed) token the next decode
    /// step feeds — exactly the `Live::next` at preemption time
    next: i32,
    tokens: Vec<i32>,
    admitted: Instant,
    admit_seq: u64,
    first_token: Option<Instant>,
    batched_steps: u64,
    single_steps: u64,
    /// when the session was evicted (accumulates
    /// [`ServerStats::preempted_wait_us`] on resume)
    preempted_at: Instant,
}

/// One slot mid-chunked-prefill: the admission bookkeeping carried while
/// the engine's [`crate::coordinator::batch::PrefillState`] fills the
/// slot's banks chunk by chunk.  `admitted` is the slot-grant instant
/// (prefill start), so `queue_us` measures pure slot wait and TTFT picks
/// up the prefill time — the same split the virtual clock reports.
struct Fill {
    req: Request,
    reply: Replier,
    submitted: Instant,
    admitted: Instant,
    admit_seq: u64,
    /// prompt tokens not yet prefilled — span-trace bookkeeping only
    /// (the engine's `PrefillState` owns the authoritative cursor)
    remaining: usize,
}

impl Fill {
    /// Terminal error reply for a request that was admitted (slot granted,
    /// prefill started) but never produced a token.
    fn respond_err(self, err: String, shard: Option<usize>) {
        let resp = Response {
            id: self.req.id,
            result: Err(err),
            latency_us: us(Instant::now(), self.submitted),
            ttft_us: None,
            queue_us: Some(us(self.admitted, self.submitted)),
            admit_seq: Some(self.admit_seq),
            batched_steps: 0,
            single_steps: 0,
            shard,
        };
        self.reply.finish(resp);
    }
}

fn run_loop(mut eng: BatchEngine, rx: mpsc::Receiver<Msg>,
            opts: ServerOptions, signal: Arc<LoadSignal>) {
    let ServerOptions {
        policy,
        shard,
        prefill_chunk,
        queue_cap,
        trace,
        qos,
    } = opts;
    let slots = eng.slots();
    let mut waiting: VecDeque<Waiting> = VecDeque::new();
    let mut live: Vec<Option<Live>> = (0..slots).map(|_| None).collect();
    let mut filling: Vec<Option<Fill>> = (0..slots).map(|_| None).collect();
    let mut stats = ServerStats {
        slots,
        shard,
        policy: policy.label().to_string(),
        prefill_chunk,
        queue_cap,
        ..ServerStats::default()
    };
    let mut admit_seq: u64 = 0;
    // span-trace sink: a no-op ring unless the server was spawned with
    // `trace`; every record site below is guarded on `sink.enabled()` so
    // an untraced router never even reads the clock for telemetry
    let mut sink = TraceSink::on(trace);
    let mut cycle_idx: u64 = 0;

    loop {
        // ---- 1. drain control messages; block only when fully idle ------
        loop {
            let idle = waiting.is_empty()
                && live.iter().all(Option::is_none)
                && filling.iter().all(Option::is_none);
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            match msg {
                Msg::Shutdown => {
                    shutdown(waiting, live, filling, shard, &mut sink);
                    return;
                }
                Msg::Stats(tx) => {
                    let mut snap = stats.clone();
                    snap.planner = eng.planner_stats();
                    let _ = tx.send(snap);
                }
                Msg::TakeTrace(tx) => {
                    // the router's shard tag doubles as the trace pid;
                    // a standalone server drains as shard 0
                    let _ = tx.send(
                        sink.drain(Some(shard.unwrap_or(0)), "router"));
                }
                Msg::Submit(req, reply_sink) => {
                    let reply = Replier {
                        sink: reply_sink,
                        signal: Arc::clone(&signal),
                    };
                    if sink.enabled() {
                        sink.record(now_ns(),
                                    EventKind::Queued { id: req.id });
                    }
                    if req.gen_len == 0 {
                        // zero-length request: an immediate terminal
                        // success with no tokens — it never queues, never
                        // occupies a slot, and never ran prefill, so the
                        // never-happened fields stay `None`
                        stats.completed += 1;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Terminal {
                                id: req.id,
                                outcome: SpanOutcome::Ok,
                            });
                        }
                        let now = Instant::now();
                        reply.finish(Response {
                            id: req.id,
                            result: Ok(Vec::new()),
                            latency_us: us(now, now),
                            ttft_us: None,
                            queue_us: None,
                            admit_seq: None,
                            batched_steps: 0,
                            single_steps: 0,
                            shard,
                        });
                        continue;
                    }
                    if queue_cap > 0 && waiting.len() >= queue_cap {
                        // shed: an immediate terminal error beats an
                        // unbounded queue — the caller learns *now* that
                        // this backend is saturated
                        stats.shed_requests += 1;
                        stats.errored += 1;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Terminal {
                                id: req.id,
                                outcome: SpanOutcome::Shed,
                            });
                        }
                        reject(req.id, reply, Instant::now(), shard,
                               format!("overloaded: admission queue at \
                                        cap ({queue_cap})"));
                        continue;
                    }
                    waiting.push_back(Waiting {
                        req,
                        reply,
                        submitted: Instant::now(),
                        passed_over: 0,
                        resume: None,
                    });
                    stats.peak_waiting =
                        stats.peak_waiting.max(waiting.len());
                }
            }
        }

        // cycle span opens here — after the control-message drain, so
        // time spent blocked idle is never charged to a router cycle
        let cycle_start = if sink.enabled() { now_ns() } else { 0 };

        // ---- 2. completion sweep: bank the tokens the last decode cycle
        //         produced, retire finished slots ------------------------
        for slot in 0..slots {
            let Some(l) = live[slot].as_mut() else { continue };
            l.tokens.push(l.next);
            l.reply
                .token(l.req.id, l.tokens.len() as u64 - 1, l.next);
            let pos = eng.session(slot).map_or(0, |s| s.pos);
            let done = l.tokens.len() >= l.req.gen_len
                || pos >= eng.model().max_seq;
            if done {
                let l = live[slot].take().unwrap();
                finish_slot(&mut eng, &mut stats, slot, l, shard,
                            &mut sink);
            }
        }

        // ---- 2b. QoS preemption: if waiting interactive requests exceed
        //          the free slots, evict batch-tier slots (checkpoint →
        //          requeue for live sessions; release-and-restart for
        //          mid-prefill slots) until the latency tier fits.  The
        //          victim is the batch slot that can best afford it —
        //          largest EDF slack first — so preemption is deadline-
        //          aware on both sides: urgent arrivals ride the EDF
        //          admission order, and near-deadline batch jobs are the
        //          last evicted ----------------------------------------
        if qos {
            let interactive_waiting = waiting
                .iter()
                .filter(|w| w.req.priority == Priority::Interactive)
                .count();
            let free = (0..slots)
                .filter(|&s| live[s].is_none() && filling[s].is_none())
                .count();
            let mut need = interactive_waiting.saturating_sub(free);
            while need > 0 {
                let Some(slot) = preempt_victim(&live, &filling) else {
                    break;
                };
                if let Some(l) = live[slot].take() {
                    match eng.checkpoint_slot(slot) {
                        Ok(ckpt) => {
                            eng.release(slot);
                            stats.preemptions += 1;
                            if sink.enabled() {
                                sink.record(now_ns(), EventKind::Preempt {
                                    id: l.req.id,
                                    slot,
                                });
                            }
                            requeue(&mut waiting, Waiting {
                                resume: Some(Resume {
                                    ckpt,
                                    next: l.next,
                                    tokens: l.tokens,
                                    admitted: l.admitted,
                                    admit_seq: l.admit_seq,
                                    first_token: l.first_token,
                                    batched_steps: l.batched_steps,
                                    single_steps: l.single_steps,
                                    preempted_at: Instant::now(),
                                }),
                                req: l.req,
                                reply: l.reply,
                                submitted: l.submitted,
                                passed_over: 0,
                            });
                        }
                        Err(_) => {
                            // no decode state to snapshot (cannot happen
                            // for a live slot) — keep it rather than risk
                            // the stream
                            live[slot] = Some(l);
                            break;
                        }
                    }
                } else if let Some(f) = filling[slot].take() {
                    // mid-prefill: nothing decoded yet, so there is no
                    // checkpoint to take — release the slot and restart
                    // the (deterministic) prefill on readmission
                    eng.release(slot);
                    stats.preemptions += 1;
                    if sink.enabled() {
                        sink.record(now_ns(), EventKind::Preempt {
                            id: f.req.id,
                            slot,
                        });
                    }
                    requeue(&mut waiting, Waiting {
                        req: f.req,
                        reply: f.reply,
                        submitted: f.submitted,
                        passed_over: 0,
                        resume: None,
                    });
                }
                stats.peak_waiting = stats.peak_waiting.max(waiting.len());
                stats.peak_checkpoints = stats.peak_checkpoints.max(
                    waiting.iter().filter(|w| w.resume.is_some()).count(),
                );
                need -= 1;
            }
        }

        // ---- 3. policy-driven slot admission (after the sweep, so slots
        //         freed this cycle refill and ride this cycle's dispatch).
        //         The queue stays in arrival order; the policy picks an
        //         index into it (FIFO: always 0, preserving the seed
        //         behaviour and `admit_seq` monotonicity in submit order).
        //         Under QoS, freed slots are reserved for the interactive
        //         tier: the policy only sees interactive candidates while
        //         any are waiting.
        while !waiting.is_empty() && eng.free_slot().is_some() {
            let pick = pick_waiting(&policy, &waiting, qos);
            let w = waiting.remove(pick).expect("policy index in range");
            // only requests the pick actually jumped over (older than
            // it, i.e. at indices < pick) were passed over — younger
            // ones weren't, or a standing queue would age everyone
            // into the starvation guard and degrade SJF/EDF to FIFO
            for o in waiting.iter_mut().take(pick) {
                o.passed_over += 1;
            }
            let granted_at = Instant::now();
            if let Some(r) = w.resume {
                // a preempted session coming back: restore its banks +
                // cursor into a free slot and resume decoding this cycle;
                // the original admission bookkeeping (queue_us, ttft_us,
                // admit_seq, streamed tokens) carries over untouched
                match eng.restore_slot(&r.ckpt) {
                    Ok(slot) => {
                        stats.restores += 1;
                        stats.preempted_wait_us +=
                            us(granted_at, r.preempted_at) as u64;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Restore {
                                id: w.req.id,
                                slot,
                            });
                        }
                        live[slot] = Some(Live {
                            req: w.req,
                            reply: w.reply,
                            slot,
                            next: r.next,
                            tokens: r.tokens,
                            submitted: w.submitted,
                            admitted: r.admitted,
                            admit_seq: r.admit_seq,
                            first_token: r.first_token,
                            batched_steps: r.batched_steps,
                            single_steps: r.single_steps,
                        });
                    }
                    Err(e) => {
                        stats.errored += 1;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Terminal {
                                id: w.req.id,
                                outcome: SpanOutcome::Error,
                            });
                        }
                        // admitted once already: reply with the original
                        // admission timings plus the tokens it streamed
                        w.reply.finish(Response {
                            id: w.req.id,
                            result: Err(format!("restore failed: {e}")),
                            latency_us: us(Instant::now(), w.submitted),
                            ttft_us: r
                                .first_token
                                .map(|t| us(t, w.submitted)),
                            queue_us: Some(us(r.admitted, w.submitted)),
                            admit_seq: Some(r.admit_seq),
                            batched_steps: r.batched_steps,
                            single_steps: r.single_steps,
                            shard,
                        });
                    }
                }
                continue;
            }
            let (req, reply, submitted) = (w.req, w.reply, w.submitted);
            // the slot-grant instant: queue_us ends here, before any
            // prefill work, so TTFT (through the first sampled token)
            // carries the prefill cost — chunked and monolithic admission
            // report the same split
            let granted = Instant::now();
            if prefill_chunk > 0 {
                // chunked admission: claim the slot only; the prefill
                // advances chunk-by-chunk below, interleaved with decode
                match eng.begin_prefill(&req.prompt) {
                    Ok(slot) => {
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::SlotGrant {
                                id: req.id,
                                slot,
                            });
                        }
                        let remaining = req.prompt.len();
                        filling[slot] = Some(Fill {
                            req,
                            reply,
                            submitted,
                            admitted: granted,
                            admit_seq,
                            remaining,
                        });
                        admit_seq += 1;
                    }
                    Err(e) => {
                        stats.errored += 1;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Terminal {
                                id: req.id,
                                outcome: SpanOutcome::Error,
                            });
                        }
                        reject(req.id, reply, submitted, shard,
                               format!("prefill failed: {e}"));
                    }
                }
                continue;
            }
            match eng.admit(&req.prompt) {
                Ok((slot, next)) => {
                    if sink.enabled() {
                        let t = now_ns();
                        sink.record(t, EventKind::SlotGrant {
                            id: req.id,
                            slot,
                        });
                        sink.record(t, EventKind::FirstToken {
                            id: req.id,
                        });
                    }
                    // the prefill-sampled token is banked right away; the
                    // decode cycle below consumes it as `l.next`
                    let l = Live {
                        req,
                        reply,
                        slot,
                        next,
                        tokens: vec![next],
                        submitted,
                        admitted: granted,
                        admit_seq,
                        first_token: Some(Instant::now()),
                        batched_steps: 0,
                        single_steps: 0,
                    };
                    l.reply.token(l.req.id, 0, next);
                    admit_seq += 1;
                    let pos = eng.session(slot).map_or(0, |s| s.pos);
                    let done = l.tokens.len() >= l.req.gen_len
                        || pos >= eng.model().max_seq;
                    if done {
                        finish_slot(&mut eng, &mut stats, slot, l, shard,
                                    &mut sink);
                    } else {
                        live[slot] = Some(l);
                    }
                }
                Err(e) => {
                    stats.errored += 1;
                    if sink.enabled() {
                        sink.record(now_ns(), EventKind::Terminal {
                            id: req.id,
                            outcome: SpanOutcome::Error,
                        });
                    }
                    reject(req.id, reply, submitted, shard,
                           format!("prefill failed: {e}"));
                }
            }
        }

        // ---- 3b. chunked prefill: advance every filling slot by at most
        //          `prefill_chunk` prompt tokens, so long prompts fill in
        //          across cycles instead of stalling the decode dispatch
        //          below (the head-of-line blocking fix) -----------------
        if prefill_chunk > 0 {
            for slot in 0..slots {
                if filling[slot].is_none() {
                    continue;
                }
                let t = unix_us();
                if stats.first_dispatch_unix_us.is_none() {
                    stats.first_dispatch_unix_us = Some(t);
                }
                stats.last_dispatch_unix_us = Some(t);
                match eng.advance_prefill(slot, prefill_chunk) {
                    Ok(None) => {
                        stats.prefill_chunks += 1;
                        let f = filling[slot].as_mut().unwrap();
                        let advanced = f.remaining.min(prefill_chunk);
                        f.remaining -= advanced;
                        if sink.enabled() {
                            sink.record(now_ns(),
                                        EventKind::PrefillChunk {
                                id: f.req.id,
                                slot,
                                advanced,
                                remaining: f.remaining,
                            });
                        }
                    }
                    Ok(Some(first)) => {
                        stats.prefill_chunks += 1;
                        let f = filling[slot].take().unwrap();
                        if sink.enabled() {
                            let t = now_ns();
                            sink.record(t, EventKind::PrefillChunk {
                                id: f.req.id,
                                slot,
                                advanced: f.remaining.min(prefill_chunk),
                                remaining: 0,
                            });
                            sink.record(t, EventKind::FirstToken {
                                id: f.req.id,
                            });
                        }
                        // prefill complete: promote to a live decode
                        // session; it rides this cycle's dispatch, exactly
                        // like a freshly admitted monolithic request
                        let l = Live {
                            req: f.req,
                            reply: f.reply,
                            slot,
                            next: first,
                            tokens: vec![first],
                            submitted: f.submitted,
                            admitted: f.admitted,
                            admit_seq: f.admit_seq,
                            first_token: Some(Instant::now()),
                            batched_steps: 0,
                            single_steps: 0,
                        };
                        l.reply.token(l.req.id, 0, first);
                        let pos = eng.session(slot).map_or(0, |s| s.pos);
                        let done = l.tokens.len() >= l.req.gen_len
                            || pos >= eng.model().max_seq;
                        if done {
                            finish_slot(&mut eng, &mut stats, slot, l,
                                        shard, &mut sink);
                        } else {
                            live[slot] = Some(l);
                        }
                    }
                    Err(e) => {
                        let f = filling[slot].take().unwrap();
                        eng.release(slot);
                        stats.errored += 1;
                        if sink.enabled() {
                            sink.record(now_ns(), EventKind::Terminal {
                                id: f.req.id,
                                outcome: SpanOutcome::Error,
                            });
                        }
                        f.respond_err(format!("prefill failed: {e}"),
                                      shard);
                    }
                }
            }
        }

        // ---- 4. one decode cycle over every live slot -------------------
        let steps: Vec<(usize, i32)> = live
            .iter()
            .flatten()
            .map(|l| (l.slot, l.next))
            .collect();
        // snapshot the cumulative planner stats so the cycle span can
        // report this cycle's plan cost as a diff (traced runs only)
        let planner_pre =
            if sink.enabled() { Some(eng.planner_stats()) } else { None };
        if !steps.is_empty() {
            // stamp the dispatch on the unix clock: the cross-shard
            // overlap evidence the concurrent-cluster tests read
            let t = unix_us();
            if stats.first_dispatch_unix_us.is_none() {
                stats.first_dispatch_unix_us = Some(t);
            }
            stats.last_dispatch_unix_us = Some(t);
            if steps.len() == 1 {
                // odd-sized tail: single-token fallback over pooled
                // storage
                let (slot, token) = steps[0];
                match eng.decode_single(slot, token) {
                    Ok((next, _plans)) => {
                        let l = live[slot].as_mut().unwrap();
                        l.next = next;
                        l.single_steps += 1;
                        stats.single_dispatches += 1;
                    }
                    Err(e) => fail_slot(&mut eng, &mut live, &mut stats,
                                        slot, e, shard, &mut sink),
                }
            } else {
                match eng.decode_batch(&steps) {
                    Ok(step) => {
                        stats.batch_dispatches += 1;
                        stats.batched_tokens += step.next.len() as u64;
                        for (slot, next) in step.next {
                            let l = live[slot].as_mut().unwrap();
                            l.next = next;
                            l.batched_steps += 1;
                        }
                    }
                    Err(e) => {
                        // a failed batch dispatch must not sink every
                        // rider: retry each slot alone so only the
                        // culprit errors out
                        let batch_err = e.to_string();
                        for (slot, token) in steps {
                            match eng.decode_single(slot, token) {
                                Ok((next, _plans)) => {
                                    let l = live[slot].as_mut().unwrap();
                                    l.next = next;
                                    l.single_steps += 1;
                                    stats.single_dispatches += 1;
                                }
                                Err(e) => fail_slot(
                                    &mut eng,
                                    &mut live,
                                    &mut stats,
                                    slot,
                                    anyhow!("{batch_err}; retry: {e}"),
                                    shard,
                                    &mut sink,
                                ),
                            }
                        }
                    }
                }
            }
        }

        // close the cycle span and sample queue depths (traced runs only;
        // `plan_cycles`/`contention` are this cycle's planner-cost diff)
        if let Some(pre) = planner_pre {
            let post = eng.planner_stats();
            let t = now_ns();
            let live_n = live.iter().flatten().count();
            let filling_n = filling.iter().flatten().count();
            sink.record_span(
                cycle_start,
                t.saturating_sub(cycle_start),
                EventKind::Cycle {
                    index: cycle_idx,
                    live: live_n,
                    filling: filling_n,
                    waiting: waiting.len(),
                    layer_steps: post.steps.saturating_sub(pre.steps)
                        as usize,
                    plan_cycles: post.cycles.saturating_sub(pre.cycles),
                    contention: post
                        .contention_cycles
                        .saturating_sub(pre.contention_cycles),
                },
            );
            cycle_idx += 1;
            sink.record(t, EventKind::Depth {
                waiting: waiting.len(),
                live: live_n,
                filling: filling_n,
                intake: 0,
            });
        }
    }
}

/// Index into `waiting` the next admission takes.  With `qos` and any
/// interactive request waiting, the policy only sees the interactive
/// candidates (slot reservation for the latency tier); otherwise the whole
/// queue — which for FIFO degenerates to index 0, the seed behaviour.
fn pick_waiting(policy: &AdmissionPolicy, waiting: &VecDeque<Waiting>,
                qos: bool) -> usize {
    let candidates: Vec<usize> = if qos
        && waiting.iter().any(|w| w.req.priority == Priority::Interactive)
    {
        waiting
            .iter()
            .enumerate()
            .filter(|(_, w)| w.req.priority == Priority::Interactive)
            .map(|(i, _)| i)
            .collect()
    } else {
        (0..waiting.len()).collect()
    };
    if matches!(policy, AdmissionPolicy::Fifo) {
        return candidates[0];
    }
    let now = Instant::now();
    let metas: Vec<QueuedMeta> = candidates
        .iter()
        .map(|&i| {
            let w = &waiting[i];
            QueuedMeta {
                gen_len: w.req.gen_len,
                deadline_us: w.req.deadline_us,
                waited_us: us(now, w.submitted) as u64,
                passed_over: w.passed_over,
            }
        })
        .collect();
    candidates[policy.select(&metas).min(candidates.len() - 1)]
}

/// The batch-tier slot to evict next: largest EDF slack first (deadline-
/// less jobs count as infinite slack and go first), ties to the larger
/// slot index.  `None` when no preemptible (batch-tier) slot exists —
/// interactive sessions are never evicted.
fn preempt_victim(live: &[Option<Live>], filling: &[Option<Fill>])
    -> Option<usize> {
    let now = Instant::now();
    let mut best: Option<(i64, usize)> = None;
    for slot in 0..live.len() {
        let (prio, deadline, submitted) = if let Some(l) = &live[slot] {
            (l.req.priority, l.req.deadline_us, l.submitted)
        } else if let Some(f) = &filling[slot] {
            (f.req.priority, f.req.deadline_us, f.submitted)
        } else {
            continue;
        };
        if prio != Priority::Batch {
            continue;
        }
        let slack = match deadline {
            Some(d) => d as i64 - us(now, submitted) as i64,
            None => i64::MAX,
        };
        if best.map_or(true, |b| (slack, slot) > b) {
            best = Some((slack, slot));
        }
    }
    best.map(|(_, slot)| slot)
}

/// Re-insert a preempted request at its arrival-order position: the
/// waiting queue's oldest-first invariant is what the starvation guard
/// and pass-over accounting assume, and it keeps a preempted request's
/// place in line instead of sending it to the back.
fn requeue(waiting: &mut VecDeque<Waiting>, w: Waiting) {
    let idx = waiting
        .iter()
        .position(|o| o.submitted > w.submitted)
        .unwrap_or(waiting.len());
    waiting.insert(idx, w);
}

/// Retire a finished request: free its slot, record stats, reply.
fn finish_slot(eng: &mut BatchEngine, stats: &mut ServerStats, slot: usize,
               mut l: Live, shard: Option<usize>, sink: &mut TraceSink) {
    eng.release(slot);
    stats.completed += 1;
    stats.tokens_generated += l.tokens.len() as u64;
    if sink.enabled() {
        sink.record(now_ns(), EventKind::Terminal {
            id: l.req.id,
            outcome: SpanOutcome::Ok,
        });
    }
    let tokens = std::mem::take(&mut l.tokens);
    l.respond(Ok(tokens), shard);
}

/// Retire `slot` with a terminal error reply.
fn fail_slot(eng: &mut BatchEngine, live: &mut [Option<Live>],
             stats: &mut ServerStats, slot: usize, err: anyhow::Error,
             shard: Option<usize>, sink: &mut TraceSink) {
    if let Some(l) = live[slot].take() {
        eng.release(slot);
        stats.errored += 1;
        if sink.enabled() {
            sink.record(now_ns(), EventKind::Terminal {
                id: l.req.id,
                outcome: SpanOutcome::Error,
            });
        }
        l.respond(Err(format!("decode failed: {err}")), shard);
    }
}

/// Terminal replies for everything in flight at shutdown: waiting,
/// mid-prefill, and live (possibly mid-stream) requests each get exactly
/// one terminal error — the exactly-once pin in
/// `rust/tests/cluster_concurrent.rs`.  Each also gets a terminal span
/// event, preserving the trace's one-terminal-per-request conservation
/// law even across shutdown (the sink dies with the router thread, so
/// these events are only visible to a `take_trace` that already drained —
/// they keep the *ring* consistent, not the exported file).
fn shutdown(waiting: VecDeque<Waiting>, live: Vec<Option<Live>>,
            filling: Vec<Option<Fill>>, shard: Option<usize>,
            sink: &mut TraceSink) {
    let t = if sink.enabled() { now_ns() } else { 0 };
    for w in waiting {
        if sink.enabled() {
            sink.record(t, EventKind::Terminal {
                id: w.req.id,
                outcome: SpanOutcome::Error,
            });
        }
        reject(w.req.id, w.reply, w.submitted, shard,
               "server shut down".into());
    }
    for l in live.into_iter().flatten() {
        if sink.enabled() {
            sink.record(t, EventKind::Terminal {
                id: l.req.id,
                outcome: SpanOutcome::Error,
            });
        }
        l.respond(Err("server shut down".into()), shard);
    }
    for f in filling.into_iter().flatten() {
        if sink.enabled() {
            sink.record(t, EventKind::Terminal {
                id: f.req.id,
                outcome: SpanOutcome::Error,
            });
        }
        f.respond_err("server shut down".into(), shard);
    }
}
