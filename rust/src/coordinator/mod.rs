//! Serving coordinator (L3 runtime path): the functional model engine with
//! KV + GO cache state, and a threaded round-robin batching server.

pub mod engine;
pub mod server;

pub use engine::{DecodeMode, GenerationResult, ModelEngine, Session};
pub use server::{Request, Response, Server};
