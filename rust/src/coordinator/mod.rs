//! Serving coordinator (L3 runtime path): the functional model engine with
//! KV + GO cache state, the slot-batched [`BatchEngine`] that advances all
//! live sessions with one dispatch per pipeline stage, and the threaded
//! serving loop built on slot admission.

pub mod batch;
pub mod engine;
pub mod server;

pub use batch::{BatchEngine, BatchStep, PrefillState, SlotSession};
pub use engine::{DecodeMode, GenerationResult, ModelEngine, Session};
pub use server::{Request, Response, Server, ServerOptions, ServerStats};
