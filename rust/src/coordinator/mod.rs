//! Serving coordinator (L3 runtime path): the functional model engine with
//! KV + GO cache state, the slot-batched [`BatchEngine`] that advances all
//! live sessions with one dispatch per pipeline stage, the threaded
//! serving loop built on slot admission, and the [`Cluster`] front door
//! that runs N of those serving loops genuinely concurrently behind one
//! bounded intake queue with live-signal placement, streaming replies,
//! and load shedding.

pub mod batch;
pub mod cluster;
pub mod engine;
pub mod server;

pub use batch::{
    BatchEngine, BatchStep, PrefillState, SlotCheckpoint, SlotSession,
};
pub use cluster::{
    Cluster, ClusterOptions, ClusterPlacement, ClusterStats,
    DEFAULT_INTAKE_CAP,
};
pub use engine::{DecodeMode, GenerationResult, ModelEngine, Session};
pub use server::{
    LoadSignal, Reply, Request, Response, Server, ServerOptions,
    ServerStats,
};
