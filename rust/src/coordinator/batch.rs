//! Slot-batched serving engine: one HLO dispatch advances every live
//! session one token.
//!
//! [`BatchEngine`] owns a fixed pool of `B = manifest.batch_slots` serving
//! slots with pooled KV storage ([`KvPool`], one contiguous `[B, S, H, Dh]`
//! pair the batched attention artifact borrows directly) and one GO cache
//! per slot.  The decode path is:
//!
//! 1. `embed_batch` + `attn_decode_batch` + `gate_batch` — one dispatch
//!    each over all B rows (inactive slots ride along as masked padding
//!    whose outputs are discarded);
//! 2. per-slot `TopKUpdate` on each active row's gate scores (host side,
//!    exactly the per-session streaming update) — *peeked* first and only
//!    committed after every fallible dispatch succeeded, so a failed batch
//!    step leaves all slot state untouched and is safe to retry;
//! 3. the [`BatchPlanner`] lays the step's expert sets out on the grouped
//!    peripherals — the cycle-by-cycle execution order on the modeled chip
//!    and the per-step contention telemetry the server exports;
//! 4. `moe_batch_sparse` — one dispatch computing every active row's
//!    selected experts (rows whose update selected more than
//!    `expert_capacity` experts fall back to the dense `moe_one` for that
//!    row, mirroring the single-token path's guard).
//!
//! Every batched artifact unrolls B copies of the exact single-token
//! subgraph (see python/compile/model.py), so each row's numerics are
//! bit-compatible with the per-session cached path —
//! `rust/tests/batch_equivalence.rs` pins the token streams.
//!
//! For odd-sized tails (a single live session), [`BatchEngine::decode_single`]
//! runs the single-token artifacts over the same pooled storage —
//! borrowed, never cloned.

use anyhow::{anyhow, Result};

use crate::cache::{GoCache, KvPool};
use crate::config::manifest::FunctionalModel;
use crate::config::SchedulePolicy;
use crate::coordinator::engine::ModelEngine;
use crate::moe::gate::softmax_rows;
use crate::runtime::executor::TensorIn;
use crate::sched::{BatchPlan, BatchPlanner, PlannerStats};

/// One live slot's sequence state (KV/GO state lives in the pools).
#[derive(Debug, Clone)]
pub struct SlotSession {
    pub ids: Vec<i32>,
    /// position of the next token to be written (== ids.len())
    pub pos: usize,
}

/// Result of one batched decode step.
#[derive(Debug, Clone)]
pub struct BatchStep {
    /// (slot, sampled next token) for every advanced slot, in step order
    pub next: Vec<(usize, i32)>,
    /// the planner's execution layout + contention telemetry for this step
    pub plan: BatchPlan,
}

pub struct BatchEngine {
    engine: ModelEngine,
    slots: usize,
    kv: KvPool,
    go: Vec<GoCache>,
    sessions: Vec<Option<SlotSession>>,
    planner: BatchPlanner,
}

impl BatchEngine {
    /// Wrap `engine` with a `manifest.batch_slots`-wide slot pool and a
    /// group-aware planner (paper defaults: uniform grouping of size 2
    /// where divisible, Algorithm 1 rescheduling).
    pub fn new(engine: ModelEngine) -> Self {
        let m = engine.model.clone();
        let group_size = if m.n_experts % 2 == 0 { 2 } else { 1 };
        let planner = BatchPlanner::new(
            m.n_experts,
            group_size,
            SchedulePolicy::Reschedule,
        );
        Self::with_planner(engine, planner)
    }

    pub fn with_planner(engine: ModelEngine, planner: BatchPlanner) -> Self {
        // the batched MoE dispatch is always sparse-gather; force the
        // single-token fallback onto the same path so a session's stream
        // never depends on whether it rode a batch or decoded alone
        let engine = engine.with_sparse_moe(true);
        let m = engine.model.clone();
        let slots = m.batch_slots.max(1);
        BatchEngine {
            kv: KvPool::new(slots, m.max_seq, m.n_heads, m.d_head),
            go: (0..slots)
                .map(|_| GoCache::new(m.n_experts, m.expert_capacity, 0))
                .collect(),
            sessions: vec![None; slots],
            slots,
            engine,
            planner,
        }
    }

    pub fn model(&self) -> &FunctionalModel {
        &self.engine.model
    }

    pub fn engine(&self) -> &ModelEngine {
        &self.engine
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Slots currently holding a live session.
    pub fn occupied(&self) -> Vec<usize> {
        (0..self.slots).filter(|&s| self.sessions[s].is_some()).collect()
    }

    pub fn free_slot(&self) -> Option<usize> {
        (0..self.slots).find(|&s| self.sessions[s].is_none())
    }

    pub fn session(&self, slot: usize) -> Option<&SlotSession> {
        self.sessions[slot].as_ref()
    }

    pub fn planner_stats(&self) -> PlannerStats {
        self.planner.stats()
    }

    /// Prefill `prompt` into a free slot; returns (slot, first sampled
    /// token).  Fails without touching any slot when the pool is full or
    /// the prompt is invalid.
    pub fn admit(&mut self, prompt: &[i32]) -> Result<(usize, i32)> {
        let slot = self
            .free_slot()
            .ok_or_else(|| anyhow!("no free serving slot"))?;
        let m = self.engine.model.clone();
        let t = prompt.len();
        let (y, routing, k, v) = self.engine.prefill_pipeline(prompt)?;
        // seed_slot overwrites the slot's whole padded region, so no
        // zero-fill is needed here (release() already reset it anyway)
        self.kv.seed_slot(slot, &k, &v, t);
        self.go[slot].reset();
        self.go[slot].seed_from_routing(&routing);
        let next =
            self.engine.sample(&y[(t - 1) * m.d_model..t * m.d_model], t)?;
        self.sessions[slot] = Some(SlotSession { ids: prompt.to_vec(), pos: t });
        Ok((slot, next))
    }

    /// Free `slot` for the next request, returning its final session state.
    pub fn release(&mut self, slot: usize) -> Option<SlotSession> {
        let sess = self.sessions[slot].take();
        if sess.is_some() {
            self.kv.reset_slot(slot);
            self.go[slot].reset();
        }
        sess
    }

    /// One batched decode step: advance every `(slot, token)` in `steps` by
    /// one token with a single dispatch per pipeline stage.
    pub fn decode_batch(&mut self, steps: &[(usize, i32)]) -> Result<BatchStep> {
        let m = self.engine.model.clone();
        if steps.is_empty() {
            return Err(anyhow!("empty batch step"));
        }
        let b = self.slots;
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        for &(slot, token) in steps {
            if slot >= b {
                return Err(anyhow!("slot {slot} out of range"));
            }
            if active[slot] {
                return Err(anyhow!("slot {slot} appears twice in one step"));
            }
            let sess = self.sessions[slot]
                .as_ref()
                .ok_or_else(|| anyhow!("slot {slot} has no live session"))?;
            if sess.pos >= m.max_seq {
                return Err(anyhow!("slot {slot} at max_seq"));
            }
            tokens[slot] = token;
            pos[slot] = sess.pos as i32;
            active[slot] = true;
        }

        let rt = self.engine.runtime();
        let x = rt
            .get("embed_batch")?
            .run(&[TensorIn::I32(&tokens)])?
            .remove(0)
            .into_f32()?;
        let mut attn = rt.get("attn_decode_batch")?.run(&[
            TensorIn::F32(&x),
            TensorIn::F32(self.kv.k_all()),
            TensorIn::F32(self.kv.v_all()),
            TensorIn::I32(&pos),
        ])?;
        let h = attn.remove(0).into_f32()?;
        let k_new = attn.remove(0).into_f32()?;
        let v_new = attn.remove(0).into_f32()?;
        let scores = rt
            .get("gate_batch")?
            .run(&[TensorIn::F32(&h)])?
            .remove(0)
            .into_f32()?;

        // Host-side routing, *peeked*: selection is computed against the
        // current GO state but nothing mutates until every fallible
        // dispatch below has succeeded, so a failed step leaves all slots
        // untouched and the server can safely retry them one by one.
        let (e, cap, d) = (m.n_experts, m.expert_capacity, m.d_model);
        let mut idx = vec![0i32; b * cap];
        let mut gates = vec![0f32; b * cap];
        let mut upds = Vec::with_capacity(steps.len());
        // rows whose update selected more than `cap` experts (possible right
        // after TopKUpdate under-full edge cases) use the dense single-row
        // MoE, exactly like the single-token path's guard
        let mut dense_rows: Vec<(usize, Vec<f32>)> = Vec::new();
        for &(slot, _) in steps {
            let sess_pos = self.sessions[slot].as_ref().unwrap().pos;
            let row = &scores[slot * e..(slot + 1) * e];
            let probs = softmax_rows(row, 1, e);
            let upd = self.go[slot].peek_probs(sess_pos, &probs);
            if upd.selected.len() <= cap {
                for (i, &ex) in upd.selected.iter().enumerate() {
                    idx[slot * cap + i] = ex as i32;
                    gates[slot * cap + i] = probs[ex];
                }
            } else {
                let mut dense_g = vec![0f32; e];
                for &ex in &upd.selected {
                    dense_g[ex] = probs[ex];
                }
                dense_rows.push((slot, dense_g));
            }
            upds.push(upd);
        }

        let mut y = rt
            .get("moe_batch_sparse")?
            .run(&[
                TensorIn::F32(&h),
                TensorIn::I32(&idx),
                TensorIn::F32(&gates),
            ])?
            .remove(0)
            .into_f32()?;
        for &(slot, ref dense_g) in &dense_rows {
            let y1 = rt
                .get("moe_one")?
                .run(&[
                    TensorIn::F32(&h[slot * d..(slot + 1) * d]),
                    TensorIn::F32(dense_g.as_slice()),
                ])?
                .remove(0)
                .into_f32()?;
            y[slot * d..(slot + 1) * d].copy_from_slice(&y1);
        }

        // Last fallible stage: sample every advanced row's next token.
        let mut next = Vec::with_capacity(steps.len());
        for &(slot, _) in steps {
            let pos_after = self.sessions[slot].as_ref().unwrap().pos + 1;
            let nt = self
                .engine
                .sample(&y[slot * d..(slot + 1) * d], pos_after)?;
            next.push((slot, nt));
        }

        // Commit (infallible from here): plan the step on the grouped
        // peripherals (the modeled chip's execution order + contention
        // telemetry — accumulated only for steps that actually landed),
        // apply GO updates, append K/V rows, advance sessions.
        let expert_sets: Vec<Vec<usize>> =
            upds.iter().map(|u| u.selected.clone()).collect();
        let plan = self.planner.plan(&expert_sets);
        let r = self.kv.row_elems();
        for (&(slot, token), upd) in steps.iter().zip(&upds) {
            let sess_pos = self.sessions[slot].as_ref().unwrap().pos;
            self.go[slot].apply_update(sess_pos, upd);
            self.kv.append_slot(
                slot,
                &k_new[slot * r..(slot + 1) * r],
                &v_new[slot * r..(slot + 1) * r],
            );
            let sess = self.sessions[slot].as_mut().unwrap();
            sess.ids.push(token);
            sess.pos += 1;
        }
        Ok(BatchStep { next, plan })
    }

    /// Single-token fallback for odd-sized tails: the per-token artifacts
    /// over the same pooled storage (KV buffers borrowed, not cloned).
    pub fn decode_single(&mut self, slot: usize, token: i32)
        -> Result<(i32, BatchPlan)> {
        let max_seq = self.engine.model.max_seq;
        let pos = match self.sessions[slot].as_ref() {
            Some(s) if s.pos >= max_seq => {
                return Err(anyhow!("slot {slot} at max_seq"))
            }
            Some(s) => s.pos,
            None => return Err(anyhow!("slot {slot} has no live session")),
        };
        let step = self.engine.decode_core(
            self.kv.slot_k(slot),
            self.kv.slot_v(slot),
            pos,
            &mut self.go[slot],
            token,
        )?;
        self.kv.append_slot(slot, &step.k_row, &step.v_row);
        let sess = self.sessions[slot].as_mut().unwrap();
        sess.ids.push(token);
        sess.pos += 1;
        let plan = self.planner.plan(std::slice::from_ref(&step.selected));
        Ok((step.next, plan))
    }
}
