//! Slot-batched serving engine: one HLO dispatch per pipeline stage *per
//! layer* advances every live session one token.
//!
//! [`BatchEngine`] owns a fixed pool of `B = manifest.batch_slots` serving
//! slots with pooled per-layer KV storage ([`KvPool`], one contiguous
//! `[L, B, S, H, Dh]` pair whose layer banks the batched attention
//! artifacts borrow directly) and one GO bank per slot *per layer*.  The
//! decode path runs the stack depth-first:
//!
//! 1. `embed_batch`, then for each layer `l`: `attn_decode_batch[_l{l}]` +
//!    `gate_batch[_l{l}]` — one dispatch each over all B rows (inactive
//!    slots ride along as masked padding whose outputs are discarded);
//! 2. per-slot `TopKUpdate` on each active row's layer-`l` gate scores
//!    (host side, exactly the per-session streaming update) — *peeked*
//!    only: nothing mutates until every fallible dispatch of every layer
//!    has succeeded, so a failed step leaves all L layers of all slots
//!    untouched and is safe to retry;
//! 3. `moe_batch_sparse[_l{l}]` — one dispatch computing every active
//!    row's selected experts at layer `l` (rows whose update selected more
//!    than the layer's `expert_capacity` experts fall back to the dense
//!    `moe_one[_l{l}]` for that row, mirroring the single-token path's
//!    per-layer guard); the MoE output is the next layer's input;
//! 4. after sampling, the **transactional commit covers all L layers of
//!    the step**: the [`BatchPlanner`] prices the step as L planned
//!    layer-steps (the per-step contention telemetry the server exports),
//!    every layer's GO updates are applied, every layer's K/V rows are
//!    appended, and the sessions advance — all infallible.
//!
//! Every batched artifact unrolls B copies of the exact single-token
//! subgraph (see python/compile/model.py), so each row's numerics are
//! bit-compatible with the per-session cached path —
//! `rust/tests/batch_equivalence.rs` pins the token streams at every
//! artifact depth.
//!
//! For odd-sized tails (a single live session), [`BatchEngine::decode_single`]
//! runs the single-token artifacts over the same pooled storage —
//! borrowed, never cloned.

use anyhow::{anyhow, Result};

use crate::cache::{GoCache, GoUpdate, KvPool};
use crate::config::manifest::FunctionalModel;
use crate::config::SchedulePolicy;
use crate::coordinator::engine::ModelEngine;
use crate::moe::gate::softmax_rows;
use crate::runtime::executor::TensorIn;
use crate::sched::{BatchPlan, BatchPlanner, PlannerStats};

/// One live slot's sequence state (KV/GO state lives in the pools).
#[derive(Debug, Clone, PartialEq)]
pub struct SlotSession {
    /// prompt + generated token ids so far
    pub ids: Vec<i32>,
    /// position of the next token to be written (== ids.len())
    pub pos: usize,
}

/// A suspended decode session: everything a live slot holds, lifted out of
/// the pools so the slot can serve another request and the session can be
/// resumed later — possibly into a *different* slot — bit-identically.
///
/// Contents: the [`SlotSession`] cursor (token ids + write position), each
/// layer's full padded KV bank (`[S, H, Dh]` per layer, exactly what
/// [`KvPool::seed_slot`] re-installs wholesale) with the valid row count,
/// and each layer's GO bank (score + output caches) by value.  Restore is
/// bit-exact because (a) `seed_slot` overwrites the slot's whole padded
/// region, so even the zero padding the batched artifacts read matches,
/// (b) GO banks are plain-old-data clones, and (c) sampling is a pure
/// function of `(logits, pos)` — no hidden rng — so a resumed session's
/// next dispatch sees byte-identical inputs (pinned at every checkpoint
/// step in `rust/tests/batch_equivalence.rs`, and at the pool level in
/// `rust/tests/props_qos.rs`).
///
/// This is the decode-side symmetric of PR 5's [`PrefillState`]: the
/// paper's GO-cache makes suspension cheap precisely because resuming
/// needs no re-run of the expert-choice router over past hidden states
/// (PAPER.md §IV) — the caches *are* the resumable state.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotCheckpoint {
    /// the suspended session's token ids + position cursor
    pub session: SlotSession,
    /// per-layer full padded K banks (`[S, H, Dh]` each)
    kv_k: Vec<Vec<f32>>,
    /// per-layer full padded V banks
    kv_v: Vec<Vec<f32>>,
    /// valid KV rows (shared by all layers)
    kv_len: usize,
    /// per-layer GO banks, by value
    go: Vec<GoCache>,
}

impl SlotCheckpoint {
    /// Snapshot `slot`'s pool state plus `session` cursor.  Pool-level
    /// (no engine needed) so property tests can drive bare pools.
    pub fn capture(kv: &KvPool, go: &[GoCache], session: &SlotSession,
                   slot: usize) -> Self {
        let layers = kv.n_layers();
        SlotCheckpoint {
            session: session.clone(),
            kv_k: (0..layers).map(|l| kv.slot_k(l, slot).to_vec()).collect(),
            kv_v: (0..layers).map(|l| kv.slot_v(l, slot).to_vec()).collect(),
            kv_len: kv.len(slot),
            go: go.to_vec(),
        }
    }

    /// Install the snapshot into `slot` of `kv`/`go`, overwriting whatever
    /// the slot held (callers reset/claim the slot first).  The inverse of
    /// [`SlotCheckpoint::capture`]: banks come back byte-identical.
    pub fn restore_into(&self, kv: &mut KvPool, go: &mut [GoCache],
                        slot: usize) {
        kv.seed_slot(slot, &self.kv_k, &self.kv_v, self.kv_len);
        go.clone_from_slice(&self.go);
    }

    /// Layers captured (sanity hook for restore-shape validation).
    pub fn n_layers(&self) -> usize {
        self.kv_k.len()
    }

    /// Valid KV rows at capture time.
    pub fn kv_len(&self) -> usize {
        self.kv_len
    }
}

/// An in-progress chunked prefill occupying a serving slot
/// (see [`BatchEngine::begin_prefill`] / [`BatchEngine::advance_prefill`]).
///
/// The prompt cursor tracks how far the slot's KV/GO banks are filled: the
/// pools currently hold the pipeline state of the length-`cursor` prefix.
/// The padded prefill artifacts recompute the whole valid prefix per
/// dispatch (fixed-shape HLO), so each chunk advance replays the layer
/// stack at the grown prefix length and re-seeds the banks; the *final*
/// chunk runs at the full prompt length, making its dispatches — and
/// therefore the banks it leaves behind and the first sampled token —
/// bit-identical to a monolithic [`BatchEngine::admit`].
#[derive(Debug, Clone)]
pub struct PrefillState {
    /// the full prompt being prefilled into this slot
    pub prompt: Vec<i32>,
    /// prompt tokens whose pipeline state the slot's banks currently hold
    /// (`0..cursor` filled; prefill completes when `cursor == prompt.len()`)
    pub cursor: usize,
    /// cached `embed_prefill` output (computed on the first chunk; the
    /// embedding has no valid-length input, so it is chunk-invariant)
    embed: Option<Vec<f32>>,
}

/// Result of one batched decode step.
#[derive(Debug, Clone)]
pub struct BatchStep {
    /// (slot, sampled next token) for every advanced slot, in step order
    pub next: Vec<(usize, i32)>,
    /// the planner's execution layout + contention telemetry, one
    /// [`BatchPlan`] per functional layer (len == `model.n_layers`)
    pub plans: Vec<BatchPlan>,
}

/// The slot-batched serving engine: a fixed pool of serving slots over
/// pooled per-layer KV/GO storage, advanced one token per decode cycle
/// with one dispatch per pipeline stage per layer (see the module docs
/// for the full cycle anatomy).
pub struct BatchEngine {
    engine: ModelEngine,
    slots: usize,
    kv: KvPool,
    /// `go[slot][layer]` — one GO bank per slot per layer
    go: Vec<Vec<GoCache>>,
    sessions: Vec<Option<SlotSession>>,
    /// per-slot in-progress chunked prefill (a slot is either decoding —
    /// `sessions[s]` — or prefilling — `prefill[s]` — never both)
    prefill: Vec<Option<PrefillState>>,
    planner: BatchPlanner,
}

impl BatchEngine {
    /// Wrap `engine` with a `manifest.batch_slots`-wide slot pool and a
    /// group-aware planner (paper defaults: uniform grouping of size 2
    /// where divisible, Algorithm 1 rescheduling).
    pub fn new(engine: ModelEngine) -> Self {
        let m = engine.model.clone();
        let group_size = if m.n_experts % 2 == 0 { 2 } else { 1 };
        let planner = BatchPlanner::new(
            m.n_experts,
            group_size,
            SchedulePolicy::Reschedule,
        );
        Self::with_planner(engine, planner)
    }

    /// Wrap `engine` with an explicit [`BatchPlanner`] (the grouping /
    /// schedule-policy knob the paper's contention studies turn).
    pub fn with_planner(engine: ModelEngine, planner: BatchPlanner) -> Self {
        // the batched MoE dispatch is always sparse-gather; force the
        // single-token fallback onto the same path so a session's stream
        // never depends on whether it rode a batch or decoded alone
        let engine = engine.with_sparse_moe(true);
        let m = engine.model.clone();
        let slots = m.batch_slots.max(1);
        BatchEngine {
            kv: KvPool::new(m.n_layers, slots, m.max_seq, m.n_heads,
                            m.d_head),
            go: (0..slots)
                .map(|_| {
                    GoCache::banks(
                        &m.expert_capacity_per_layer,
                        m.n_experts,
                        0,
                    )
                })
                .collect(),
            sessions: vec![None; slots],
            prefill: vec![None; slots],
            slots,
            engine,
            planner,
        }
    }

    /// The loaded model's manifest-derived shape.
    pub fn model(&self) -> &FunctionalModel {
        &self.engine.model
    }

    /// The wrapped per-session engine (shared decode core).
    pub fn engine(&self) -> &ModelEngine {
        &self.engine
    }

    /// Serving slots (batch width B, from the manifest).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Slots currently holding a live session.
    pub fn occupied(&self) -> Vec<usize> {
        (0..self.slots).filter(|&s| self.sessions[s].is_some()).collect()
    }

    /// The lowest-indexed free slot (neither decoding nor mid-prefill),
    /// if any.
    pub fn free_slot(&self) -> Option<usize> {
        (0..self.slots)
            .find(|&s| self.sessions[s].is_none() && self.prefill[s].is_none())
    }

    /// The live session in `slot`, if any.
    pub fn session(&self, slot: usize) -> Option<&SlotSession> {
        self.sessions[slot].as_ref()
    }

    /// Slots currently mid-chunked-prefill (holding a [`PrefillState`]).
    pub fn prefilling(&self) -> Vec<usize> {
        (0..self.slots).filter(|&s| self.prefill[s].is_some()).collect()
    }

    /// The in-progress prefill in `slot`, if any.
    pub fn prefill_state(&self, slot: usize) -> Option<&PrefillState> {
        self.prefill[slot].as_ref()
    }

    /// Cumulative planner telemetry over every committed step.
    pub fn planner_stats(&self) -> PlannerStats {
        self.planner.stats()
    }

    /// Prefill `prompt` into a free slot; returns (slot, first sampled
    /// token).  Fails without leaving any slot occupied when the pool is
    /// full or the prompt is invalid.
    ///
    /// Implemented as [`BatchEngine::begin_prefill`] plus one full-length
    /// [`BatchEngine::advance_prefill`], so monolithic admission *is* the
    /// single-chunk case — chunked/monolithic stream equivalence is
    /// structural (one prefill code path), not merely test-enforced, and
    /// prompt rows are priced on the planner identically either way.
    pub fn admit(&mut self, prompt: &[i32]) -> Result<(usize, i32)> {
        let slot = self.begin_prefill(prompt)?;
        match self.advance_prefill(slot, prompt.len()) {
            Ok(Some(next)) => Ok((slot, next)),
            Ok(None) => {
                self.release(slot);
                Err(anyhow!("full-length prefill chunk did not complete"))
            }
            Err(e) => {
                self.release(slot);
                Err(e)
            }
        }
    }

    /// Snapshot the live session in `slot` as a [`SlotCheckpoint`] without
    /// disturbing it (read-only; the slot keeps decoding until the caller
    /// [`BatchEngine::release`]s it).  Fails when the slot holds no live
    /// session — mid-prefill slots have no decode state to checkpoint;
    /// preempting one simply releases it and restarts the (deterministic)
    /// prefill later.
    pub fn checkpoint_slot(&self, slot: usize) -> Result<SlotCheckpoint> {
        if slot >= self.slots {
            return Err(anyhow!("slot {slot} out of range"));
        }
        let sess = self.sessions[slot]
            .as_ref()
            .ok_or_else(|| anyhow!("slot {slot} has no live session"))?;
        Ok(SlotCheckpoint::capture(&self.kv, &self.go[slot], sess, slot))
    }

    /// Resume a checkpointed session into a free slot (not necessarily the
    /// one it was captured from); returns the claimed slot.  Same
    /// transactional discipline as batched decode: all fallible checks
    /// (free slot, shape match) run first, then the commit — bank seeds +
    /// session install — is infallible, so a failed restore leaves every
    /// slot untouched.
    pub fn restore_slot(&mut self, ckpt: &SlotCheckpoint) -> Result<usize> {
        let m = &self.engine.model;
        if ckpt.n_layers() != m.n_layers {
            return Err(anyhow!(
                "checkpoint has {} layers, engine has {}",
                ckpt.n_layers(),
                m.n_layers
            ));
        }
        if ckpt.kv_len() > m.max_seq || ckpt.session.pos > m.max_seq {
            return Err(anyhow!("checkpoint longer than max_seq"));
        }
        let slot = self
            .free_slot()
            .ok_or_else(|| anyhow!("no free serving slot"))?;
        // commit: infallible from here
        ckpt.restore_into(&mut self.kv, &mut self.go[slot], slot);
        self.sessions[slot] = Some(ckpt.session.clone());
        Ok(slot)
    }

    /// Free `slot` for the next request, returning its final session state.
    /// Also aborts an in-progress chunked prefill holding the slot (its
    /// partial bank fill is reset; there is no session to return).
    pub fn release(&mut self, slot: usize) -> Option<SlotSession> {
        let sess = self.sessions[slot].take();
        let fill = self.prefill[slot].take();
        if sess.is_some() || fill.is_some() {
            self.kv.reset_slot(slot);
            for bank in self.go[slot].iter_mut() {
                bank.reset();
            }
        }
        sess
    }

    /// Claim a free slot for a chunked prefill of `prompt` without running
    /// any dispatch yet; returns the claimed slot.  The slot is occupied
    /// (invisible to [`BatchEngine::free_slot`] / [`BatchEngine::admit`])
    /// until [`BatchEngine::advance_prefill`] completes the prompt or
    /// [`BatchEngine::release`] aborts it.  Fails without touching any
    /// slot when the pool is full or the prompt is invalid (empty /
    /// longer than `max_seq` — the same checks monolithic admission runs).
    pub fn begin_prefill(&mut self, prompt: &[i32]) -> Result<usize> {
        let m = &self.engine.model;
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        if prompt.len() > m.max_seq {
            return Err(anyhow!("prompt longer than max_seq"));
        }
        let slot = self
            .free_slot()
            .ok_or_else(|| anyhow!("no free serving slot"))?;
        self.prefill[slot] = Some(PrefillState {
            prompt: prompt.to_vec(),
            cursor: 0,
            embed: None,
        });
        Ok(slot)
    }

    /// Advance the chunked prefill in `slot` by up to `chunk` prompt
    /// tokens (at least one).  Returns `Ok(None)` while the prompt is
    /// still filling and `Ok(Some(first_token))` on the chunk that
    /// completes it — at which point the slot holds a live decode session
    /// exactly as if [`BatchEngine::admit`] had prefilled it monolithically.
    ///
    /// Each advance re-runs the padded layer stack at the grown prefix
    /// length `t_c` from the cached embedding (the fixed-shape prefill
    /// artifacts recompute the whole valid prefix per dispatch), seeds the
    /// slot's KV/GO banks with the length-`t_c` state (the partial bank
    /// fill), and prices the chunk's newly-covered token rows on the
    /// [`BatchPlanner`] so prefill work shows up in the same peripheral
    /// contention telemetry as decode rows.  The final chunk runs at the
    /// full prompt length, so its dispatches, bank seeds and sampled first
    /// token are bit-identical to the monolithic path — pinned by
    /// `rust/tests/batch_equivalence.rs` across chunk sizes.
    ///
    /// On error the prefill stays claimed but un-advanced; callers retire
    /// the slot with [`BatchEngine::release`].
    pub fn advance_prefill(&mut self, slot: usize, chunk: usize)
        -> Result<Option<i32>> {
        // take the state out of the slot so the chunk body can borrow the
        // engine/pools mutably without cloning the prompt or the cached
        // embedding (it goes back on every non-completing outcome)
        let mut st = self.prefill[slot]
            .take()
            .ok_or_else(|| {
                anyhow!("slot {slot} has no prefill in progress")
            })?;
        match self.advance_chunk(slot, chunk, &mut st) {
            Ok(Some(next)) => {
                let t = st.prompt.len();
                self.sessions[slot] =
                    Some(SlotSession { ids: st.prompt, pos: t });
                Ok(Some(next))
            }
            Ok(None) => {
                self.prefill[slot] = Some(st);
                Ok(None)
            }
            Err(e) => {
                self.prefill[slot] = Some(st);
                Err(e)
            }
        }
    }

    /// One chunk of the padded prefill replay over `st` (state borrowed,
    /// never cloned).  Returns the first sampled token when the chunk
    /// reaches the full prompt length.
    fn advance_chunk(&mut self, slot: usize, chunk: usize,
                     st: &mut PrefillState) -> Result<Option<i32>> {
        let t = st.prompt.len();
        if st.embed.is_none() {
            st.embed = Some(self.engine.prefill_embed(&st.prompt)?);
        }
        let x0 = st.embed.as_deref().expect("embedding cached above");
        let t_c = (st.cursor + chunk.max(1)).min(t);
        let out = self.engine.prefill_layers(x0, t_c)?;
        // partial bank fill: the pools now hold the length-t_c prefix
        // state (seed_slot overwrites the slot's whole padded region per
        // layer, so each chunk supersedes the previous fill wholesale)
        self.kv.seed_slot(slot, &out.ks, &out.vs, t_c);
        for (bank, routing) in self.go[slot].iter_mut().zip(&out.routings)
        {
            bank.reset();
            bank.seed_from_routing(routing);
        }
        // price the chunk's newly-covered token rows as L planned
        // layer-steps: prefill rows occupy the same grouped peripherals
        // the decode dispatches are priced on, so the serving-lifetime
        // contention telemetry sees prefill work too.  Monolithic
        // admission rides this same path as the single-chunk case, so
        // prompt rows are always priced — but a multi-chunk prefill
        // emits ceil(P/C)·L layer-steps (vs L monolithic) and its
        // intermediate chunks price rows from shorter-prefix routings,
        // so planner counters are comparable in mechanism, not
        // numerically identical, across the chunk knob
        let layer_sets: Vec<Vec<Vec<usize>>> = out
            .routings
            .iter()
            .map(|routing| {
                (st.cursor..t_c)
                    .map(|tok| routing.choices.experts_of(tok))
                    .collect()
            })
            .collect();
        self.planner.plan_layers(&layer_sets);
        if t_c < t {
            st.cursor = t_c;
            return Ok(None);
        }
        // final chunk: runs at the full prompt length — sample the first
        // token; the caller promotes the slot to a live session
        st.cursor = t_c;
        let d = self.engine.model.d_model;
        let next = self.engine.sample(&out.y[(t - 1) * d..t * d], t)?;
        Ok(Some(next))
    }

    /// One batched decode step: advance every `(slot, token)` in `steps` by
    /// one token with a single dispatch per pipeline stage per layer.
    pub fn decode_batch(&mut self, steps: &[(usize, i32)]) -> Result<BatchStep> {
        let m = self.engine.model.clone();
        if steps.is_empty() {
            return Err(anyhow!("empty batch step"));
        }
        let b = self.slots;
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        for &(slot, token) in steps {
            if slot >= b {
                return Err(anyhow!("slot {slot} out of range"));
            }
            if active[slot] {
                return Err(anyhow!("slot {slot} appears twice in one step"));
            }
            let sess = self.sessions[slot]
                .as_ref()
                .ok_or_else(|| anyhow!("slot {slot} has no live session"))?;
            if sess.pos >= m.max_seq {
                return Err(anyhow!("slot {slot} at max_seq"));
            }
            tokens[slot] = token;
            pos[slot] = sess.pos as i32;
            active[slot] = true;
        }

        let rt = self.engine.runtime();
        let (e, d) = (m.n_experts, m.d_model);
        let r = self.kv.row_elems();
        let mut x = rt
            .get("embed_batch")?
            .run(&[TensorIn::I32(&tokens)])?
            .remove(0)
            .into_f32()?;
        // per-layer peeked updates / K/V rows, committed only after every
        // fallible dispatch of every layer succeeded
        let mut upds_per_layer: Vec<Vec<GoUpdate>> =
            Vec::with_capacity(m.n_layers);
        let mut k_news: Vec<Vec<f32>> = Vec::with_capacity(m.n_layers);
        let mut v_news: Vec<Vec<f32>> = Vec::with_capacity(m.n_layers);
        for layer in 0..m.n_layers {
            let nm = self.engine.layer_names(layer);
            let mut attn = rt.get(&nm.attn_decode_batch)?.run(&[
                TensorIn::F32(&x),
                TensorIn::F32(self.kv.layer_k(layer)),
                TensorIn::F32(self.kv.layer_v(layer)),
                TensorIn::I32(&pos),
            ])?;
            let h = attn.remove(0).into_f32()?;
            let k_new = attn.remove(0).into_f32()?;
            let v_new = attn.remove(0).into_f32()?;
            let scores = rt
                .get(&nm.gate_batch)?
                .run(&[TensorIn::F32(&h)])?
                .remove(0)
                .into_f32()?;

            // Host-side routing, *peeked*: selection is computed against
            // the current GO bank state but nothing mutates until the
            // whole stack has dispatched, so a failed step leaves every
            // layer of every slot untouched and the server can safely
            // retry them one by one.
            let cap = m.capacity(layer);
            let mut idx = vec![0i32; b * cap];
            let mut gates = vec![0f32; b * cap];
            let mut upds = Vec::with_capacity(steps.len());
            // rows whose update selected more than `cap` experts (possible
            // right after TopKUpdate under-full edge cases) use the dense
            // single-row MoE, exactly like the single-token path's
            // per-layer guard
            let mut dense_rows: Vec<(usize, Vec<f32>)> = Vec::new();
            for &(slot, _) in steps {
                let sess_pos = pos[slot] as usize;
                let row = &scores[slot * e..(slot + 1) * e];
                let probs = softmax_rows(row, 1, e);
                let upd = self.go[slot][layer].peek_probs(sess_pos, &probs);
                if upd.selected.len() <= cap {
                    for (i, &ex) in upd.selected.iter().enumerate() {
                        idx[slot * cap + i] = ex as i32;
                        gates[slot * cap + i] = probs[ex];
                    }
                } else {
                    let mut dense_g = vec![0f32; e];
                    for &ex in &upd.selected {
                        dense_g[ex] = probs[ex];
                    }
                    dense_rows.push((slot, dense_g));
                }
                upds.push(upd);
            }

            let mut y = rt
                .get(&nm.moe_batch_sparse)?
                .run(&[
                    TensorIn::F32(&h),
                    TensorIn::I32(&idx),
                    TensorIn::F32(&gates),
                ])?
                .remove(0)
                .into_f32()?;
            for &(slot, ref dense_g) in &dense_rows {
                let y1 = rt
                    .get(&nm.moe_one)?
                    .run(&[
                        TensorIn::F32(&h[slot * d..(slot + 1) * d]),
                        TensorIn::F32(dense_g.as_slice()),
                    ])?
                    .remove(0)
                    .into_f32()?;
                y[slot * d..(slot + 1) * d].copy_from_slice(&y1);
            }

            x = y;
            upds_per_layer.push(upds);
            k_news.push(k_new);
            v_news.push(v_new);
        }

        // Last fallible stage: sample every advanced row's next token.
        let mut next = Vec::with_capacity(steps.len());
        for &(slot, _) in steps {
            let pos_after = pos[slot] as usize + 1;
            let nt = self
                .engine
                .sample(&x[slot * d..(slot + 1) * d], pos_after)?;
            next.push((slot, nt));
        }

        // Commit (infallible from here), covering all L layers of the
        // step: plan it on the grouped peripherals as L layer-steps (the
        // modeled chip's execution order + contention telemetry —
        // accumulated only for steps that actually landed), apply every
        // layer's GO updates, append every layer's K/V rows, advance
        // sessions.
        let layer_sets: Vec<Vec<Vec<usize>>> = upds_per_layer
            .iter()
            .map(|upds| upds.iter().map(|u| u.selected.clone()).collect())
            .collect();
        let plans = self.planner.plan_layers(&layer_sets);
        for (layer, upds) in upds_per_layer.iter().enumerate() {
            for (&(slot, _), upd) in steps.iter().zip(upds) {
                let sess_pos = pos[slot] as usize;
                self.go[slot][layer].apply_update(sess_pos, upd);
            }
        }
        for &(slot, token) in steps {
            // borrowed row slices straight out of the dispatch outputs —
            // no per-token clones on the commit path
            let k_rows: Vec<&[f32]> = k_news
                .iter()
                .map(|bank| &bank[slot * r..(slot + 1) * r])
                .collect();
            let v_rows: Vec<&[f32]> = v_news
                .iter()
                .map(|bank| &bank[slot * r..(slot + 1) * r])
                .collect();
            self.kv.append_slot(slot, &k_rows, &v_rows);
            let sess = self.sessions[slot].as_mut().unwrap();
            sess.ids.push(token);
            sess.pos += 1;
        }
        Ok(BatchStep { next, plans })
    }

    /// Single-token fallback for odd-sized tails: the per-token artifacts
    /// over the same pooled storage (KV banks borrowed, not cloned).
    /// Returns the sampled token plus the step's per-layer plans.
    pub fn decode_single(&mut self, slot: usize, token: i32)
        -> Result<(i32, Vec<BatchPlan>)> {
        let max_seq = self.engine.model.max_seq;
        let n_layers = self.engine.model.n_layers;
        let pos = match self.sessions[slot].as_ref() {
            Some(s) if s.pos >= max_seq => {
                return Err(anyhow!("slot {slot} at max_seq"))
            }
            Some(s) => s.pos,
            None => return Err(anyhow!("slot {slot} has no live session")),
        };
        let kv = &self.kv; // shared borrow outliving the closure
        let kv_layers: Vec<(&[f32], &[f32])> = (0..n_layers)
            .map(|l| (kv.slot_k(l, slot), kv.slot_v(l, slot)))
            .collect();
        let step = self.engine.decode_core(
            &kv_layers,
            pos,
            &mut self.go[slot],
            token,
        )?;
        drop(kv_layers);
        self.kv.append_slot(slot, &step.k_rows, &step.v_rows);
        let sess = self.sessions[slot].as_mut().unwrap();
        sess.ids.push(token);
        sess.pos += 1;
        let layer_sets: Vec<Vec<Vec<usize>>> = step
            .selected
            .iter()
            .map(|sel| vec![sel.clone()])
            .collect();
        let plans = self.planner.plan_layers(&layer_sets);
        Ok((step.next, plans))
    }
}
