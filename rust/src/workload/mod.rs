//! Workload subsystem: traffic generation, SLO telemetry, and
//! policy-driven admission for the batched serving engine.
//!
//! Everything upstream of this module evaluates the stack as an
//! *algorithm* (equivalence suites, figure benches); this module
//! evaluates it as a *service*:
//!
//! * [`arrival`] — deterministic arrival processes (Poisson, bursty
//!   on/off, closed-loop, replay) and request-size models, materialized
//!   from one seed into policy-independent [`RequestSpec`]s;
//! * [`policy`] — pluggable slot-admission policies (FIFO / SJF / EDF
//!   with starvation guards) shared by the real server and the virtual
//!   cluster;
//! * [`driver`] — open-/closed-loop load driver against the real
//!   [`crate::coordinator::Server`] (or the concurrent
//!   [`crate::coordinator::Cluster`] front door), collecting per-request
//!   [`Sample`]s;
//! * [`vsim`] — a virtual-time discrete-event mirror of the router loop,
//!   priced by the real [`crate::sched::BatchPlanner`] contention model —
//!   the backend whose reports are byte-identical per seed; includes
//!   [`run_virtual_live`], live-signal least-outstanding placement over
//!   N incrementally-advanced virtual backends, and
//!   [`run_virtual_dynamic`], the full [`crate::placement`] control loop
//!   (queued-request migration, heterogeneous fleets, area-ledgered
//!   hot-expert replication);
//! * [`shard`] — the multi-server fan-out: a [`ShardedDriver`] splits one
//!   [`WorkloadSpec`] across N backends under a pluggable
//!   [`PlacementPolicy`] (round-robin / least-outstanding / size-hash /
//!   routing-aware; each a thin wrapper over the
//!   [`crate::placement::StaticPlacer`]) and merges the per-shard
//!   outcomes shard-exactly; real shards run concurrently
//!   ([`ShardedDriver::run_real_concurrent`],
//!   [`shard::run_against_cluster`]);
//! * [`hist`] / [`report`] — mergeable log-bucketed latency histograms
//!   folded into the `moepim.slo_report.v1` JSON document (p50/p95/p99
//!   queue/TTFT/e2e, SLO attainment, tokens/sec, planner contention
//!   snapshot), or the merged `moepim.slo_report.v2` for sharded runs
//!   (per-shard breakdown + imbalance metrics);
//! * [`record`] — the trace lifecycle's record/replay half: a
//!   [`TraceRecorder`] dumps a served workload (exact arrivals, sizes,
//!   deadlines, shard tags, outcomes) as a `moepim.trace.v1` document,
//!   and [`RecordedTrace`] loads it back for exact
//!   (`replay_requests`) or timeline-shaped (`replay_spec`) replay;
//! * [`calibrate`] — least-squares fit of [`VirtualConfig`]'s cost
//!   constants against a recorded trace, emitting `moepim.calibration.v1`
//!   with a re-prediction accuracy report;
//! * [`scenario`] — named, seeded [`WorkloadSpec`] presets (`diurnal`,
//!   `flash-crowd`, `long-prompt-flood`, `mixed-tenants`) for
//!   `loadtest --scenario`;
//! * [`perfcmp`] — cross-PR perf-trajectory comparison of successive
//!   `BENCH_*.json` artifacts (`moepim perfcmp OLD NEW`, regression
//!   threshold exit code for CI).
//!
//! Entry points: `moepim loadtest` / `moepim shardtest` /
//! `moepim calibrate` (CLI), `cargo bench --bench loadgen`,
//! `examples/loadtest_policies.rs` (E8), `examples/shard_placement.rs`
//! (E9), `examples/trace_roundtrip.rs` (E11), and the
//! `rust/tests/{props_workload,loadtest_virtual,shard_virtual,
//! trace_lifecycle}.rs` suites.

pub mod arrival;
pub mod calibrate;
pub mod driver;
pub mod hist;
pub mod perfcmp;
pub mod policy;
pub mod record;
pub mod report;
pub mod scenario;
pub mod shard;
pub mod vsim;

pub use arrival::{ArrivalProcess, RequestSpec, SizeModel, WorkloadSpec};
pub use calibrate::{calibrate, Calibration, CALIBRATION_SCHEMA};
pub use driver::{
    request_for, run_against_server, run_requests_against_server,
    LoadOutcome, Sample,
};
pub use hist::LatencyHistogram;
pub use policy::{AdmissionPolicy, Priority, QueuedMeta};
pub use record::{
    RecordedTrace, TraceBackend, TraceRecorder, TraceRequest, TRACE_SCHEMA,
};
pub use report::{
    metrics_registry, metrics_registry_merged, summarize, SloSummary,
};
pub use scenario::{scenario_names, scenario_spec, SCENARIOS};
pub use shard::{
    run_against_cluster, Imbalance, MergedLoad, PlacementPolicy,
    ShardLoad, ShardOutcome, ShardedDriver, ShardedRun,
};
pub use perfcmp::{compare as perf_compare, PerfDelta, DEFAULT_THRESHOLD_PCT};
pub use vsim::{
    run_virtual, run_virtual_dynamic, run_virtual_dynamic_traced,
    run_virtual_live, run_virtual_live_traced, run_virtual_requests,
    run_virtual_requests_traced, run_virtual_traced, VirtualConfig,
};
