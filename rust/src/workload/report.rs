//! SLO report assembly: fold a [`LoadOutcome`] into latency histograms
//! and serialize the `moepim.slo_report` JSON schemas via [`Json`]
//! (ordered keys — deterministic output, so virtual-clock reports are
//! byte-identical across runs of the same seed).
//!
//! Two documents share the core shape (see DESIGN.md §Workload for the
//! field-by-field table):
//!
//! * **v1** ([`build`]) — one backend, one outcome:
//!
//! ```text
//! { schema, workload{seed, requests, process, sizes, policy, clock, slots},
//!   latency_us{queue|ttft|e2e → {count, mean, min, max, p50, p95, p99}},
//!   slo{target_e2e_ms, attainment},
//!   throughput{duration_s, tokens_per_s, requests_per_s},
//!   counts{completed, errored, tokens},
//!   server{batch_dispatches, single_dispatches, mean_batch_occupancy,
//!          prefill_chunks, peak_waiting, shed_requests,
//!          peak_intake_depth, preemptions, restores, preempted_wait_us,
//!          peak_checkpoints, checkpoint_spill_mm2},
//!   planner{steps, work, cycles, transfers, contention_ratio},
//!   metrics{counters, gauges, summaries} }
//! ```
//!
//! * **v2** ([`build_sharded`] / [`build_sharded_labeled`] /
//!   [`build_sharded_placed`]) — a sharded fan-out, merged shard-exact:
//!   the same sections over the merged data (`workload` gains `shards` +
//!   `placement`; `slots` is the cluster total; `duration_s` the cluster
//!   makespan), plus a per-shard `shards[]` breakdown, an `imbalance`
//!   section (max/min shard load, per-shard p99 spread vs the merged
//!   p99), and a `placement` section — the dynamic control loop's
//!   [`PlacementReport`] (migrations, replicas, mm² spent, worst-tick
//!   imbalance pair; all-zero for static placements) plus the
//!   area-ledger price of the checkpoint-spill high-water.
//!
//! Both schemas keep their ids across the concurrent-cluster revision:
//! `shed_requests` / `peak_intake_depth` (and the per-shard
//! `shed_requests`) are purely additive fields — every pre-existing path
//! is unchanged (see DESIGN.md §Concurrent cluster).

use crate::obs::MetricsRegistry;
use crate::placement::{checkpoint_spill_mm2, PlacementReport};
use crate::sched::PlannerStats;
use crate::util::json::Json;
use crate::workload::arrival::WorkloadSpec;
use crate::workload::driver::LoadOutcome;
use crate::workload::hist::LatencyHistogram;
use crate::workload::policy::AdmissionPolicy;
use crate::workload::shard::{self, MergedLoad, ShardedDriver, ShardedRun};

/// Aggregated view of one experiment's samples.  Histograms cover
/// successful requests (errored ones count against SLO attainment and in
/// `errored`, but their timings aren't latencies of served traffic).
#[derive(Debug, Clone)]
pub struct SloSummary {
    /// submit → slot-admission latencies of successful requests
    pub queue: LatencyHistogram,
    /// submit → first-token latencies of successful requests
    pub ttft: LatencyHistogram,
    /// submit → terminal-reply latencies of successful requests
    pub e2e: LatencyHistogram,
    /// requests that completed successfully
    pub completed: u64,
    /// requests that ended in a terminal error
    pub errored: u64,
    /// generated tokens across completed requests
    pub tokens: u64,
    /// requests that completed within the SLO target (the numerator of
    /// `attainment` — kept separately so shard merges stay exact instead
    /// of re-deriving counts from a rounded ratio)
    pub slo_met: u64,
    /// fraction of *all* terminal requests that completed within the SLO
    /// target (errors are misses)
    pub attainment: f64,
    /// generated tokens per second of experiment duration
    pub tokens_per_s: f64,
    /// terminal requests per second of experiment duration
    pub requests_per_s: f64,
}

/// Fold one [`LoadOutcome`]'s samples into an [`SloSummary`].
pub fn summarize(spec: &WorkloadSpec, out: &LoadOutcome) -> SloSummary {
    let slo_us = spec.slo_e2e_ms * 1000.0;
    let mut queue = LatencyHistogram::new();
    let mut ttft = LatencyHistogram::new();
    let mut e2e = LatencyHistogram::new();
    let mut completed = 0u64;
    let mut errored = 0u64;
    let mut tokens = 0u64;
    let mut met = 0u64;
    for s in &out.samples {
        if !s.ok {
            errored += 1;
            continue;
        }
        completed += 1;
        tokens += s.tokens;
        if let Some(q) = s.queue_us {
            queue.record(q);
        }
        if let Some(t) = s.ttft_us {
            ttft.record(t);
        }
        e2e.record(s.e2e_us);
        if s.e2e_us <= slo_us {
            met += 1;
        }
    }
    let n = out.samples.len();
    let attainment =
        if n == 0 { 1.0 } else { met as f64 / n as f64 };
    let dur = out.duration_s.max(1e-9);
    SloSummary {
        queue,
        ttft,
        e2e,
        completed,
        errored,
        tokens,
        slo_met: met,
        attainment,
        tokens_per_s: tokens as f64 / dur,
        requests_per_s: n as f64 / dur,
    }
}

/// Fold one experiment into the unified [`MetricsRegistry`]: the
/// counters/gauges/latency summaries rendered by `--metrics-file`
/// (Prometheus text) and embedded as the additive `metrics` section of
/// both report schemas.  Everything here derives deterministically from
/// the outcome, so virtual-clock reports stay byte-identical per seed.
pub fn metrics_registry(s: &SloSummary, out: &LoadOutcome)
    -> MetricsRegistry {
    registry_parts(s, out.slots, out.peak_waiting, out.peak_intake_depth,
                   out.batch_dispatches, out.single_dispatches,
                   out.mean_batch_occupancy(), out.prefill_chunks,
                   out.shed_requests, out.preemptions, out.restores,
                   out.preempted_wait_us, out.peak_checkpoints,
                   &out.planner, out.duration_s)
}

/// [`metrics_registry`] over a sharded fan-out's [`MergedLoad`] — the
/// cluster-wide registry behind `moepim shardtest --metrics-file` and the
/// v2 report's `metrics` section.
pub fn metrics_registry_merged(m: &MergedLoad) -> MetricsRegistry {
    registry_parts(&m.summary, m.slots, m.peak_waiting,
                   m.peak_intake_depth, m.batch_dispatches,
                   m.single_dispatches, m.mean_batch_occupancy(),
                   m.prefill_chunks, m.shed_requests, m.preemptions,
                   m.restores, m.preempted_wait_us, m.peak_checkpoints,
                   &m.planner, m.duration_s)
}

#[allow(clippy::too_many_arguments)]
fn registry_parts(s: &SloSummary, slots: usize, peak_waiting: usize,
                  peak_intake_depth: usize, batch_dispatches: u64,
                  single_dispatches: u64, occupancy: f64,
                  prefill_chunks: u64, shed_requests: u64,
                  preemptions: u64, restores: u64, preempted_wait_us: u64,
                  peak_checkpoints: usize, planner: &PlannerStats,
                  duration_s: f64)
    -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    reg.counter("moepim_requests_completed_total",
                "Requests that completed successfully", s.completed);
    reg.counter("moepim_requests_errored_total",
                "Requests that ended in a terminal error", s.errored);
    reg.counter("moepim_requests_shed_total",
                "Requests shed with a terminal overloaded error",
                shed_requests);
    reg.counter("moepim_tokens_generated_total",
                "Generated tokens across completed requests", s.tokens);
    reg.counter("moepim_batch_dispatches_total",
                "Batched decode dispatches", batch_dispatches);
    reg.counter("moepim_single_dispatches_total",
                "Single-token fallback dispatches", single_dispatches);
    reg.counter("moepim_prefill_chunks_total",
                "Prefill chunk advances dispatched", prefill_chunks);
    reg.counter("moepim_preemptions_total",
                "Batch-tier slots preempted for interactive arrivals",
                preemptions);
    reg.counter("moepim_restores_total",
                "Checkpointed slots restored and resumed", restores);
    reg.counter("moepim_preempted_wait_us_total",
                "Total microseconds preempted requests spent requeued",
                preempted_wait_us);
    reg.counter("moepim_planner_steps_total",
                "Layer steps priced by the batch planner", planner.steps);
    reg.counter("moepim_planner_cycles_total",
                "Planner-priced crossbar cycles", planner.cycles);
    reg.counter("moepim_planner_contention_cycles_total",
                "Planner-priced peripheral-contention cycles",
                planner.contention_cycles);
    reg.counter("moepim_planner_transfers_total",
                "Planner-priced peripheral transfers", planner.transfers);
    reg.gauge("moepim_slots", "Serving slots (batch width)",
              slots as f64);
    reg.gauge("moepim_peak_waiting",
              "High-water mark of the admission queue",
              peak_waiting as f64);
    reg.gauge("moepim_peak_intake_depth",
              "High-water mark of the cluster intake queue",
              peak_intake_depth as f64);
    reg.gauge("moepim_peak_checkpoints",
              "High-water mark of simultaneously parked checkpoints",
              peak_checkpoints as f64);
    reg.gauge("moepim_checkpoint_spill_mm2",
              "Area charged for beyond-one-slot checkpoint spill copies",
              checkpoint_spill_mm2(peak_checkpoints));
    reg.gauge("moepim_mean_batch_occupancy",
              "Mean live slots per batched dispatch", occupancy);
    reg.gauge("moepim_slo_attainment",
              "Fraction of terminal requests inside the SLO target",
              s.attainment);
    reg.gauge("moepim_tokens_per_second",
              "Generated tokens per second of experiment duration",
              s.tokens_per_s);
    reg.gauge("moepim_requests_per_second",
              "Terminal requests per second of experiment duration",
              s.requests_per_s);
    reg.gauge("moepim_duration_seconds",
              "Experiment wall/virtual duration", duration_s);
    reg.histogram("moepim_queue_latency_us",
                  "Submit-to-slot-admission latency (us)", &s.queue);
    reg.histogram("moepim_ttft_latency_us",
                  "Submit-to-first-token latency (us)", &s.ttft);
    reg.histogram("moepim_e2e_latency_us",
                  "Submit-to-terminal-reply latency (us)", &s.e2e);
    reg
}

/// Build the full `moepim.slo_report.v1` document.
pub fn build(spec: &WorkloadSpec, policy: AdmissionPolicy,
             out: &LoadOutcome) -> Json {
    let s = summarize(spec, out);
    Json::obj(vec![
        ("schema", Json::str("moepim.slo_report.v1")),
        (
            "workload",
            Json::obj(vec![
                // string, not number: a u64 seed above 2^53 would lose
                // precision through the f64-backed Json::Num
                ("seed", Json::str(&spec.seed.to_string())),
                ("requests", Json::num(spec.requests as f64)),
                ("process", Json::str(spec.arrival.label())),
                ("sizes", Json::str(spec.sizes.label())),
                ("interactive_mix", Json::num(spec.interactive_mix)),
                ("policy", Json::str(policy.label())),
                ("clock", Json::str(out.clock)),
                ("slots", Json::num(out.slots as f64)),
            ]),
        ),
        (
            "latency_us",
            Json::obj(vec![
                ("queue", hist_json(&s.queue)),
                ("ttft", hist_json(&s.ttft)),
                ("e2e", hist_json(&s.e2e)),
            ]),
        ),
        (
            "slo",
            Json::obj(vec![
                ("target_e2e_ms", Json::num(spec.slo_e2e_ms)),
                ("attainment", Json::num(round6(s.attainment))),
            ]),
        ),
        (
            "throughput",
            Json::obj(vec![
                ("duration_s", Json::num(round6(out.duration_s))),
                ("tokens_per_s", Json::num(round3(s.tokens_per_s))),
                ("requests_per_s", Json::num(round3(s.requests_per_s))),
            ]),
        ),
        (
            "counts",
            Json::obj(vec![
                ("completed", Json::num(s.completed as f64)),
                ("errored", Json::num(s.errored as f64)),
                ("tokens", Json::num(s.tokens as f64)),
            ]),
        ),
        (
            "server",
            Json::obj(vec![
                ("batch_dispatches", Json::num(out.batch_dispatches as f64)),
                ("single_dispatches",
                 Json::num(out.single_dispatches as f64)),
                ("mean_batch_occupancy",
                 Json::num(round3(out.mean_batch_occupancy()))),
                ("prefill_chunks", Json::num(out.prefill_chunks as f64)),
                ("peak_waiting", Json::num(out.peak_waiting as f64)),
                ("shed_requests", Json::num(out.shed_requests as f64)),
                ("peak_intake_depth",
                 Json::num(out.peak_intake_depth as f64)),
                ("preemptions", Json::num(out.preemptions as f64)),
                ("restores", Json::num(out.restores as f64)),
                ("preempted_wait_us",
                 Json::num(out.preempted_wait_us as f64)),
                ("peak_checkpoints",
                 Json::num(out.peak_checkpoints as f64)),
                ("checkpoint_spill_mm2",
                 Json::num(round6(checkpoint_spill_mm2(
                     out.peak_checkpoints,
                 )))),
            ]),
        ),
        (
            "planner",
            Json::obj(vec![
                ("steps", Json::num(out.planner.steps as f64)),
                ("work", Json::num(out.planner.work as f64)),
                ("cycles", Json::num(out.planner.cycles as f64)),
                ("transfers", Json::num(out.planner.transfers as f64)),
                ("contention_ratio",
                 Json::num(round6(out.planner.contention_ratio()))),
            ]),
        ),
        // additive: the unified registry view of the same run (see
        // DESIGN.md §Observability); derived deterministically from the
        // outcome, so virtual reports stay byte-identical per seed
        ("metrics", metrics_registry(&s, out).to_json()),
    ])
}

/// Build the merged `moepim.slo_report.v2` document for a sharded
/// fan-out run: the v1 shape (schema bumped, `workload` gaining `shards`
/// + `placement`), plus a per-shard breakdown array and cluster
/// [`shard::Imbalance`] metrics.  The merge is shard-exact
/// ([`LatencyHistogram::merge`] adds bucket counts), so a 1-shard v2
/// report carries exactly the latency quantiles of the unsharded v1
/// report for the same `(spec, policy)` — the degeneracy pin in
/// `rust/tests/shard_virtual.rs`.
pub fn build_sharded(spec: &WorkloadSpec, policy: AdmissionPolicy,
                     driver: &ShardedDriver, run: &ShardedRun) -> Json {
    build_sharded_labeled(spec, policy, driver.shards,
                          driver.placement.label(), run)
}

/// [`build_sharded`] with the shard count and placement label supplied
/// directly — for runs that don't go through a [`ShardedDriver`] split,
/// i.e. the live-placement paths (the real
/// [`crate::coordinator::Cluster`] front door and the virtual
/// [`crate::workload::run_virtual_live`]), whose placement labels
/// (`"live-least-outstanding"`, …) aren't [`shard::PlacementPolicy`]
/// variants.
pub fn build_sharded_labeled(spec: &WorkloadSpec, policy: AdmissionPolicy,
                             shards: usize, placement: &str,
                             run: &ShardedRun) -> Json {
    build_sharded_placed(spec, policy, shards, placement, run,
                         &PlacementReport::default())
}

/// [`build_sharded_labeled`] with the dynamic control loop's
/// [`PlacementReport`] folded in as the report's `placement` block.
/// Static placements pass the all-zero default (the block is always
/// present, so report consumers never probe for it); the dynamic paths
/// ([`crate::workload::run_virtual_dynamic`] and the real cluster's
/// `--placement dynamic`) pass the run's actual counters.
pub fn build_sharded_placed(spec: &WorkloadSpec, policy: AdmissionPolicy,
                            shards: usize, placement: &str,
                            run: &ShardedRun, pr: &PlacementReport)
    -> Json {
    // fold every shard's samples exactly once; the merge, the per-shard
    // breakdown and the imbalance section all reuse these summaries
    let parts: Vec<SloSummary> = run
        .shards
        .iter()
        .map(|s| summarize(spec, &s.outcome))
        .collect();
    let m = shard::merge_summaries(&run.shards, &parts);
    let imb = shard::imbalance_from(&run.shards, &parts, &m);
    let shards_json: Vec<Json> = run
        .shards
        .iter()
        .zip(&parts)
        .map(|(s, part)| {
            Json::obj(vec![
                ("shard",
                 Json::num(s.outcome.shard.unwrap_or(s.shard) as f64)),
                ("requests", Json::num(s.requests as f64)),
                ("completed", Json::num(part.completed as f64)),
                ("errored", Json::num(part.errored as f64)),
                ("tokens", Json::num(part.tokens as f64)),
                ("duration_s", Json::num(round6(s.outcome.duration_s))),
                ("peak_waiting",
                 Json::num(s.outcome.peak_waiting as f64)),
                ("shed_requests",
                 Json::num(s.outcome.shed_requests as f64)),
                ("p50_e2e_us", Json::num(round3(part.e2e.quantile(0.5)))),
                ("p99_e2e_us",
                 Json::num(round3(part.e2e.quantile(0.99)))),
                ("attainment", Json::num(round6(part.attainment))),
                ("tokens_per_s", Json::num(round3(part.tokens_per_s))),
                ("contention_ratio",
                 Json::num(round6(
                     s.outcome.planner.contention_ratio(),
                 ))),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str("moepim.slo_report.v2")),
        (
            "workload",
            Json::obj(vec![
                ("seed", Json::str(&spec.seed.to_string())),
                ("requests", Json::num(spec.requests as f64)),
                ("process", Json::str(spec.arrival.label())),
                ("sizes", Json::str(spec.sizes.label())),
                ("interactive_mix", Json::num(spec.interactive_mix)),
                ("policy", Json::str(policy.label())),
                ("clock", Json::str(m.clock)),
                ("slots", Json::num(m.slots as f64)),
                ("shards", Json::num(shards as f64)),
                ("placement", Json::str(placement)),
            ]),
        ),
        (
            "latency_us",
            Json::obj(vec![
                ("queue", hist_json(&m.summary.queue)),
                ("ttft", hist_json(&m.summary.ttft)),
                ("e2e", hist_json(&m.summary.e2e)),
            ]),
        ),
        (
            "slo",
            Json::obj(vec![
                ("target_e2e_ms", Json::num(spec.slo_e2e_ms)),
                ("attainment", Json::num(round6(m.summary.attainment))),
            ]),
        ),
        (
            "throughput",
            Json::obj(vec![
                ("duration_s", Json::num(round6(m.duration_s))),
                ("tokens_per_s",
                 Json::num(round3(m.summary.tokens_per_s))),
                ("requests_per_s",
                 Json::num(round3(m.summary.requests_per_s))),
            ]),
        ),
        (
            "counts",
            Json::obj(vec![
                ("completed", Json::num(m.summary.completed as f64)),
                ("errored", Json::num(m.summary.errored as f64)),
                ("tokens", Json::num(m.summary.tokens as f64)),
            ]),
        ),
        (
            "server",
            Json::obj(vec![
                ("batch_dispatches",
                 Json::num(m.batch_dispatches as f64)),
                ("single_dispatches",
                 Json::num(m.single_dispatches as f64)),
                ("mean_batch_occupancy",
                 Json::num(round3(m.mean_batch_occupancy()))),
                ("prefill_chunks", Json::num(m.prefill_chunks as f64)),
                ("peak_waiting", Json::num(m.peak_waiting as f64)),
                ("shed_requests", Json::num(m.shed_requests as f64)),
                ("peak_intake_depth",
                 Json::num(m.peak_intake_depth as f64)),
                ("preemptions", Json::num(m.preemptions as f64)),
                ("restores", Json::num(m.restores as f64)),
                ("preempted_wait_us",
                 Json::num(m.preempted_wait_us as f64)),
                ("peak_checkpoints",
                 Json::num(m.peak_checkpoints as f64)),
                ("checkpoint_spill_mm2",
                 Json::num(round6(checkpoint_spill_mm2(
                     m.peak_checkpoints,
                 )))),
            ]),
        ),
        (
            "planner",
            Json::obj(vec![
                ("steps", Json::num(m.planner.steps as f64)),
                ("work", Json::num(m.planner.work as f64)),
                ("cycles", Json::num(m.planner.cycles as f64)),
                ("transfers", Json::num(m.planner.transfers as f64)),
                ("contention_ratio",
                 Json::num(round6(m.planner.contention_ratio()))),
            ]),
        ),
        // additive: the cluster-wide registry view of the merged run
        ("metrics", metrics_registry_merged(&m).to_json()),
        ("shards", Json::arr(shards_json)),
        (
            "imbalance",
            Json::obj(vec![
                ("requests_max", Json::num(imb.requests_max as f64)),
                ("requests_min", Json::num(imb.requests_min as f64)),
                ("load_ratio", Json::num(round3(imb.load_ratio))),
                ("p99_e2e_max_us",
                 Json::num(round3(imb.p99_e2e_max_us))),
                ("p99_e2e_min_us",
                 Json::num(round3(imb.p99_e2e_min_us))),
                ("p99_gap_us", Json::num(round3(imb.p99_gap_us))),
                ("merged_p99_e2e_us",
                 Json::num(round3(imb.merged_p99_e2e_us))),
            ]),
        ),
        // additive: the dynamic-placement control loop's telemetry
        // (all-zero counters for static placements — see
        // crate::placement::PlacementReport); checkpoint_spill_mm2
        // prices the cluster-wide checkpoint high-water against the
        // same area ledger the replicas are charged to
        (
            "placement",
            Json::obj(vec![
                ("area_mm2_delta", Json::num(round6(pr.area_mm2_delta))),
                ("checkpoint_spill_mm2",
                 Json::num(round6(checkpoint_spill_mm2(
                     m.peak_checkpoints,
                 )))),
                ("imbalance_after",
                 Json::num(round6(pr.imbalance_after))),
                ("imbalance_before",
                 Json::num(round6(pr.imbalance_before))),
                ("migrations", Json::num(pr.migrations as f64)),
                ("replicas", Json::num(pr.replicas as f64)),
            ]),
        ),
    ])
}

fn hist_json(h: &LatencyHistogram) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("mean", Json::num(round3(h.mean_us()))),
        ("min", Json::num(round3(h.min_us()))),
        ("max", Json::num(round3(h.max_us()))),
        ("p50", Json::num(round3(h.quantile(0.5)))),
        ("p95", Json::num(round3(h.quantile(0.95)))),
        ("p99", Json::num(round3(h.quantile(0.99)))),
    ])
}

fn round3(v: f64) -> f64 {
    if !v.is_finite() {
        return 0.0;
    }
    (v * 1e3).round() / 1e3
}

fn round6(v: f64) -> f64 {
    if !v.is_finite() {
        return 0.0;
    }
    (v * 1e6).round() / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use crate::workload::vsim::{run_virtual, VirtualConfig};

    #[test]
    fn report_round_trips_and_has_every_headline_field() {
        let spec = WorkloadSpec { requests: 16, ..WorkloadSpec::default() };
        let out = run_virtual(&VirtualConfig::default(), &spec,
                              AdmissionPolicy::sjf());
        let report = build(&spec, AdmissionPolicy::sjf(), &out);
        let text = report.to_string_pretty();
        let parsed = json::parse(&text).expect("report parses");
        for path in [
            vec!["workload", "policy"],
            vec!["latency_us", "queue", "p50"],
            vec!["latency_us", "ttft", "p95"],
            vec!["latency_us", "e2e", "p99"],
            vec!["slo", "attainment"],
            vec!["throughput", "tokens_per_s"],
            vec!["planner", "contention_ratio"],
            vec!["server", "mean_batch_occupancy"],
        ] {
            assert!(parsed.path(&path).is_some(), "missing {path:?}");
        }
        assert_eq!(
            parsed.path(&["workload", "clock"]).unwrap().as_str(),
            Some("virtual")
        );
        // the seed is a string so full-width u64 seeds survive round-trips
        assert_eq!(
            parsed.path(&["workload", "seed"]).unwrap().as_str(),
            Some("2026")
        );
        assert_eq!(
            parsed.path(&["counts", "completed"]).unwrap().as_usize(),
            Some(16)
        );
    }

    #[test]
    fn v2_report_always_carries_the_placement_block() {
        use crate::workload::shard::PlacementPolicy;
        let spec = WorkloadSpec { requests: 12, ..WorkloadSpec::default() };
        let cfg = VirtualConfig::default();
        let driver = ShardedDriver {
            shards: 2,
            placement: PlacementPolicy::RoundRobin,
        };
        let run = driver.run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        let report = build_sharded(&spec, AdmissionPolicy::fifo(),
                                   &driver, &run);
        let parsed = json::parse(&report.to_string_pretty()).unwrap();
        // static placements still carry the block, all-zero
        for key in [
            "area_mm2_delta", "checkpoint_spill_mm2", "imbalance_after",
            "imbalance_before", "migrations", "replicas",
        ] {
            assert_eq!(
                parsed.path(&["placement", key]).and_then(Json::as_f64),
                Some(0.0),
                "placement.{key}"
            );
        }
        assert!(parsed
            .path(&["server", "peak_checkpoints"])
            .is_some());
        // a dynamic run's counters flow through build_sharded_placed
        let pr = PlacementReport {
            migrations: 3,
            replicas: 1,
            area_mm2_delta: 85.25,
            imbalance_before: 1.5,
            imbalance_after: 0.5,
        };
        let placed = build_sharded_placed(
            &spec, AdmissionPolicy::fifo(), 2, "dynamic", &run, &pr);
        let parsed = json::parse(&placed.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.path(&["placement", "migrations"])
                .and_then(Json::as_usize),
            Some(3)
        );
        assert_eq!(
            parsed.path(&["workload", "placement"]).and_then(Json::as_str),
            Some("dynamic")
        );
    }

    #[test]
    fn attainment_counts_errors_as_misses() {
        let spec = WorkloadSpec {
            requests: 8,
            sizes: crate::workload::arrival::SizeModel::Fixed {
                prompt_len: 500,
                gen_len: 4,
            },
            ..WorkloadSpec::default()
        };
        let out = run_virtual(&VirtualConfig::default(), &spec,
                              AdmissionPolicy::fifo());
        let s = summarize(&spec, &out);
        assert_eq!(s.errored, 8);
        assert_eq!(s.completed, 0);
        assert_eq!(s.attainment, 0.0);
    }
}
