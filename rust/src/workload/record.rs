//! Trace recording and replay: dump a served workload as a versioned
//! `moepim.trace.v1` document and load it back for deterministic replay.
//!
//! The document captures everything a replay needs, at three levels:
//!
//! * the **original [`WorkloadSpec`]** (seed, arrival process, size
//!   model, SLO) — so a trace *names* the experiment that produced it
//!   and [`RecordedTrace::original_spec`] can re-materialize it;
//! * the **backend configuration** that served it ([`TraceBackend`]:
//!   slots, admission policy, prefill chunk, queue cap, shard count and
//!   placement), read off the live [`crate::coordinator::ServerStats`] /
//!   [`crate::coordinator::ClusterStats`] recording hooks rather than
//!   re-threaded by the caller;
//! * the **per-request records** ([`TraceRequest`]): exact arrival
//!   timestamps (integer ns), prompt/gen sizes, deadline budgets, shard
//!   tags, and the measured outcome (ok, queue/TTFT/e2e, tokens).
//!
//! Replay has two fidelities:
//!
//! * [`RecordedTrace::replay_requests`] rebuilds the exact
//!   [`RequestSpec`]s (ns-precision arrivals) — feeding them through
//!   [`crate::workload::run_virtual_requests`] with
//!   [`RecordedTrace::original_spec`] replays the recorded run
//!   *byte-identically* (same `moepim.slo_report.v1`), which is the
//!   round-trip pin in `rust/tests/trace_lifecycle.rs`;
//! * [`RecordedTrace::replay_spec`] folds the arrivals into an
//!   [`ArrivalProcess::Replay`] timeline (µs truncation) — the generic
//!   path for driving *any* backend or request count with the recorded
//!   traffic shape, at the cost of sub-µs arrival detail.
//!
//! The calibration fit ([`crate::workload::calibrate`]) consumes the same
//! document: recorded planner telemetry supplies the mean cycles/step the
//! cost-constant decomposition needs.

use crate::coordinator::{ClusterStats, ServerStats};
use crate::sched::PlannerStats;
use crate::util::json::Json;
use crate::workload::arrival::{
    ArrivalProcess, RequestSpec, SizeModel, WorkloadSpec,
};
use crate::workload::driver::LoadOutcome;
use crate::workload::policy::AdmissionPolicy;
use crate::workload::shard::ShardedRun;
use crate::workload::vsim::VirtualConfig;

/// Schema id stamped on every trace document.
pub const TRACE_SCHEMA: &str = "moepim.trace.v1";

/// The serving-side configuration a trace was recorded under.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceBackend {
    /// serving slots (per backend)
    pub slots: usize,
    /// prefill chunk budget (0: monolithic)
    pub prefill_chunk: usize,
    /// admission-queue cap (0: unbounded)
    pub queue_cap: usize,
    /// backend count (1: single server / virtual cluster)
    pub shards: usize,
    /// placement label for multi-backend runs (`None`: unsharded)
    pub placement: Option<String>,
}

impl TraceBackend {
    /// Backend block for a virtual run under `cfg`.
    pub fn from_virtual(cfg: &VirtualConfig) -> TraceBackend {
        TraceBackend {
            slots: cfg.slots.max(1),
            prefill_chunk: cfg.prefill_chunk,
            queue_cap: 0,
            shards: 1,
            placement: None,
        }
    }

    /// Backend block read off a live server's recording hooks.
    pub fn from_server_stats(stats: &ServerStats) -> TraceBackend {
        TraceBackend {
            slots: stats.slots,
            prefill_chunk: stats.prefill_chunk,
            queue_cap: stats.queue_cap,
            shards: 1,
            placement: None,
        }
    }

    /// Backend block read off a live cluster's recording hooks (slots /
    /// chunk / cap come from shard 0 — the cluster spawns homogeneous
    /// backends).
    pub fn from_cluster_stats(stats: &ClusterStats) -> TraceBackend {
        let first = stats.shards.first();
        TraceBackend {
            slots: first.map_or(0, |s| s.slots),
            prefill_chunk: first.map_or(0, |s| s.prefill_chunk),
            queue_cap: first.map_or(0, |s| s.queue_cap),
            shards: stats.shards.len().max(1),
            placement: Some(stats.placement.clone()),
        }
    }
}

/// One request's recorded lifetime: what arrived, and what happened.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// workload-global request id
    pub id: u64,
    /// exact arrival offset from experiment start (ns — integer-exact in
    /// JSON up to 2^53 ns ≈ 104 days, far beyond any experiment)
    pub arrival_ns: u64,
    /// prompt tokens prefillled
    pub prompt_len: usize,
    /// tokens requested
    pub gen_len: usize,
    /// deadline budget from submit (µs)
    pub deadline_us: u64,
    /// shard that served (or shed) it, when sharded
    pub shard: Option<usize>,
    /// terminal outcome
    pub ok: bool,
    /// submit → slot admission (µs); `None`: never admitted
    pub queue_us: Option<f64>,
    /// submit → first token (µs); `None`: none produced
    pub ttft_us: Option<f64>,
    /// submit → terminal reply (µs)
    pub e2e_us: f64,
    /// tokens banked by the terminal reply
    pub tokens: u64,
}

/// A loaded (or freshly recorded) `moepim.trace.v1` document.
#[derive(Debug, Clone, PartialEq)]
pub struct RecordedTrace {
    /// the spec that generated the workload
    pub spec: WorkloadSpec,
    /// admission-policy label the backend ran
    pub policy: String,
    /// `"virtual"` or `"wall"`
    pub clock: String,
    /// serving-side configuration
    pub backend: TraceBackend,
    /// cumulative planner telemetry of the recorded run (the calibration
    /// fit reads mean cycles/step from here)
    pub planner: PlannerStats,
    /// recorded experiment duration (s)
    pub duration_s: f64,
    /// per-request records, in id order
    pub requests: Vec<TraceRequest>,
}

/// Records one load experiment into a [`RecordedTrace`].  Construct it
/// with the spec/policy the experiment runs under, run the experiment,
/// then `finish` with the outcome and the backend block read off the
/// serving stats:
///
/// ```
/// use moepim::workload::record::{TraceBackend, TraceRecorder};
/// use moepim::workload::{run_virtual, AdmissionPolicy, VirtualConfig,
///                        WorkloadSpec};
///
/// let cfg = VirtualConfig::default();
/// let spec = WorkloadSpec { requests: 8, ..WorkloadSpec::default() };
/// let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
/// let trace = TraceRecorder::new(&spec, AdmissionPolicy::fifo())
///     .finish(&out, TraceBackend::from_virtual(&cfg));
/// assert_eq!(trace.requests.len(), 8);
/// // the document round-trips through its JSON form
/// let back = moepim::workload::record::RecordedTrace::from_json(
///     &trace.to_json()).unwrap();
/// assert_eq!(back, trace);
/// ```
pub struct TraceRecorder {
    spec: WorkloadSpec,
    policy: String,
}

impl TraceRecorder {
    /// Start recording an experiment described by `spec` under `policy`.
    pub fn new(spec: &WorkloadSpec, policy: AdmissionPolicy)
        -> TraceRecorder {
        TraceRecorder {
            spec: spec.clone(),
            policy: policy.label().to_string(),
        }
    }

    /// Fold a single-backend outcome into a trace document.
    pub fn finish(self, out: &LoadOutcome, backend: TraceBackend)
        -> RecordedTrace {
        let requests =
            join_requests(&self.spec, out.samples.iter().map(|s| (s, None)));
        RecordedTrace {
            spec: self.spec,
            policy: self.policy,
            clock: out.clock.to_string(),
            backend,
            planner: out.planner,
            duration_s: out.duration_s,
            requests,
        }
    }

    /// Fold a sharded run into one trace document: samples from every
    /// shard merged back into id order (each tagged with its shard),
    /// planner telemetry summed, duration the cluster makespan.
    pub fn finish_sharded(self, run: &ShardedRun, backend: TraceBackend)
        -> RecordedTrace {
        let mut planner = PlannerStats::default();
        let mut duration_s = 0.0f64;
        let mut clock = "virtual";
        let samples = run.shards.iter().flat_map(|s| {
            planner.steps += s.outcome.planner.steps;
            planner.work += s.outcome.planner.work;
            planner.cycles += s.outcome.planner.cycles;
            planner.contention_cycles += s.outcome.planner.contention_cycles;
            planner.transfers += s.outcome.planner.transfers;
            duration_s = duration_s.max(s.outcome.duration_s);
            clock = s.outcome.clock;
            let tag = s.outcome.shard.unwrap_or(s.shard);
            s.outcome.samples.iter().map(move |smp| (smp, Some(tag)))
        });
        let requests = join_requests(&self.spec, samples);
        RecordedTrace {
            spec: self.spec,
            policy: self.policy,
            clock: clock.to_string(),
            backend,
            planner,
            duration_s,
            requests,
        }
    }
}

/// Join samples (id → outcome) with the spec's materialized requests
/// (id → arrival/sizes/deadline), producing id-ordered records.  Samples
/// override the per-sample shard tag when the iterator supplies one.
fn join_requests<'a, I>(spec: &WorkloadSpec, samples: I) -> Vec<TraceRequest>
where
    I: Iterator<Item = (&'a crate::workload::driver::Sample, Option<usize>)>,
{
    let reqs = spec.materialize();
    let mut records: Vec<TraceRequest> = samples
        .filter_map(|(s, tag)| {
            let r = reqs.get(s.id as usize)?;
            Some(TraceRequest {
                id: s.id,
                arrival_ns: r.arrival_ns,
                prompt_len: r.prompt_len,
                gen_len: r.gen_len,
                deadline_us: r.deadline_us,
                shard: tag.or(s.shard),
                ok: s.ok,
                queue_us: s.queue_us,
                ttft_us: s.ttft_us,
                e2e_us: s.e2e_us,
                tokens: s.tokens,
            })
        })
        .collect();
    records.sort_by_key(|r| r.id);
    records
}

impl RecordedTrace {
    /// The spec the workload was generated from, verbatim.  Re-running it
    /// (`spec.materialize()`) regenerates the recorded request stream
    /// exactly — arrivals, sizes, and deadlines all derive from the seed.
    pub fn original_spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Exact replay: rebuild the recorded [`RequestSpec`]s with their
    /// integer-ns arrival offsets.  Driving these through
    /// [`crate::workload::run_virtual_requests`] (with
    /// [`RecordedTrace::original_spec`] supplying the seed) replays the
    /// recorded event sequence byte-identically.
    pub fn replay_requests(&self) -> Vec<RequestSpec> {
        self.requests
            .iter()
            .map(|r| RequestSpec {
                id: r.id,
                prompt_len: r.prompt_len,
                gen_len: r.gen_len,
                deadline_us: r.deadline_us,
                arrival_ns: r.arrival_ns,
            })
            .collect()
    }

    /// The recorded arrival timeline as a replayable
    /// [`ArrivalProcess::Replay`] (µs offsets — sub-µs detail truncates).
    pub fn replay_process(&self) -> ArrivalProcess {
        ArrivalProcess::Replay {
            times_us: self
                .requests
                .iter()
                .map(|r| r.arrival_ns / 1000)
                .collect(),
        }
    }

    /// The original spec with its arrival process swapped for the
    /// recorded timeline — the generic "drive anything with this traffic
    /// shape" handle.  Size/deadline draws are salted independently of
    /// the arrival stream, so when the original arrival was already a
    /// canonical `Replay` timeline this materializes the recorded
    /// workload exactly; for ns-granular processes (Poisson/bursty) the
    /// arrivals are µs-truncated.
    pub fn replay_spec(&self) -> WorkloadSpec {
        WorkloadSpec {
            arrival: self.replay_process(),
            ..self.spec.clone()
        }
    }

    // ----- JSON ------------------------------------------------------------

    /// Serialize to the `moepim.trace.v1` document.
    pub fn to_json(&self) -> Json {
        let requests: Vec<Json> = self
            .requests
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("arrival_ns", Json::num(r.arrival_ns as f64)),
                    ("prompt_len", Json::num(r.prompt_len as f64)),
                    ("gen_len", Json::num(r.gen_len as f64)),
                    ("deadline_us", Json::num(r.deadline_us as f64)),
                    (
                        "shard",
                        r.shard.map_or(Json::Null, |s| Json::num(s as f64)),
                    ),
                    ("ok", Json::Bool(r.ok)),
                    ("queue_us", r.queue_us.map_or(Json::Null, Json::num)),
                    ("ttft_us", r.ttft_us.map_or(Json::Null, Json::num)),
                    ("e2e_us", Json::num(r.e2e_us)),
                    ("tokens", Json::num(r.tokens as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str(TRACE_SCHEMA)),
            ("workload", spec_json(&self.spec)),
            ("policy", Json::str(&self.policy)),
            ("clock", Json::str(&self.clock)),
            (
                "backend",
                Json::obj(vec![
                    ("slots", Json::num(self.backend.slots as f64)),
                    (
                        "prefill_chunk",
                        Json::num(self.backend.prefill_chunk as f64),
                    ),
                    ("queue_cap", Json::num(self.backend.queue_cap as f64)),
                    ("shards", Json::num(self.backend.shards as f64)),
                    (
                        "placement",
                        self.backend
                            .placement
                            .as_deref()
                            .map_or(Json::Null, Json::str),
                    ),
                ]),
            ),
            (
                "planner",
                Json::obj(vec![
                    ("steps", Json::num(self.planner.steps as f64)),
                    ("work", Json::num(self.planner.work as f64)),
                    ("cycles", Json::num(self.planner.cycles as f64)),
                    (
                        "contention_cycles",
                        Json::num(self.planner.contention_cycles as f64),
                    ),
                    ("transfers", Json::num(self.planner.transfers as f64)),
                ]),
            ),
            ("duration_s", Json::num(self.duration_s)),
            ("requests", Json::arr(requests)),
        ])
    }

    /// Parse a `moepim.trace.v1` document.
    pub fn from_json(doc: &Json) -> Result<RecordedTrace, String> {
        match doc.get("schema").and_then(Json::as_str) {
            Some(TRACE_SCHEMA) => {}
            other => {
                return Err(format!(
                    "expected schema {TRACE_SCHEMA:?}, found {other:?}"
                ))
            }
        }
        let spec = spec_from_json(
            doc.get("workload").ok_or("missing workload block")?,
        )?;
        let policy = doc
            .get("policy")
            .and_then(Json::as_str)
            .ok_or("missing policy")?
            .to_string();
        let clock = doc
            .get("clock")
            .and_then(Json::as_str)
            .ok_or("missing clock")?
            .to_string();
        let b = doc.get("backend").ok_or("missing backend block")?;
        let backend = TraceBackend {
            slots: req_usize(b, "slots")?,
            prefill_chunk: req_usize(b, "prefill_chunk")?,
            queue_cap: req_usize(b, "queue_cap")?,
            shards: req_usize(b, "shards")?,
            placement: b
                .get("placement")
                .and_then(Json::as_str)
                .map(str::to_string),
        };
        let p = doc.get("planner").ok_or("missing planner block")?;
        let planner = PlannerStats {
            steps: req_u64(p, "steps")?,
            work: req_u64(p, "work")?,
            cycles: req_u64(p, "cycles")?,
            contention_cycles: req_u64(p, "contention_cycles")?,
            transfers: req_u64(p, "transfers")?,
        };
        let duration_s = doc
            .get("duration_s")
            .and_then(Json::as_f64)
            .ok_or("missing duration_s")?;
        let requests = doc
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or("missing requests array")?
            .iter()
            .map(|r| {
                Ok(TraceRequest {
                    id: req_u64(r, "id")?,
                    arrival_ns: req_u64(r, "arrival_ns")?,
                    prompt_len: req_usize(r, "prompt_len")?,
                    gen_len: req_usize(r, "gen_len")?,
                    deadline_us: req_u64(r, "deadline_us")?,
                    shard: r.get("shard").and_then(Json::as_usize),
                    ok: r
                        .get("ok")
                        .and_then(Json::as_bool)
                        .ok_or("missing ok")?,
                    queue_us: r.get("queue_us").and_then(Json::as_f64),
                    ttft_us: r.get("ttft_us").and_then(Json::as_f64),
                    e2e_us: r
                        .get("e2e_us")
                        .and_then(Json::as_f64)
                        .ok_or("missing e2e_us")?,
                    tokens: req_u64(r, "tokens")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(RecordedTrace {
            spec,
            policy,
            clock,
            backend,
            planner,
            duration_s,
            requests,
        })
    }
}

fn req_usize(obj: &Json, key: &str) -> Result<usize, String> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("missing or non-integer {key}"))
}

fn req_u64(obj: &Json, key: &str) -> Result<u64, String> {
    obj.get(key)
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| format!("missing or non-integer {key}"))
}

/// Serialize the full spec (not just labels — the trace must *name* the
/// experiment precisely enough to re-materialize it).
fn spec_json(spec: &WorkloadSpec) -> Json {
    let arrival = match &spec.arrival {
        ArrivalProcess::Poisson { rate_rps } => Json::obj(vec![
            ("kind", Json::str("poisson")),
            ("rate_rps", Json::num(*rate_rps)),
        ]),
        ArrivalProcess::Bursty { rate_rps, mean_on_ms, mean_off_ms } => {
            Json::obj(vec![
                ("kind", Json::str("bursty")),
                ("rate_rps", Json::num(*rate_rps)),
                ("mean_on_ms", Json::num(*mean_on_ms)),
                ("mean_off_ms", Json::num(*mean_off_ms)),
            ])
        }
        ArrivalProcess::Closed { users, think_ms } => Json::obj(vec![
            ("kind", Json::str("closed")),
            ("users", Json::num(*users as f64)),
            ("think_ms", Json::num(*think_ms)),
        ]),
        ArrivalProcess::Replay { times_us } => Json::obj(vec![
            ("kind", Json::str("replay")),
            (
                "times_us",
                Json::arr(times_us.iter().map(|&t| Json::num(t as f64))),
            ),
        ]),
    };
    let sizes = match &spec.sizes {
        SizeModel::Fixed { prompt_len, gen_len } => Json::obj(vec![
            ("kind", Json::str("fixed")),
            ("prompt_len", Json::num(*prompt_len as f64)),
            ("gen_len", Json::num(*gen_len as f64)),
        ]),
        SizeModel::Uniform { prompt, gen } => Json::obj(vec![
            ("kind", Json::str("uniform")),
            ("prompt", range_json(*prompt)),
            ("gen", range_json(*gen)),
        ]),
        SizeModel::TraceSeeded { n_experts, skew, prompt, gen } => {
            Json::obj(vec![
                ("kind", Json::str("trace")),
                ("n_experts", Json::num(*n_experts as f64)),
                ("skew", Json::num(*skew)),
                ("prompt", range_json(*prompt)),
                ("gen", range_json(*gen)),
            ])
        }
    };
    Json::obj(vec![
        // string, not number: a u64 seed above 2^53 would lose precision
        // through the f64-backed Json::Num (same convention as the SLO
        // reports)
        ("seed", Json::str(&spec.seed.to_string())),
        ("requests", Json::num(spec.requests as f64)),
        ("arrival", arrival),
        ("sizes", sizes),
        ("slo_e2e_ms", Json::num(spec.slo_e2e_ms)),
        (
            "deadline_slack_us_per_token",
            Json::num(spec.deadline_slack_us_per_token as f64),
        ),
        ("interactive_mix", Json::num(spec.interactive_mix)),
    ])
}

fn range_json((lo, hi): (usize, usize)) -> Json {
    Json::arr([Json::num(lo as f64), Json::num(hi as f64)])
}

fn range_from_json(obj: &Json, key: &str)
    -> Result<(usize, usize), String> {
    let arr = obj
        .get(key)
        .and_then(Json::as_arr)
        .filter(|a| a.len() == 2)
        .ok_or_else(|| format!("{key}: expected a [lo, hi] pair"))?;
    match (arr[0].as_usize(), arr[1].as_usize()) {
        (Some(lo), Some(hi)) => Ok((lo, hi)),
        _ => Err(format!("{key}: non-integer bound")),
    }
}

fn spec_from_json(w: &Json) -> Result<WorkloadSpec, String> {
    let seed = w
        .get("seed")
        .and_then(Json::as_str)
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or("missing or non-numeric seed string")?;
    let a = w.get("arrival").ok_or("missing arrival block")?;
    let arrival = match a.get("kind").and_then(Json::as_str) {
        Some("poisson") => ArrivalProcess::Poisson {
            rate_rps: req_f64(a, "rate_rps")?,
        },
        Some("bursty") => ArrivalProcess::Bursty {
            rate_rps: req_f64(a, "rate_rps")?,
            mean_on_ms: req_f64(a, "mean_on_ms")?,
            mean_off_ms: req_f64(a, "mean_off_ms")?,
        },
        Some("closed") => ArrivalProcess::Closed {
            users: req_usize(a, "users")?,
            think_ms: req_f64(a, "think_ms")?,
        },
        Some("replay") => ArrivalProcess::Replay {
            times_us: a
                .get("times_us")
                .and_then(Json::as_arr)
                .ok_or("replay: missing times_us")?
                .iter()
                .map(|t| {
                    t.as_f64()
                        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                        .map(|v| v as u64)
                        .ok_or_else(|| "replay: bad offset".to_string())
                })
                .collect::<Result<Vec<_>, String>>()?,
        },
        other => return Err(format!("unknown arrival kind {other:?}")),
    };
    let s = w.get("sizes").ok_or("missing sizes block")?;
    let sizes = match s.get("kind").and_then(Json::as_str) {
        Some("fixed") => SizeModel::Fixed {
            prompt_len: req_usize(s, "prompt_len")?,
            gen_len: req_usize(s, "gen_len")?,
        },
        Some("uniform") => SizeModel::Uniform {
            prompt: range_from_json(s, "prompt")?,
            gen: range_from_json(s, "gen")?,
        },
        Some("trace") => SizeModel::TraceSeeded {
            n_experts: req_usize(s, "n_experts")?,
            skew: req_f64(s, "skew")?,
            prompt: range_from_json(s, "prompt")?,
            gen: range_from_json(s, "gen")?,
        },
        other => return Err(format!("unknown sizes kind {other:?}")),
    };
    Ok(WorkloadSpec {
        seed,
        requests: req_usize(w, "requests")?,
        arrival,
        sizes,
        slo_e2e_ms: req_f64(w, "slo_e2e_ms")?,
        deadline_slack_us_per_token: req_u64(
            w,
            "deadline_slack_us_per_token",
        )?,
        // additive field: traces recorded before QoS tiering carry no
        // mix and replay single-tier (the legacy behaviour)
        interactive_mix: w
            .get("interactive_mix")
            .and_then(Json::as_f64)
            .unwrap_or(1.0),
    })
}

fn req_f64(obj: &Json, key: &str) -> Result<f64, String> {
    obj.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing or non-numeric {key}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use crate::workload::vsim::run_virtual;

    fn record_default() -> RecordedTrace {
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec { requests: 12, ..WorkloadSpec::default() };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::sjf());
        TraceRecorder::new(&spec, AdmissionPolicy::sjf())
            .finish(&out, TraceBackend::from_virtual(&cfg))
    }

    #[test]
    fn trace_round_trips_through_json_text() {
        let trace = record_default();
        let text = trace.to_json().to_string_pretty();
        let parsed = json::parse(&text).expect("trace parses");
        assert_eq!(
            parsed.get("schema").unwrap().as_str(),
            Some(TRACE_SCHEMA)
        );
        let back = RecordedTrace::from_json(&parsed).expect("loads");
        assert_eq!(back, trace);
    }

    #[test]
    fn replay_requests_match_the_original_materialization() {
        let trace = record_default();
        assert_eq!(trace.replay_requests(), trace.spec.materialize());
    }

    #[test]
    fn replay_spec_swaps_arrival_only() {
        let trace = record_default();
        let rs = trace.replay_spec();
        assert!(matches!(rs.arrival, ArrivalProcess::Replay { .. }));
        assert_eq!(rs.seed, trace.spec.seed);
        assert_eq!(rs.sizes, trace.spec.sizes);
        assert_eq!(rs.requests, trace.spec.requests);
    }

    #[test]
    fn every_spec_variant_round_trips() {
        for (arrival, sizes) in [
            (
                ArrivalProcess::Bursty {
                    rate_rps: 100.0,
                    mean_on_ms: 5.0,
                    mean_off_ms: 45.0,
                },
                SizeModel::Fixed { prompt_len: 8, gen_len: 4 },
            ),
            (
                ArrivalProcess::Closed { users: 3, think_ms: 1.5 },
                SizeModel::Uniform { prompt: (4, 12), gen: (1, 8) },
            ),
            (
                ArrivalProcess::Replay { times_us: vec![0, 10, 25] },
                SizeModel::TraceSeeded {
                    n_experts: 16,
                    skew: 1.2,
                    prompt: (4, 24),
                    gen: (1, 12),
                },
            ),
        ] {
            let spec = WorkloadSpec {
                arrival,
                sizes,
                requests: 6,
                ..WorkloadSpec::default()
            };
            let doc = spec_json(&spec);
            let back = spec_from_json(&doc).expect("spec loads");
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn interactive_mix_round_trips_and_defaults_single_tier() {
        let spec = WorkloadSpec {
            interactive_mix: 0.25,
            ..WorkloadSpec::default()
        };
        let back = spec_from_json(&spec_json(&spec)).expect("spec loads");
        assert_eq!(back.interactive_mix, 0.25);
        // a pre-QoS trace (no interactive_mix key) replays single-tier
        let mut doc = spec_json(&spec);
        if let Json::Obj(m) = &mut doc {
            m.remove("interactive_mix");
        }
        let legacy = spec_from_json(&doc).expect("legacy spec loads");
        assert_eq!(legacy.interactive_mix, 1.0);
    }

    #[test]
    fn unknown_schema_is_rejected() {
        let mut doc = record_default().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema".into(), Json::str("moepim.trace.v0"));
        }
        assert!(RecordedTrace::from_json(&doc).is_err());
    }
}
