//! Cross-PR perf-trajectory comparison of `BENCH_*.json` artifacts.
//!
//! `moepim perfcmp OLD.json NEW.json` loads two successive bench
//! artifacts — `moepim.bench_cluster.v1` (`shardtest --bench-cluster`) or
//! `moepim.bench_scenarios.v1` (`loadtest --bench-scenarios`) — matches
//! their legs by name, and reports per-metric deltas.  A leg regresses
//! when throughput (`tokens_per_s`, higher-better) drops or tail latency
//! (`p50_e2e_us` / `p99_e2e_us`, lower-better) rises by more than the
//! threshold; the CLI exits non-zero on any regression so CI can gate on
//! a committed baseline.
//!
//! The scenario bench runs on the virtual clock, so its numbers are
//! deterministic per seed and a committed baseline compares exactly; the
//! cluster bench is wall-clock and should be read as a trajectory, not a
//! gate.

use crate::util::json::Json;

/// Default regression threshold (percent change of a leg metric).
pub const DEFAULT_THRESHOLD_PCT: f64 = 10.0;

/// One metric compared between matching legs of two bench artifacts.
#[derive(Debug, Clone)]
pub struct PerfDelta {
    /// Leg name (`scenario` or `mode` field of the leg).
    pub leg: String,
    /// Metric name (`tokens_per_s`, `p99_e2e_us`, …).
    pub metric: String,
    /// Value in the old artifact.
    pub old: f64,
    /// Value in the new artifact.
    pub new: f64,
    /// Percent change `(new - old) / old * 100`.
    pub delta_pct: f64,
    /// `true` iff the change is a regression beyond the threshold.
    pub regression: bool,
}

/// `(metric name, higher_is_better)` pairs compared when present in both
/// legs.
const METRICS: [(&str, bool); 3] = [
    ("tokens_per_s", true),
    ("p50_e2e_us", false),
    ("p99_e2e_us", false),
];

fn leg_name(leg: &Json, index: usize) -> String {
    for key in ["scenario", "mode"] {
        if let Some(name) = leg.get(key).and_then(Json::as_str) {
            return name.to_string();
        }
    }
    format!("leg{index}")
}

fn legs_of(doc: &Json, which: &str) -> Result<Vec<(String, Json)>, String> {
    // bench_cluster stores its legs under `legs`, bench_scenarios under
    // `scenarios`; both are leg arrays to the comparison
    let legs = doc
        .get("legs")
        .or_else(|| doc.get("scenarios"))
        .and_then(Json::as_arr)
        .ok_or_else(|| {
            format!("{which}: no `legs`/`scenarios` array — not a bench artifact")
        })?;
    Ok(legs
        .iter()
        .enumerate()
        .map(|(i, leg)| (leg_name(leg, i), leg.clone()))
        .collect())
}

/// Compare two bench artifacts leg by leg.  Legs are matched by name;
/// legs present in only one artifact are skipped (a new scenario is not a
/// regression).  Returns one [`PerfDelta`] per (shared leg, metric
/// present in both).
pub fn compare(
    old: &Json,
    new: &Json,
    threshold_pct: f64,
) -> Result<Vec<PerfDelta>, String> {
    let old_schema = old.get("schema").and_then(Json::as_str).unwrap_or("");
    let new_schema = new.get("schema").and_then(Json::as_str).unwrap_or("");
    if old_schema != new_schema {
        return Err(format!(
            "schema mismatch: old is {old_schema:?}, new is {new_schema:?}"
        ));
    }
    let old_legs = legs_of(old, "old")?;
    let new_legs = legs_of(new, "new")?;
    let mut deltas = Vec::new();
    for (name, old_leg) in &old_legs {
        let Some((_, new_leg)) = new_legs.iter().find(|(n, _)| n == name)
        else {
            continue;
        };
        for (metric, higher_better) in METRICS {
            let (Some(a), Some(b)) = (
                old_leg.get(metric).and_then(Json::as_f64),
                new_leg.get(metric).and_then(Json::as_f64),
            ) else {
                continue;
            };
            if !(a.is_finite() && b.is_finite()) || a <= 0.0 {
                continue;
            }
            let delta_pct = (b - a) / a * 100.0;
            let regression = if higher_better {
                delta_pct < -threshold_pct
            } else {
                delta_pct > threshold_pct
            };
            deltas.push(PerfDelta {
                leg: name.clone(),
                metric: metric.to_string(),
                old: a,
                new: b,
                delta_pct,
                regression,
            });
        }
    }
    if deltas.is_empty() {
        return Err("no comparable legs/metrics between the artifacts".into());
    }
    Ok(deltas)
}

/// Render the comparison as an aligned text table (one line per delta,
/// regressions flagged).
pub fn render(deltas: &[PerfDelta]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<20} {:<14} {:>12} {:>12} {:>9}\n",
        "leg", "metric", "old", "new", "delta"
    ));
    for d in deltas {
        out.push_str(&format!(
            "{:<20} {:<14} {:>12.2} {:>12.2} {:>+8.2}% {}\n",
            d.leg,
            d.metric,
            d.old,
            d.new,
            d.delta_pct,
            if d.regression { "REGRESSION" } else { "" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario_doc(tps: f64, p99: f64) -> Json {
        Json::obj(vec![
            ("schema", Json::str("moepim.bench_scenarios.v1")),
            (
                "legs",
                Json::arr([Json::obj(vec![
                    ("scenario", Json::str("diurnal")),
                    ("tokens_per_s", Json::num(tps)),
                    ("p50_e2e_us", Json::num(p99 / 2.0)),
                    ("p99_e2e_us", Json::num(p99)),
                ])]),
            ),
        ])
    }

    #[test]
    fn identical_artifacts_have_no_regression() {
        let doc = scenario_doc(1000.0, 5000.0);
        let deltas = compare(&doc, &doc, DEFAULT_THRESHOLD_PCT).unwrap();
        assert_eq!(deltas.len(), 3);
        assert!(deltas.iter().all(|d| !d.regression));
        assert!(deltas.iter().all(|d| d.delta_pct == 0.0));
    }

    #[test]
    fn throughput_drop_beyond_threshold_regresses() {
        let old = scenario_doc(1000.0, 5000.0);
        let new = scenario_doc(800.0, 5000.0);
        let deltas = compare(&old, &new, 10.0).unwrap();
        let tps = deltas.iter().find(|d| d.metric == "tokens_per_s").unwrap();
        assert!(tps.regression);
        assert!((tps.delta_pct + 20.0).abs() < 1e-9);
        // within threshold: not a regression
        let new_ok = scenario_doc(950.0, 5000.0);
        let deltas = compare(&old, &new_ok, 10.0).unwrap();
        assert!(deltas.iter().all(|d| !d.regression));
    }

    #[test]
    fn latency_rise_beyond_threshold_regresses() {
        let old = scenario_doc(1000.0, 5000.0);
        let new = scenario_doc(1000.0, 6000.0);
        let deltas = compare(&old, &new, 10.0).unwrap();
        let p99 = deltas.iter().find(|d| d.metric == "p99_e2e_us").unwrap();
        assert!(p99.regression);
        // latency *improvement* is never a regression
        let faster = scenario_doc(1000.0, 2000.0);
        let deltas = compare(&old, &faster, 10.0).unwrap();
        assert!(deltas.iter().all(|d| !d.regression));
    }

    #[test]
    fn cluster_legs_match_by_mode() {
        let doc = Json::obj(vec![
            ("schema", Json::str("moepim.bench_cluster.v1")),
            (
                "legs",
                Json::arr([
                    Json::obj(vec![
                        ("mode", Json::str("concurrent")),
                        ("tokens_per_s", Json::num(500.0)),
                    ]),
                    Json::obj(vec![
                        ("mode", Json::str("serial")),
                        ("tokens_per_s", Json::num(250.0)),
                    ]),
                ]),
            ),
        ]);
        let deltas = compare(&doc, &doc, 10.0).unwrap();
        assert_eq!(deltas.len(), 2);
        assert!(deltas.iter().any(|d| d.leg == "concurrent"));
        assert!(deltas.iter().any(|d| d.leg == "serial"));
    }

    #[test]
    fn scenarios_key_is_accepted() {
        // the real BENCH_scenarios.json artifact keys its leg array as
        // `scenarios`, not `legs`
        let doc = Json::obj(vec![
            ("schema", Json::str("moepim.bench_scenarios.v1")),
            (
                "scenarios",
                Json::arr([Json::obj(vec![
                    ("scenario", Json::str("diurnal")),
                    ("tokens_per_s", Json::num(100.0)),
                ])]),
            ),
        ]);
        let deltas = compare(&doc, &doc, 10.0).unwrap();
        assert_eq!(deltas.len(), 1);
        assert!(!deltas[0].regression);
    }

    #[test]
    fn schema_mismatch_and_missing_legs_error() {
        let a = scenario_doc(1.0, 1.0);
        let b = Json::obj(vec![("schema", Json::str("moepim.bench_cluster.v1"))]);
        assert!(compare(&a, &b, 10.0).is_err());
        let c = Json::obj(vec![
            ("schema", Json::str("moepim.bench_scenarios.v1")),
        ]);
        assert!(compare(&c, &c, 10.0).is_err());
        // disjoint leg names: nothing comparable
        let d = Json::obj(vec![
            ("schema", Json::str("moepim.bench_scenarios.v1")),
            (
                "legs",
                Json::arr([Json::obj(vec![
                    ("scenario", Json::str("other")),
                    ("tokens_per_s", Json::num(1.0)),
                ])]),
            ),
        ]);
        assert!(compare(&a, &d, 10.0).is_err());
        let render_out = render(&compare(&a, &a, 10.0).unwrap());
        assert!(render_out.contains("tokens_per_s"));
    }
}
