//! Deterministic traffic generation: arrival processes and request-size
//! models, all driven by [`Pcg32`] so a whole load experiment replays from
//! one `u64` seed.
//!
//! Three open-loop arrival shapes (Poisson, bursty on/off, replay of an
//! explicit timeline) plus a closed-loop shape (`users` concurrent
//! sessions with think time — the driver issues the next request when the
//! previous one completes).  Request sizes come from a [`SizeModel`];
//! the [`SizeModel::TraceSeeded`] variant derives its length distribution
//! from a [`crate::moe::TraceGenerator`] routing trace, so prompt/gen
//! lengths follow the same skew shape as the expert loads the grouping
//! study measures.
//!
//! [`WorkloadSpec::materialize`] turns a spec into concrete
//! [`RequestSpec`]s — identical for every admission policy under test,
//! which is what makes policy comparisons apples-to-apples.

use crate::moe::TraceGenerator;
use crate::util::rng::Pcg32;

/// Distinct rng streams per concern, so adding a size draw never perturbs
/// the arrival timeline of the same seed.
const ARRIVAL_SALT: u64 = 0xA221_7A1E_57A6_0001;
const SIZE_SALT: u64 = 0x517E_D157_0000_0002;
const TRACE_SALT: u64 = 0x7124_CE00_0000_0003;

/// When requests arrive.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Open loop, exponential inter-arrivals at `rate_rps` requests/s.
    Poisson { rate_rps: f64 },
    /// Open loop, Markov-modulated on/off: Poisson at `rate_rps` during
    /// ON windows (mean `mean_on_ms`), silent during OFF gaps (mean
    /// `mean_off_ms`).  Long-run rate ≈ `rate_rps · on/(on+off)`.
    Bursty {
        rate_rps: f64,
        mean_on_ms: f64,
        mean_off_ms: f64,
    },
    /// Closed loop: `users` concurrent sessions, each submitting its next
    /// request `think_ms` after its previous one completed.  Arrival
    /// times are produced by the driver, not precomputed.
    Closed { users: usize, think_ms: f64 },
    /// Open loop, replay of an explicit timeline (µs offsets).  The
    /// timeline is canonicalized before use: sorted ascending and shifted
    /// to a zero start, so a segment cut out of a longer recording
    /// replays identically wherever its absolute clock began.  Requests
    /// beyond the timeline wrap around; the seam between laps preserves
    /// the trace's mean inter-arrival gap (floored at 1 µs) instead of
    /// inserting a fixed epsilon the trace may never contain.
    ///
    /// Recorded timelines come from
    /// [`crate::workload::record::RecordedTrace::replay_process`]; ad-hoc
    /// ones from `--replay-us`.
    Replay { times_us: Vec<u64> },
}

impl ArrivalProcess {
    /// The spelling used in CLI flags and report JSON.
    pub fn label(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Closed { .. } => "closed",
            ArrivalProcess::Replay { .. } => "replay",
        }
    }

    /// `true` for the closed-loop shape (driver paces by completions).
    pub fn is_closed(&self) -> bool {
        matches!(self, ArrivalProcess::Closed { .. })
    }

    /// Arrival times in ns for `n` requests, ascending.  For the closed
    /// loop this returns all-zero placeholders (the driver paces
    /// submissions by completions instead).
    ///
    /// Deterministic: the same `(process, n, rng seed)` always produces
    /// the same timeline, which is what lets a whole load experiment
    /// replay from one `u64` seed.
    ///
    /// ```
    /// use moepim::util::rng::Pcg32;
    /// use moepim::workload::ArrivalProcess;
    ///
    /// let p = ArrivalProcess::Poisson { rate_rps: 500.0 };
    /// let a = p.times_ns(64, &mut Pcg32::new(7));
    /// let b = p.times_ns(64, &mut Pcg32::new(7));
    /// assert_eq!(a, b); // same seed => same timeline, bit for bit
    /// assert!(a.windows(2).all(|w| w[0] <= w[1])); // and it ascends
    ///
    /// let c = p.times_ns(64, &mut Pcg32::new(8));
    /// assert_ne!(a, c); // a different seed is a different experiment
    /// ```
    pub fn times_ns(&self, n: usize, rng: &mut Pcg32) -> Vec<u64> {
        match self {
            ArrivalProcess::Poisson { rate_rps } => {
                let mean_ns = 1e9 / rate_rps.max(1e-9);
                let mut t = 0u64;
                (0..n)
                    .map(|_| {
                        t += exp_ns(rng, mean_ns);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Bursty { rate_rps, mean_on_ms, mean_off_ms } => {
                let mean_ns = 1e9 / rate_rps.max(1e-9);
                let on_ns = (mean_on_ms.max(1e-6)) * 1e6;
                let off_ns = (mean_off_ms.max(0.0)) * 1e6;
                let mut t = 0u64;
                let mut window_end = exp_ns(rng, on_ns);
                (0..n)
                    .map(|_| {
                        // bounded: degenerate parameters (ON windows much
                        // shorter than one inter-arrival gap) force-place
                        // the arrival instead of spinning across windows
                        for _ in 0..10_000 {
                            let dt = exp_ns(rng, mean_ns);
                            if t + dt <= window_end {
                                t += dt;
                                return t;
                            }
                            // window exhausted: jump over an OFF gap into
                            // the next ON window
                            t = window_end + exp_ns(rng, off_ns);
                            window_end = t + exp_ns(rng, on_ns);
                        }
                        // force-place, then re-derive a *fresh* ON window:
                        // leaving `window_end == t` made every later
                        // arrival fail the in-window check and eat an OFF
                        // gap — one degenerate window poisoned the
                        // remainder of the stream
                        t += exp_ns(rng, mean_ns);
                        window_end = t + exp_ns(rng, on_ns);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Closed { .. } => vec![0; n],
            ArrivalProcess::Replay { times_us } => {
                if times_us.is_empty() {
                    return vec![0; n];
                }
                // canonicalize: sort (unsorted timelines used to leak
                // through as non-monotone arrivals that `drive_open`
                // clamps into a spurious burst) and shift to a zero start
                // (a nonzero-offset timeline used to re-apply its offset
                // on every lap)
                let mut tl = times_us.clone();
                tl.sort_unstable();
                let start = tl[0];
                for t in tl.iter_mut() {
                    *t -= start;
                }
                let span_us = *tl.last().expect("non-empty timeline");
                // the lap seam carries the trace's mean inter-arrival gap
                // (rounded, floored at 1 µs so degenerate all-coincident
                // timelines still advance) — a fixed 1 µs seam used to
                // glue laps together regardless of the trace's structure
                let m = (tl.len() - 1) as u64;
                let seam_us =
                    if m == 0 { 1 } else { ((span_us + m / 2) / m).max(1) };
                let period_us = span_us + seam_us;
                (0..n)
                    .map(|k| {
                        let lap = (k / tl.len()) as u64;
                        (tl[k % tl.len()] + lap * period_us) * 1000
                    })
                    .collect()
            }
        }
    }
}

/// Exponential sample with the given mean, truncated to whole ns.
///
/// The truncation means ns-scale mean gaps legitimately produce
/// `dt == 0`, i.e. *coincident* arrival timestamps at extreme rates.
/// Downstream consumers must break those ties deterministically:
/// `drive_open` submits coincident arrivals strictly in request order,
/// and the virtual cluster ingests them in timeline order into a FIFO
/// waiting queue — both pinned by tests
/// (`driver::tests::open_loop_submits_coincident_arrivals_in_order`,
/// `vsim::tests::coincident_arrivals_admit_fifo_by_id`).
fn exp_ns(rng: &mut Pcg32, mean_ns: f64) -> u64 {
    let u = rng.gen_f64(); // in [0, 1) => 1-u in (0, 1]
    (-(1.0 - u).ln() * mean_ns) as u64
}

/// How big requests are.  All ranges are inclusive.
#[derive(Debug, Clone, PartialEq)]
pub enum SizeModel {
    /// Every request has exactly this prompt/generation length.
    Fixed { prompt_len: usize, gen_len: usize },
    /// Lengths drawn uniformly from the inclusive ranges.
    Uniform {
        prompt: (usize, usize),
        gen: (usize, usize),
    },
    /// Lengths follow the load shape of a seeded routing trace: a
    /// [`TraceGenerator::token_choice_zipf`] trace's per-expert loads
    /// become a categorical distribution over the length range, so the
    /// same skew that concentrates tokens on popular experts concentrates
    /// requests on short lengths, with a heavy tail of long ones.
    TraceSeeded {
        n_experts: usize,
        skew: f64,
        prompt: (usize, usize),
        gen: (usize, usize),
    },
}

impl SizeModel {
    /// The spelling used in CLI flags and report JSON.
    pub fn label(&self) -> &'static str {
        match self {
            SizeModel::Fixed { .. } => "fixed",
            SizeModel::Uniform { .. } => "uniform",
            SizeModel::TraceSeeded { .. } => "trace",
        }
    }

    /// Per-spec precomputation (the trace-derived categorical weights).
    fn weights(&self, seed: u64) -> Vec<f64> {
        match self {
            SizeModel::TraceSeeded { n_experts, skew, .. } => {
                let e = (*n_experts).max(1);
                let mut gen = TraceGenerator::new(e, seed ^ TRACE_SALT);
                let m = gen.token_choice_zipf(256, 2, *skew);
                m.expert_loads()
                    .into_iter()
                    .map(|l| l as f64 + 1.0)
                    .collect()
            }
            _ => Vec::new(),
        }
    }

    fn sample(&self, rng: &mut Pcg32, weights: &[f64]) -> (usize, usize) {
        match self {
            SizeModel::Fixed { prompt_len, gen_len } => (*prompt_len, *gen_len),
            SizeModel::Uniform { prompt, gen } => {
                (range_sample(rng, *prompt), range_sample(rng, *gen))
            }
            SizeModel::TraceSeeded { prompt, gen, .. } => {
                let jp = categorical(rng, weights);
                let jg = categorical(rng, weights);
                (
                    map_to_range(jp, weights.len(), *prompt),
                    map_to_range(jg, weights.len(), *gen),
                )
            }
        }
    }
}

fn range_sample(rng: &mut Pcg32, (lo, hi): (usize, usize)) -> usize {
    let (lo, hi) = (lo.min(hi), lo.max(hi));
    lo + rng.gen_range(hi - lo + 1)
}

fn categorical(rng: &mut Pcg32, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    let mut u = rng.gen_f64() * total;
    for (j, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return j;
        }
    }
    weights.len().saturating_sub(1)
}

/// Map category `j` of `n` linearly onto an inclusive range (category 0 —
/// the most loaded expert under zipf skew — maps to the range's low end).
fn map_to_range(j: usize, n: usize, (lo, hi): (usize, usize)) -> usize {
    let (lo, hi) = (lo.min(hi), lo.max(hi));
    if n <= 1 {
        return lo;
    }
    lo + (j * (hi - lo)) / (n - 1)
}

/// One concrete request of a materialized workload.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpec {
    /// workload-global request id (index in submission order); prompt and
    /// routing streams key off `(spec.seed, id)`, so a request behaves
    /// identically regardless of queue position or shard placement
    pub id: u64,
    /// prompt tokens to prefill
    pub prompt_len: usize,
    /// tokens to generate
    pub gen_len: usize,
    /// deadline budget from submit, for deadline-aware admission
    pub deadline_us: u64,
    /// arrival offset from experiment start (0 for closed-loop specs)
    pub arrival_ns: u64,
}

/// A complete seeded load experiment: who arrives when, how big, and what
/// the SLO target is.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// the one `u64` every random draw in the experiment derives from
    pub seed: u64,
    /// requests to materialize
    pub requests: usize,
    /// when requests arrive
    pub arrival: ArrivalProcess,
    /// how big requests are
    pub sizes: SizeModel,
    /// end-to-end latency target for SLO-attainment accounting (ms)
    pub slo_e2e_ms: f64,
    /// per-token slack added to each request's deadline budget
    /// (`deadline_us = slo_e2e_ms·1000 + gen_len · this`)
    pub deadline_slack_us_per_token: u64,
    /// fraction of requests in the interactive QoS tier, assigned
    /// deterministically by id stride
    /// ([`crate::workload::Priority::assign`]).  `1.0` (the default)
    /// keeps the legacy single-tier behaviour: every request is
    /// interactive and QoS-enabled backends behave exactly as before
    pub interactive_mix: f64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            seed: 2026,
            requests: 64,
            arrival: ArrivalProcess::Poisson { rate_rps: 64.0 },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 24),
                gen: (1, 12),
            },
            slo_e2e_ms: 250.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        }
    }
}

impl WorkloadSpec {
    /// Expand into concrete requests — deterministic in `seed`, and
    /// independent of whichever admission policy or backend later serves
    /// them.
    ///
    /// ```
    /// use moepim::workload::WorkloadSpec;
    ///
    /// let spec = WorkloadSpec { seed: 42, ..WorkloadSpec::default() };
    /// // same spec => byte-identical request stream, every time
    /// assert_eq!(spec.materialize(), spec.materialize());
    /// // a different seed materializes a different experiment
    /// let other = WorkloadSpec { seed: 43, ..spec.clone() };
    /// assert_ne!(spec.materialize(), other.materialize());
    /// ```
    pub fn materialize(&self) -> Vec<RequestSpec> {
        let mut arr_rng = Pcg32::new(self.seed ^ ARRIVAL_SALT);
        let mut size_rng = Pcg32::new(self.seed ^ SIZE_SALT);
        let times = self.arrival.times_ns(self.requests, &mut arr_rng);
        let weights = self.sizes.weights(self.seed);
        let base_us = (self.slo_e2e_ms * 1000.0).max(0.0) as u64;
        times
            .into_iter()
            .enumerate()
            .map(|(i, arrival_ns)| {
                let (prompt_len, gen_len) =
                    self.sizes.sample(&mut size_rng, &weights);
                RequestSpec {
                    id: i as u64,
                    prompt_len,
                    gen_len,
                    deadline_us: base_us
                        + gen_len as u64 * self.deadline_slack_us_per_token,
                    arrival_ns,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_times_ascend_and_are_deterministic() {
        let p = ArrivalProcess::Poisson { rate_rps: 500.0 };
        let a = p.times_ns(200, &mut Pcg32::new(9));
        let b = p.times_ns(200, &mut Pcg32::new(9));
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn bursty_clusters_arrivals() {
        let p = ArrivalProcess::Bursty {
            rate_rps: 2000.0,
            mean_on_ms: 5.0,
            mean_off_ms: 50.0,
        };
        let t = p.times_ns(400, &mut Pcg32::new(3));
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        // effective rate must be well below the in-burst rate
        let dur_s = *t.last().unwrap() as f64 / 1e9;
        let eff = 400.0 / dur_s;
        assert!(eff < 1200.0, "effective rate {eff} not bursty-limited");
    }

    #[test]
    fn replay_wraps_monotonically() {
        let p = ArrivalProcess::Replay { times_us: vec![0, 10, 25] };
        let t = p.times_ns(7, &mut Pcg32::new(1));
        assert_eq!(t.len(), 7);
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(t[0], 0);
        assert_eq!(t[1], 10_000);
        // second lap starts after span(25) + mean-gap seam(13) = 38 µs
        assert_eq!(t[3], 38_000);
    }

    #[test]
    fn replay_seam_preserves_mean_gap() {
        // trace gaps are 10 and 15 µs; mean 12.5 rounds to a 13 µs seam,
        // and every lap repeats with the same 38 µs period
        let p = ArrivalProcess::Replay { times_us: vec![0, 10, 25] };
        let t = p.times_ns(7, &mut Pcg32::new(1));
        assert_eq!(t[3] - t[2], 13_000);
        assert_eq!(t[4], 48_000);
        assert_eq!(t[6], 76_000);
    }

    #[test]
    fn replay_normalizes_nonzero_start() {
        let base = ArrivalProcess::Replay { times_us: vec![0, 10, 25] };
        let offs = ArrivalProcess::Replay { times_us: vec![500, 510, 525] };
        let a = base.times_ns(9, &mut Pcg32::new(1));
        let b = offs.times_ns(9, &mut Pcg32::new(1));
        assert_eq!(a, b, "nonzero start must not shift or skew laps");
        assert_eq!(b[0], 0);
    }

    #[test]
    fn replay_sorts_unsorted_timelines() {
        let sorted = ArrivalProcess::Replay { times_us: vec![0, 10, 25] };
        let shuffled = ArrivalProcess::Replay { times_us: vec![25, 0, 10] };
        assert_eq!(
            sorted.times_ns(9, &mut Pcg32::new(1)),
            shuffled.times_ns(9, &mut Pcg32::new(1)),
        );
    }

    #[test]
    fn replay_degenerate_timelines_still_advance() {
        // single point: normalized to 0, 1 µs seam per lap
        let p = ArrivalProcess::Replay { times_us: vec![40] };
        let t = p.times_ns(4, &mut Pcg32::new(1));
        assert_eq!(t, vec![0, 1_000, 2_000, 3_000]);
        // all-coincident timeline: seam floors at 1 µs, no stuck laps
        let q = ArrivalProcess::Replay { times_us: vec![7, 7, 7] };
        let u = q.times_ns(6, &mut Pcg32::new(1));
        assert_eq!(u, vec![0, 0, 0, 1_000, 1_000, 1_000]);
    }

    #[test]
    fn bursty_force_place_recovers_the_stream() {
        // degenerate regime: ON windows (1 ns mean after the clamp) are
        // far shorter than one inter-arrival gap and OFF gaps are zero,
        // so every arrival rides the bounded force-place fallback.  The
        // stream must keep advancing at roughly the nominal rate instead
        // of collapsing once the first fallback fires.
        let p = ArrivalProcess::Bursty {
            rate_rps: 1_000.0,
            mean_on_ms: 1e-9,
            mean_off_ms: 0.0,
        };
        let t = p.times_ns(64, &mut Pcg32::new(11));
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        let dur_s = *t.last().unwrap() as f64 / 1e9;
        let eff = 64.0 / dur_s;
        assert!(
            (200.0..5_000.0).contains(&eff),
            "post-fallback effective rate {eff} rps degenerated"
        );
    }

    #[test]
    fn extreme_rates_truncate_to_coincident_arrivals() {
        // whole-ns truncation of exponential gaps: at a 2 ns mean gap,
        // `dt == 0` is common, so duplicate timestamps are a legitimate
        // output — the timeline stays non-decreasing and consumers break
        // the ties FIFO (pinned in the driver and vsim tests)
        let p = ArrivalProcess::Poisson { rate_rps: 500_000_000.0 };
        let t = p.times_ns(256, &mut Pcg32::new(5));
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert!(
            t.windows(2).any(|w| w[0] == w[1]),
            "expected dt == 0 duplicates at a 2 ns mean gap"
        );
    }

    #[test]
    fn materialize_is_seed_deterministic() {
        let spec = WorkloadSpec::default();
        assert_eq!(spec.materialize(), spec.materialize());
        let other = WorkloadSpec { seed: 1, ..WorkloadSpec::default() };
        assert_ne!(spec.materialize(), other.materialize());
    }

    #[test]
    fn sizes_respect_ranges() {
        let spec = WorkloadSpec {
            requests: 300,
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 24),
                gen: (1, 12),
            },
            ..WorkloadSpec::default()
        };
        for r in spec.materialize() {
            assert!((4..=24).contains(&r.prompt_len), "{r:?}");
            assert!((1..=12).contains(&r.gen_len), "{r:?}");
            assert!(r.deadline_us >= 250_000);
        }
    }

    #[test]
    fn closed_loop_materializes_placeholder_arrivals() {
        let spec = WorkloadSpec {
            requests: 5,
            arrival: ArrivalProcess::Closed { users: 2, think_ms: 1.0 },
            ..WorkloadSpec::default()
        };
        assert!(spec.materialize().iter().all(|r| r.arrival_ns == 0));
    }
}
