//! Mergeable log-bucketed latency histogram.
//!
//! The loadtest driver records tens of thousands of per-request timings;
//! keeping them all to sort at the end would make merged / sharded
//! experiments awkward, so the histogram stores counts in geometric
//! buckets instead: bucket `i` covers `[2^(i/8), 2^((i+1)/8))`
//! microseconds (8 buckets per octave).  Any quantile it reports is the
//! geometric midpoint of the bucket holding that order statistic, which is
//! within `2^(1/16) - 1` (≈ 4.4%) of the exact sample — the bound
//! `rust/tests/props_workload.rs` pins, together with quantile
//! monotonicity and merge == concat-then-build.
//!
//! Buckets live in a `BTreeMap` keyed by bucket index, so iteration is in
//! value order and two histograms merge by adding counts — merging is
//! exact, not approximate-on-approximate.

use std::collections::BTreeMap;

/// Buckets per power of two.  8 → worst-case relative quantile error
/// `2^(1/16) - 1 ≈ 4.4%`, ~600 live buckets for values spanning ns..hours.
const BUCKETS_PER_OCTAVE: f64 = 8.0;

/// Log-bucketed histogram of non-negative latencies (microseconds by
/// convention — the unit the serving stack reports).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    counts: BTreeMap<i32, u64>,
    zeros: u64,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: BTreeMap::new(),
            zeros: 0,
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Record one value.  Non-finite or negative values are rejected (they
    /// indicate a driver bug, not a latency) — debug builds assert.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            debug_assert!(false, "latency must be finite and >= 0, got {v}");
            return;
        }
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v == 0.0 {
            self.zeros += 1;
        } else {
            let idx = (v.log2() * BUCKETS_PER_OCTAVE).floor() as i32;
            *self.counts.entry(idx).or_insert(0) += 1;
        }
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// `true` iff nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact mean of the recorded values (0 when empty) — the sum is
    /// tracked outside the buckets, so the mean carries no bucket error.
    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_us(&self) -> f64 {
        self.max
    }

    /// The q-quantile (`0 < q <= 1`) as the representative of the bucket
    /// holding order statistic `clamp(ceil(q·n), 1, n)` — the same rank
    /// rule as `sorted[ceil(q·n) - 1]` on the raw samples, so the reported
    /// value sits in the same bucket as the exact one and inherits the
    /// [`Self::rel_error_bound`] guarantee.  Returns 0 on an empty
    /// histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = self.zeros;
        if cum >= rank {
            return 0.0;
        }
        for (&idx, &c) in &self.counts {
            cum += c;
            if cum >= rank {
                return bucket_rep(idx);
            }
        }
        self.max
    }

    /// Add `other`'s counts into `self`.  Exact on the bucket level:
    /// merging two histograms gives the same buckets (hence the same
    /// quantiles) as building one histogram over the concatenated samples
    /// — the contract the sharded fan-out's report merge leans on.
    ///
    /// ```
    /// use moepim::workload::LatencyHistogram;
    ///
    /// let mut left = LatencyHistogram::new();
    /// let mut right = LatencyHistogram::new();
    /// let mut concat = LatencyHistogram::new();
    /// for v in [3.0, 120.5, 0.0, 9_999.0] {
    ///     left.record(v);
    ///     concat.record(v);
    /// }
    /// for v in [0.25, 88.0, 1.0e6] {
    ///     right.record(v);
    ///     concat.record(v);
    /// }
    ///
    /// left.merge(&right);
    /// assert_eq!(left.count(), concat.count());
    /// assert_eq!(left.min_us(), concat.min_us());
    /// assert_eq!(left.max_us(), concat.max_us());
    /// for k in 1..=20 {
    ///     let q = k as f64 / 20.0;
    ///     assert_eq!(left.quantile(q), concat.quantile(q));
    /// }
    /// ```
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (&idx, &c) in &other.counts {
            *self.counts.entry(idx).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Worst-case relative error of [`Self::quantile`] against the exact
    /// order statistic (for positive samples).
    pub fn rel_error_bound() -> f64 {
        2f64.powf(0.5 / BUCKETS_PER_OCTAVE) - 1.0
    }
}

/// Geometric midpoint of bucket `idx`: `2^((idx + 0.5) / 8)`.
fn bucket_rep(idx: i32) -> f64 {
    2f64.powf((idx as f64 + 0.5) / BUCKETS_PER_OCTAVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.min_us(), 0.0);
        assert_eq!(h.max_us(), 0.0);
    }

    #[test]
    fn single_value_within_bound() {
        let mut h = LatencyHistogram::new();
        h.record(1234.5);
        let p50 = h.quantile(0.5);
        let err = (p50 - 1234.5).abs() / 1234.5;
        assert!(err <= LatencyHistogram::rel_error_bound() + 1e-12,
                "p50 {p50} err {err}");
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn quantiles_track_order_statistics() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000 {
            h.record(v as f64);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 - 500.0).abs() / 500.0 < 0.05, "p50 {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.05, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn zeros_are_their_own_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(0.0);
        h.record(8.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(h.quantile(1.0) > 0.0);
        assert_eq!(h.min_us(), 0.0);
    }

    #[test]
    fn merge_equals_concat() {
        let xs = [3.0, 17.5, 0.0, 250.0];
        let ys = [9.9, 1.0e6, 42.0];
        let mut h1 = LatencyHistogram::new();
        let mut h2 = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for &v in &xs {
            h1.record(v);
            all.record(v);
        }
        for &v in &ys {
            h2.record(v);
            all.record(v);
        }
        h1.merge(&h2);
        assert_eq!(h1.count(), all.count());
        assert_eq!(h1.min_us(), all.min_us());
        assert_eq!(h1.max_us(), all.max_us());
        for i in 1..=20 {
            let q = i as f64 / 20.0;
            assert_eq!(h1.quantile(q), all.quantile(q), "q={q}");
        }
    }
}
