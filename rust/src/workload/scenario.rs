//! Named, seeded workload scenario presets.
//!
//! Each scenario is a complete [`WorkloadSpec`] built from one `u64` seed,
//! so `loadtest --scenario <name> --seed S` names a reproducible load
//! experiment the same way a bare seed names a Poisson one.  The presets
//! cover the traffic shapes the serving stack is meant to survive:
//!
//! | name                | shape                                           |
//! |---------------------|-------------------------------------------------|
//! | `diurnal`           | sinusoidally-modulated replay timeline (a       |
//! |                     | compressed "day": 1.8× peak, 0.2× trough)       |
//! | `flash-crowd`       | bursty on/off — 4 krps bursts, ~10% duty cycle  |
//! | `long-prompt-flood` | Poisson stream of near-`max_seq` prompts        |
//! | `mixed-tenants`     | merged interactive-Poisson + batch-metronome    |
//! |                     | timeline with a wide size distribution          |
//!
//! Timelines for `diurnal` and `mixed-tenants` are materialized into
//! [`ArrivalProcess::Replay`] at spec-build time (seeded, deterministic),
//! which also exercises the replay path the trace recorder feeds
//! ([`crate::workload::record`]).  Prompt lengths in every preset stay
//! below the default virtual `max_seq` of 96 so all four run on both the
//! real and virtual backends unmodified.

use crate::util::rng::Pcg32;
use crate::workload::{ArrivalProcess, SizeModel, WorkloadSpec};

/// Distinct rng streams for the scenario timelines, mirroring the salt
/// scheme in [`crate::workload::arrival`].
const DIURNAL_SALT: u64 = 0xD1DA_7A11_0000_0004;
const MIXED_SALT: u64 = 0x3117_ED7E_0000_0005;

/// `(name, one-line description)` for every preset, in the order the CLI
/// lists them.
pub const SCENARIOS: [(&str, &str); 4] = [
    (
        "diurnal",
        "compressed-day sinusoidal load: 1.8x peak to 0.2x trough over a \
         2 s replay timeline",
    ),
    (
        "flash-crowd",
        "bursty on/off: 4000 rps bursts at a ~10% duty cycle (long-run \
         ~400 rps)",
    ),
    (
        "long-prompt-flood",
        "adversarial Poisson stream of near-max_seq prompts (48..=90 \
         tokens) with short generations",
    ),
    (
        "mixed-tenants",
        "interactive Poisson tenant merged with a batch metronome tenant \
         on one replay timeline, wide size spread",
    ),
];

/// The preset names, for CLI validation and sweep loops.
pub fn scenario_names() -> impl Iterator<Item = &'static str> {
    SCENARIOS.iter().map(|(n, _)| *n)
}

/// Build the named preset from `seed`, or `None` for an unknown name.
///
/// ```
/// use moepim::workload::scenario::scenario_spec;
///
/// let a = scenario_spec("diurnal", 2026).unwrap();
/// let b = scenario_spec("diurnal", 2026).unwrap();
/// assert_eq!(a.materialize(), b.materialize()); // seeded => reproducible
/// assert!(scenario_spec("weekday", 2026).is_none());
/// ```
pub fn scenario_spec(name: &str, seed: u64) -> Option<WorkloadSpec> {
    match name {
        "diurnal" => {
            let requests = 64;
            Some(WorkloadSpec {
                seed,
                requests,
                arrival: ArrivalProcess::Replay {
                    times_us: diurnal_times_us(seed, requests),
                },
                sizes: SizeModel::TraceSeeded {
                    n_experts: 16,
                    skew: 1.2,
                    prompt: (4, 24),
                    gen: (1, 12),
                },
                slo_e2e_ms: 250.0,
                deadline_slack_us_per_token: 500,
                interactive_mix: 1.0,
            })
        }
        "flash-crowd" => Some(WorkloadSpec {
            seed,
            requests: 64,
            arrival: ArrivalProcess::Bursty {
                rate_rps: 4000.0,
                mean_on_ms: 5.0,
                mean_off_ms: 45.0,
            },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 24),
                gen: (1, 12),
            },
            slo_e2e_ms: 150.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        }),
        "long-prompt-flood" => Some(WorkloadSpec {
            seed,
            requests: 48,
            arrival: ArrivalProcess::Poisson { rate_rps: 200.0 },
            // prompts crowd the default virtual max_seq of 96 without
            // crossing it (>= max_seq is a terminal error in vsim)
            sizes: SizeModel::Uniform { prompt: (48, 90), gen: (1, 4) },
            slo_e2e_ms: 400.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        }),
        "mixed-tenants" => Some(WorkloadSpec {
            seed,
            requests: 64,
            arrival: ArrivalProcess::Replay {
                times_us: mixed_tenant_times_us(seed),
            },
            // wider spread than the default so interactive-short and
            // batch-long requests share the queue
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 1.2,
                prompt: (4, 48),
                gen: (1, 16),
            },
            slo_e2e_ms: 250.0,
            deadline_slack_us_per_token: 500,
            // the interactive tenant's ~40-of-64 share, strided over
            // request ids so `--qos` runs get a genuine two-tier queue
            interactive_mix: 0.625,
        }),
        _ => None,
    }
}

/// Sinusoidally-modulated arrival timeline: one "day" compressed into 2 s,
/// intensity `1 + 0.8·sin(2πt/day)` around the mean rate that fits `n`
/// arrivals into the day.  Inter-arrival gaps are exponential at the
/// local intensity, so the timeline is non-decreasing by construction.
fn diurnal_times_us(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Pcg32::new(seed ^ DIURNAL_SALT);
    let day_us = 2_000_000.0;
    let base_gap_us = day_us / n.max(1) as f64;
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let phase = (t / day_us) * std::f64::consts::TAU;
            let intensity = 1.0 + 0.8 * phase.sin();
            t += exp_us(&mut rng, base_gap_us / intensity);
            t as u64
        })
        .collect()
}

/// Two tenants merged onto one timeline: an interactive Poisson stream
/// (~100 rps, 40 requests) and a batch metronome submitting every 15 ms
/// (24 requests).  Sorted here for readability; [`ArrivalProcess::Replay`]
/// canonicalizes anyway.
fn mixed_tenant_times_us(seed: u64) -> Vec<u64> {
    let mut rng = Pcg32::new(seed ^ MIXED_SALT);
    let mut times: Vec<u64> = Vec::with_capacity(64);
    let mut t = 0.0f64;
    for _ in 0..40 {
        t += exp_us(&mut rng, 10_000.0);
        times.push(t as u64);
    }
    for k in 0..24u64 {
        times.push(5_000 + k * 15_000);
    }
    times.sort_unstable();
    times
}

fn exp_us(rng: &mut Pcg32, mean_us: f64) -> f64 {
    let u = rng.gen_f64();
    -(1.0 - u).ln() * mean_us
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_builds_and_is_seed_deterministic() {
        for name in scenario_names() {
            let a = scenario_spec(name, 2026).expect(name);
            let b = scenario_spec(name, 2026).expect(name);
            assert_eq!(a, b, "{name}: spec not deterministic");
            assert_eq!(
                a.materialize(),
                b.materialize(),
                "{name}: requests not deterministic"
            );
            let c = scenario_spec(name, 7).expect(name);
            assert_ne!(
                a.materialize(),
                c.materialize(),
                "{name}: seed is not load-bearing"
            );
        }
    }

    #[test]
    fn unknown_scenario_is_rejected() {
        assert!(scenario_spec("weekday", 2026).is_none());
        assert!(scenario_spec("", 2026).is_none());
    }

    #[test]
    fn preset_prompts_fit_the_default_virtual_max_seq() {
        for name in scenario_names() {
            let spec = scenario_spec(name, 2026).expect(name);
            for r in spec.materialize() {
                assert!(
                    r.prompt_len > 0 && r.prompt_len < 96,
                    "{name}: prompt_len {} outside (0, 96)",
                    r.prompt_len
                );
            }
        }
    }

    #[test]
    fn replay_presets_carry_full_length_timelines() {
        for name in ["diurnal", "mixed-tenants"] {
            let spec = scenario_spec(name, 2026).unwrap();
            match &spec.arrival {
                ArrivalProcess::Replay { times_us } => {
                    assert_eq!(times_us.len(), spec.requests, "{name}");
                    assert!(
                        times_us.windows(2).all(|w| w[0] <= w[1]),
                        "{name}: timeline not sorted"
                    );
                }
                other => panic!("{name}: expected Replay, got {other:?}"),
            }
        }
    }

    #[test]
    fn diurnal_peaks_early_and_troughs_late() {
        // the sine peaks in the first half-day and bottoms out in the
        // second, so more than half the arrivals land in the first half
        let spec = scenario_spec("diurnal", 2026).unwrap();
        let times = match &spec.arrival {
            ArrivalProcess::Replay { times_us } => times_us.clone(),
            _ => unreachable!(),
        };
        let mid = times[times.len() / 2];
        let early = times.iter().filter(|&&t| t <= mid).count();
        assert!(early * 2 >= times.len());
        let span = *times.last().unwrap() - times[0];
        let first_half = times.iter().filter(|&&t| t < span / 2).count();
        assert!(
            first_half > times.len() / 2,
            "diurnal modulation missing: {first_half}/{} in first half",
            times.len()
        );
    }
}
