//! Pluggable slot-admission policies for the serving loop.
//!
//! The router keeps its waiting queue in arrival order and, whenever a
//! serving slot frees up, asks the policy which waiting request to prefill
//! next.  The policy sees only per-request metadata ([`QueuedMeta`]) — it
//! cannot touch engine state — so the same policy drives both the real
//! [`crate::coordinator::Server`] and the virtual-time cluster in
//! [`crate::workload::vsim`], and two policies can be compared under
//! byte-identical seeded traffic.
//!
//! Non-FIFO policies carry a starvation guard: once the *oldest* waiting
//! request has been passed over `starvation_limit` times it is admitted
//! unconditionally.  The guard inspects the queue head only, so it is a
//! progress guarantee, not a per-request constant bound: the head drains
//! within `starvation_limit` further admissions, then the next-oldest
//! becomes the head, and so on — a request at queue position `p` can
//! therefore wait up to ~`p · starvation_limit` admissions in the worst
//! case, but never indefinitely ("SJF must not starve", pinned in
//! `rust/tests/loadtest_virtual.rs`).

/// QoS tier of one request.  Ordered: [`Priority::Batch`] <
/// [`Priority::Interactive`], so `max` picks the more urgent tier.
///
/// With QoS enabled the router serves waiting interactive requests
/// before batch ones (slot reservation), and a waiting interactive
/// request may preempt a batch-tier slot (checkpoint → requeue — see
/// DESIGN.md §Preemption & QoS).  With QoS disabled the tier is carried
/// but ignored, preserving the seed scheduling behaviour exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Throughput tier: preemptible, admitted only when no interactive
    /// request waits (under QoS).
    Batch,
    /// Latency tier: admitted first, never preempted.
    Interactive,
}

impl Priority {
    /// The spelling used in CLI flags and report JSON.
    pub fn label(&self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }

    /// Parse a CLI spelling; `None` on unknown input.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "batch" | "BATCH" => Some(Priority::Batch),
            "interactive" | "INTERACTIVE" => Some(Priority::Interactive),
            _ => None,
        }
    }

    /// Deterministic tier assignment for request `id` under an
    /// `interactive_mix` fraction in `[0, 1]`: the evenly-strided rule
    /// `floor((id+1)·mix) > floor(id·mix)` marks ~`mix` of all ids
    /// interactive, spread uniformly through the id space (mix `0.25` →
    /// ids 3, 7, 11, …).  A pure function of `(id, mix)` — no rng stream
    /// — so tests and the sharded fan-out can recompute any request's
    /// tier without replaying the workload.
    pub fn assign(id: u64, interactive_mix: f64) -> Self {
        let mix = interactive_mix.clamp(0.0, 1.0);
        if mix >= 1.0 {
            return Priority::Interactive;
        }
        let before = (id as f64 * mix).floor();
        let after = ((id + 1) as f64 * mix).floor();
        if after > before {
            Priority::Interactive
        } else {
            Priority::Batch
        }
    }
}

/// What the policy knows about one waiting request.  `queue[0]` is the
/// oldest (arrival order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueuedMeta {
    /// requested generation length (the "job size" SJF orders by)
    pub gen_len: usize,
    /// deadline budget from submit, if the request carries one
    pub deadline_us: Option<u64>,
    /// how long the request has been waiting already
    pub waited_us: u64,
    /// admissions that picked a younger request over this one
    pub passed_over: u32,
}

/// Which waiting request gets the next free serving slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Arrival order (the seed behaviour; `admit_seq` monotone in submit
    /// order).
    Fifo,
    /// Shortest job (smallest `gen_len`) first; ties by arrival order.
    Sjf { starvation_limit: u32 },
    /// Earliest deadline first: smallest `deadline_us - waited_us` slack;
    /// requests without a deadline sort last.  Ties by arrival order.
    Deadline { starvation_limit: u32 },
}

impl AdmissionPolicy {
    /// Pass-overs the queue head tolerates before the starvation guard
    /// force-admits it (the limit the convenience constructors use).
    pub const DEFAULT_STARVATION_LIMIT: u32 = 8;

    /// FIFO admission (the seed behaviour).
    pub fn fifo() -> Self {
        AdmissionPolicy::Fifo
    }

    /// Shortest-job-first with the default starvation limit.
    pub fn sjf() -> Self {
        AdmissionPolicy::Sjf {
            starvation_limit: Self::DEFAULT_STARVATION_LIMIT,
        }
    }

    /// Earliest-deadline-first with the default starvation limit.
    pub fn deadline() -> Self {
        AdmissionPolicy::Deadline {
            starvation_limit: Self::DEFAULT_STARVATION_LIMIT,
        }
    }

    /// The spelling used in CLI flags and report JSON.
    pub fn label(&self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::Sjf { .. } => "sjf",
            AdmissionPolicy::Deadline { .. } => "edf",
        }
    }

    /// Parse a CLI spelling; `None` on unknown input.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" | "FIFO" => Some(Self::fifo()),
            "sjf" | "SJF" => Some(Self::sjf()),
            "edf" | "EDF" | "deadline" => Some(Self::deadline()),
            _ => None,
        }
    }

    /// Index of the request to admit next.  `queue` must be non-empty and
    /// in arrival order (index 0 oldest).  Deterministic: ties always go
    /// to the lower index.
    pub fn select(&self, queue: &[QueuedMeta]) -> usize {
        debug_assert!(!queue.is_empty(), "select on an empty queue");
        if queue.is_empty() {
            return 0;
        }
        match self {
            AdmissionPolicy::Fifo => 0,
            AdmissionPolicy::Sjf { starvation_limit } => {
                if queue[0].passed_over >= *starvation_limit {
                    return 0;
                }
                let mut best = 0usize;
                for (i, m) in queue.iter().enumerate().skip(1) {
                    if m.gen_len < queue[best].gen_len {
                        best = i;
                    }
                }
                best
            }
            AdmissionPolicy::Deadline { starvation_limit } => {
                if queue[0].passed_over >= *starvation_limit {
                    return 0;
                }
                let mut best = 0usize;
                for (i, m) in queue.iter().enumerate().skip(1) {
                    if slack_us(m) < slack_us(&queue[best]) {
                        best = i;
                    }
                }
                best
            }
        }
    }
}

/// Remaining slack before the deadline (negative when already blown);
/// deadline-less requests report `i64::MAX` and sort last.
fn slack_us(m: &QueuedMeta) -> i64 {
    match m.deadline_us {
        Some(d) => d as i64 - m.waited_us as i64,
        None => i64::MAX,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(gen_len: usize, deadline_us: Option<u64>, waited_us: u64,
            passed_over: u32) -> QueuedMeta {
        QueuedMeta { gen_len, deadline_us, waited_us, passed_over }
    }

    #[test]
    fn fifo_always_takes_the_head() {
        let q = vec![meta(9, None, 10, 0), meta(1, Some(5), 0, 0)];
        assert_eq!(AdmissionPolicy::fifo().select(&q), 0);
    }

    #[test]
    fn sjf_picks_shortest_with_stable_ties() {
        let q = vec![
            meta(5, None, 30, 0),
            meta(2, None, 20, 0),
            meta(2, None, 10, 0),
            meta(8, None, 0, 0),
        ];
        assert_eq!(AdmissionPolicy::sjf().select(&q), 1);
    }

    #[test]
    fn sjf_starvation_guard_boosts_the_head() {
        let limit = AdmissionPolicy::DEFAULT_STARVATION_LIMIT;
        let q = vec![meta(50, None, 900, limit), meta(1, None, 5, 0)];
        assert_eq!(AdmissionPolicy::sjf().select(&q), 0);
        let fresh = vec![meta(50, None, 900, limit - 1), meta(1, None, 5, 0)];
        assert_eq!(AdmissionPolicy::sjf().select(&fresh), 1);
    }

    #[test]
    fn deadline_picks_tightest_slack() {
        let q = vec![
            meta(4, Some(10_000), 2_000, 0), // slack 8000
            meta(4, Some(5_000), 1_000, 0),  // slack 4000
            meta(4, None, 9_000, 0),         // no deadline: last
        ];
        assert_eq!(AdmissionPolicy::deadline().select(&q), 1);
    }

    #[test]
    fn parse_round_trips_labels() {
        for p in [
            AdmissionPolicy::fifo(),
            AdmissionPolicy::sjf(),
            AdmissionPolicy::deadline(),
        ] {
            assert_eq!(AdmissionPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("lifo"), None);
    }

    #[test]
    fn priority_orders_interactive_above_batch() {
        assert!(Priority::Interactive > Priority::Batch);
        for p in [Priority::Batch, Priority::Interactive] {
            assert_eq!(Priority::parse(p.label()), Some(p));
        }
        assert_eq!(Priority::parse("premium"), None);
    }

    #[test]
    fn priority_assign_is_an_even_stride() {
        // mix 1.0 keeps the legacy all-interactive behaviour.
        assert!((0..64).all(|i| Priority::assign(i, 1.0) == Priority::Interactive));
        // mix 0.0 demotes everything.
        assert!((0..64).all(|i| Priority::assign(i, 0.0) == Priority::Batch));
        // The stride hits ~mix of ids, evenly spread: mix 0.25 -> 3,7,11,...
        let hits: Vec<u64> = (0..16)
            .filter(|&i| Priority::assign(i, 0.25) == Priority::Interactive)
            .collect();
        assert_eq!(hits, vec![3, 7, 11, 15]);
        // Out-of-range mixes clamp rather than misbehave.
        assert_eq!(Priority::assign(5, 2.5), Priority::Interactive);
        assert_eq!(Priority::assign(5, -1.0), Priority::Batch);
    }
}
