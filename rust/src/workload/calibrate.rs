//! Calibration: fit [`VirtualConfig`]'s cost constants against a recorded
//! trace so virtual SLO studies quantitatively predict the backend that
//! produced it.
//!
//! The virtual cluster prices a request's service time as
//!
//! ```text
//! service ≈ prompt_len · prefill_ns_per_token
//!         + (gen_len − 1) · (dispatch_overhead_ns + k̄ · cycle_ns)
//! ```
//!
//! where `k̄` is the planner's mean slot-cycles per decode step (the
//! contention model's output, recorded in the trace's planner block).
//! [`calibrate`] runs a two-variable least-squares fit of the recorded
//! service times (`e2e − queue`) over `(prompt_len, gen_len − 1)`:
//!
//! * the prompt slope **is** `prefill_ns_per_token`;
//! * the decode-step slope `c` bundles the collinear pair
//!   `dispatch_overhead_ns + k̄·cycle_ns` — per-step telemetry can't
//!   separate them, so the fit preserves the base config's
//!   overhead-to-cycle *ratio*: both are scaled by `s = c / c₀` with
//!   `c₀` the base config's per-step cost at the recorded `k̄`.
//!
//! The fit then *re-predicts the trace* — the calibrated config replays
//! the recorded requests on the virtual cluster — and reports p50/p99
//! end-to-end error, which is the accuracy figure that matters (the
//! acceptance gate is ≤ 15%).  Results serialize as
//! `moepim.calibration.v1` ([`Calibration::to_json`]).
//!
//! Caveat: traces recorded under chunked prefill interleave prefill with
//! decode, so the linear model is an approximation there; record the
//! calibration run with `prefill_chunk == 0` for the cleanest fit.

use crate::util::json::Json;
use crate::workload::policy::AdmissionPolicy;
use crate::workload::record::RecordedTrace;
use crate::workload::vsim::{run_virtual_requests, VirtualConfig};

/// Schema id stamped on every calibration document.
pub const CALIBRATION_SCHEMA: &str = "moepim.calibration.v1";

/// A fitted cost model plus its fit-quality report.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// the base config the fit scaled (supplies slots/chip shape and the
    /// overhead-to-cycle ratio)
    pub base: VirtualConfig,
    /// the calibrated config: base with fitted cost constants and the
    /// trace's slots/prefill_chunk substituted
    pub cfg: VirtualConfig,
    /// fitted prefill slope (ns per prompt token)
    pub prefill_ns_per_token: f64,
    /// fitted per-decode-step cost `c` (ns)
    pub decode_step_ns: f64,
    /// `c / c₀` — the factor applied to both `dispatch_overhead_ns` and
    /// `cycle_ns`
    pub scale: f64,
    /// recorded mean planner slot-cycles per decode step (`k̄`)
    pub mean_cycles_per_step: f64,
    /// successful samples the regression used
    pub n_samples: usize,
    /// root-mean-square service-time residual of the fit (µs)
    pub rms_residual_us: f64,
    /// recorded p50 end-to-end latency (µs)
    pub recorded_p50_e2e_us: f64,
    /// recorded p99 end-to-end latency (µs)
    pub recorded_p99_e2e_us: f64,
    /// calibrated re-prediction's p50 end-to-end latency (µs)
    pub predicted_p50_e2e_us: f64,
    /// calibrated re-prediction's p99 end-to-end latency (µs)
    pub predicted_p99_e2e_us: f64,
    /// |predicted − recorded| / recorded at p50, in percent
    pub p50_err_pct: f64,
    /// |predicted − recorded| / recorded at p99, in percent
    pub p99_err_pct: f64,
}

/// Fit `base`'s cost constants against `trace` and validate by
/// re-predicting it.  Errors when the trace has too few successful
/// samples to regress (needs ≥ 2 with distinct shapes).
pub fn calibrate(trace: &RecordedTrace, base: &VirtualConfig)
    -> Result<Calibration, String> {
    // ---- least squares: service_ns ≈ a·prompt + c·(gen−1) -------------
    let mut spp = 0.0f64; // Σ p²
    let mut spd = 0.0f64; // Σ p·d
    let mut sdd = 0.0f64; // Σ d²
    let mut sps = 0.0f64; // Σ p·service
    let mut sds = 0.0f64; // Σ d·service
    let mut n = 0usize;
    for r in &trace.requests {
        if !r.ok || r.tokens == 0 {
            continue;
        }
        let service_us = r.e2e_us - r.queue_us.unwrap_or(0.0);
        if !service_us.is_finite() || service_us < 0.0 {
            continue;
        }
        let s = service_us * 1e3; // ns
        let p = r.prompt_len as f64;
        let d = (r.gen_len.saturating_sub(1)) as f64;
        spp += p * p;
        spd += p * d;
        sdd += d * d;
        sps += p * s;
        sds += d * s;
        n += 1;
    }
    if n < 2 {
        return Err(format!(
            "calibration needs at least 2 successful samples, found {n}"
        ));
    }
    let det = spp * sdd - spd * spd;
    let (a, c) = if det.abs() > 1e-9 * (spp * sdd).max(1.0) {
        (
            (sps * sdd - sds * spd) / det,
            (sds * spp - sps * spd) / det,
        )
    } else if spp > 0.0 && sdd == 0.0 {
        // every request generated exactly one token: prefill-only fit,
        // decode cost unobservable — keep the base per-step cost
        let kbar = trace.planner.mean_cycles();
        let c0 = base.dispatch_overhead_ns as f64
            + kbar * base.cycle_ns as f64;
        (sps / spp, c0)
    } else {
        return Err(
            "degenerate trace: no prompt/decode shape variation to fit"
                .to_string(),
        );
    };
    let a = a.max(0.0);
    let c = c.max(0.0);

    // ---- decompose c across the collinear overhead/cycle pair ---------
    let kbar = trace.planner.mean_cycles();
    let c0 = base.dispatch_overhead_ns as f64 + kbar * base.cycle_ns as f64;
    let scale = if c0 > 0.0 { c / c0 } else { 1.0 };
    let mut cfg = base.clone();
    cfg.slots = trace.backend.slots.max(1);
    cfg.prefill_chunk = trace.backend.prefill_chunk;
    cfg.prefill_ns_per_token = (a.round() as u64).max(1);
    cfg.dispatch_overhead_ns =
        ((base.dispatch_overhead_ns as f64 * scale).round() as u64).max(1);
    cfg.cycle_ns = ((base.cycle_ns as f64 * scale).round() as u64).max(1);

    // ---- fit residuals -------------------------------------------------
    let mut sq = 0.0f64;
    for r in &trace.requests {
        if !r.ok || r.tokens == 0 {
            continue;
        }
        let service_us = r.e2e_us - r.queue_us.unwrap_or(0.0);
        if !service_us.is_finite() || service_us < 0.0 {
            continue;
        }
        let pred_us = (a * r.prompt_len as f64
            + c * (r.gen_len.saturating_sub(1)) as f64)
            / 1e3;
        sq += (pred_us - service_us).powi(2);
    }
    let rms_residual_us = (sq / n as f64).sqrt();

    // ---- validate: re-predict the trace with the calibrated config ----
    let policy = AdmissionPolicy::parse(&trace.policy)
        .unwrap_or(AdmissionPolicy::Fifo);
    let replay = run_virtual_requests(
        &cfg,
        trace.original_spec(),
        &trace.replay_requests(),
        policy,
    );
    let mut recorded: Vec<f64> = trace
        .requests
        .iter()
        .filter(|r| r.ok)
        .map(|r| r.e2e_us)
        .collect();
    let mut predicted: Vec<f64> = replay
        .samples
        .iter()
        .filter(|s| s.ok)
        .map(|s| s.e2e_us)
        .collect();
    recorded.sort_by(|x, y| x.total_cmp(y));
    predicted.sort_by(|x, y| x.total_cmp(y));
    let recorded_p50_e2e_us = percentile(&recorded, 0.50);
    let recorded_p99_e2e_us = percentile(&recorded, 0.99);
    let predicted_p50_e2e_us = percentile(&predicted, 0.50);
    let predicted_p99_e2e_us = percentile(&predicted, 0.99);

    Ok(Calibration {
        base: base.clone(),
        cfg,
        prefill_ns_per_token: a,
        decode_step_ns: c,
        scale,
        mean_cycles_per_step: kbar,
        n_samples: n,
        rms_residual_us,
        recorded_p50_e2e_us,
        recorded_p99_e2e_us,
        predicted_p50_e2e_us,
        predicted_p99_e2e_us,
        p50_err_pct: err_pct(predicted_p50_e2e_us, recorded_p50_e2e_us),
        p99_err_pct: err_pct(predicted_p99_e2e_us, recorded_p99_e2e_us),
    })
}

impl Calibration {
    /// Serialize as the `moepim.calibration.v1` document.
    pub fn to_json(&self) -> Json {
        let consts = |cfg: &VirtualConfig| {
            Json::obj(vec![
                ("cycle_ns", Json::num(cfg.cycle_ns as f64)),
                (
                    "dispatch_overhead_ns",
                    Json::num(cfg.dispatch_overhead_ns as f64),
                ),
                (
                    "prefill_ns_per_token",
                    Json::num(cfg.prefill_ns_per_token as f64),
                ),
            ])
        };
        Json::obj(vec![
            ("schema", Json::str(CALIBRATION_SCHEMA)),
            ("base", consts(&self.base)),
            ("fitted", consts(&self.cfg)),
            (
                "fit",
                Json::obj(vec![
                    ("n_samples", Json::num(self.n_samples as f64)),
                    (
                        "prefill_ns_per_token",
                        Json::num(round3(self.prefill_ns_per_token)),
                    ),
                    (
                        "decode_step_ns",
                        Json::num(round3(self.decode_step_ns)),
                    ),
                    ("scale", Json::num(round6(self.scale))),
                    (
                        "mean_cycles_per_step",
                        Json::num(round3(self.mean_cycles_per_step)),
                    ),
                    (
                        "rms_residual_us",
                        Json::num(round3(self.rms_residual_us)),
                    ),
                ]),
            ),
            (
                "validation",
                Json::obj(vec![
                    (
                        "recorded",
                        Json::obj(vec![
                            (
                                "p50_e2e_us",
                                Json::num(round3(self.recorded_p50_e2e_us)),
                            ),
                            (
                                "p99_e2e_us",
                                Json::num(round3(self.recorded_p99_e2e_us)),
                            ),
                        ]),
                    ),
                    (
                        "predicted",
                        Json::obj(vec![
                            (
                                "p50_e2e_us",
                                Json::num(round3(self.predicted_p50_e2e_us)),
                            ),
                            (
                                "p99_e2e_us",
                                Json::num(round3(self.predicted_p99_e2e_us)),
                            ),
                        ]),
                    ),
                    (
                        "p50_err_pct",
                        Json::num(round3(self.p50_err_pct)),
                    ),
                    (
                        "p99_err_pct",
                        Json::num(round3(self.p99_err_pct)),
                    ),
                ]),
            ),
        ])
    }
}

/// Nearest-rank percentile of an ascending-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn err_pct(predicted: f64, recorded: f64) -> f64 {
    if recorded <= 0.0 {
        return 0.0;
    }
    (predicted - recorded).abs() / recorded * 100.0
}

fn round3(v: f64) -> f64 {
    if !v.is_finite() {
        return 0.0;
    }
    (v * 1e3).round() / 1e3
}

fn round6(v: f64) -> f64 {
    if !v.is_finite() {
        return 0.0;
    }
    (v * 1e6).round() / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::record::{TraceBackend, TraceRecorder};
    use crate::workload::vsim::run_virtual;
    use crate::workload::WorkloadSpec;

    fn virtual_trace(cfg: &VirtualConfig) -> RecordedTrace {
        let spec = WorkloadSpec { requests: 48, ..WorkloadSpec::default() };
        let out = run_virtual(cfg, &spec, AdmissionPolicy::fifo());
        TraceRecorder::new(&spec, AdmissionPolicy::fifo())
            .finish(&out, TraceBackend::from_virtual(cfg))
    }

    #[test]
    fn self_calibration_recovers_the_generating_constants() {
        // a trace recorded by the virtual cluster itself must calibrate
        // back to (approximately) the constants that generated it, and
        // re-predict its own percentiles well inside the 15% gate
        let cfg = VirtualConfig::default();
        let trace = virtual_trace(&cfg);
        let cal = calibrate(&trace, &cfg).expect("fit");
        let prefill_err = (cal.prefill_ns_per_token
            - cfg.prefill_ns_per_token as f64)
            .abs()
            / cfg.prefill_ns_per_token as f64;
        assert!(
            prefill_err < 0.10,
            "prefill slope {} vs true {}",
            cal.prefill_ns_per_token,
            cfg.prefill_ns_per_token
        );
        assert!(
            cal.p50_err_pct <= 15.0 && cal.p99_err_pct <= 15.0,
            "re-prediction error p50 {:.2}% p99 {:.2}%",
            cal.p50_err_pct,
            cal.p99_err_pct
        );
    }

    #[test]
    fn calibration_tracks_a_scaled_cost_model() {
        // record under a 2x-cost config, fit starting from the default:
        // the fitted constants must move toward the generating ones
        let mut gen_cfg = VirtualConfig::default();
        gen_cfg.cycle_ns *= 2;
        gen_cfg.dispatch_overhead_ns *= 2;
        gen_cfg.prefill_ns_per_token *= 2;
        let trace = virtual_trace(&gen_cfg);
        let cal =
            calibrate(&trace, &VirtualConfig::default()).expect("fit");
        assert!(
            cal.scale > 1.5,
            "decode scale {} did not track the 2x cost model",
            cal.scale
        );
        assert!(
            cal.p50_err_pct <= 15.0 && cal.p99_err_pct <= 15.0,
            "re-prediction error p50 {:.2}% p99 {:.2}%",
            cal.p50_err_pct,
            cal.p99_err_pct
        );
    }

    #[test]
    fn calibration_document_shape() {
        let cfg = VirtualConfig::default();
        let cal = calibrate(&virtual_trace(&cfg), &cfg).expect("fit");
        let doc = cal.to_json();
        assert_eq!(
            doc.get("schema").unwrap().as_str(),
            Some(CALIBRATION_SCHEMA)
        );
        for path in [
            vec!["fitted", "cycle_ns"],
            vec!["fitted", "dispatch_overhead_ns"],
            vec!["fitted", "prefill_ns_per_token"],
            vec!["fit", "n_samples"],
            vec!["fit", "rms_residual_us"],
            vec!["validation", "p50_err_pct"],
            vec!["validation", "p99_err_pct"],
        ] {
            assert!(doc.path(&path).is_some(), "missing {path:?}");
        }
    }

    #[test]
    fn too_small_traces_are_rejected() {
        let cfg = VirtualConfig::default();
        let mut trace = virtual_trace(&cfg);
        trace.requests.truncate(1);
        assert!(calibrate(&trace, &cfg).is_err());
    }
}
