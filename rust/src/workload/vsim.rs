//! Virtual-time serving cluster: a deterministic discrete-event mirror of
//! the router loop in `coordinator/server.rs`, for load experiments whose
//! reports must be byte-identical across runs.
//!
//! Wall-clock loadtests measure the machine as much as the policy; this
//! module replaces the PJRT dispatches with a deterministic cost model so
//! `moepim loadtest` replays exactly from a seed:
//!
//! * slot admission, the completion sweep, batched-vs-single dispatch and
//!   slot recycling follow the real router's cycle structure;
//! * each decode cycle's cost comes from the *real* [`BatchPlanner`]: the
//!   live slots' expert sets (sampled per-request from a seeded zipf
//!   router, mirroring `moe::trace`) are laid out on the grouped
//!   peripherals and the makespan prices the cycle — so admission policies
//!   are compared under the paper's contention model, not a constant;
//! * prefill costs scale with prompt length and serialise on the engine,
//!   like `BatchEngine::admit` does — or, with
//!   [`VirtualConfig::prefill_chunk`] > 0, advance in bounded chunks
//!   interleaved with decode cycles, mirroring the real router's chunked
//!   admission (each chunk rides the cycle's planned step as extra rows,
//!   so prefill/decode peripheral contention is priced too).
//!
//! The event clock is integer nanoseconds; every timing in the resulting
//! [`Sample`]s derives from it, which is what makes the serialized
//! `SloReport` reproducible byte-for-byte.
//!
//! The multi-backend entry points scale the same mirror out: per-shard
//! backends advanced incrementally on a shared arrival clock, placed
//! either by the cluster's live-signal rules ([`run_virtual_live`]) or by
//! the full dynamic control loop — queued-request migration and
//! area-ledgered expert-group replication — in [`run_virtual_dynamic`]
//! (see `crate::placement`).

use std::collections::VecDeque;

use crate::config::SchedulePolicy;
use crate::moe::{group_loads, TraceGenerator};
use crate::obs::sink::{TraceShard, TraceSink};
use crate::obs::span::{EventKind, SpanOutcome};
use crate::placement::{
    Arrival, DynamicConfig, DynamicPlacer, Placer, PlacementReport,
    RoutingFeedback, ShardSpec,
};
use crate::sched::BatchPlanner;
use crate::util::rng::Pcg32;
use crate::workload::arrival::{ArrivalProcess, RequestSpec, WorkloadSpec};
use crate::workload::driver::{LoadOutcome, Sample};
use crate::workload::policy::{AdmissionPolicy, Priority, QueuedMeta};

/// Salt for the per-request expert-routing stream — deliberately distinct
/// from `driver::PROMPT_SALT` so routing and prompt-token draws of the
/// same request id are uncorrelated.
const ROUTE_SALT: u64 = 0x6A09_E667_F3BC_C909;

/// Salt for the prefill-chunk routing stream — distinct from both salts
/// above so chunked prefill's planner rows draw from their own stream and
/// never perturb the request's *decode* routing trajectory: a request's
/// decode expert draws are identical whether its prefill ran chunked or
/// monolithically (what keeps chunked-vs-unchunked SLO studies
/// apples-to-apples, and the route-aware placement peek valid).
const PREFILL_ROUTE_SALT: u64 = 0xBB67_AE85_84CA_A73B;

/// Cost model + modeled-chip shape for the virtual cluster.  Defaults
/// mirror the paper configuration the serving stack ships (16 experts,
/// uniform g=2 grouping, Algorithm 1 rescheduling, 4 serving slots).
#[derive(Debug, Clone, PartialEq)]
pub struct VirtualConfig {
    /// serving slots (continuous-batching width B)
    pub slots: usize,
    /// experts on the modeled chip
    pub n_experts: usize,
    /// functional depth: each decode cycle is priced as this many planned
    /// layer-steps
    pub n_layers: usize,
    /// experts selected per token per layer (top-k routing width)
    pub experts_per_token: usize,
    /// zipf skew of the simulated router's expert popularity
    pub route_skew: f64,
    /// peripheral-sharing group size handed to the planner
    pub group_size: usize,
    /// prefill schedule policy the planner prices cycles under
    pub schedule: SchedulePolicy,
    /// ns per planner slot-cycle (peripheral-shared expert execution)
    pub cycle_ns: u64,
    /// fixed per-decode-cycle cost (embed + sample + dispatch framework)
    pub dispatch_overhead_ns: u64,
    /// prefill cost per prompt token (serialises on the engine)
    pub prefill_ns_per_token: u64,
    /// maximum sequence length a slot can hold (prompt + generated)
    pub max_seq: usize,
    /// chunked-prefill budget in prompt tokens per slot per router cycle
    /// (`0`: monolithic prefill at admission, mirroring the real server's
    /// [`crate::coordinator::ServerOptions::prefill_chunk`] default).
    /// With `N > 0`, admission only claims the slot and each cycle
    /// advances every filling slot by at most `N` tokens before the
    /// decode rows are priced — prefill interleaves with decode instead
    /// of stalling it, and each chunk contributes one row per layer to
    /// the cycle's planned step so contention telemetry sees prefill
    /// occupancy of the shared peripheral groups.
    pub prefill_chunk: usize,
    /// QoS tiering: reserve freed slots for waiting interactive-tier
    /// requests and preempt batch-tier slots (checkpoint → requeue →
    /// restore) when interactive arrivals would otherwise queue behind
    /// them — the virtual mirror of
    /// [`crate::coordinator::ServerOptions::qos`].  Off (the default)
    /// the event loop is byte-identical to the seed router.
    pub qos: bool,
    /// planner cycles charged for one checkpoint *or* one restore of a
    /// slot's KV/GO banks.  Slot churn is never free: preempting and
    /// resuming a request each stall the engine for this many
    /// [`VirtualConfig::cycle_ns`] (regression pinned in
    /// `rust/tests/loadtest_virtual.rs`).
    pub checkpoint_cycles: u64,
}

impl Default for VirtualConfig {
    fn default() -> Self {
        VirtualConfig {
            slots: 4,
            n_experts: 16,
            n_layers: 1,
            experts_per_token: 2,
            route_skew: 1.2,
            group_size: 2,
            schedule: SchedulePolicy::Reschedule,
            cycle_ns: 400,
            dispatch_overhead_ns: 25_000,
            prefill_ns_per_token: 4_000,
            max_seq: 96,
            prefill_chunk: 0,
            qos: false,
            checkpoint_cycles: 250,
        }
    }
}

/// One waiting request (arrival order preserved by the queue).
struct VQueued {
    idx: usize,
    arrived_ns: u64,
    passed_over: u32,
    /// checkpointed decode state when this entry is a preempted request
    /// waiting to resume (`None` for fresh arrivals, and for preempted
    /// prefills — those restart their prefill deterministically)
    resume: Option<VResume>,
}

/// A preempted slot's stashed decode state — the virtual analogue of
/// [`crate::coordinator::SlotCheckpoint`]: the whole [`VLive`], router
/// stream included, so the resumed expert trajectory is bit-identical
/// to an uninterrupted run; plus the preemption instant, for the
/// `preempted_wait_us` telemetry.
struct VResume {
    live: VLive,
    preempted_ns: u64,
}

/// One live serving slot.
struct VLive {
    idx: usize,
    arrived_ns: u64,
    /// slot-grant instant (prefill start): `queue_us` ends here
    admitted_ns: u64,
    /// prefill-completion instant (the first token is sampled by the
    /// dispatch that finishes prefill): `ttft_us` ends here, so TTFT
    /// carries the prefill cost the clock was charged — the
    /// `ttft >= queue + prefill` invariant pinned in this module's tests
    first_token_ns: u64,
    admit_seq: u64,
    /// generated tokens banked so far (prefill's sampled token included)
    tokens: u64,
    /// per-request router stream — seeded from (spec.seed, request id) so
    /// a request's expert trajectory is independent of scheduling order
    rng: Pcg32,
}

/// One slot mid-chunked-prefill (the virtual mirror of the real router's
/// `Fill` bookkeeping over [`crate::coordinator::BatchEngine`]'s
/// `PrefillState`).
struct VFill {
    idx: usize,
    arrived_ns: u64,
    /// slot-grant instant — stamped at claim, before any prefill charge
    admitted_ns: u64,
    admit_seq: u64,
    /// prompt tokens still to prefill
    remaining: usize,
    /// dedicated prefill routing stream (see [`PREFILL_ROUTE_SALT`])
    rng: Pcg32,
}

/// Closed-loop continuation: issue the next request `think` after a
/// completion (no-op once the spec is exhausted, or for open loops).
fn issue_next(upcoming: &mut VecDeque<(u64, usize)>, next_issue: &mut usize,
              total: usize, at_ns: u64) {
    if *next_issue < total {
        upcoming.push_back((at_ns, *next_issue));
        *next_issue += 1;
    }
}

fn ns_to_us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// The per-request router stream: seeded from `(spec seed, request id)` so
/// a request's expert trajectory is independent of scheduling order, of
/// which shard serves it, and of whatever else ran before it.  The sharded
/// driver's routing-aware placement peeks the same stream (see
/// [`crate::workload::shard`]), which is what aligns its shard choice with
/// the experts the request will actually hit.
pub(crate) fn route_rng(spec_seed: u64, id: u64) -> Pcg32 {
    Pcg32::new(spec_seed ^ id.wrapping_mul(ROUTE_SALT))
}

/// The per-request prefill-chunk routing stream (planner rows only; the
/// decode stream above is untouched by chunking).
fn prefill_rng(spec_seed: u64, id: u64) -> Pcg32 {
    Pcg32::new(spec_seed ^ id.wrapping_mul(PREFILL_ROUTE_SALT))
}

/// Pick the index of the next waiting entry to admit.  Under QoS, freed
/// slots are reserved for the interactive tier: batch entries are only
/// eligible when no interactive request waits (the admission half of the
/// no-priority-inversion guarantee; the preemption pass is the other
/// half).  With `qos` off — or a single-tier queue — this reduces
/// exactly to the seed rule, so the loop stays byte-identical.
fn v_pick(policy: &AdmissionPolicy, waiting: &VecDeque<VQueued>,
          reqs: &[RequestSpec], mix: f64, now: u64, qos: bool) -> usize {
    let all = || (0..waiting.len()).collect::<Vec<usize>>();
    let candidates: Vec<usize> = if qos {
        let interactive: Vec<usize> = (0..waiting.len())
            .filter(|&i| {
                Priority::assign(reqs[waiting[i].idx].id, mix)
                    == Priority::Interactive
            })
            .collect();
        if interactive.is_empty() { all() } else { interactive }
    } else {
        all()
    };
    if matches!(policy, AdmissionPolicy::Fifo) {
        return candidates[0];
    }
    let metas: Vec<QueuedMeta> = candidates
        .iter()
        .map(|&i| {
            let w = &waiting[i];
            QueuedMeta {
                gen_len: reqs[w.idx].gen_len,
                deadline_us: Some(reqs[w.idx].deadline_us),
                waited_us: (now - w.arrived_ns) / 1000,
                passed_over: w.passed_over,
            }
        })
        .collect();
    candidates[policy.select(&metas).min(candidates.len() - 1)]
}

/// Deadline-aware victim selection, mirroring the real router's rule:
/// among batch-tier occupied slots (live or filling), evict the one with
/// the *largest* remaining deadline slack — a near-deadline batch job is
/// evicted last — breaking ties toward the higher slot index.
fn v_preempt_victim(live: &[Option<VLive>], filling: &[Option<VFill>],
                    reqs: &[RequestSpec], mix: f64, now: u64)
    -> Option<usize> {
    (0..live.len())
        .filter_map(|s| {
            let (idx, arrived_ns) = match (&live[s], &filling[s]) {
                (Some(l), _) => (l.idx, l.arrived_ns),
                (_, Some(f)) => (f.idx, f.arrived_ns),
                _ => return None,
            };
            if Priority::assign(reqs[idx].id, mix) != Priority::Batch {
                return None;
            }
            let slack = reqs[idx].deadline_us as i64
                - ((now - arrived_ns) / 1000) as i64;
            Some((slack, s))
        })
        .max()
        .map(|(_, s)| s)
}

/// Re-insert a preempted entry into the waiting queue preserving arrival
/// order (the invariant every admission policy assumes of the queue).
fn v_requeue(waiting: &mut VecDeque<VQueued>, w: VQueued) {
    let pos = waiting
        .iter()
        .position(|o| o.arrived_ns > w.arrived_ns)
        .unwrap_or(waiting.len());
    waiting.insert(pos, w);
}

/// The QoS preemption pass, shared verbatim by both event loops: when
/// more interactive requests wait than slots are free, checkpoint and
/// requeue batch-tier victims (largest slack first) until the shortfall
/// is covered or the batch tier is exhausted.  Checkpointing a live
/// slot's KV/GO banks stalls the engine for
/// [`VirtualConfig::checkpoint_cycles`]; a mid-prefill victim has no
/// decode state to save — its slot is simply released and the prefill
/// restarts on re-admission (the already-charged chunks are the cost).
#[allow(clippy::too_many_arguments)]
fn v_preempt_pass(cfg: &VirtualConfig, reqs: &[RequestSpec], mix: f64,
                  now: &mut u64, waiting: &mut VecDeque<VQueued>,
                  live: &mut [Option<VLive>],
                  filling: &mut [Option<VFill>], preemptions: &mut u64,
                  peak_waiting: &mut usize, peak_checkpoints: &mut usize,
                  sink: &mut TraceSink) {
    if !cfg.qos || waiting.is_empty() {
        return;
    }
    let free = (0..live.len())
        .filter(|&s| live[s].is_none() && filling[s].is_none())
        .count();
    let interactive_waiting = waiting
        .iter()
        .filter(|w| {
            Priority::assign(reqs[w.idx].id, mix) == Priority::Interactive
        })
        .count();
    let mut need = interactive_waiting.saturating_sub(free);
    while need > 0 {
        let Some(s) = v_preempt_victim(live, filling, reqs, mix, *now)
        else {
            break;
        };
        if let Some(l) = live[s].take() {
            let start = *now;
            *now += cfg.checkpoint_cycles * cfg.cycle_ns;
            *preemptions += 1;
            sink.record_span(
                start,
                *now - start,
                EventKind::Preempt { id: reqs[l.idx].id, slot: s },
            );
            v_requeue(waiting, VQueued {
                idx: l.idx,
                arrived_ns: l.arrived_ns,
                passed_over: 0,
                resume: Some(VResume { live: l, preempted_ns: *now }),
            });
        } else if let Some(f) = filling[s].take() {
            *preemptions += 1;
            sink.record(
                *now,
                EventKind::Preempt { id: reqs[f.idx].id, slot: s },
            );
            v_requeue(waiting, VQueued {
                idx: f.idx,
                arrived_ns: f.arrived_ns,
                passed_over: 0,
                resume: None,
            });
        }
        *peak_waiting = (*peak_waiting).max(waiting.len());
        // checkpoint-store high-water: snapshots held right now are the
        // requeued entries still carrying decode state (restores shed
        // them), priced into the report's checkpoint-spill area charge
        *peak_checkpoints = (*peak_checkpoints)
            .max(waiting.iter().filter(|w| w.resume.is_some()).count());
        need -= 1;
    }
}

/// Sample `k` distinct experts from a zipf-skewed popularity profile.
pub(crate) fn sample_experts(rng: &mut Pcg32, e: usize, k: usize, skew: f64)
    -> Vec<usize> {
    let k = k.min(e);
    let mut sel: Vec<usize> = Vec::with_capacity(k);
    for _ in 0..64 {
        if sel.len() == k {
            break;
        }
        let j = rng.gen_zipf(e, skew);
        if !sel.contains(&j) {
            sel.push(j);
        }
    }
    let mut fill = 0;
    while sel.len() < k {
        if !sel.contains(&fill) {
            sel.push(fill);
        }
        fill += 1;
    }
    sel
}

/// Run `spec` under `policy` on the virtual cluster.  Deterministic: the
/// same `(cfg, spec, policy)` always yields an identical [`LoadOutcome`].
pub fn run_virtual(cfg: &VirtualConfig, spec: &WorkloadSpec,
                   policy: AdmissionPolicy) -> LoadOutcome {
    run_virtual_requests(cfg, spec, &spec.materialize(), policy)
}

/// [`run_virtual`] with lifecycle/cycle events recorded into `sink`,
/// timestamped on the virtual event clock — so a trace dump is
/// byte-identical across reruns at the same seed.  Recording never
/// touches the clock, the routing streams, or the planner, so the
/// returned [`LoadOutcome`] is identical to the untraced run's.
pub fn run_virtual_traced(cfg: &VirtualConfig, spec: &WorkloadSpec,
                          policy: AdmissionPolicy, sink: &mut TraceSink)
    -> LoadOutcome {
    run_virtual_requests_traced(cfg, spec, &spec.materialize(), policy, sink)
}

/// Run an explicit request list under `policy` on the virtual cluster.
///
/// This is [`run_virtual`] with the materialization step factored out: the
/// sharded fan-out driver ([`crate::workload::shard`]) materializes a spec
/// once, partitions the requests across shards, and hands each shard its
/// subset — so a one-shard split runs *exactly* the same event sequence as
/// [`run_virtual`] on the whole spec.  `spec` still supplies the seed (per
/// request prompt/routing streams key off `spec.seed ^ id`, not off queue
/// position) and the arrival discipline; arrival *times* come from the
/// `reqs` themselves.
pub fn run_virtual_requests(cfg: &VirtualConfig, spec: &WorkloadSpec,
                            reqs: &[RequestSpec], policy: AdmissionPolicy)
    -> LoadOutcome {
    run_virtual_requests_traced(cfg, spec, reqs, policy,
                                &mut TraceSink::off())
}

/// [`run_virtual_requests`] with events recorded into `sink` (see
/// [`run_virtual_traced`] — same guarantees: the outcome is unaffected by
/// tracing, and a traced run is deterministic per seed).
pub fn run_virtual_requests_traced(cfg: &VirtualConfig, spec: &WorkloadSpec,
                                   reqs: &[RequestSpec],
                                   policy: AdmissionPolicy,
                                   sink: &mut TraceSink)
    -> LoadOutcome {
    let slots = cfg.slots.max(1);
    let n_layers = cfg.n_layers.max(1);
    let (closed, think_ns) = match spec.arrival {
        ArrivalProcess::Closed { users, think_ms } => {
            (users.max(1), (think_ms.max(0.0) * 1e6) as u64)
        }
        _ => (0, 0),
    };

    // Open loops precompute the full arrival timeline; closed loops start
    // one request per user and chain the rest off completions.
    let mut upcoming: VecDeque<(u64, usize)> = if closed > 0 {
        (0..reqs.len().min(closed)).map(|i| (0u64, i)).collect()
    } else {
        reqs.iter().enumerate().map(|(i, r)| (r.arrival_ns, i)).collect()
    };
    let mut next_issue =
        if closed > 0 { reqs.len().min(closed) } else { reqs.len() };

    let chunk = cfg.prefill_chunk;
    let qos = cfg.qos;
    let mix = spec.interactive_mix;
    let mut planner =
        BatchPlanner::new(cfg.n_experts.max(1), cfg.group_size.max(1),
                          cfg.schedule);
    let mut waiting: VecDeque<VQueued> = VecDeque::new();
    let mut live: Vec<Option<VLive>> = (0..slots).map(|_| None).collect();
    let mut filling: Vec<Option<VFill>> = (0..slots).map(|_| None).collect();
    let mut samples: Vec<Sample> = Vec::with_capacity(reqs.len());
    let mut now: u64 = 0;
    let mut admit_seq: u64 = 0;
    let mut peak_waiting = 0usize;
    let mut batch_dispatches = 0u64;
    let mut batched_tokens = 0u64;
    let mut single_dispatches = 0u64;
    let mut prefill_chunks = 0u64;
    let mut cycle_idx = 0u64;
    let mut preemptions = 0u64;
    let mut restores = 0u64;
    let mut preempted_wait_us = 0u64;
    let mut peak_checkpoints = 0usize;

    loop {
        // ---- 1. ingest arrivals due by now --------------------------------
        while let Some(&(t, idx)) = upcoming.front() {
            if t > now {
                break;
            }
            upcoming.pop_front();
            let r = &reqs[idx];
            sink.record(t, EventKind::Queued { id: r.id });
            if r.gen_len == 0 {
                // zero-length request: immediate terminal reply, no slot
                // (mirrors the server's submit-path short-circuit)
                sink.record(
                    t,
                    EventKind::Terminal { id: r.id, outcome: SpanOutcome::Ok },
                );
                samples.push(Sample {
                    id: r.id,
                    submit_seq: idx as u64,
                    ok: true,
                    queue_us: None,
                    ttft_us: None,
                    e2e_us: 0.0,
                    tokens: 0,
                    admit_seq: None,
                    shard: None,
                });
                if closed > 0 {
                    issue_next(&mut upcoming, &mut next_issue, reqs.len(),
                               now + think_ns);
                }
                continue;
            }
            waiting.push_back(VQueued {
                idx,
                arrived_ns: t,
                passed_over: 0,
                resume: None,
            });
            peak_waiting = peak_waiting.max(waiting.len());
        }

        // ---- 2a. QoS preemption pass --------------------------------------
        v_preempt_pass(cfg, reqs, mix, &mut now, &mut waiting, &mut live,
                       &mut filling, &mut preemptions, &mut peak_waiting,
                       &mut peak_checkpoints, sink);

        // ---- 2b. policy-driven slot admission (QoS: interactive first) ----
        while !waiting.is_empty() {
            let Some(slot) = (0..slots)
                .find(|&s| live[s].is_none() && filling[s].is_none())
            else {
                break;
            };
            let pick = v_pick(&policy, &waiting, reqs, mix, now, qos);
            let w = waiting.remove(pick).expect("selected index in range");
            // mirror of the server rule: only entries the pick jumped
            // over (indices < pick) count as passed over
            for o in waiting.iter_mut().take(pick) {
                o.passed_over += 1;
            }
            if let Some(res) = w.resume {
                // resuming a preempted slot: restoring the checkpointed
                // banks is priced like the checkpoint was, and the
                // stashed session continues exactly where it left off —
                // admission timings, banked tokens and the router stream
                // all survive the round trip
                let start = now;
                now += cfg.checkpoint_cycles * cfg.cycle_ns;
                restores += 1;
                preempted_wait_us += (start - res.preempted_ns) / 1000;
                sink.record_span(
                    start,
                    now - start,
                    EventKind::Restore { id: reqs[res.live.idx].id, slot },
                );
                live[slot] = Some(res.live);
                continue;
            }
            let r = &reqs[w.idx];
            if r.prompt_len == 0 || r.prompt_len >= cfg.max_seq {
                // admission failure: terminal error reply, never admitted
                sink.record(
                    now,
                    EventKind::Terminal {
                        id: r.id,
                        outcome: SpanOutcome::Error,
                    },
                );
                samples.push(Sample {
                    id: r.id,
                    submit_seq: w.idx as u64,
                    ok: false,
                    queue_us: None,
                    ttft_us: None,
                    e2e_us: ns_to_us(now - w.arrived_ns),
                    tokens: 0,
                    admit_seq: None,
                    shard: None,
                });
                if closed > 0 {
                    issue_next(&mut upcoming, &mut next_issue, reqs.len(),
                               now + think_ns);
                }
                continue;
            }
            if chunk == 0 {
                // monolithic: the slot is granted now (queue_us ends), the
                // prefill charge serialises on the engine, and the first
                // token is banked once the charge lands (ttft_us ends)
                let admitted_ns = now;
                sink.record(
                    admitted_ns,
                    EventKind::SlotGrant { id: r.id, slot },
                );
                now += r.prompt_len as u64 * cfg.prefill_ns_per_token;
                sink.record(now, EventKind::FirstToken { id: r.id });
                let l = VLive {
                    idx: w.idx,
                    arrived_ns: w.arrived_ns,
                    admitted_ns,
                    first_token_ns: now,
                    admit_seq,
                    tokens: 1,
                    rng: route_rng(spec.seed, r.id),
                };
                admit_seq += 1;
                if l.tokens >= r.gen_len as u64
                    || r.prompt_len + 1 >= cfg.max_seq
                {
                    // the prefill-sampled token already completed the
                    // request
                    sink.record(
                        now,
                        EventKind::Terminal {
                            id: r.id,
                            outcome: SpanOutcome::Ok,
                        },
                    );
                    samples.push(finish_sample(reqs, &l, now));
                    if closed > 0 {
                        issue_next(&mut upcoming, &mut next_issue,
                                   reqs.len(), now + think_ns);
                    }
                } else {
                    live[slot] = Some(l);
                }
            } else {
                // chunked: claim the slot without charging the clock; the
                // prefill advances chunk-by-chunk in the cycle loop below,
                // interleaved with decode (the head-of-line blocking fix)
                sink.record(now, EventKind::SlotGrant { id: r.id, slot });
                filling[slot] = Some(VFill {
                    idx: w.idx,
                    arrived_ns: w.arrived_ns,
                    admitted_ns: now,
                    admit_seq,
                    remaining: r.prompt_len,
                    rng: prefill_rng(spec.seed, r.id),
                });
                admit_seq += 1;
            }
        }

        // ---- 3. idle fast-forward / termination ---------------------------
        if live.iter().all(Option::is_none)
            && filling.iter().all(Option::is_none)
        {
            match upcoming.front() {
                Some(&(t, _)) => {
                    now = now.max(t);
                    continue;
                }
                None => break,
            }
        }

        // ---- 4. one router cycle ------------------------------------------
        // 4a. chunked prefill advances (serialise on the engine ahead of
        //     the decode dispatch, like the real router's step 3b): each
        //     filling slot is charged up to `chunk` tokens of prefill and
        //     contributes one row per layer to this cycle's planned step;
        //     a slot whose prompt completes banks its first token here and
        //     joins this very cycle's decode, exactly like a freshly
        //     admitted monolithic request.
        let cycle_start = now;
        let mut prefill_sets: Vec<Vec<Vec<usize>>> =
            vec![Vec::new(); n_layers];
        for s in 0..slots {
            let Some(f) = filling[s].as_mut() else { continue };
            let advanced = f.remaining.min(chunk);
            now += advanced as u64 * cfg.prefill_ns_per_token;
            f.remaining -= advanced;
            prefill_chunks += 1;
            sink.record(
                now,
                EventKind::PrefillChunk {
                    id: reqs[f.idx].id,
                    slot: s,
                    advanced,
                    remaining: f.remaining,
                },
            );
            for layer_rows in prefill_sets.iter_mut() {
                layer_rows.push(sample_experts(
                    &mut f.rng,
                    cfg.n_experts.max(1),
                    cfg.experts_per_token.max(1),
                    cfg.route_skew,
                ));
            }
            if f.remaining == 0 {
                let f = filling[s].take().unwrap();
                let r = &reqs[f.idx];
                sink.record(now, EventKind::FirstToken { id: r.id });
                let l = VLive {
                    idx: f.idx,
                    arrived_ns: f.arrived_ns,
                    admitted_ns: f.admitted_ns,
                    first_token_ns: now,
                    admit_seq: f.admit_seq,
                    tokens: 1,
                    rng: route_rng(spec.seed, r.id),
                };
                if l.tokens >= r.gen_len as u64
                    || r.prompt_len + 1 >= cfg.max_seq
                {
                    sink.record(
                        now,
                        EventKind::Terminal {
                            id: r.id,
                            outcome: SpanOutcome::Ok,
                        },
                    );
                    samples.push(finish_sample(reqs, &l, now));
                    if closed > 0 {
                        issue_next(&mut upcoming, &mut next_issue,
                                   reqs.len(), now + think_ns);
                    }
                } else {
                    live[s] = Some(l);
                }
            }
        }

        // 4b. the mixed step, priced as L planned layer-steps: decode rows
        //     first (slot order), then this cycle's prefill-chunk rows —
        //     both share the grouped peripherals, so the makespan (and the
        //     contention telemetry) reflects prefill/decode interference.
        let active: Vec<usize> =
            (0..slots).filter(|&s| live[s].is_some()).collect();
        let mut layer_sets: Vec<Vec<Vec<usize>>> =
            Vec::with_capacity(n_layers);
        for prefill_rows in prefill_sets.iter_mut() {
            let mut sets: Vec<Vec<usize>> = active
                .iter()
                .map(|&s| {
                    let l = live[s].as_mut().unwrap();
                    sample_experts(
                        &mut l.rng,
                        cfg.n_experts.max(1),
                        cfg.experts_per_token.max(1),
                        cfg.route_skew,
                    )
                })
                .collect();
            sets.append(prefill_rows);
            layer_sets.push(sets);
        }
        if layer_sets[0].is_empty() {
            // nothing to dispatch this cycle (every slot is still
            // mid-prefill and no chunk advanced — unreachable, but cheap
            // to guard); re-enter the loop rather than price an empty step
            continue;
        }
        let plans = planner.plan_layers(&layer_sets);
        let cycles: u64 = plans.iter().map(|p| p.cycles as u64).sum();
        now += cfg.dispatch_overhead_ns + cycles * cfg.cycle_ns;
        if sink.enabled() {
            let contention: u64 =
                plans.iter().map(|p| p.contention_cycles as u64).sum();
            sink.record_span(
                cycle_start,
                now - cycle_start,
                EventKind::Cycle {
                    index: cycle_idx,
                    live: active.len(),
                    filling: filling.iter().flatten().count(),
                    waiting: waiting.len(),
                    layer_steps: plans.len(),
                    plan_cycles: cycles,
                    contention,
                },
            );
        }
        cycle_idx += 1;
        match active.len() {
            0 => {}
            1 => single_dispatches += 1,
            _ => {
                batch_dispatches += 1;
                batched_tokens += active.len() as u64;
            }
        }

        // ---- 5. bank tokens, retire finished slots ------------------------
        for &s in &active {
            let done = {
                let l = live[s].as_mut().unwrap();
                l.tokens += 1;
                let r = &reqs[l.idx];
                l.tokens >= r.gen_len as u64
                    || r.prompt_len as u64 + l.tokens >= cfg.max_seq as u64
            };
            if done {
                let l = live[s].take().unwrap();
                sink.record(
                    now,
                    EventKind::Terminal {
                        id: reqs[l.idx].id,
                        outcome: SpanOutcome::Ok,
                    },
                );
                samples.push(finish_sample(reqs, &l, now));
                if closed > 0 {
                    issue_next(&mut upcoming, &mut next_issue, reqs.len(),
                               now + think_ns);
                }
            }
        }
        if sink.enabled() {
            sink.record(
                now,
                EventKind::Depth {
                    waiting: waiting.len(),
                    live: live.iter().flatten().count(),
                    filling: filling.iter().flatten().count(),
                    intake: 0,
                },
            );
        }
    }

    LoadOutcome {
        samples,
        planner: planner.stats(),
        slots,
        peak_waiting,
        batch_dispatches,
        batched_tokens,
        single_dispatches,
        prefill_chunks,
        shed_requests: 0,
        peak_intake_depth: 0,
        preemptions,
        restores,
        preempted_wait_us,
        peak_checkpoints,
        first_dispatch_unix_us: None,
        last_dispatch_unix_us: None,
        duration_s: now as f64 / 1e9,
        clock: "virtual",
        shard: None,
    }
}

fn finish_sample(reqs: &[RequestSpec], l: &VLive, now: u64) -> Sample {
    let r = &reqs[l.idx];
    Sample {
        id: r.id,
        submit_seq: l.idx as u64,
        ok: true,
        // queue ends at slot grant; TTFT ends at prefill completion (the
        // dispatch that samples the first token), so the prefill cost the
        // clock was charged shows up in TTFT — not silently dropped
        queue_us: Some(ns_to_us(l.admitted_ns - l.arrived_ns)),
        ttft_us: Some(ns_to_us(l.first_token_ns - l.arrived_ns)),
        e2e_us: ns_to_us(now - l.arrived_ns),
        tokens: l.tokens,
        admit_seq: Some(l.admit_seq),
        shard: None,
    }
}

/// One incrementally-advanced virtual backend, for live-signal placement
/// ([`run_virtual_live`]): the open-loop event loop of
/// [`run_virtual_requests`] restructured so a placement loop can park the
/// clock at each global arrival, read the backend's simulated load, and
/// inject the next request — the virtual mirror of the real cluster's
/// placement thread reading [`crate::coordinator::LoadSignal`].
///
/// The pump is an exact mirror of the single-run loop (ingest → admit →
/// idle fast-forward → cycle), with two differences only: arrivals come
/// from an inbox filled by [`VBackend::arrive`] instead of a precomputed
/// timeline, and [`VBackend::advance_to`] parks at the loop *top* once
/// `now` reaches the horizon — before ingesting — so requests assigned at
/// the same instant still batch through one admission pass exactly as
/// they would mid-timeline in the single run.  A 1-shard
/// [`run_virtual_live`] therefore replays [`run_virtual_requests`]'s
/// event sequence exactly (pinned in `rust/tests/shard_virtual.rs`),
/// which guards the two loops against drifting apart.
struct VBackend {
    cfg: VirtualConfig,
    seed: u64,
    /// interactive-tier fraction (see [`Priority::assign`]) — carried
    /// from the spec so the pump can recompute any request's tier
    mix: f64,
    policy: AdmissionPolicy,
    /// requests assigned to this backend, arrival order; local index is
    /// the sample's `submit_seq`, matching a static shard's subset run
    reqs: Vec<RequestSpec>,
    /// assigned but not yet ingested: (arrival_ns, local idx)
    inbox: VecDeque<(u64, usize)>,
    waiting: VecDeque<VQueued>,
    live: Vec<Option<VLive>>,
    filling: Vec<Option<VFill>>,
    planner: BatchPlanner,
    samples: Vec<Sample>,
    now: u64,
    admit_seq: u64,
    peak_waiting: usize,
    batch_dispatches: u64,
    batched_tokens: u64,
    single_dispatches: u64,
    prefill_chunks: u64,
    cycle_idx: u64,
    preemptions: u64,
    restores: u64,
    preempted_wait_us: u64,
    peak_checkpoints: usize,
    /// per-backend trace sink (off unless the caller enables tracing);
    /// stamped on this backend's own virtual clock
    sink: TraceSink,
}

impl VBackend {
    fn new(cfg: &VirtualConfig, seed: u64, mix: f64,
           policy: AdmissionPolicy) -> VBackend {
        let slots = cfg.slots.max(1);
        VBackend {
            cfg: cfg.clone(),
            seed,
            mix,
            policy,
            reqs: Vec::new(),
            inbox: VecDeque::new(),
            waiting: VecDeque::new(),
            live: (0..slots).map(|_| None).collect(),
            filling: (0..slots).map(|_| None).collect(),
            planner: BatchPlanner::new(cfg.n_experts.max(1),
                                       cfg.group_size.max(1), cfg.schedule),
            samples: Vec::new(),
            now: 0,
            admit_seq: 0,
            peak_waiting: 0,
            batch_dispatches: 0,
            batched_tokens: 0,
            single_dispatches: 0,
            prefill_chunks: 0,
            cycle_idx: 0,
            preemptions: 0,
            restores: 0,
            preempted_wait_us: 0,
            peak_checkpoints: 0,
            sink: TraceSink::off(),
        }
    }

    /// The live load signal: requests assigned but not yet terminally
    /// sampled — inbox (assigned, not ingested) + waiting queue +
    /// occupied slots.  The virtual analogue of
    /// [`crate::coordinator::LoadSignal::inflight`].
    fn load(&self) -> usize {
        self.inbox.len()
            + self.waiting.len()
            + self.live.iter().flatten().count()
            + self.filling.iter().flatten().count()
    }

    /// Assign a request to this backend (ingested once the clock reaches
    /// its arrival; callers feed arrivals in global arrival order).
    fn arrive(&mut self, r: RequestSpec) {
        let idx = self.reqs.len();
        self.inbox.push_back((r.arrival_ns, idx));
        self.reqs.push(r);
    }

    /// Queued entries a rebalance pass may steal: waiting, not yet
    /// admitted, and not holding a checkpoint (a preempted request's
    /// decode state lives in this backend's banks — migrating it would
    /// mean moving silicon state, which the real cluster can't do
    /// either).  Inbox entries aren't stealable: the placement loop only
    /// rebalances at arrival instants, when every due arrival has been
    /// ingested.
    fn queued_stealable(&self) -> usize {
        self.waiting.iter().filter(|w| w.resume.is_none()).count()
    }

    /// Remove the *youngest* stealable queued entry (search from the
    /// queue's back — the entry that waited least loses least by
    /// restarting its queue time elsewhere) and hand back its spec plus
    /// original arrival instant.  `None` when nothing is stealable.
    fn steal_queued(&mut self) -> Option<(RequestSpec, u64)> {
        let pos = self
            .waiting
            .iter()
            .rposition(|w| w.resume.is_none())?;
        let w = self.waiting.remove(pos).expect("rposition in range");
        Some((self.reqs[w.idx].clone(), w.arrived_ns))
    }

    /// Accept a request migrated from another backend, preserving its
    /// original arrival instant: it joins the waiting queue in arrival
    /// order (the invariant every admission policy assumes), exactly as
    /// if it had arrived here — same id-keyed routing/prompt streams, so
    /// migration changes *where* it queues, never *what* it computes.
    fn accept_migrated(&mut self, r: RequestSpec, arrived_ns: u64) {
        let idx = self.reqs.len();
        self.reqs.push(r);
        v_requeue(&mut self.waiting, VQueued {
            idx,
            arrived_ns,
            passed_over: 0,
            resume: None,
        });
        self.peak_waiting = self.peak_waiting.max(self.waiting.len());
    }

    /// Advance the event clock to `horizon` (parking there even when
    /// idle, so the next `load()` read is a same-instant snapshot).
    fn advance_to(&mut self, horizon: u64) {
        self.pump(Some(horizon));
    }

    /// Run to completion: every assigned request terminates.
    fn drain(&mut self) {
        self.pump(None);
    }

    /// The event loop — phases 1–4/5 of [`run_virtual_requests`] (open
    /// loop only; no closed-loop chaining), plus horizon parking at the
    /// loop top.
    fn pump(&mut self, horizon: Option<u64>) {
        let cfg = self.cfg.clone();
        let slots = cfg.slots.max(1);
        let n_layers = cfg.n_layers.max(1);
        let chunk = cfg.prefill_chunk;
        loop {
            if let Some(h) = horizon {
                if self.now >= h {
                    return;
                }
            }

            // ---- 1. ingest arrivals due by now ----------------------
            while let Some(&(t, idx)) = self.inbox.front() {
                if t > self.now {
                    break;
                }
                self.inbox.pop_front();
                let r = &self.reqs[idx];
                self.sink.record(t, EventKind::Queued { id: r.id });
                if r.gen_len == 0 {
                    self.sink.record(
                        t,
                        EventKind::Terminal {
                            id: r.id,
                            outcome: SpanOutcome::Ok,
                        },
                    );
                    self.samples.push(Sample {
                        id: r.id,
                        submit_seq: idx as u64,
                        ok: true,
                        queue_us: None,
                        ttft_us: None,
                        e2e_us: 0.0,
                        tokens: 0,
                        admit_seq: None,
                        shard: None,
                    });
                    continue;
                }
                self.waiting.push_back(VQueued {
                    idx,
                    arrived_ns: t,
                    passed_over: 0,
                    resume: None,
                });
                self.peak_waiting =
                    self.peak_waiting.max(self.waiting.len());
            }

            // ---- 2a. QoS preemption pass ----------------------------
            v_preempt_pass(&cfg, &self.reqs, self.mix, &mut self.now,
                           &mut self.waiting, &mut self.live,
                           &mut self.filling, &mut self.preemptions,
                           &mut self.peak_waiting,
                           &mut self.peak_checkpoints, &mut self.sink);

            // ---- 2b. policy-driven slot admission -------------------
            while !self.waiting.is_empty() {
                let Some(slot) = (0..slots).find(|&s| {
                    self.live[s].is_none() && self.filling[s].is_none()
                }) else {
                    break;
                };
                let pick = v_pick(&self.policy, &self.waiting, &self.reqs,
                                  self.mix, self.now, cfg.qos);
                let w = self
                    .waiting
                    .remove(pick)
                    .expect("selected index in range");
                for o in self.waiting.iter_mut().take(pick) {
                    o.passed_over += 1;
                }
                if let Some(res) = w.resume {
                    let start = self.now;
                    self.now += cfg.checkpoint_cycles * cfg.cycle_ns;
                    self.restores += 1;
                    self.preempted_wait_us +=
                        (start - res.preempted_ns) / 1000;
                    self.sink.record_span(
                        start,
                        self.now - start,
                        EventKind::Restore {
                            id: self.reqs[res.live.idx].id,
                            slot,
                        },
                    );
                    self.live[slot] = Some(res.live);
                    continue;
                }
                let r = &self.reqs[w.idx];
                if r.prompt_len == 0 || r.prompt_len >= cfg.max_seq {
                    self.sink.record(
                        self.now,
                        EventKind::Terminal {
                            id: r.id,
                            outcome: SpanOutcome::Error,
                        },
                    );
                    self.samples.push(Sample {
                        id: r.id,
                        submit_seq: w.idx as u64,
                        ok: false,
                        queue_us: None,
                        ttft_us: None,
                        e2e_us: ns_to_us(self.now - w.arrived_ns),
                        tokens: 0,
                        admit_seq: None,
                        shard: None,
                    });
                    continue;
                }
                if chunk == 0 {
                    let admitted_ns = self.now;
                    self.sink.record(
                        admitted_ns,
                        EventKind::SlotGrant { id: r.id, slot },
                    );
                    self.now +=
                        r.prompt_len as u64 * cfg.prefill_ns_per_token;
                    self.sink
                        .record(self.now, EventKind::FirstToken { id: r.id });
                    let l = VLive {
                        idx: w.idx,
                        arrived_ns: w.arrived_ns,
                        admitted_ns,
                        first_token_ns: self.now,
                        admit_seq: self.admit_seq,
                        tokens: 1,
                        rng: route_rng(self.seed, r.id),
                    };
                    self.admit_seq += 1;
                    if l.tokens >= r.gen_len as u64
                        || r.prompt_len + 1 >= cfg.max_seq
                    {
                        self.sink.record(
                            self.now,
                            EventKind::Terminal {
                                id: r.id,
                                outcome: SpanOutcome::Ok,
                            },
                        );
                        self.samples
                            .push(finish_sample(&self.reqs, &l, self.now));
                    } else {
                        self.live[slot] = Some(l);
                    }
                } else {
                    self.sink.record(
                        self.now,
                        EventKind::SlotGrant { id: r.id, slot },
                    );
                    self.filling[slot] = Some(VFill {
                        idx: w.idx,
                        arrived_ns: w.arrived_ns,
                        admitted_ns: self.now,
                        admit_seq: self.admit_seq,
                        remaining: r.prompt_len,
                        rng: prefill_rng(self.seed, r.id),
                    });
                    self.admit_seq += 1;
                }
            }

            // ---- 3. idle fast-forward / park / terminate ------------
            if self.live.iter().all(Option::is_none)
                && self.filling.iter().all(Option::is_none)
            {
                match self.inbox.front() {
                    Some(&(t, _)) => {
                        self.now = self.now.max(t);
                        continue;
                    }
                    None => {
                        // idle with nothing assigned: park at the
                        // horizon so the caller's next load() read is a
                        // same-instant snapshot, or finish the drain
                        if let Some(h) = horizon {
                            self.now = self.now.max(h);
                        }
                        return;
                    }
                }
            }

            // ---- 4a. chunked prefill advances -----------------------
            let cycle_start = self.now;
            let mut prefill_sets: Vec<Vec<Vec<usize>>> =
                vec![Vec::new(); n_layers];
            for s in 0..slots {
                let Some(f) = self.filling[s].as_mut() else { continue };
                let advanced = f.remaining.min(chunk);
                self.now += advanced as u64 * cfg.prefill_ns_per_token;
                f.remaining -= advanced;
                self.prefill_chunks += 1;
                self.sink.record(
                    self.now,
                    EventKind::PrefillChunk {
                        id: self.reqs[f.idx].id,
                        slot: s,
                        advanced,
                        remaining: f.remaining,
                    },
                );
                for layer_rows in prefill_sets.iter_mut() {
                    layer_rows.push(sample_experts(
                        &mut f.rng,
                        cfg.n_experts.max(1),
                        cfg.experts_per_token.max(1),
                        cfg.route_skew,
                    ));
                }
                if f.remaining == 0 {
                    let f = self.filling[s].take().unwrap();
                    let r = &self.reqs[f.idx];
                    self.sink
                        .record(self.now, EventKind::FirstToken { id: r.id });
                    let l = VLive {
                        idx: f.idx,
                        arrived_ns: f.arrived_ns,
                        admitted_ns: f.admitted_ns,
                        first_token_ns: self.now,
                        admit_seq: f.admit_seq,
                        tokens: 1,
                        rng: route_rng(self.seed, r.id),
                    };
                    if l.tokens >= r.gen_len as u64
                        || r.prompt_len + 1 >= cfg.max_seq
                    {
                        self.sink.record(
                            self.now,
                            EventKind::Terminal {
                                id: r.id,
                                outcome: SpanOutcome::Ok,
                            },
                        );
                        self.samples
                            .push(finish_sample(&self.reqs, &l, self.now));
                    } else {
                        self.live[s] = Some(l);
                    }
                }
            }

            // ---- 4b. the mixed step, planner-priced -----------------
            let active: Vec<usize> =
                (0..slots).filter(|&s| self.live[s].is_some()).collect();
            let mut layer_sets: Vec<Vec<Vec<usize>>> =
                Vec::with_capacity(n_layers);
            for prefill_rows in prefill_sets.iter_mut() {
                let mut sets: Vec<Vec<usize>> = active
                    .iter()
                    .map(|&s| {
                        let l = self.live[s].as_mut().unwrap();
                        sample_experts(
                            &mut l.rng,
                            cfg.n_experts.max(1),
                            cfg.experts_per_token.max(1),
                            cfg.route_skew,
                        )
                    })
                    .collect();
                sets.append(prefill_rows);
                layer_sets.push(sets);
            }
            if layer_sets[0].is_empty() {
                continue;
            }
            let plans = self.planner.plan_layers(&layer_sets);
            let cycles: u64 = plans.iter().map(|p| p.cycles as u64).sum();
            self.now += cfg.dispatch_overhead_ns + cycles * cfg.cycle_ns;
            if self.sink.enabled() {
                let contention: u64 =
                    plans.iter().map(|p| p.contention_cycles as u64).sum();
                self.sink.record_span(
                    cycle_start,
                    self.now - cycle_start,
                    EventKind::Cycle {
                        index: self.cycle_idx,
                        live: active.len(),
                        filling: self.filling.iter().flatten().count(),
                        waiting: self.waiting.len(),
                        layer_steps: plans.len(),
                        plan_cycles: cycles,
                        contention,
                    },
                );
            }
            self.cycle_idx += 1;
            match active.len() {
                0 => {}
                1 => self.single_dispatches += 1,
                _ => {
                    self.batch_dispatches += 1;
                    self.batched_tokens += active.len() as u64;
                }
            }

            // ---- 5. bank tokens, retire finished slots --------------
            for &s in &active {
                let done = {
                    let l = self.live[s].as_mut().unwrap();
                    l.tokens += 1;
                    let r = &self.reqs[l.idx];
                    l.tokens >= r.gen_len as u64
                        || r.prompt_len as u64 + l.tokens
                            >= cfg.max_seq as u64
                };
                if done {
                    let l = self.live[s].take().unwrap();
                    self.sink.record(
                        self.now,
                        EventKind::Terminal {
                            id: self.reqs[l.idx].id,
                            outcome: SpanOutcome::Ok,
                        },
                    );
                    self.samples
                        .push(finish_sample(&self.reqs, &l, self.now));
                }
            }
            if self.sink.enabled() {
                self.sink.record(
                    self.now,
                    EventKind::Depth {
                        waiting: self.waiting.len(),
                        live: self.live.iter().flatten().count(),
                        filling: self.filling.iter().flatten().count(),
                        intake: self.inbox.len(),
                    },
                );
            }
        }
    }

    /// Close out the backend into a [`LoadOutcome`] (the caller tags the
    /// shard id).  `duration_s` is this backend's own event-clock end.
    fn into_outcome(self) -> LoadOutcome {
        let slots = self.cfg.slots.max(1);
        LoadOutcome {
            samples: self.samples,
            planner: self.planner.stats(),
            slots,
            peak_waiting: self.peak_waiting,
            batch_dispatches: self.batch_dispatches,
            batched_tokens: self.batched_tokens,
            single_dispatches: self.single_dispatches,
            prefill_chunks: self.prefill_chunks,
            shed_requests: 0,
            peak_intake_depth: 0,
            preemptions: self.preemptions,
            restores: self.restores,
            preempted_wait_us: self.preempted_wait_us,
            peak_checkpoints: self.peak_checkpoints,
            first_dispatch_unix_us: None,
            last_dispatch_unix_us: None,
            duration_s: self.now as f64 / 1e9,
            clock: "virtual",
            shard: None,
        }
    }
}

/// Live-signal least-outstanding placement on the virtual clock: N
/// incremental [`VBackend`]s, one placement loop walking the global
/// arrival timeline — each arrival advances every backend's clock to its
/// arrival instant, reads the backends' *simulated* loads (inbox + queue
/// + occupied slots), and assigns the request to the least-loaded backend
/// (ties to the lowest shard id).  This is the virtual mirror of the real
/// [`crate::coordinator::Cluster`]'s control loop, and the live
/// counterpart of the estimate-based
/// [`crate::workload::PlacementPolicy::LeastOutstanding`] split: the
/// estimate assumes service starts at arrival and never sees queueing;
/// the live signal *is* the queueing, so the two diverge under skewed
/// bursts (pinned in `rust/tests/shard_virtual.rs`).
///
/// Deterministic: same `(cfg, spec, policy, shards)` → identical
/// [`crate::workload::ShardedRun`].  With `shards == 1` it replays
/// [`run_virtual_requests`] exactly.
///
/// Open-loop arrival processes only — a closed loop has no global arrival
/// timeline to place from (arrivals chain off per-backend completions),
/// so this panics on [`ArrivalProcess::Closed`]; the CLI rejects the
/// combination before calling.
pub fn run_virtual_live(cfg: &VirtualConfig, spec: &WorkloadSpec,
                        policy: AdmissionPolicy, shards: usize)
    -> crate::workload::shard::ShardedRun {
    run_virtual_live_traced(cfg, spec, policy, shards, false).0
}

/// [`run_virtual_live`] with tracing: when `trace` is on, every backend
/// records its own lifecycle/cycle events (pid = shard in the export) and
/// the placement loop records `intake` / `placed` events on a front-door
/// sink (shard `None`) — all on the shared virtual arrival clock, so the
/// merged dump is byte-identical per seed.  With `trace` off this is
/// exactly [`run_virtual_live`] (the returned shard list is empty).
pub fn run_virtual_live_traced(cfg: &VirtualConfig, spec: &WorkloadSpec,
                               policy: AdmissionPolicy, shards: usize,
                               trace: bool)
    -> (crate::workload::shard::ShardedRun, Vec<TraceShard>) {
    assert!(
        !matches!(spec.arrival, ArrivalProcess::Closed { .. }),
        "live placement requires an open-loop arrival process"
    );
    let n = shards.max(1);
    let mut front = TraceSink::on(trace);
    let mut backends: Vec<VBackend> = (0..n)
        .map(|_| {
            let mut b =
                VBackend::new(cfg, spec.seed, spec.interactive_mix, policy);
            b.sink = TraceSink::on(trace);
            b
        })
        .collect();
    for r in spec.materialize() {
        let t = r.arrival_ns;
        for b in backends.iter_mut() {
            b.advance_to(t);
        }
        let best = (0..n)
            .min_by_key(|&i| (backends[i].load(), i))
            .unwrap_or(0);
        front.record(t, EventKind::Intake { id: r.id });
        front.record(t, EventKind::Placed { id: r.id, shard: best });
        backends[best].arrive(r);
    }
    for b in backends.iter_mut() {
        b.drain();
    }
    let mut traces = Vec::new();
    if trace {
        traces.push(front.drain(None, "placement"));
    }
    let shards = backends
        .into_iter()
        .enumerate()
        .map(|(i, mut b)| {
            if trace {
                traces.push(b.sink.drain(Some(i), "vsim"));
            }
            let requests = b.reqs.len();
            let mut outcome = b.into_outcome();
            outcome.shard = Some(i);
            crate::workload::shard::ShardOutcome {
                shard: i,
                requests,
                outcome,
            }
        })
        .collect();
    (crate::workload::shard::ShardedRun { shards }, traces)
}

/// The dynamic-placement control loop on the virtual clock
/// (DESIGN.md §Placement): N incrementally-advanced [`VBackend`]s — one
/// per entry of `cfgs`, so *heterogeneous* fleets (mixed slot counts and
/// cost constants) are first-class — driven by a
/// [`crate::placement::DynamicPlacer`] over a live
/// [`crate::placement::RoutingFeedback`] view.
///
/// Each arrival: every backend's clock advances to the arrival instant,
/// the feedback view refreshes from the backends' simulated loads, and
/// the placer routes the request to the capacity-weighted least-loaded
/// host of its expert group (home + replicas; with no replicas this is
/// exactly the static route-aware mapping).  Every
/// [`crate::placement::DynamicConfig::rebalance_every`] arrivals the
/// control loop fires: queued (not yet admitted, non-resuming) requests
/// migrate off capacity-weighted hot shards onto cold ones
/// ([`VBackend::steal_queued`] → [`VBackend::accept_migrated`], original
/// arrival instants preserved), then hot expert groups replicate within
/// the `--replicate-budget-mm2` area ledger.  The returned
/// [`crate::placement::PlacementReport`] carries the run's control-loop
/// telemetry (migrations, replicas, mm² spent, the worst tick's
/// pre/post-migration imbalance pair) for the report's `placement` block.
///
/// Deterministic: same `(cfgs, spec, policy, dcfg)` → identical run and
/// report, so v2 reports stay byte-identical per seed.  Open-loop
/// arrival processes only (panics on [`ArrivalProcess::Closed`], like
/// [`run_virtual_live`]).
pub fn run_virtual_dynamic(cfgs: &[VirtualConfig], spec: &WorkloadSpec,
                           policy: AdmissionPolicy, dcfg: &DynamicConfig)
    -> (crate::workload::shard::ShardedRun, PlacementReport) {
    let (run, report, _) =
        run_virtual_dynamic_traced(cfgs, spec, policy, dcfg, false);
    (run, report)
}

/// [`run_virtual_dynamic`] with tracing: backends record their lifecycle
/// events per shard, and the front-door sink records `intake` / `placed`
/// plus the control loop's `migrate` / `replicate` events — all on the
/// shared virtual arrival clock (same guarantees as
/// [`run_virtual_live_traced`]: tracing never perturbs the outcome).
pub fn run_virtual_dynamic_traced(cfgs: &[VirtualConfig],
                                  spec: &WorkloadSpec,
                                  policy: AdmissionPolicy,
                                  dcfg: &DynamicConfig, trace: bool)
    -> (crate::workload::shard::ShardedRun, PlacementReport,
        Vec<TraceShard>) {
    assert!(
        !matches!(spec.arrival, ArrivalProcess::Closed { .. }),
        "dynamic placement requires an open-loop arrival process"
    );
    let default_cfg;
    let cfgs: &[VirtualConfig] = if cfgs.is_empty() {
        default_cfg = [VirtualConfig::default()];
        &default_cfg
    } else {
        cfgs
    };
    let n = cfgs.len();
    let mut front = TraceSink::on(trace);
    let mut backends: Vec<VBackend> = cfgs
        .iter()
        .map(|c| {
            let mut b =
                VBackend::new(c, spec.seed, spec.interactive_mix, policy);
            b.sink = TraceSink::on(trace);
            b
        })
        .collect();
    let specs: Vec<ShardSpec> =
        cfgs.iter().map(ShardSpec::from_virtual).collect();
    let mut fb = RoutingFeedback::new(specs, dcfg.n_groups());
    // Prime the routing histogram from a small moe::trace calibration
    // sample (the offline prediction §III-B grounds grouping in), so the
    // first replication decisions are informed before any arrival-driven
    // counts accumulate.  Seeded from the spec, so priming is
    // deterministic per seed.
    let mut cal = TraceGenerator::new(dcfg.n_experts.max(1), spec.seed);
    let expected = group_loads(
        &cal.calibration_loads(
            2,
            64,
            dcfg.experts_per_token.max(1),
            dcfg.skew,
        ),
        dcfg.group_size.max(1),
    );
    fb.prime(&expected);
    let mut placer = DynamicPlacer::new(*dcfg, spec.seed);
    for r in spec.materialize() {
        let t = r.arrival_ns;
        for b in backends.iter_mut() {
            b.advance_to(t);
        }
        for (i, b) in backends.iter().enumerate() {
            fb.set_load(i, b.load());
        }
        front.record(t, EventKind::Intake { id: r.id });
        let best = placer.place(&Arrival::of(&r), &mut fb).min(n - 1);
        front.record(t, EventKind::Placed { id: r.id, shard: best });
        backends[best].arrive(r);
        fb.set_load(best, backends[best].load());
        if placer.due() {
            // ---- rebalance tick: migrate queued work, then replicate.
            // Loads are already a same-instant snapshot of every backend
            // (refreshed above); the plan executes atomically at `t`
            // before any backend's clock moves again, so the post-move
            // spread can never exceed the pre-move spread.
            let before = fb.spread();
            let stealable: Vec<usize> =
                backends.iter().map(|b| b.queued_stealable()).collect();
            for (from, to) in placer.plan_migrations(&fb, &stealable) {
                let Some((req, arrived_ns)) = backends[from].steal_queued()
                else {
                    continue;
                };
                let id = req.id;
                backends[to].accept_migrated(req, arrived_ns);
                placer.report.migrations += 1;
                front.record(t, EventKind::Migrate { id, from, to });
                fb.set_load(from, backends[from].load());
                fb.set_load(to, backends[to].load());
            }
            placer.note_imbalance(before, fb.spread());
            for (group, shard) in placer.maybe_replicate(&mut fb) {
                front.record(t, EventKind::Replicate { group, shard });
            }
        }
    }
    for b in backends.iter_mut() {
        b.drain();
    }
    let mut traces = Vec::new();
    if trace {
        traces.push(front.drain(None, "placement"));
    }
    let shards = backends
        .into_iter()
        .enumerate()
        .map(|(i, mut b)| {
            if trace {
                traces.push(b.sink.drain(Some(i), "vsim"));
            }
            // served count, not assignment count: a migrated request's
            // terminal sample lands on the backend that served it
            let requests = b.samples.len();
            let mut outcome = b.into_outcome();
            outcome.shard = Some(i);
            crate::workload::shard::ShardOutcome {
                shard: i,
                requests,
                outcome,
            }
        })
        .collect();
    (
        crate::workload::shard::ShardedRun { shards },
        placer.report,
        traces,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrival::SizeModel;

    fn base_spec() -> WorkloadSpec {
        WorkloadSpec {
            seed: 77,
            requests: 24,
            arrival: ArrivalProcess::Poisson { rate_rps: 2_000.0 },
            sizes: SizeModel::Uniform { prompt: (4, 12), gen: (1, 8) },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 200,
            interactive_mix: 1.0,
        }
    }

    #[test]
    fn repeated_runs_are_identical() {
        let cfg = VirtualConfig::default();
        let a = run_virtual(&cfg, &base_spec(), AdmissionPolicy::sjf());
        let b = run_virtual(&cfg, &base_spec(), AdmissionPolicy::sjf());
        assert_eq!(a, b);
    }

    #[test]
    fn every_request_terminates_exactly_once() {
        let cfg = VirtualConfig::default();
        for policy in [
            AdmissionPolicy::fifo(),
            AdmissionPolicy::sjf(),
            AdmissionPolicy::deadline(),
        ] {
            let out = run_virtual(&cfg, &base_spec(), policy);
            assert_eq!(out.samples.len(), 24, "{}", policy.label());
            let mut ids: Vec<u64> =
                out.samples.iter().map(|s| s.id).collect();
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<u64>>());
            assert!(out.samples.iter().all(|s| s.ok));
            assert!(out.duration_s > 0.0);
        }
    }

    #[test]
    fn fifo_admits_in_submit_order() {
        let cfg = VirtualConfig::default();
        let out = run_virtual(&cfg, &base_spec(), AdmissionPolicy::fifo());
        let mut by_submit = out.samples.clone();
        by_submit.sort_by_key(|s| s.submit_seq);
        let seqs: Vec<u64> =
            by_submit.iter().filter_map(|s| s.admit_seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");
    }

    #[test]
    fn closed_loop_terminates_with_zero_think() {
        let cfg = VirtualConfig { slots: 2, ..VirtualConfig::default() };
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Closed { users: 5, think_ms: 0.0 },
            ..base_spec()
        };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::sjf());
        assert_eq!(out.samples.len(), 24);
        assert!(out.samples.iter().all(|s| s.ok));
    }

    #[test]
    fn zero_gen_requests_never_occupy_a_slot() {
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec {
            sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 0 },
            ..base_spec()
        };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        assert_eq!(out.samples.len(), 24);
        assert!(out.samples.iter().all(|s| {
            s.ok && s.tokens == 0 && s.admit_seq.is_none()
        }));
        assert_eq!(out.batch_dispatches + out.single_dispatches, 0);
        assert_eq!(out.planner.steps, 0);
    }

    /// Satellite regression for the TTFT bug: the virtual clock charges
    /// `prompt_len * prefill_ns_per_token` for prefill, so TTFT (submit →
    /// first generated token, which the prefill-completing dispatch
    /// samples) must carry that cost on top of the pure slot wait — it
    /// used to be reported equal to `queue_us`, silently dropping prefill
    /// from every virtual TTFT quantile.
    #[test]
    fn virtual_ttft_includes_prefill_time() {
        let prompt_len = 16usize;
        let spec = WorkloadSpec {
            sizes: SizeModel::Fixed { prompt_len, gen_len: 4 },
            ..base_spec()
        };
        for chunk in [0usize, 1, 5] {
            let cfg = VirtualConfig {
                prefill_chunk: chunk,
                ..VirtualConfig::default()
            };
            let prefill_us = prompt_len as f64
                * cfg.prefill_ns_per_token as f64
                / 1000.0;
            let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
            assert_eq!(out.samples.len(), 24);
            for s in &out.samples {
                let q = s.queue_us.expect("admitted");
                let t = s.ttft_us.expect("served");
                assert!(
                    t >= q + prefill_us - 1e-6,
                    "chunk {chunk}: ttft {t} < queue {q} + prefill \
                     {prefill_us}"
                );
                assert!(s.e2e_us >= t);
            }
        }
    }

    #[test]
    fn chunked_prefill_is_deterministic_and_conserves_requests() {
        let cfg = VirtualConfig {
            prefill_chunk: 4,
            ..VirtualConfig::default()
        };
        let a = run_virtual(&cfg, &base_spec(), AdmissionPolicy::sjf());
        let b = run_virtual(&cfg, &base_spec(), AdmissionPolicy::sjf());
        assert_eq!(a, b);
        assert_eq!(a.samples.len(), 24);
        assert!(a.samples.iter().all(|s| s.ok));
        assert!(a.prefill_chunks > 0, "chunked run never advanced a chunk");
    }

    /// Chunking reshapes *when* work happens, not *what* happens: every
    /// request still terminates exactly once with the same outcome and
    /// token count (its decode routing stream is salted separately from
    /// the prefill-chunk stream, so the expert trajectory is untouched).
    #[test]
    fn chunking_changes_latency_not_outcomes() {
        let spec = base_spec();
        let mono = run_virtual(
            &VirtualConfig::default(),
            &spec,
            AdmissionPolicy::fifo(),
        );
        let chunked = run_virtual(
            &VirtualConfig {
                prefill_chunk: 3,
                ..VirtualConfig::default()
            },
            &spec,
            AdmissionPolicy::fifo(),
        );
        let key = |o: &LoadOutcome| {
            let mut v: Vec<(u64, bool, u64)> = o
                .samples
                .iter()
                .map(|s| (s.id, s.ok, s.tokens))
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&mono), key(&chunked));
        assert_eq!(mono.prefill_chunks, 0);
        assert!(chunked.prefill_chunks > 0);
    }

    /// Satellite regression: a `gen_len == 0` request must short-circuit
    /// at submit even with chunking enabled — no slot, no chunk budget,
    /// no planner step.
    #[test]
    fn zero_gen_requests_consume_no_chunk_budget() {
        let cfg = VirtualConfig {
            prefill_chunk: 2,
            ..VirtualConfig::default()
        };
        let spec = WorkloadSpec {
            sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 0 },
            ..base_spec()
        };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        assert_eq!(out.samples.len(), 24);
        assert!(out.samples.iter().all(|s| {
            s.ok && s.tokens == 0 && s.admit_seq.is_none()
        }));
        assert_eq!(out.prefill_chunks, 0);
        assert_eq!(out.batch_dispatches + out.single_dispatches, 0);
        assert_eq!(out.planner.steps, 0);
    }

    #[test]
    fn oversized_prompts_error_terminally() {
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec {
            sizes: SizeModel::Fixed { prompt_len: 500, gen_len: 4 },
            ..base_spec()
        };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        assert_eq!(out.samples.len(), 24);
        assert!(out.samples.iter().all(|s| !s.ok && s.admit_seq.is_none()));
    }

    /// QoS with a single-tier workload (the default `interactive_mix` of
    /// 1.0 marks every request interactive) never finds a batch victim
    /// and never filters admission, so the event sequence — and the whole
    /// outcome — matches the seed loop bit for bit.
    #[test]
    fn qos_with_single_tier_matches_the_seed_loop() {
        let off = run_virtual(
            &VirtualConfig::default(),
            &base_spec(),
            AdmissionPolicy::deadline(),
        );
        let on = run_virtual(
            &VirtualConfig { qos: true, ..VirtualConfig::default() },
            &base_spec(),
            AdmissionPolicy::deadline(),
        );
        assert_eq!(off, on);
        assert_eq!(on.preemptions, 0);
        assert_eq!(on.restores, 0);
    }

    /// A tight interactive arrival behind a slot-saturating batch tier
    /// preempts (checkpoint → requeue → restore) — and because the churn
    /// is priced on the clock, the preempting run can never finish
    /// faster than the undisturbed one on the same trace (the satellite
    /// regression for free slot churn).
    #[test]
    fn qos_preempts_batch_tier_for_interactive_arrivals() {
        let spec = WorkloadSpec {
            requests: 10,
            arrival: ArrivalProcess::Replay {
                times_us: vec![0, 0, 0, 0, 300, 300, 300, 300, 300, 300],
            },
            sizes: SizeModel::Fixed { prompt_len: 8, gen_len: 32 },
            // mix 0.2 → ids 4 and 9 are interactive; 0–3 fill the slots
            interactive_mix: 0.2,
            ..base_spec()
        };
        let cfg = VirtualConfig { qos: true, ..VirtualConfig::default() };
        let out = run_virtual(&cfg, &spec, AdmissionPolicy::deadline());
        assert_eq!(out.samples.len(), 10);
        assert!(out.samples.iter().all(|s| s.ok));
        assert!(out.preemptions >= 1, "no preemption fired");
        assert_eq!(out.restores, out.preemptions);
        assert!(out.preempted_wait_us > 0);
        let again = run_virtual(&cfg, &spec, AdmissionPolicy::deadline());
        assert_eq!(out, again);
        let base = run_virtual(
            &VirtualConfig::default(),
            &spec,
            AdmissionPolicy::deadline(),
        );
        assert_eq!(base.preemptions, 0);
        assert!(out.duration_s >= base.duration_s);
    }

    /// Satellite regression: coincident arrival timestamps (duplicate ns
    /// offsets, produced by `exp_ns` truncation at extreme rates and by
    /// recorded replay timelines) must break the tie deterministically —
    /// ingest in request order, FIFO admit in ingest order — on *both*
    /// vsim loops, so `admit_seq` follows request id.
    #[test]
    fn coincident_arrivals_admit_fifo_by_id() {
        let cfg = VirtualConfig::default();
        let spec = WorkloadSpec {
            requests: 8,
            arrival: ArrivalProcess::Replay {
                times_us: vec![0; 8],
            },
            ..base_spec()
        };
        assert!(spec
            .materialize()
            .iter()
            .all(|r| r.arrival_ns == 0));
        let batch = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        let live = run_virtual_live(&cfg, &spec, AdmissionPolicy::fifo(), 1);
        for out in [&batch, &live.shards[0].outcome] {
            let mut admitted: Vec<(u64, u64)> = out
                .samples
                .iter()
                .filter_map(|s| s.admit_seq.map(|a| (a, s.id)))
                .collect();
            assert_eq!(admitted.len(), 8);
            admitted.sort_unstable();
            let ids: Vec<u64> =
                admitted.iter().map(|&(_, id)| id).collect();
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            assert_eq!(
                ids, sorted,
                "admission order broke the id tie-break"
            );
        }
        // and the two loops agree sample for sample
        assert_eq!(batch.samples, live.shards[0].outcome.samples);
    }

    /// Skewed flash-crowd shape used by the dynamic-placement tests:
    /// bursty arrivals + trace-seeded sizes concentrate queued work on the
    /// hot expert group's home shard, so rebalance ticks have something
    /// to migrate.
    fn skewed_burst_spec(seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            seed,
            requests: 48,
            arrival: ArrivalProcess::Bursty {
                rate_rps: 4_000.0,
                mean_on_ms: 5.0,
                mean_off_ms: 20.0,
            },
            sizes: SizeModel::TraceSeeded {
                n_experts: 16,
                skew: 2.0,
                prompt: (4, 48),
                gen: (1, 24),
            },
            slo_e2e_ms: 150.0,
            deadline_slack_us_per_token: 500,
            interactive_mix: 1.0,
        }
    }

    fn hetero_fleet() -> Vec<VirtualConfig> {
        vec![
            VirtualConfig { slots: 2, ..VirtualConfig::default() },
            VirtualConfig {
                slots: 4,
                cycle_ns: 200,
                ..VirtualConfig::default()
            },
            VirtualConfig { slots: 2, ..VirtualConfig::default() },
        ]
    }

    #[test]
    fn dynamic_runs_are_identical_per_seed() {
        let cfgs = hetero_fleet();
        let dcfg = DynamicConfig::from_virtual(&cfgs[0], 4, 100.0);
        let spec = skewed_burst_spec(11);
        let a = run_virtual_dynamic_traced(
            &cfgs, &spec, AdmissionPolicy::fifo(), &dcfg, true);
        let b = run_virtual_dynamic_traced(
            &cfgs, &spec, AdmissionPolicy::fifo(), &dcfg, true);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2.len(), b.2.len());
        for (sa, sb) in a.2.iter().zip(&b.2) {
            assert_eq!(sa.events, sb.events);
        }
    }

    /// With one shard there is nothing to balance: the dynamic loop must
    /// collapse to the single-backend event loop sample for sample, the
    /// same 1-shard pin [`run_virtual_live`] keeps.
    #[test]
    fn one_shard_dynamic_matches_the_single_backend_loop() {
        let cfg = VirtualConfig::default();
        let dcfg = DynamicConfig::from_virtual(&cfg, 4, 100.0);
        let spec = skewed_burst_spec(7);
        let (run, report) = run_virtual_dynamic(
            &[cfg.clone()], &spec, AdmissionPolicy::fifo(), &dcfg);
        let solo = run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        assert_eq!(run.shards.len(), 1);
        assert_eq!(run.shards[0].outcome.samples, solo.samples);
        assert_eq!(report.migrations, 0);
    }

    /// Conservation across rebalances: every materialized request reaches
    /// exactly one terminal, in the samples *and* in the exported trace
    /// (migrated ids terminate on the shard that served them), and the
    /// per-tick imbalance pair keeps its ordering.
    #[test]
    fn dynamic_conserves_requests_across_migrations() {
        let cfgs = hetero_fleet();
        let dcfg = DynamicConfig::from_virtual(&cfgs[0], 4, 0.0);
        let mut migrated_somewhere = false;
        for seed in [7, 9, 11, 13] {
            let spec = skewed_burst_spec(seed);
            let (run, report, traces) = run_virtual_dynamic_traced(
                &cfgs, &spec, AdmissionPolicy::fifo(), &dcfg, true);
            migrated_somewhere |= report.migrations > 0;
            assert!(
                report.imbalance_after <= report.imbalance_before,
                "seed {seed}: {report:?}"
            );
            let mut ids: Vec<u64> = run
                .shards
                .iter()
                .flat_map(|s| s.outcome.samples.iter().map(|smp| smp.id))
                .collect();
            assert_eq!(ids.len(), spec.requests, "seed {seed}");
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..spec.requests as u64).collect::<Vec<u64>>(),
                "seed {seed}"
            );
            for s in &run.shards {
                assert_eq!(s.requests, s.outcome.samples.len());
            }
            let doc = crate::obs::export::chrome_trace(&traces, "virtual");
            assert_eq!(
                crate::obs::export::check_conservation(&doc),
                Ok(spec.requests),
                "seed {seed}"
            );
        }
        assert!(migrated_somewhere, "no migration fired on any probe seed");
    }

    /// Replication stays inside the mm² ledger and is deterministic: the
    /// budgeted run replicates at least one hot group, never overspends,
    /// and the zero-budget run replicates nothing.
    #[test]
    fn dynamic_replication_respects_the_area_budget() {
        let cfgs = hetero_fleet();
        let spec = skewed_burst_spec(11);
        // one paper-chip g=2 group replica costs ~85.3 mm²; 100 buys
        // exactly one, so the budgeted leg must stop after it
        let budget = 100.0;
        let with = DynamicConfig::from_virtual(&cfgs[0], 4, budget);
        let (_, rep) = run_virtual_dynamic(
            &cfgs, &spec, AdmissionPolicy::fifo(), &with);
        assert!(rep.replicas > 0, "budget unused: {rep:?}");
        assert!(rep.area_mm2_delta <= budget + 1e-9, "{rep:?}");
        let without = DynamicConfig::from_virtual(&cfgs[0], 4, 0.0);
        let (_, none) = run_virtual_dynamic(
            &cfgs, &spec, AdmissionPolicy::fifo(), &without);
        assert_eq!(none.replicas, 0);
        assert_eq!(none.area_mm2_delta, 0.0);
    }
}
