//! Load driver: runs a [`WorkloadSpec`] against the real threaded
//! [`Server`] and collects per-request [`Sample`]s.
//!
//! Two loop disciplines, chosen by the spec's arrival process:
//!
//! * **open loop** — submissions are paced by the precomputed arrival
//!   timeline regardless of completions (the "users keep coming" regime
//!   where queues actually build up);
//! * **closed loop** — `users` concurrent sessions, each submitting its
//!   next request `think_ms` after its previous reply (the saturation
//!   regime; offered load adapts to service rate).
//!
//! Wall-clock runs are inherently non-repeatable, so their reports carry
//! `"clock": "wall"`; the byte-identical variant is the virtual-time
//! cluster in [`crate::workload::vsim`], which produces the same
//! [`LoadOutcome`] shape from a deterministic discrete-event simulation.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{Request, Response, Server};
use crate::sched::PlannerStats;
use crate::util::rng::Pcg32;
use crate::workload::arrival::{ArrivalProcess, RequestSpec, WorkloadSpec};
use crate::workload::policy::Priority;

/// Vocabulary cap for generated prompt tokens (safely below every
/// artifact set's vocab).
const PROMPT_VOCAB: usize = 512;
const PROMPT_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// One request's terminal measurement, backend-agnostic: the real driver
/// fills it from a [`Response`], the virtual cluster from its event clock.
/// `None` timing fields mean "never happened" (e.g. a rejected request was
/// never admitted), mirroring [`Response`].
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// the request's workload-global id ([`RequestSpec::id`])
    pub id: u64,
    /// submission order within the experiment (0-based)
    pub submit_seq: u64,
    /// terminal outcome: `true` iff the request completed successfully
    pub ok: bool,
    /// submit → slot admission (µs); `None` when never admitted
    pub queue_us: Option<f64>,
    /// submit → first generated token (µs); `None` when none was produced
    pub ttft_us: Option<f64>,
    /// submit → terminal reply (µs)
    pub e2e_us: f64,
    /// generated tokens banked by the terminal reply
    pub tokens: u64,
    /// admission sequence number; `None` when never admitted
    pub admit_seq: Option<u64>,
    /// shard tag on the terminal reply (`None`: unsharded backend or
    /// virtual clock, where the outcome-level
    /// [`LoadOutcome::shard`] tag already identifies the backend).
    /// Cluster runs use it to bucket one interleaved sample stream back
    /// into per-shard outcomes — shed replies carry the shard that
    /// would have received the request
    pub shard: Option<usize>,
}

/// Everything one load experiment produced: per-request samples plus the
/// serving-side telemetry snapshot the report folds in.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadOutcome {
    /// one terminal measurement per submitted request
    pub samples: Vec<Sample>,
    /// cumulative group-aware planner telemetry (peripheral contention)
    pub planner: PlannerStats,
    /// serving slots of the backend that produced this outcome
    pub slots: usize,
    /// high-water mark of the admission queue
    pub peak_waiting: usize,
    /// batched decode dispatches
    pub batch_dispatches: u64,
    /// tokens advanced by batched dispatches
    pub batched_tokens: u64,
    /// single-token fallback dispatches
    pub single_dispatches: u64,
    /// prefill chunk advances (0 when the backend ran monolithic prefill,
    /// i.e. `prefill_chunk == 0` — see
    /// [`crate::coordinator::ServerOptions::prefill_chunk`] and
    /// [`crate::workload::VirtualConfig::prefill_chunk`])
    pub prefill_chunks: u64,
    /// requests shed with an immediate terminal `overloaded` error —
    /// per-server `queue_cap` sheds plus, in cluster runs, front-door
    /// sheds attributed to this shard (0 when shedding is off)
    pub shed_requests: u64,
    /// high-water mark of the cluster intake queue (0 for single-server
    /// and virtual runs, which have no front-door queue; a cluster run
    /// records the cluster-wide peak on every shard's outcome, and the
    /// merge takes the max)
    pub peak_intake_depth: usize,
    /// batch-tier slots evicted (checkpoint → requeue) for a waiting
    /// interactive request (0 unless the backend ran with QoS on — see
    /// [`crate::coordinator::ServerOptions::qos`] /
    /// [`crate::workload::VirtualConfig::qos`])
    pub preemptions: u64,
    /// checkpointed sessions resumed into a slot (`<= preemptions`;
    /// every preempted request is restored or terminally replied exactly
    /// once)
    pub restores: u64,
    /// total µs preempted requests spent requeued between eviction and
    /// resume
    pub preempted_wait_us: u64,
    /// high-water mark of simultaneously held preemption checkpoints (a
    /// lifetime view like `peak_waiting`, not differenced); the report
    /// prices the beyond-one-slot excess against the area ledger via
    /// [`crate::placement::checkpoint_spill_mm2`]
    pub peak_checkpoints: usize,
    /// unix-epoch µs of the backend's first dispatch (`None`: virtual
    /// clock, or never dispatched); with
    /// [`LoadOutcome::last_dispatch_unix_us`] this is the router
    /// thread's busy interval on a common clock — the concurrency
    /// evidence the cluster tests assert on (shards' intervals overlap)
    pub first_dispatch_unix_us: Option<u64>,
    /// unix-epoch µs of the backend's most recent dispatch (`None`:
    /// virtual clock, or never dispatched)
    pub last_dispatch_unix_us: Option<u64>,
    /// experiment wall/virtual time in seconds
    pub duration_s: f64,
    /// `"virtual"` (deterministic, byte-identical reports) or `"wall"`
    pub clock: &'static str,
    /// which shard of a fan-out produced this outcome (`None`: unsharded).
    /// Real runs inherit it from [`crate::coordinator::ServerStats::shard`];
    /// the sharded driver tags virtual outcomes itself.
    pub shard: Option<usize>,
}

impl LoadOutcome {
    /// Mean live slots per batched dispatch.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_dispatches == 0 {
            0.0
        } else {
            self.batched_tokens as f64 / self.batch_dispatches as f64
        }
    }

    /// Total generated tokens across all samples.
    pub fn tokens_generated(&self) -> u64 {
        self.samples.iter().map(|s| s.tokens).sum()
    }
}

/// Convert a terminal [`Response`] into a [`Sample`].
pub fn sample_from_response(resp: &Response, submit_seq: u64) -> Sample {
    Sample {
        id: resp.id,
        submit_seq,
        ok: resp.is_ok(),
        queue_us: resp.queue_us,
        ttft_us: resp.ttft_us,
        e2e_us: resp.latency_us,
        tokens: resp.tokens().len() as u64,
        admit_seq: resp.admit_seq,
        shard: resp.shard,
    }
}

/// Materialize one request's payload: seeded toy prompt + deadline budget.
///
/// Public so equivalence tests can submit byte-identical prompts through
/// different front ends (bare [`Server`], serial fan-out, concurrent
/// cluster) — the prompt depends only on the workload seed and the
/// request's global id, never on which backend serves it.
pub fn request_for(spec: &WorkloadSpec, r: &RequestSpec) -> Request {
    let mut rng = Pcg32::new(spec.seed ^ r.id.wrapping_mul(PROMPT_SALT));
    let prompt: Vec<i32> = (0..r.prompt_len)
        .map(|_| rng.gen_range(PROMPT_VOCAB) as i32)
        .collect();
    Request::new(r.id, prompt, r.gen_len)
        .with_deadline_us(r.deadline_us)
        .with_priority(Priority::assign(r.id, spec.interactive_mix))
}

/// Run `spec` against a live server and collect every terminal reply.
///
/// Counter telemetry (`planner`, dispatch counters, sheds) is reported as
/// the *delta* across this run — a stats snapshot is taken before the
/// first submit and subtracted from the end-of-run snapshot — so driving
/// a reused server yields the same outcome a fresh server would.
/// `peak_waiting` is the one lifetime view left: it is a high-water mark,
/// not a counter, and cannot be differenced.
pub fn run_against_server(server: &Server, spec: &WorkloadSpec)
    -> Result<LoadOutcome> {
    run_requests_against_server(server, spec, &spec.materialize())
}

/// Run an explicit request list against a live server.
///
/// This is [`run_against_server`] with the materialization step factored
/// out, for the sharded fan-out driver: the full spec is materialized
/// once, partitioned, and each shard's server is driven with its subset
/// (arrival offsets are kept from the global timeline).  The outcome's
/// `shard` tag is inherited from the server's
/// [`crate::coordinator::ServerStats::shard`], and counters are
/// differenced against a pre-run snapshot (see [`run_against_server`]).
pub fn run_requests_against_server(server: &Server, spec: &WorkloadSpec,
                                   reqs: &[RequestSpec])
    -> Result<LoadOutcome> {
    let before = server.stats()?;
    let t0 = Instant::now();
    let samples = drive(|r| server.submit(r), spec, reqs)?;
    let duration_s = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = server.stats()?;
    let planner = PlannerStats {
        steps: stats.planner.steps - before.planner.steps,
        work: stats.planner.work - before.planner.work,
        cycles: stats.planner.cycles - before.planner.cycles,
        contention_cycles: stats.planner.contention_cycles
            - before.planner.contention_cycles,
        transfers: stats.planner.transfers - before.planner.transfers,
    };
    Ok(LoadOutcome {
        samples,
        planner,
        slots: stats.slots,
        peak_waiting: stats.peak_waiting,
        batch_dispatches: stats.batch_dispatches - before.batch_dispatches,
        batched_tokens: stats.batched_tokens - before.batched_tokens,
        single_dispatches: stats.single_dispatches
            - before.single_dispatches,
        prefill_chunks: stats.prefill_chunks - before.prefill_chunks,
        shed_requests: stats.shed_requests - before.shed_requests,
        peak_intake_depth: 0,
        preemptions: stats.preemptions - before.preemptions,
        restores: stats.restores - before.restores,
        preempted_wait_us: stats.preempted_wait_us
            - before.preempted_wait_us,
        peak_checkpoints: stats.peak_checkpoints,
        first_dispatch_unix_us: stats.first_dispatch_unix_us,
        last_dispatch_unix_us: stats.last_dispatch_unix_us,
        duration_s,
        clock: "wall",
        shard: stats.shard,
    })
}

/// Drive `reqs` through any submit surface — a bare [`Server`] or the
/// concurrent cluster front door — using the loop discipline the spec's
/// arrival process selects.  The submit closure hides which backend (or
/// placement layer) receives each request; pacing and collection are
/// identical either way.
pub(crate) fn drive<F>(submit: F, spec: &WorkloadSpec, reqs: &[RequestSpec])
    -> Result<Vec<Sample>>
where
    F: Fn(Request) -> mpsc::Receiver<Response>,
{
    match spec.arrival {
        ArrivalProcess::Closed { users, think_ms } => {
            drive_closed(&submit, spec, reqs, users.max(1), think_ms)
        }
        _ => drive_open(&submit, spec, reqs),
    }
}

/// Open loop: pace submissions by the arrival timeline, then drain.
fn drive_open<F>(submit: &F, spec: &WorkloadSpec, reqs: &[RequestSpec])
    -> Result<Vec<Sample>>
where
    F: Fn(Request) -> mpsc::Receiver<Response>,
{
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(reqs.len());
    for (submit_seq, r) in reqs.iter().enumerate() {
        let target = Duration::from_nanos(r.arrival_ns);
        let elapsed = t0.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        let rx = submit(request_for(spec, r));
        rxs.push((submit_seq as u64, r.id, rx));
    }
    let mut samples = Vec::with_capacity(rxs.len());
    for (submit_seq, id, rx) in rxs {
        let resp = rx
            .recv()
            .map_err(|_| anyhow!("request {id}: reply channel dropped"))?;
        samples.push(sample_from_response(&resp, submit_seq));
    }
    Ok(samples)
}

/// One closed-loop user's request in flight.
struct InFlight {
    id: u64,
    submit_seq: u64,
    rx: mpsc::Receiver<Response>,
}

/// Closed loop: `users` sessions, each resubmitting `think_ms` after its
/// previous reply.  Polls with `try_recv` so every user's completion is
/// reacted to promptly (blocking on one user would delay the others'
/// resubmissions and distort the loop).
fn drive_closed<F>(submit: &F, spec: &WorkloadSpec, reqs: &[RequestSpec],
                   users: usize, think_ms: f64) -> Result<Vec<Sample>>
where
    F: Fn(Request) -> mpsc::Receiver<Response>,
{
    let think = Duration::from_nanos((think_ms.max(0.0) * 1e6) as u64);
    let mut outstanding: Vec<Option<InFlight>> =
        (0..users).map(|_| None).collect();
    let mut ready_at: Vec<Instant> = vec![Instant::now(); users];
    let mut next = 0usize;
    let mut submit_seq = 0u64;
    let mut samples = Vec::with_capacity(reqs.len());
    while samples.len() < reqs.len() {
        let mut progressed = false;
        for u in 0..users {
            if outstanding[u].is_none()
                && next < reqs.len()
                && Instant::now() >= ready_at[u]
            {
                let r = &reqs[next];
                let rx = submit(request_for(spec, r));
                outstanding[u] =
                    Some(InFlight { id: r.id, submit_seq, rx });
                submit_seq += 1;
                next += 1;
                progressed = true;
            }
            let finished = match outstanding[u].as_ref() {
                Some(inflight) => match inflight.rx.try_recv() {
                    Ok(resp) => Some(Some(resp)),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => Some(None),
                },
                None => None,
            };
            if let Some(resp) = finished {
                let inflight = outstanding[u].take().unwrap();
                match resp {
                    Some(resp) => {
                        samples.push(sample_from_response(
                            &resp,
                            inflight.submit_seq,
                        ));
                    }
                    None => {
                        return Err(anyhow!(
                            "request {}: reply channel dropped",
                            inflight.id
                        ));
                    }
                }
                ready_at[u] = Instant::now() + think;
                progressed = true;
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn open_loop_submits_coincident_arrivals_in_order() {
        // duplicate arrival timestamps are legitimate (whole-ns truncation
        // in `arrival::exp_ns` at extreme rates, and recorded replay
        // timelines); the open loop must break the tie by submitting
        // strictly in request order — the deterministic FIFO tie-break
        // that record→replay byte-identity leans on
        let spec = WorkloadSpec {
            requests: 6,
            arrival: ArrivalProcess::Replay {
                times_us: vec![0, 0, 0, 1, 1, 1],
            },
            ..WorkloadSpec::default()
        };
        let reqs = spec.materialize();
        assert!(
            reqs.windows(2).any(|w| w[0].arrival_ns == w[1].arrival_ns),
            "setup: expected coincident arrivals"
        );
        let order = Mutex::new(Vec::new());
        let samples = drive(
            |req| {
                order.lock().unwrap().push(req.id);
                let (tx, rx) = mpsc::channel();
                tx.send(Response {
                    id: req.id,
                    result: Ok(vec![0; req.gen_len]),
                    latency_us: 1.0,
                    ttft_us: Some(1.0),
                    queue_us: Some(0.5),
                    admit_seq: Some(req.id),
                    batched_steps: 0,
                    single_steps: 0,
                    shard: None,
                })
                .expect("rx alive");
                rx
            },
            &spec,
            &reqs,
        )
        .expect("mock drive");
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4, 5]);
        let ids: Vec<u64> = samples.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
        assert!(samples
            .iter()
            .enumerate()
            .all(|(i, s)| s.submit_seq == i as u64));
    }
}
