//! Sharded multi-server fan-out: split one [`WorkloadSpec`] across N
//! backends and merge the per-shard results into one cluster-level view.
//!
//! The paper's multiplexing story is intra-chip (crossbars sharing
//! peripherals); this module is the same idea one level up — N serving
//! stacks sharing one request stream.  A [`ShardedDriver`] materializes
//! the spec **once**, assigns every request to a shard with a pluggable
//! [`PlacementPolicy`] (a thin adapter over the unified
//! [`crate::placement`] subsystem — the assignment state machines live
//! in [`crate::placement::StaticPlacer`], and the *dynamic* control loop
//! with migration/replication lives in
//! [`crate::workload::vsim::run_virtual_dynamic`]), runs each shard's
//! subset on its own backend
//! (a [`crate::coordinator::Server`] or a virtual cluster from
//! [`crate::workload::vsim`]), and merges the per-shard
//! [`LoadOutcome`]s:
//!
//! ```text
//! WorkloadSpec ──materialize──► [RequestSpec; R]
//!                                     │ PlacementPolicy::assign
//!                     ┌───────────────┼────────────────┐
//!                     ▼               ▼                ▼
//!                 shard 0          shard 1   …      shard N-1
//!               run_virtual_     run_virtual_     run_virtual_
//!                requests()       requests()       requests()
//!                     │               │                │
//!                     └──────── merge() ───────────────┘
//!                                     ▼
//!                    moepim.slo_report.v2 (merged + per-shard)
//! ```
//!
//! Everything stays deterministic: per-request prompt and routing streams
//! are keyed off `(spec.seed, request id)` — not off queue position or
//! shard — so a request behaves identically wherever it is placed, and a
//! one-shard split replays *exactly* the event sequence of the unsharded
//! [`crate::workload::run_virtual`] (pinned by
//! `rust/tests/shard_virtual.rs`).  Merging is shard-exact because
//! [`LatencyHistogram::merge`] adds bucket counts: merged quantiles equal
//! the quantiles of one histogram built over the concatenated samples.
//!
//! Real backends run **concurrently**: each [`crate::coordinator::Server`]
//! owns its engine and PJRT client inside its own router thread, so
//! [`ShardedDriver::run_real_concurrent`] drives all N shards in parallel
//! driver threads (the static-split path), and [`run_against_cluster`]
//! drives the live-placement [`crate::coordinator::Cluster`] front door
//! and buckets its interleaved reply stream back into per-shard outcomes.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::{Cluster, Server, ServerOptions};

use crate::obs::sink::{TraceShard, TraceSink};
use crate::placement::{Arrival, StaticPlacer};
use crate::sched::PlannerStats;
use crate::workload::arrival::{ArrivalProcess, RequestSpec, WorkloadSpec};
use crate::workload::driver::{
    drive, run_requests_against_server, LoadOutcome, Sample,
};
use crate::workload::hist::LatencyHistogram;
use crate::workload::policy::AdmissionPolicy;
use crate::workload::report::{summarize, SloSummary};
use crate::workload::vsim::{
    run_virtual_requests, run_virtual_requests_traced, VirtualConfig,
};

/// Real-path calibration estimate for least-outstanding placement when
/// the backends are `--real` servers (ns per prompt token of prefill).
/// The PJRT prefill dispatch is padded fixed-shape, so per-token cost is
/// an amortized estimate; refine via the ROADMAP "virtual-cluster
/// calibration" item when measured fits land.
pub const REAL_EST_PREFILL_NS_PER_TOKEN: u64 = 60_000;
/// Real-path calibration estimate per generated token (one batched
/// decode-cycle share on the threaded server).
pub const REAL_EST_DECODE_NS_PER_TOKEN: u64 = 450_000;

/// Which shard each request of a workload is served by.
///
/// Placement runs at *split* time over the materialized request stream, so
/// it is deterministic per seed and identical for every admission policy
/// under test — policy comparisons stay apples-to-apples even sharded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlacementPolicy {
    /// Request `i` goes to shard `i mod N` — the oblivious baseline.
    RoundRobin,
    /// Each arrival goes to the shard with the fewest requests still
    /// estimated in flight at its arrival time (deterministic analytic
    /// estimate — prompt·prefill + gen·decode cost constants — not
    /// feedback from the backends); count ties break by least estimated
    /// outstanding *work*, then lowest shard id.  For closed-loop specs
    /// every materialized arrival offset is 0, so nothing has "completed"
    /// by any arrival and the count degenerates to balanced assignment —
    /// the work tie-break is then what spreads large requests apart.
    ///
    /// The cost constants must describe the backend actually serving the
    /// run: build via [`PlacementPolicy::least_outstanding`] (derived from
    /// the run's [`VirtualConfig`]) or
    /// [`PlacementPolicy::least_outstanding_real`] (the `--real`
    /// calibration constants) rather than hand-picking numbers — a
    /// mismatched estimate silently mis-ranks shards for any non-default
    /// config.
    LeastOutstanding {
        /// estimated prefill cost per prompt token (ns)
        prefill_ns_per_token: u64,
        /// estimated cost per generated token (ns)
        decode_ns_per_token: u64,
    },
    /// Hash of `(prompt_len, gen_len)` picks the shard, so same-sized
    /// requests colocate — size affinity keeps each shard's batch
    /// composition homogeneous under SJF-style admission.
    SizeHash,
    /// Routing-aware placement: peek the request's seeded expert-routing
    /// stream (the same `(seed, id)` stream the virtual cluster will
    /// draw), take its first decode cycle's first-drawn expert — a
    /// zipf-weighted draw, so biased toward (not guaranteed to be) the
    /// hottest expert — and shard by that expert's peripheral-sharing
    /// group.  Requests that will contend on the same group tend to land
    /// on the same shard, so the *other* shards don't pay that group's
    /// makespan.  The peeked stream is the *virtual* route model: against
    /// virtual backends it is exactly what each shard will draw; against
    /// `--real` servers (whose routing comes from the compiled model) it
    /// is only a seeded proxy, so the colocation rationale does not carry
    /// over.
    RouteAware {
        /// experts in the modeled router (match the backend's config)
        n_experts: usize,
        /// top-k routing width of the modeled router
        experts_per_token: usize,
        /// zipf skew of the modeled router's expert popularity
        skew: f64,
        /// peripheral-sharing group size (experts per group)
        group_size: usize,
    },
}

impl PlacementPolicy {
    /// Least-outstanding placement whose cost estimates are derived from
    /// the virtual cluster that will serve the run: prefill cost is the
    /// config's own `prefill_ns_per_token`, and the per-generated-token
    /// estimate is the config's dispatch overhead plus a typical priced
    /// decode cycle (each of the token's `n_layers · experts_per_token`
    /// expert executions costing ~2 slot-cycles under grouped
    /// peripherals).  With [`VirtualConfig::default`] this lands within a
    /// few µs of the constants the placement used to hardcode; with any
    /// other config it now tracks the backend instead of silently
    /// mis-estimating (the bug this replaced: a fixed 4 µs/token prefill
    /// estimate "mirroring the default config" regardless of the actual
    /// `prefill_ns_per_token` under test).
    pub fn least_outstanding(cfg: &VirtualConfig) -> Self {
        let per_token_cycles = 2 * cfg.n_layers.max(1) as u64
            * cfg.experts_per_token.max(1) as u64;
        PlacementPolicy::LeastOutstanding {
            prefill_ns_per_token: cfg.prefill_ns_per_token,
            decode_ns_per_token: cfg.dispatch_overhead_ns
                + per_token_cycles * cfg.cycle_ns,
        }
    }

    /// Least-outstanding placement with the `--real` threaded-server
    /// calibration constants ([`REAL_EST_PREFILL_NS_PER_TOKEN`] /
    /// [`REAL_EST_DECODE_NS_PER_TOKEN`]).
    pub fn least_outstanding_real() -> Self {
        PlacementPolicy::LeastOutstanding {
            prefill_ns_per_token: REAL_EST_PREFILL_NS_PER_TOKEN,
            decode_ns_per_token: REAL_EST_DECODE_NS_PER_TOKEN,
        }
    }

    /// Routing-aware placement matching a virtual cluster's route model.
    pub fn route_aware(cfg: &VirtualConfig) -> Self {
        PlacementPolicy::RouteAware {
            n_experts: cfg.n_experts.max(1),
            experts_per_token: cfg.experts_per_token.max(1),
            skew: cfg.route_skew,
            group_size: cfg.group_size.max(1),
        }
    }

    /// Canonical CLI spelling (`moepim shardtest --placement <label>`).
    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::RoundRobin => "round-robin",
            PlacementPolicy::LeastOutstanding { .. } => "least-outstanding",
            PlacementPolicy::SizeHash => "size-hash",
            PlacementPolicy::RouteAware { .. } => "route-aware",
        }
    }

    /// Parse a CLI spelling; `None` on unknown input.  `route-aware` and
    /// `least-outstanding` derive their model constants from `cfg` — the
    /// [`VirtualConfig`] actually serving the run — so placement and
    /// backend agree for *any* config, not just the default.  (The bug
    /// this replaced: parse always built from `VirtualConfig::default()`
    /// and the CLI path never rebuilt, so a non-default `cycle_ns`
    /// silently mis-ranked shards.)  `--real` callers should still swap
    /// in [`PlacementPolicy::least_outstanding_real`] afterwards.
    pub fn parse(s: &str, cfg: &VirtualConfig) -> Option<Self> {
        match s {
            "round-robin" | "rr" => Some(PlacementPolicy::RoundRobin),
            "least-outstanding" | "lo" => {
                Some(PlacementPolicy::least_outstanding(cfg))
            }
            "size-hash" | "hash" => Some(PlacementPolicy::SizeHash),
            "route-aware" | "route" => {
                Some(PlacementPolicy::route_aware(cfg))
            }
            _ => None,
        }
    }

    /// Assign every request to a shard in `[0, shards)`.  Deterministic in
    /// `(spec.seed, reqs, shards)`; requests must be in arrival order
    /// (which [`WorkloadSpec::materialize`] guarantees).
    ///
    /// This is a thin adapter over the unified placement subsystem: it
    /// folds the request stream through a
    /// [`crate::placement::StaticPlacer`], the per-arrival state machine
    /// these policies' assignment rules now live in.
    pub fn assign(&self, spec: &WorkloadSpec, reqs: &[RequestSpec],
                  shards: usize) -> Vec<usize> {
        let mut placer = StaticPlacer::new(*self, spec.seed, shards);
        reqs.iter()
            .map(|r| placer.place_next(&Arrival::of(r)))
            .collect()
    }
}

/// One shard's slice of a split workload: the sub-spec its backend runs
/// under (same seed/SLO; closed-loop user share adjusted) plus its
/// requests, with workload-global ids and arrival offsets preserved.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLoad {
    /// shard index in `[0, N)`
    pub shard: usize,
    /// the per-shard spec (`requests` = this shard's count; for closed
    /// loops, `users` is this shard's share of the population)
    pub spec: WorkloadSpec,
    /// this shard's requests, in global arrival order
    pub reqs: Vec<RequestSpec>,
}

/// One shard's collected result.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardOutcome {
    /// shard index in `[0, N)`
    pub shard: usize,
    /// requests assigned to this shard (== terminal samples collected)
    pub requests: usize,
    /// the shard backend's full load outcome
    pub outcome: LoadOutcome,
}

/// Every shard's outcome from one fan-out run, in shard order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedRun {
    /// one entry per shard, index == shard id
    pub shards: Vec<ShardOutcome>,
}

/// Splits one workload across N backends and collects per-shard outcomes.
///
/// The driver is backend-agnostic: [`ShardedDriver::run_virtual`] fans out
/// over N independent virtual clusters (deterministic, byte-identical
/// reports per seed); [`ShardedDriver::run_real_concurrent`] drives N
/// real [`crate::coordinator::Server`]s genuinely in parallel (each owns
/// its engine and PJRT client on its own router thread, and each shard
/// gets its own driver thread); and [`ShardedDriver::run_with`] accepts
/// any serial per-shard runner for A/B comparison (e.g. the legacy
/// `--serial` fan-out, which runs one shard at a time and whose summed
/// wall time is the concurrent path's speedup baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardedDriver {
    /// number of shards N (>= 1)
    pub shards: usize,
    /// how requests are assigned to shards
    pub placement: PlacementPolicy,
}

impl ShardedDriver {
    /// A driver fanning out over `shards` backends under `placement`.
    pub fn new(shards: usize, placement: PlacementPolicy) -> Self {
        ShardedDriver { shards: shards.max(1), placement }
    }

    /// Materialize `spec` once and partition it: every request lands on
    /// exactly one shard (pinned by `rust/tests/shard_virtual.rs`).  For
    /// closed-loop specs the user population is divided across shards
    /// (shard `i` gets `users/N`, the first `users % N` shards one extra,
    /// minimum one per shard — a shard holding requests needs a driver to
    /// make progress).  That floor means a closed loop fanned out over
    /// more shards than users runs *more* concurrent users than the spec
    /// asked for (up to one per request-holding shard); keep
    /// `users >= N` when the closed-loop concurrency level is the thing
    /// under study.
    pub fn split(&self, spec: &WorkloadSpec) -> Vec<ShardLoad> {
        let n = self.shards.max(1);
        let reqs = spec.materialize();
        let assign = self.placement.assign(spec, &reqs, n);
        let mut parts: Vec<Vec<RequestSpec>> = vec![Vec::new(); n];
        for (r, &s) in reqs.iter().zip(&assign) {
            parts[s.min(n - 1)].push(r.clone());
        }
        parts
            .into_iter()
            .enumerate()
            .map(|(i, reqs_i)| {
                let arrival = match &spec.arrival {
                    ArrivalProcess::Closed { users, think_ms } => {
                        let share = users / n + usize::from(i < users % n);
                        ArrivalProcess::Closed {
                            users: share.max(1),
                            think_ms: *think_ms,
                        }
                    }
                    other => other.clone(),
                };
                ShardLoad {
                    shard: i,
                    spec: WorkloadSpec {
                        requests: reqs_i.len(),
                        arrival,
                        ..spec.clone()
                    },
                    reqs: reqs_i,
                }
            })
            .collect()
    }

    /// Fan `spec` out over N independent virtual clusters (each a fresh
    /// [`VirtualConfig`]-shaped chip with its own event clock) and collect
    /// every shard's outcome.  Deterministic: the same
    /// `(cfg, spec, policy, shards, placement)` always yields an identical
    /// [`ShardedRun`], so merged reports are byte-identical per seed.
    pub fn run_virtual(&self, cfg: &VirtualConfig, spec: &WorkloadSpec,
                       policy: AdmissionPolicy) -> ShardedRun {
        self.run_with(spec, |shard, sspec, reqs| {
            let mut out = run_virtual_requests(cfg, sspec, reqs, policy);
            out.shard = Some(shard);
            Ok(out)
        })
        .expect("virtual shard runs are infallible")
    }

    /// [`ShardedDriver::run_virtual`] with span tracing: every shard's
    /// virtual cluster records its request-lifecycle events on its own
    /// event clock, and the per-shard [`TraceShard`]s come back in shard
    /// order for `--trace-out` export.  The outcome is identical to the
    /// untraced run — recording never touches the event clock or the
    /// routing/admission state.
    pub fn run_virtual_traced(&self, cfg: &VirtualConfig,
                              spec: &WorkloadSpec, policy: AdmissionPolicy)
        -> (ShardedRun, Vec<TraceShard>) {
        let loads = self.split(spec);
        let mut shards = Vec::with_capacity(loads.len());
        let mut traces = Vec::with_capacity(loads.len());
        for load in &loads {
            let mut sink = TraceSink::on(true);
            let mut outcome = run_virtual_requests_traced(
                cfg, &load.spec, &load.reqs, policy, &mut sink);
            outcome.shard = Some(load.shard);
            traces.push(sink.drain(Some(load.shard), "vsim"));
            shards.push(ShardOutcome {
                shard: load.shard,
                requests: load.reqs.len(),
                outcome,
            });
        }
        (ShardedRun { shards }, traces)
    }

    /// Fan `spec` out over N **concurrently-running** real servers: every
    /// shard's backend is spawned first (serially — each spawn blocks on
    /// artifact compilation), then each `(backend, subset)` pair is driven
    /// on its own thread under `std::thread::scope`, so the N router
    /// threads decode in parallel and the fan-out's wall time is the
    /// slowest shard's drive time, not the sum.  Each server is moved
    /// into its driver thread (reply senders are `Send`, not `Sync`) and
    /// dropped there, so shard shutdowns overlap too.  The per-shard
    /// durations exclude compilation, making them comparable with the
    /// serial [`ShardedDriver::run_with`] baseline.
    pub fn run_real_concurrent(&self, artifacts_dir: &Path,
                               spec: &WorkloadSpec, opts: &ServerOptions)
        -> Result<ShardedRun> {
        Ok(self.run_real_concurrent_traced(artifacts_dir, spec, opts)?.0)
    }

    /// [`ShardedDriver::run_real_concurrent`] returning the per-shard
    /// span traces alongside the outcomes.  When `opts.trace` is set,
    /// each driver thread drains its server's ring
    /// ([`Server::take_trace`]) after its last reply and *before*
    /// dropping the server, so the shard's trace survives router
    /// shutdown; with tracing off the trace vector is empty.
    pub fn run_real_concurrent_traced(&self, artifacts_dir: &Path,
                                      spec: &WorkloadSpec,
                                      opts: &ServerOptions)
        -> Result<(ShardedRun, Vec<TraceShard>)> {
        let loads = self.split(spec);
        let trace = opts.trace;
        let mut servers = Vec::with_capacity(loads.len());
        for load in &loads {
            servers.push(Server::spawn_opts(
                artifacts_dir.to_path_buf(),
                ServerOptions { shard: Some(load.shard), ..opts.clone() },
            )?);
        }
        let results: Vec<Result<(LoadOutcome, Option<TraceShard>)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = loads
                    .iter()
                    .zip(servers.drain(..))
                    .map(|(load, server)| {
                        scope.spawn(move || {
                            let out = run_requests_against_server(
                                &server, &load.spec, &load.reqs,
                            )?;
                            let shard_trace = if trace {
                                Some(server.take_trace()?)
                            } else {
                                None
                            };
                            Ok((out, shard_trace))
                            // server drops here: shutdown + join happen
                            // inside the driver thread, concurrently
                            // across shards
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(r) => r,
                        Err(_) => {
                            Err(anyhow!("shard driver thread panicked"))
                        }
                    })
                    .collect()
            });
        let mut shards = Vec::with_capacity(loads.len());
        let mut traces = Vec::with_capacity(loads.len());
        for (load, result) in loads.iter().zip(results) {
            let (mut outcome, shard_trace) = result?;
            if outcome.shard.is_none() {
                outcome.shard = Some(load.shard);
            }
            traces.extend(shard_trace);
            shards.push(ShardOutcome {
                shard: load.shard,
                requests: load.reqs.len(),
                outcome,
            });
        }
        Ok((ShardedRun { shards }, traces))
    }

    /// Fan `spec` out with a caller-supplied per-shard runner (shard id,
    /// per-shard spec, this shard's requests).  Shards run in shard order;
    /// the first runner error aborts the fan-out.  An outcome the runner
    /// left untagged gets its shard id filled in.
    pub fn run_with<F>(&self, spec: &WorkloadSpec, mut run: F)
        -> Result<ShardedRun>
    where
        F: FnMut(usize, &WorkloadSpec, &[RequestSpec])
            -> Result<LoadOutcome>,
    {
        let loads = self.split(spec);
        let mut shards = Vec::with_capacity(loads.len());
        for load in &loads {
            let mut outcome = run(load.shard, &load.spec, &load.reqs)?;
            if outcome.shard.is_none() {
                outcome.shard = Some(load.shard);
            }
            shards.push(ShardOutcome {
                shard: load.shard,
                requests: load.reqs.len(),
                outcome,
            });
        }
        Ok(ShardedRun { shards })
    }
}

/// Run one whole `spec` through a live-placement [`Cluster`] front door
/// and bucket the interleaved reply stream back into per-shard outcomes.
///
/// Unlike the static-split paths there is no per-shard request list ahead
/// of time: the cluster's placement thread decides each arrival online,
/// and every terminal [`crate::coordinator::Response`] carries the shard
/// that served (or, for a shed, would have served) it.  All shards share
/// the one global wall-clock duration — they genuinely ran concurrently —
/// so the merged makespan equals it.  Per-shard `shed_requests` combines
/// the backend's own `queue_cap` sheds with front-door sheds attributed
/// to that shard; `peak_intake_depth` (a cluster-wide value) is recorded
/// on every shard and max-merged.
pub fn run_against_cluster(cluster: &Cluster, spec: &WorkloadSpec)
    -> Result<ShardedRun> {
    let reqs = spec.materialize();
    let t0 = Instant::now();
    let samples = drive(|r| cluster.submit(r), spec, &reqs)?;
    let duration_s = t0.elapsed().as_secs_f64().max(1e-9);
    let stats = cluster.stats()?;
    let n = cluster.shards();
    let mut buckets: Vec<Vec<Sample>> = vec![Vec::new(); n];
    for s in samples {
        let shard = s.shard.unwrap_or(0).min(n - 1);
        buckets[shard].push(s);
    }
    let shards = buckets
        .into_iter()
        .enumerate()
        .map(|(i, samples)| {
            let st = &stats.shards[i];
            ShardOutcome {
                shard: i,
                requests: samples.len(),
                outcome: LoadOutcome {
                    samples,
                    planner: st.planner.clone(),
                    slots: st.slots,
                    peak_waiting: st.peak_waiting,
                    batch_dispatches: st.batch_dispatches,
                    batched_tokens: st.batched_tokens,
                    single_dispatches: st.single_dispatches,
                    prefill_chunks: st.prefill_chunks,
                    shed_requests: st.shed_requests + stats.shed[i],
                    peak_intake_depth: stats.peak_intake_depth,
                    preemptions: st.preemptions,
                    restores: st.restores,
                    preempted_wait_us: st.preempted_wait_us,
                    peak_checkpoints: st.peak_checkpoints,
                    first_dispatch_unix_us: st.first_dispatch_unix_us,
                    last_dispatch_unix_us: st.last_dispatch_unix_us,
                    duration_s,
                    clock: "wall",
                    shard: Some(i),
                },
            }
        })
        .collect();
    Ok(ShardedRun { shards })
}

/// The cluster-level merge of a fan-out run: shard-exact histograms plus
/// summed/extremal serving telemetry, ready for the
/// `moepim.slo_report.v2` document.
#[derive(Debug, Clone)]
pub struct MergedLoad {
    /// merged latency histograms + counts; throughput is computed over the
    /// cluster makespan (the slowest shard's duration — shards run
    /// concurrently)
    pub summary: SloSummary,
    /// cluster makespan: max per-shard `duration_s`
    pub duration_s: f64,
    /// total serving slots across shards
    pub slots: usize,
    /// max per-shard admission-queue high-water mark
    pub peak_waiting: usize,
    /// batched decode dispatches, summed across shards
    pub batch_dispatches: u64,
    /// tokens advanced by batched dispatches, summed
    pub batched_tokens: u64,
    /// single-token fallback dispatches, summed
    pub single_dispatches: u64,
    /// prefill chunk advances, summed across shards (0 for monolithic
    /// prefill backends)
    pub prefill_chunks: u64,
    /// requests shed with terminal `overloaded` errors, summed across
    /// shards (per-backend `queue_cap` sheds plus cluster front-door
    /// sheds; 0 when shedding is off)
    pub shed_requests: u64,
    /// max per-shard recorded cluster intake-queue high-water mark (a
    /// cluster run records the cluster-wide peak on every shard, so the
    /// max recovers it; 0 for single-server and virtual runs)
    pub peak_intake_depth: usize,
    /// QoS preemptions (batch-tier slots checkpointed and requeued for
    /// an interactive arrival), summed across shards; 0 with QoS off
    pub preemptions: u64,
    /// checkpointed slots restored and resumed, summed across shards
    pub restores: u64,
    /// total µs preempted requests spent requeued (preempt → slot
    /// re-grant), summed across shards
    pub preempted_wait_us: u64,
    /// max per-shard high-water mark of simultaneously-held preemption
    /// checkpoints — what the report's checkpoint-spill area charge is
    /// priced from (the worst single shard sets the store size)
    pub peak_checkpoints: usize,
    /// planner telemetry with every counter summed across shards
    pub planner: PlannerStats,
    /// `"virtual"` or `"wall"`, from the shard outcomes
    pub clock: &'static str,
}

impl MergedLoad {
    /// Mean live slots per batched dispatch, cluster-wide.
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_dispatches == 0 {
            0.0
        } else {
            self.batched_tokens as f64 / self.batch_dispatches as f64
        }
    }
}

/// Merge per-shard outcomes into one cluster view.  Exact on the bucket
/// level: merged quantiles equal those of one histogram built over all
/// shards' samples concatenated (within zero error — same buckets), which
/// is why a 1-shard merge reproduces the unsharded report's quantiles
/// exactly.
pub fn merge(spec: &WorkloadSpec, shards: &[ShardOutcome]) -> MergedLoad {
    let parts: Vec<SloSummary> =
        shards.iter().map(|s| summarize(spec, &s.outcome)).collect();
    merge_summaries(shards, &parts)
}

/// [`merge`] over per-shard summaries the caller already computed — the
/// report builder folds each shard's samples exactly once and reuses the
/// summaries for the breakdown and imbalance sections.  `parts[i]` must
/// be `summarize(spec, &shards[i].outcome)`.
pub(crate) fn merge_summaries(shards: &[ShardOutcome],
                              parts: &[SloSummary]) -> MergedLoad {
    debug_assert_eq!(shards.len(), parts.len());
    let summary = SloSummary {
        queue: LatencyHistogram::new(),
        ttft: LatencyHistogram::new(),
        e2e: LatencyHistogram::new(),
        completed: 0,
        errored: 0,
        tokens: 0,
        slo_met: 0,
        attainment: 1.0,
        tokens_per_s: 0.0,
        requests_per_s: 0.0,
    };
    let mut merged = MergedLoad {
        summary,
        duration_s: 0.0,
        slots: 0,
        peak_waiting: 0,
        batch_dispatches: 0,
        batched_tokens: 0,
        single_dispatches: 0,
        prefill_chunks: 0,
        shed_requests: 0,
        peak_intake_depth: 0,
        preemptions: 0,
        restores: 0,
        preempted_wait_us: 0,
        peak_checkpoints: 0,
        planner: PlannerStats::default(),
        clock: "virtual",
    };
    for (i, (s, part)) in shards.iter().zip(parts).enumerate() {
        merged.summary.queue.merge(&part.queue);
        merged.summary.ttft.merge(&part.ttft);
        merged.summary.e2e.merge(&part.e2e);
        merged.summary.completed += part.completed;
        merged.summary.errored += part.errored;
        merged.summary.tokens += part.tokens;
        merged.summary.slo_met += part.slo_met;
        merged.duration_s = merged.duration_s.max(s.outcome.duration_s);
        merged.slots += s.outcome.slots;
        merged.peak_waiting =
            merged.peak_waiting.max(s.outcome.peak_waiting);
        merged.batch_dispatches += s.outcome.batch_dispatches;
        merged.batched_tokens += s.outcome.batched_tokens;
        merged.single_dispatches += s.outcome.single_dispatches;
        merged.prefill_chunks += s.outcome.prefill_chunks;
        merged.shed_requests += s.outcome.shed_requests;
        merged.peak_intake_depth =
            merged.peak_intake_depth.max(s.outcome.peak_intake_depth);
        merged.preemptions += s.outcome.preemptions;
        merged.restores += s.outcome.restores;
        merged.preempted_wait_us += s.outcome.preempted_wait_us;
        merged.peak_checkpoints =
            merged.peak_checkpoints.max(s.outcome.peak_checkpoints);
        merged.planner.steps += s.outcome.planner.steps;
        merged.planner.work += s.outcome.planner.work;
        merged.planner.cycles += s.outcome.planner.cycles;
        merged.planner.contention_cycles +=
            s.outcome.planner.contention_cycles;
        merged.planner.transfers += s.outcome.planner.transfers;
        if i == 0 {
            merged.clock = s.outcome.clock;
        }
    }
    let n = merged.summary.completed + merged.summary.errored;
    merged.summary.attainment = if n == 0 {
        1.0
    } else {
        merged.summary.slo_met as f64 / n as f64
    };
    let dur = merged.duration_s.max(1e-9);
    merged.summary.tokens_per_s = merged.summary.tokens as f64 / dur;
    merged.summary.requests_per_s = n as f64 / dur;
    merged
}

/// Cluster imbalance metrics: how evenly the placement spread the load,
/// and how far the worst shard's tail sits from the merged one.
#[derive(Debug, Clone, PartialEq)]
pub struct Imbalance {
    /// requests on the most-loaded shard
    pub requests_max: usize,
    /// requests on the least-loaded shard
    pub requests_min: usize,
    /// `requests_max / max(requests_min, 1)`
    pub load_ratio: f64,
    /// highest per-shard p99 e2e latency (µs)
    pub p99_e2e_max_us: f64,
    /// lowest per-shard p99 e2e latency (µs; 0 for an empty shard)
    pub p99_e2e_min_us: f64,
    /// `p99_e2e_max_us - p99_e2e_min_us` — the per-shard tail spread
    pub p99_gap_us: f64,
    /// the merged histogram's p99 e2e (µs), for gauging how much the
    /// worst shard's tail exceeds the cluster-level tail
    pub merged_p99_e2e_us: f64,
}

/// Compute [`Imbalance`] over a fan-out run.  Callers that also need the
/// merge should use [`analyze`] — it folds each shard's samples once and
/// returns both.
pub fn imbalance(spec: &WorkloadSpec, shards: &[ShardOutcome])
    -> Imbalance {
    analyze(spec, shards).1
}

/// The merge and the imbalance metrics in one pass: each shard's samples
/// are folded into summaries exactly once and both views derive from the
/// same fold (so they can never disagree).  This is what the report
/// builder and the placement-study example use.
pub fn analyze(spec: &WorkloadSpec, shards: &[ShardOutcome])
    -> (MergedLoad, Imbalance) {
    let parts: Vec<SloSummary> =
        shards.iter().map(|s| summarize(spec, &s.outcome)).collect();
    let merged = merge_summaries(shards, &parts);
    let imb = imbalance_from(shards, &parts, &merged);
    (merged, imb)
}

/// [`imbalance`] over summaries and a merge the caller already computed
/// (`parts[i]` must summarize `shards[i]`; `merged` their merge).
pub(crate) fn imbalance_from(shards: &[ShardOutcome],
                             parts: &[SloSummary], merged: &MergedLoad)
    -> Imbalance {
    debug_assert_eq!(shards.len(), parts.len());
    let mut requests_max = 0usize;
    let mut requests_min = usize::MAX;
    let mut p99_max = 0.0f64;
    let mut p99_min = f64::INFINITY;
    for (s, part) in shards.iter().zip(parts) {
        requests_max = requests_max.max(s.requests);
        requests_min = requests_min.min(s.requests);
        let p99 = part.e2e.quantile(0.99);
        p99_max = p99_max.max(p99);
        p99_min = p99_min.min(p99);
    }
    if shards.is_empty() {
        requests_min = 0;
        p99_min = 0.0;
    }
    Imbalance {
        requests_max,
        requests_min,
        load_ratio: requests_max as f64 / requests_min.max(1) as f64,
        p99_e2e_max_us: p99_max,
        p99_e2e_min_us: if p99_min.is_finite() { p99_min } else { 0.0 },
        p99_gap_us: p99_max
            - if p99_min.is_finite() { p99_min } else { 0.0 },
        merged_p99_e2e_us: merged.summary.e2e.quantile(0.99),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrival::SizeModel;

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            seed: 51,
            requests: 40,
            arrival: ArrivalProcess::Poisson { rate_rps: 1_500.0 },
            sizes: SizeModel::Uniform { prompt: (4, 12), gen: (1, 8) },
            slo_e2e_ms: 50.0,
            deadline_slack_us_per_token: 200,
            interactive_mix: 1.0,
        }
    }

    fn all_placements() -> Vec<PlacementPolicy> {
        vec![
            PlacementPolicy::RoundRobin,
            PlacementPolicy::least_outstanding(&VirtualConfig::default()),
            PlacementPolicy::SizeHash,
            PlacementPolicy::route_aware(&VirtualConfig::default()),
        ]
    }

    #[test]
    fn assignments_are_deterministic_and_in_range() {
        let spec = spec();
        let reqs = spec.materialize();
        for p in all_placements() {
            for n in [1usize, 2, 4, 8] {
                let a = p.assign(&spec, &reqs, n);
                let b = p.assign(&spec, &reqs, n);
                assert_eq!(a, b, "{} not deterministic", p.label());
                assert_eq!(a.len(), reqs.len());
                assert!(a.iter().all(|&s| s < n), "{}", p.label());
            }
        }
    }

    #[test]
    fn least_outstanding_estimates_derive_from_the_run_config() {
        // the derived constants track the config under test…
        let slow = VirtualConfig {
            prefill_ns_per_token: 40_000,
            ..VirtualConfig::default()
        };
        match PlacementPolicy::least_outstanding(&slow) {
            PlacementPolicy::LeastOutstanding {
                prefill_ns_per_token, ..
            } => assert_eq!(prefill_ns_per_token, 40_000),
            other => panic!("wrong variant: {other:?}"),
        }
        // …and the estimate genuinely changes placement: a prompt-heavy
        // request stays "in flight" much longer under a prefill-expensive
        // config, so a later arrival dodges its shard — under the default
        // config the same request is long done and the arrival lands on
        // the (estimated-idle) lowest shard instead.  The hardcoded 4 µs
        // constant this replaced could never see that difference.
        let mk = |id, prompt_len, gen_len, arrival_ns| RequestSpec {
            id,
            prompt_len,
            gen_len,
            deadline_us: 1_000_000,
            arrival_ns,
        };
        let reqs = vec![
            mk(0, 100, 1, 0),
            mk(1, 1, 100, 0),
            mk(2, 8, 4, 2_000_000),
        ];
        let spec = spec();
        let expensive_prefill = VirtualConfig {
            prefill_ns_per_token: 1_000_000,
            ..VirtualConfig::default()
        };
        let a = PlacementPolicy::least_outstanding(&expensive_prefill)
            .assign(&spec, &reqs, 2);
        let b = PlacementPolicy::least_outstanding(&VirtualConfig::default())
            .assign(&spec, &reqs, 2);
        assert_eq!(a[..2], b[..2], "first two arrivals balance identically");
        assert_ne!(
            a[2], b[2],
            "the config-derived estimate must be able to change placement"
        );
    }

    #[test]
    fn parse_derives_estimates_from_the_run_config() {
        // the satellite bugfix: `parse` used to build least-outstanding
        // from `VirtualConfig::default()` no matter what config the run
        // actually used, and the CLI never rebuilt.  Now the run config
        // threads through parse, and a non-default `cycle_ns` genuinely
        // changes placement: a cheaper decode cycle retires the
        // gen-heavy request on shard 0 before the probe arrival, so the
        // probe lands back on shard 0 — under the default constants
        // shard 0 still looks busy and the probe dodges to shard 1.
        let mk = |id, prompt_len, gen_len, arrival_ns| RequestSpec {
            id,
            prompt_len,
            gen_len,
            deadline_us: 1_000_000,
            arrival_ns,
        };
        let reqs = vec![
            mk(0, 1, 100, 0),
            mk(1, 400, 1, 0),
            mk(2, 8, 4, 2_600_000),
        ];
        let spec = spec();
        let fast = VirtualConfig { cycle_ns: 100, ..VirtualConfig::default() };
        let a = PlacementPolicy::parse("least-outstanding", &fast)
            .unwrap()
            .assign(&spec, &reqs, 2);
        let b = PlacementPolicy::parse(
            "least-outstanding",
            &VirtualConfig::default(),
        )
        .unwrap()
        .assign(&spec, &reqs, 2);
        assert_eq!(a[..2], b[..2], "first two arrivals balance identically");
        assert_ne!(a[2], b[2], "parse must honor the run's cycle_ns");
        // route-aware parse follows the config's routing knobs too
        let wide = VirtualConfig { group_size: 4, ..VirtualConfig::default() };
        assert_eq!(
            PlacementPolicy::parse("route-aware", &wide),
            Some(PlacementPolicy::route_aware(&wide)),
        );
        assert!(PlacementPolicy::parse("nope", &fast).is_none());
    }

    #[test]
    fn round_robin_is_modular() {
        let spec = spec();
        let reqs = spec.materialize();
        let a = PlacementPolicy::RoundRobin.assign(&spec, &reqs, 3);
        assert!(a.iter().enumerate().all(|(i, &s)| s == i % 3));
    }

    #[test]
    fn split_partitions_every_request_exactly_once() {
        let spec = spec();
        for p in all_placements() {
            let driver = ShardedDriver::new(4, p);
            let loads = driver.split(&spec);
            assert_eq!(loads.len(), 4);
            let mut ids: Vec<u64> = loads
                .iter()
                .flat_map(|l| l.reqs.iter().map(|r| r.id))
                .collect();
            ids.sort_unstable();
            assert_eq!(
                ids,
                (0..spec.requests as u64).collect::<Vec<u64>>(),
                "{}",
                p.label()
            );
        }
    }

    #[test]
    fn one_shard_split_is_the_whole_spec() {
        let spec = spec();
        let driver = ShardedDriver::new(
            1,
            PlacementPolicy::least_outstanding(&VirtualConfig::default()),
        );
        let loads = driver.split(&spec);
        assert_eq!(loads.len(), 1);
        assert_eq!(loads[0].reqs, spec.materialize());
        assert_eq!(loads[0].spec, spec);
    }

    #[test]
    fn merged_one_shard_equals_direct_run() {
        let cfg = VirtualConfig::default();
        let spec = spec();
        let policy = AdmissionPolicy::sjf();
        let direct = run_virtual_requests(
            &cfg,
            &spec,
            &spec.materialize(),
            policy,
        );
        let run = ShardedDriver::new(1, PlacementPolicy::RoundRobin)
            .run_virtual(&cfg, &spec, policy);
        assert_eq!(run.shards.len(), 1);
        assert_eq!(run.shards[0].outcome.samples, direct.samples);
        let merged = merge(&spec, &run.shards);
        let solo = summarize(&spec, &direct);
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.summary.e2e.quantile(q), solo.e2e.quantile(q));
        }
        assert_eq!(merged.summary.attainment, solo.attainment);
        assert_eq!(merged.duration_s, direct.duration_s);
    }

    #[test]
    fn closed_loop_user_shares_cover_the_population() {
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Closed { users: 6, think_ms: 0.0 },
            ..spec()
        };
        let driver = ShardedDriver::new(4, PlacementPolicy::RoundRobin);
        let loads = driver.split(&spec);
        let users: Vec<usize> = loads
            .iter()
            .map(|l| match l.spec.arrival {
                ArrivalProcess::Closed { users, .. } => users,
                _ => panic!("closed spec lost its arrival shape"),
            })
            .collect();
        assert_eq!(users, vec![2, 2, 1, 1]);
        let run = driver.run_virtual(
            &VirtualConfig::default(),
            &spec,
            AdmissionPolicy::fifo(),
        );
        let total: usize =
            run.shards.iter().map(|s| s.outcome.samples.len()).sum();
        assert_eq!(total, spec.requests);
    }

    #[test]
    fn imbalance_is_consistent() {
        let cfg = VirtualConfig::default();
        let spec = spec();
        let run = ShardedDriver::new(4, PlacementPolicy::SizeHash)
            .run_virtual(&cfg, &spec, AdmissionPolicy::fifo());
        let imb = imbalance(&spec, &run.shards);
        assert!(imb.requests_max >= imb.requests_min);
        assert!(imb.load_ratio >= 1.0 || imb.requests_max == 0);
        assert!(imb.p99_e2e_max_us >= imb.p99_e2e_min_us);
        assert!(imb.p99_gap_us >= 0.0);
        let total: usize = run.shards.iter().map(|s| s.requests).sum();
        assert_eq!(total, spec.requests);
    }
}
